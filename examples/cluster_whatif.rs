//! What-if cluster studies: the robustness argument of §4.1 made concrete.
//! DPC's pass-combining depends on *absolute* phase times, so changing the
//! cluster's speed changes its decisions (and can degrade it); ETDPC keys
//! on the *relative* times of consecutive phases and keeps its plan.
//!
//! Each cluster shape is one `MiningSession` over the same dataset — the
//! session API's "same data, different cluster" comparison pattern. Also
//! demonstrates the TOML config system end to end.
//!
//! Run: `cargo run --release --example cluster_whatif`

use mrapriori::cluster::FaultModel;
use mrapriori::config;
use mrapriori::coordinator::{Algorithm, MiningRequest, MiningSession};
use mrapriori::dataset::registry;
use mrapriori::util::tomlmini::Doc;

const SLOW_CLUSTER: &str = r#"
# A cluster one third the speed of the paper's (e.g. older nodes).
[cluster]
data_nodes = 4
map_slots_per_node = 4
node_speeds = [0.33, 0.33, 0.33, 0.33]
reducers = 4

[overhead]
job_submit = 15.0
"#;

fn plan(outcome: &mrapriori::coordinator::MiningOutcome) -> Vec<usize> {
    outcome.phases.iter().map(|p| p.n_passes).collect()
}

fn main() {
    let db = registry::load("mushroom");
    let min_sup = 0.15;

    let fast = mrapriori::cluster::ClusterConfig::paper_cluster();
    let slow = config::cluster_from_doc(&Doc::parse(SLOW_CLUSTER).unwrap()).unwrap();
    println!("fast cluster: node speed {:.2}", fast.nodes[0].speed);
    println!("slow cluster: node speed {:.2}\n", slow.nodes[0].speed);

    // One session per cluster shape; both bind the same dataset.
    let on_fast_session = MiningSession::for_db(&db, fast.clone())
        .split_lines(1000)
        .build()
        .expect("valid session");
    let on_slow_session = MiningSession::for_db(&db, slow.clone())
        .split_lines(1000)
        .build()
        .expect("valid session");

    for algo in [Algorithm::Dpc, Algorithm::Etdpc] {
        let req = MiningRequest::new(algo).min_sup(min_sup);
        let on_fast = on_fast_session.run(&req).expect("valid request");
        let on_slow = on_slow_session.run(&req).expect("valid request");
        let same = plan(&on_fast) == plan(&on_slow);
        println!("{}:", algo.name());
        println!(
            "  fast cluster plan (passes/phase): {:?}  -> {:.0} s",
            plan(&on_fast),
            on_fast.actual_time
        );
        println!(
            "  slow cluster plan (passes/phase): {:?}  -> {:.0} s",
            plan(&on_slow),
            on_slow.actual_time
        );
        println!(
            "  combining plan {} across cluster speeds\n",
            if same { "UNCHANGED" } else { "CHANGED" }
        );
    }

    println!("per §4.1: DPC needs its α/β retuned per cluster; ETDPC does not.");

    // Same pattern, third axis: what if the cluster misbehaves? One query
    // per fault scenario — mining output is identical in every cell
    // (DESIGN.md §6), only the simulated schedule moves.
    println!("\nfault what-if (Optimized-ETDPC on the paper cluster):");
    for (label, model) in [
        ("clean", None),
        ("5% task failures", Some(FaultModel { fail_prob: 0.05, ..Default::default() })),
        (
            "15% stragglers",
            Some(FaultModel { straggler_prob: 0.15, ..Default::default() }),
        ),
        (
            "15% stragglers + speculation",
            Some(FaultModel { straggler_prob: 0.15, speculation: true, ..Default::default() }),
        ),
    ] {
        let mut req = MiningRequest::new(Algorithm::OptimizedEtdpc).min_sup(min_sup);
        if let Some(model) = model {
            req = req.faults(model);
        }
        let out = on_fast_session.run(&req).expect("valid request");
        let totals = out.fault_totals().unwrap_or_default();
        println!(
            "  {label:<30} {:>6.0} s  ({} frequent itemsets; {} attempts, {} failures, {} stragglers, {}/{} spec)",
            out.faulted_actual_time().unwrap_or(out.actual_time),
            out.total_frequent(),
            totals.attempts,
            totals.failures,
            totals.stragglers,
            totals.speculative_launches,
            totals.speculative_wins,
        );
    }

    println!("\nfitted config (render/parse round-trip):");
    println!("{}", config::render_cluster(&slow));
}
