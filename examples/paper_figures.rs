//! End-to-end validation driver: run the full system — HDFS splits,
//! MapReduce engine, cluster simulator, all seven drivers — on all three
//! paper workloads at their reference supports, verify every algorithm
//! against the sequential oracle, and report the paper's headline metric
//! (execution-time ranking and the Optimized-* savings).
//!
//! This is the run recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example paper_figures`

use mrapriori::apriori::sequential::mine;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{run_with, Algorithm, RunOptions};
use mrapriori::dataset::registry;

fn main() {
    let cluster = ClusterConfig::paper_cluster();
    println!(
        "cluster: {} DataNodes, {} map slots, job_submit {:.0} s\n",
        cluster.nodes.len(),
        cluster.total_map_slots(),
        cluster.overhead.job_submit
    );

    for name in registry::NAMES {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        let opts = RunOptions {
            split_lines: registry::split_lines(name),
            dpc_alpha: if name == "chess" { 3.0 } else { 2.0 },
            ..Default::default()
        };
        let oracle = mine(&db, min_sup);
        println!(
            "=== {name} @ min_sup {min_sup} — oracle: {} frequent, max length {} ===",
            oracle.total_frequent(),
            oracle.max_len()
        );
        println!(
            "{:<18} {:>7} {:>10} {:>10} {:>10} {:>8}",
            "algorithm", "phases", "total(s)", "actual(s)", "vs SPC", "oracle"
        );
        let mut spc_actual = 0.0;
        for algo in Algorithm::ALL {
            let out = run_with(algo, &db, min_sup, &cluster, &opts);
            if algo == Algorithm::Spc {
                spc_actual = out.actual_time;
            }
            let ok = out.all_frequent() == oracle.all_frequent();
            println!(
                "{:<18} {:>7} {:>10.0} {:>10.0} {:>9.2}x {:>8}",
                algo.name(),
                out.n_phases(),
                out.total_time,
                out.actual_time,
                out.actual_time / spc_actual,
                if ok { "match" } else { "FAIL" }
            );
            assert!(ok, "{algo} diverged from the oracle on {name}");
        }
        println!();
    }
    println!("all 21 runs matched the sequential oracle exactly.");
}
