//! End-to-end validation driver: run the full system — HDFS splits,
//! MapReduce engine, cluster simulator, all seven drivers — on all three
//! paper workloads at their reference supports, verify every algorithm
//! against the sequential oracle, and report the paper's headline metric
//! (execution-time ranking and the Optimized-* savings).
//!
//! Each dataset is served by ONE `MiningSession`, so its seven algorithm
//! runs share a single Job1 dataset scan — the run asserts the reuse via
//! the session counters.
//!
//! This is the run recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example paper_figures`

use mrapriori::apriori::sequential::mine;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, MiningRequest, MiningSession};
use mrapriori::dataset::registry;

fn main() {
    let cluster = ClusterConfig::paper_cluster();
    println!(
        "cluster: {} DataNodes, {} map slots, job_submit {:.0} s\n",
        cluster.nodes.len(),
        cluster.total_map_slots(),
        cluster.overhead.job_submit
    );

    for name in registry::NAMES {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        let session = MiningSession::for_db(&db, cluster.clone())
            .split_lines(registry::split_lines(name))
            .build()
            .expect("registry datasets are valid");
        let dpc_alpha = if name == "chess" { 3.0 } else { 2.0 };
        let oracle = mine(&db, min_sup);
        println!(
            "=== {name} @ min_sup {min_sup} — oracle: {} frequent, max length {} ===",
            oracle.total_frequent(),
            oracle.max_len()
        );
        println!(
            "{:<18} {:>7} {:>10} {:>10} {:>10} {:>8}",
            "algorithm", "phases", "total(s)", "actual(s)", "vs SPC", "oracle"
        );
        let mut spc_actual = 0.0;
        for algo in Algorithm::ALL {
            let req = MiningRequest::new(algo).min_sup(min_sup).dpc_alpha(dpc_alpha);
            let out = session.run(&req).expect("valid request");
            if algo == Algorithm::Spc {
                spc_actual = out.actual_time;
            }
            let ok = out.all_frequent() == oracle.all_frequent();
            println!(
                "{:<18} {:>7} {:>10.0} {:>10.0} {:>9.2}x {:>8}",
                algo.name(),
                out.n_phases(),
                out.total_time,
                out.actual_time,
                out.actual_time / spc_actual,
                if ok { "match" } else { "FAIL" }
            );
            assert!(ok, "{algo} diverged from the oracle on {name}");
        }
        let stats = session.stats();
        assert_eq!(stats.job1_runs, 1, "{name}: Job1 must run once per session support");
        println!(
            "session: Job1 ran {} time(s) for {} queries ({} cache hits)\n",
            stats.job1_runs, stats.queries, stats.job1_cache_hits
        );
    }
    println!("all 21 runs matched the sequential oracle exactly.");
}
