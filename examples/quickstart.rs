//! Quickstart: open a mining session on the mushroom dataset over the
//! paper's 4-DataNode cluster, run the paper's best algorithm
//! (Optimized-VFPC) with live phase events, reuse the session's Job1 cache
//! for a second query, then derive association rules.
//!
//! Run: `cargo run --release --example quickstart`

use mrapriori::apriori::rules::derive_rules;
use mrapriori::apriori::sequential::MineResult;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, CancelToken, MiningRequest, MiningSession, PhaseEvent};
use mrapriori::dataset::registry;

fn main() {
    // 1. A dataset: registry analogs of the paper's Table 2, or load your
    //    own FIMI-format file with `dataset::loader::load_file`.
    let db = registry::load("mushroom");
    println!("dataset: {} ({} txns, {} items)", db.name, db.len(), db.n_items);

    // 2. A session: the dataset bound to the paper's heterogeneous
    //    4-DataNode cluster (Table 1). The split plan is computed once and
    //    Job1 results are memoized across queries.
    let session = MiningSession::for_db(&db, ClusterConfig::paper_cluster())
        .build()
        .expect("mushroom is a valid dataset");

    // 3. Mine, streaming events as the run executes: phase boundaries plus
    //    the executor's per-task progress (every map/reduce task started
    //    and finished on the session's shared worker pool).
    let request = MiningRequest::new(Algorithm::OptimizedVfpc).min_sup(0.25);
    let mut tasks_done = 0usize;
    let out = session
        .run_streaming(&request, &CancelToken::new(), |event| match event {
            PhaseEvent::TaskFinished { .. } => tasks_done += 1,
            PhaseEvent::PhaseFinished { record, from_cache } => {
                println!(
                    "  phase {} ({}): {:.0} simulated s{}",
                    record.phase,
                    record.job,
                    record.elapsed,
                    if from_cache { " [job1 cache]" } else { "" }
                );
            }
            _ => {}
        })
        .expect("valid request");
    println!(
        "{}: {} frequent itemsets in {} phases — {:.0} simulated s ({:.2} s host)",
        out.algorithm,
        out.total_frequent(),
        out.n_phases(),
        out.actual_time,
        out.wall_time
    );
    println!("|L_k| profile: {:?}", out.lk_profile());
    println!(
        "executor: {} tasks on a {}-thread pool (peak task concurrency {})",
        tasks_done,
        session.executor().workers(),
        session.executor().high_water_mark()
    );

    // 4. A second query at the same support skips the dataset scan: Job1
    //    comes straight from the session cache.
    let spc = session
        .run(&MiningRequest::new(Algorithm::Spc).min_sup(0.25))
        .expect("valid request");
    let stats = session.stats();
    println!(
        "second query ({}): {:.0} simulated s; Job1 ran {} time(s) for {} queries",
        spc.algorithm, spc.actual_time, stats.job1_runs, stats.queries
    );

    // 5. Association rules from the mined itemsets.
    let as_mine_result = MineResult {
        levels: out.levels.clone(),
        min_count: out.min_count,
        candidates_per_pass: vec![],
        gen_stats: Default::default(),
        subset_visits: 0,
    };
    let rules = derive_rules(&as_mine_result, db.len(), 0.9);
    println!("\ntop rules (confidence >= 0.90):");
    for rule in rules.iter().take(10) {
        println!("  {rule}");
    }
}
