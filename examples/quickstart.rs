//! Quickstart: mine frequent itemsets with the paper's best algorithm
//! (Optimized-VFPC) on the mushroom dataset, on the paper's 4-DataNode
//! cluster, then derive association rules.
//!
//! Run: `cargo run --release --example quickstart`

use mrapriori::apriori::rules::derive_rules;
use mrapriori::apriori::sequential::MineResult;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{self, Algorithm};
use mrapriori::dataset::registry;

fn main() {
    // 1. A dataset: registry analogs of the paper's Table 2, or load your
    //    own FIMI-format file with `dataset::loader::load_file`.
    let db = registry::load("mushroom");
    println!("dataset: {} ({} txns, {} items)", db.name, db.len(), db.n_items);

    // 2. A cluster: the paper's heterogeneous 4-DataNode setup (Table 1).
    let cluster = ClusterConfig::paper_cluster();

    // 3. Mine.
    let out = coordinator::run(
        Algorithm::OptimizedVfpc,
        &db,
        0.25,
        &cluster,
        registry::split_lines("mushroom"),
    );
    println!(
        "{}: {} frequent itemsets in {} phases — {:.0} simulated s ({:.2} s host)",
        out.algorithm,
        out.total_frequent(),
        out.n_phases(),
        out.actual_time,
        out.wall_time
    );
    println!("|L_k| profile: {:?}", out.lk_profile());

    // 4. Association rules from the mined itemsets.
    let as_mine_result = MineResult {
        levels: out.levels.clone(),
        min_count: out.min_count,
        candidates_per_pass: vec![],
        gen_stats: Default::default(),
        subset_visits: 0,
    };
    let rules = derive_rules(&as_mine_result, db.len(), 0.9);
    println!("\ntop rules (confidence >= 0.90):");
    for rule in rules.iter().take(10) {
        println!("  {rule}");
    }
}
