//! Counting-backend demo: the same Apriori pass counted by (1) the paper's
//! prefix-tree `subset()` walk and (2) the AOT-compiled XLA executable
//! authored as a JAX/Pallas kernel (`python/compile/kernels/`), loaded here
//! through the PJRT C API. Python is NOT running — only its compiled HLO.
//!
//! Run: `make artifacts && cargo run --release --example counting_backends`

use mrapriori::apriori::gen::apriori_gen;
use mrapriori::apriori::sequential::mine;
use mrapriori::dataset::registry;
use mrapriori::itemset::{Itemset, Trie};
use mrapriori::runtime::counting::XlaCounter;
use mrapriori::runtime::pjrt::{artifacts_dir, ArtifactSpec, PjrtRuntime};
use std::time::Instant;

fn main() {
    let db = registry::load("chess");
    let min_sup = 0.75;
    let result = mine(&db, min_sup);
    let l2: Vec<Itemset> = result.levels[1].iter().map(|(s, _)| s.clone()).collect();
    let l2_trie = Trie::from_itemsets(2, l2.iter());
    let (c3, _) = apriori_gen(&l2_trie);
    println!(
        "pass 3 on {}: {} candidate 3-itemsets x {} transactions",
        db.name,
        c3.len(),
        db.len()
    );

    // Backend 1: trie walk.
    let mut trie = c3.clone();
    let t0 = Instant::now();
    let mut visits = 0u64;
    for t in &db.txns {
        visits += trie.count_transaction(t).0;
    }
    let trie_time = t0.elapsed();
    println!("trie backend: {visits} node visits in {trie_time:?}");

    // Backend 2: XLA executable.
    let runtime = match PjrtRuntime::load(&artifacts_dir(), ArtifactSpec::DEFAULT) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load XLA artifact ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "XLA backend: platform {}, tile {:?}",
        runtime.platform(),
        runtime.spec
    );
    let counter = XlaCounter::new(runtime);
    let t0 = Instant::now();
    let counted = counter.count_trie(&c3, &db.txns).expect("xla counting");
    let xla_time = t0.elapsed();
    println!("XLA backend: {} supports in {xla_time:?}", counted.len());

    // Agreement check.
    let mut mismatches = 0;
    for (set, count) in &counted {
        if trie.count_of(set) != Some(*count) {
            mismatches += 1;
        }
    }
    println!(
        "agreement: {}/{} supports identical ({} mismatches)",
        counted.len() - mismatches,
        counted.len(),
        mismatches
    );
    assert_eq!(mismatches, 0);
    println!(
        "note: interpret-lowered Pallas on CPU PJRT is a correctness path; \
         see DESIGN.md §Hardware-Adaptation for the TPU performance model."
    );
}
