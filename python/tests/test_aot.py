"""AOT export checks: the lowered HLO text parses, has the right entry
signature, and the fused (non-Pallas) L2 graph stays fused (no materialized
boolean intermediate bigger than the product tile)."""

import os

import jax
import numpy as np

from compile.aot import lower_spec, to_hlo_text
from compile.model import example_args, support_count_fused


def test_lowered_hlo_text_structure():
    text = lower_spec(128, 256, 256)
    assert "HloModule" in text
    assert "f32[128,256]" in text  # txn tile param
    assert "f32[256,256]" in text  # cand tile param
    assert "f32[256]" in text  # lengths / output
    # dot is the MXU op the kernel is built around.
    assert "dot(" in text or "dot " in text


def test_fused_variant_lowers_and_runs():
    args = example_args(128, 256, 128)
    lowered = jax.jit(support_count_fused).lower(*args)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    t = np.zeros((128, 256), np.float32)
    c = np.zeros((128, 256), np.float32)
    t[0, :3] = 1.0
    c[0, :2] = 1.0
    lens = np.full((128,), 257.0, np.float32)
    lens[0] = 2.0
    (out,) = support_count_fused(t, c, lens)
    assert out[0] == 1.0
    assert (np.asarray(out)[1:] == 0.0).all()


def test_fusion_no_giant_intermediates():
    # The compare+reduce must fuse into the matmul consumer: the optimized
    # HLO should not contain a materialized pred[C,T] tensor as a module-
    # level instruction outside a fusion.
    args = example_args(256, 256, 256)
    lowered = jax.jit(support_count_fused).lower(*args)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    standalone_pred = [
        line
        for line in hlo.splitlines()
        if line.strip().startswith("pred[256,256]") and "fusion" not in line
    ]
    assert not standalone_pred, standalone_pred


def test_artifacts_match_written_files(tmp_path):
    # aot.main writes files named after their spec; simulate one spec.
    text = lower_spec(128, 256, 256)
    path = tmp_path / "support_count_t128_i256_c256.hlo.txt"
    path.write_text(text)
    assert path.exists()
    assert os.path.getsize(path) > 1000
