"""L1 kernel correctness: Pallas support_count vs the pure-jnp reference
and a set-based python oracle, across shapes, densities and paddings."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import support_count_numpy, support_count_ref
from compile.kernels.support_count import (
    mxu_utilization_estimate,
    support_count,
    vmem_footprint_bytes,
)


def encode(sets, rows, width):
    m = np.zeros((rows, width), dtype=np.float32)
    for r, s in enumerate(sets):
        for i in s:
            m[r, i] = 1.0
    return m


def lengths_of(sets, rows, width):
    lens = np.full((rows,), width + 1, dtype=np.float32)
    for r, s in enumerate(sets):
        lens[r] = len(s)
    return lens


def run_both(txn_sets, cand_sets, txn_tile=256, item_width=256, cand_tile=256):
    t = encode(txn_sets, txn_tile, item_width)
    c = encode(cand_sets, cand_tile, item_width)
    lens = lengths_of(cand_sets, cand_tile, item_width)
    pallas_out = np.asarray(
        support_count(t, c, lens, txn_tile=txn_tile, item_width=item_width, cand_tile=cand_tile)
    )
    ref_out = np.asarray(support_count_ref(t, c, lens))
    return pallas_out, ref_out


def test_simple_counts():
    txns = [[0, 1, 2], [1, 2], [0, 3]]
    cands = [[0], [1, 2], [0, 3], [2, 3]]
    p, r = run_both(txns, cands)
    np.testing.assert_array_equal(p[:4], [2.0, 2.0, 1.0, 0.0])
    np.testing.assert_array_equal(p, r)


def test_padding_rows_never_count():
    # All-zero padding txns and sentinel-length padding candidates.
    p, r = run_both([[0]], [[0]])
    assert p[0] == 1.0
    assert (p[1:] == 0.0).all()
    np.testing.assert_array_equal(p, r)


def test_empty_everything():
    p, r = run_both([], [])
    assert (p == 0.0).all()
    np.testing.assert_array_equal(p, r)


@pytest.mark.parametrize("tile", [(128, 256, 256), (256, 256, 256), (256, 256, 512)])
def test_alternate_tile_shapes(tile):
    t_tile, i_w, c_tile = tile
    txns = [[i, i + 1, 64 + i] for i in range(50)]
    cands = [[i, i + 1] for i in range(40)] + [[64 + i] for i in range(30)]
    p, r = run_both(txns, cands, txn_tile=t_tile, item_width=i_w, cand_tile=c_tile)
    np.testing.assert_array_equal(p, r)
    oracle = support_count_numpy(txns, cands)
    np.testing.assert_array_equal(p[: len(cands)], oracle)


sets_strategy = st.lists(
    st.lists(st.integers(min_value=0, max_value=255), min_size=1, max_size=24).map(
        lambda xs: sorted(set(xs))
    ),
    min_size=0,
    max_size=64,
)


@settings(max_examples=40, deadline=None)
@given(txns=sets_strategy, cands=sets_strategy)
def test_hypothesis_matches_python_oracle(txns, cands):
    p, r = run_both(txns, cands)
    np.testing.assert_array_equal(p, r)
    oracle = support_count_numpy(txns, cands)
    np.testing.assert_array_equal(p[: len(cands)], oracle)
    # Sentinel rows must be exactly zero.
    assert (p[len(cands):] == 0.0).all()


@settings(max_examples=20, deadline=None)
@given(
    txns=sets_strategy,
    cands=sets_strategy,
    dtype=st.sampled_from([np.float32, np.float64, np.int32]),
)
def test_input_dtypes_normalized(txns, cands, dtype):
    # The L2 model casts to f32; feeding other dtypes through the ref path
    # must agree after casting.
    t = encode(txns, 256, 256).astype(dtype)
    c = encode(cands, 256, 256).astype(dtype)
    lens = lengths_of(cands, 256, 256).astype(dtype)
    from compile.model import support_count_model

    (out,) = support_count_model(t, c, lens)
    oracle = support_count_numpy(txns, cands)
    np.testing.assert_array_equal(np.asarray(out)[: len(cands)], oracle)


def test_vmem_footprint_fits():
    # Default geometry must fit a 16 MiB VMEM with margin.
    assert vmem_footprint_bytes() < 2 * 1024 * 1024


def test_mxu_estimate_monotone():
    assert mxu_utilization_estimate(1, 1, 8.0) == 8.0 / 256.0
    assert mxu_utilization_estimate(1, 1, 16.0) > mxu_utilization_estimate(1, 1, 4.0)
