"""L2: the JAX compute graph around the L1 kernel.

For this paper the "model" is the support-counting graph a map task
executes: encode-free containment counting over one (transactions ×
candidates) tile, calling the Pallas kernel, with the numerics guards the
rust runtime relies on (f32 exactness, sentinel padding). One jitted
function per tile geometry is AOT-lowered by aot.py; the rust coordinator
loops tiles.

Also provides `support_count_fused`, the pure-XLA (non-Pallas) variant used
to verify that XLA fuses the compare+reduce into the matmul consumer — the
L2 optimization check in EXPERIMENTS.md §Perf.
"""

import jax
import jax.numpy as jnp

from .kernels.support_count import support_count as _pallas_support_count


def support_count_model(txns, cands, lengths, *, txn_tile=256, item_width=256, cand_tile=256):
    """The exported entry point: validates dtypes and calls the L1 kernel.

    All inputs f32 (PJRT CPU client feeds f32 literals); output f32 counts,
    exact for counts < 2^24.
    """
    txns = txns.astype(jnp.float32)
    cands = cands.astype(jnp.float32)
    lengths = lengths.astype(jnp.float32)
    return (
        _pallas_support_count(
            txns,
            cands,
            lengths,
            txn_tile=txn_tile,
            item_width=item_width,
            cand_tile=cand_tile,
        ),
    )


@jax.jit
def support_count_fused(txns, cands, lengths):
    """Non-Pallas L2 graph (matmul + compare + reduce) for fusion checks."""
    inter = jnp.dot(cands, txns.T, preferred_element_type=jnp.float32)
    return ((inter == lengths[:, None]).astype(jnp.float32).sum(axis=1),)


def example_args(txn_tile=256, item_width=256, cand_tile=256):
    """ShapeDtypeStructs for AOT lowering."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((txn_tile, item_width), f32),
        jax.ShapeDtypeStruct((cand_tile, item_width), f32),
        jax.ShapeDtypeStruct((cand_tile,), f32),
    )
