"""L1: Pallas support-counting kernel.

The hot-spot of every MapReduce-Apriori pass is `subset()` — counting, for
each candidate itemset c and each transaction t, whether c ⊆ t. The paper
does this with a per-transaction prefix-tree walk on CPU; re-thought for a
matrix unit (DESIGN.md §Hardware-Adaptation), containment becomes a tiled
0/1 matmul:

    S[c, t] = Σ_i C[c, i] · T[t, i]          (MXU-shaped dot product)
    c ⊆ t   ⇔  S[c, t] == |c|
    support[c] = Σ_t [c ⊆ t]

Tiles are sized for TPU VMEM: a (128 cand × 256 item) candidate block, a
(256 txn × 256 item) transaction pane and the (128 × 256) product all fit
comfortably in ~16 MiB VMEM (f32: 128·256·4 = 128 KiB per operand block).
The grid walks candidate blocks; the transaction pane is re-used across
grid steps (Pallas keeps it resident — the HBM→VMEM schedule the paper's
threadblock analog would hand-manage).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter to plain HLO
(numerically identical; real-TPU performance is *estimated* in DESIGN.md,
not measured here).

Exactness: items-per-candidate and tile sums stay far below 2^24, so f32
equality against |c| is exact.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile geometry (must match runtime::ArtifactSpec::DEFAULT).
TXN_TILE = 256
ITEM_WIDTH = 256
CAND_TILE = 256
# Candidate rows processed per grid step.
CAND_BLOCK = 128


def _kernel(t_ref, c_ref, len_ref, o_ref):
    """One grid step: supports for a CAND_BLOCK slice of candidates."""
    txns = t_ref[...]          # (TXN_TILE, ITEM_WIDTH) f32 0/1
    cands = c_ref[...]         # (CAND_BLOCK, ITEM_WIDTH) f32 0/1
    lens = len_ref[...]        # (CAND_BLOCK,) f32; padding rows = width+1

    # (CAND_BLOCK, TXN_TILE) intersection sizes on the MXU.
    inter = jnp.dot(cands, txns.T, preferred_element_type=jnp.float32)
    contained = (inter == lens[:, None]).astype(jnp.float32)
    # Padding *transactions* are all-zero rows: inter == 0 < |c| >= 1, so
    # they can never count; no explicit mask needed.
    o_ref[...] = contained.sum(axis=1)


@partial(jax.jit, static_argnames=("txn_tile", "item_width", "cand_tile"))
def support_count(
    txns: jax.Array,
    cands: jax.Array,
    lengths: jax.Array,
    *,
    txn_tile: int = TXN_TILE,
    item_width: int = ITEM_WIDTH,
    cand_tile: int = CAND_TILE,
) -> jax.Array:
    """Pallas-tiled support counts.

    Args:
      txns: (txn_tile, item_width) f32 0/1 transaction bitmap.
      cands: (cand_tile, item_width) f32 0/1 candidate bitmap.
      lengths: (cand_tile,) f32 candidate sizes; padding rows must carry a
        value that no dot product can reach (e.g. item_width + 1).

    Returns:
      (cand_tile,) f32 supports.
    """
    assert txns.shape == (txn_tile, item_width), txns.shape
    assert cands.shape == (cand_tile, item_width), cands.shape
    assert lengths.shape == (cand_tile,), lengths.shape
    cand_block = min(CAND_BLOCK, cand_tile)
    assert cand_tile % cand_block == 0

    grid = (cand_tile // cand_block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            # Transactions: one pane shared by every grid step.
            pl.BlockSpec((txn_tile, item_width), lambda i: (0, 0)),
            # Candidates: walk blocks of rows.
            pl.BlockSpec((cand_block, item_width), lambda i: (i, 0)),
            pl.BlockSpec((cand_block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((cand_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cand_tile,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(txns, cands, lengths)


def vmem_footprint_bytes(
    txn_tile: int = TXN_TILE,
    item_width: int = ITEM_WIDTH,
    cand_block: int = CAND_BLOCK,
) -> int:
    """Estimated VMEM residency of one grid step (f32 operands + product).

    Used by DESIGN.md's TPU performance estimate; interpret-mode wallclock
    is *not* a TPU proxy, so we reason about structure instead.
    """
    txn_pane = txn_tile * item_width * 4
    cand_block_bytes = cand_block * item_width * 4
    product = cand_block * txn_tile * 4
    vectors = 2 * cand_block * 4
    return txn_pane + cand_block_bytes + product + vectors


def mxu_utilization_estimate(
    n_cands: int,
    n_txns: int,
    avg_cand_len: float,
    item_width: int = ITEM_WIDTH,
) -> float:
    """Fraction of MXU MACs doing useful work for a real workload.

    The dense matmul spends `item_width` MACs per (c, t) pair; a trie walk
    would touch ~avg_cand_len items. Utilization of the *useful* compute is
    therefore avg_cand_len / item_width — the price of regularity. The MXU's
    raw throughput advantage (~100x on bf16) has to beat that ratio, which
    it does for item_width <= 256 and |c| >= 3.
    """
    del n_cands, n_txns  # shape-independent
    return float(avg_cand_len) / float(item_width)
