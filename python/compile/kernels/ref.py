"""Pure-jnp oracle for the support-count kernel (no Pallas, no tiling).

This is the correctness reference every kernel variant is tested against,
and the baseline for the L2 fusion checks.
"""

import jax
import jax.numpy as jnp


@jax.jit
def support_count_ref(txns: jax.Array, cands: jax.Array, lengths: jax.Array) -> jax.Array:
    """Reference supports.

    Args:
      txns: (T, I) f32 0/1.
      cands: (C, I) f32 0/1.
      lengths: (C,) f32; padding candidates carry an unreachable sentinel.

    Returns:
      (C,) f32 supports.
    """
    inter = cands @ txns.T                      # (C, T)
    contained = inter == lengths[:, None]       # (C, T) bool
    return contained.sum(axis=1).astype(jnp.float32)


def support_count_numpy(txn_sets, cand_sets, n_txns=None):
    """Set-based python oracle (for hypothesis tests, no arrays involved)."""
    out = []
    for c in cand_sets:
        cs = set(c)
        out.append(sum(1 for t in txn_sets if cs.issubset(set(t))))
    return out
