"""AOT lowering: JAX/Pallas support-count model -> HLO text artifacts.

Run once at build time (`make artifacts`); python never runs on the mining
path. The rust runtime loads `artifacts/support_count_t{T}_i{I}_c{C}.hlo.txt`
via `HloModuleProto::from_text_file`.

Interchange is HLO *text*, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the published `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. Lowered with return_tuple=True; the
rust side unwraps with `to_tuple1()`. (See /opt/xla-example/README.md.)
"""

import argparse
import os
from functools import partial

import jax
from jax._src.lib import xla_client as xc

from .model import example_args, support_count_model

# Tile geometries to export. The first is ArtifactSpec::DEFAULT in rust;
# the rest support the block-shape perf sweep (EXPERIMENTS.md §Perf).
SPECS = [
    (256, 256, 256),
    (128, 256, 256),
    (512, 256, 256),
    (256, 256, 512),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(txn_tile: int, item_width: int, cand_tile: int) -> str:
    fn = partial(
        support_count_model,
        txn_tile=txn_tile,
        item_width=item_width,
        cand_tile=cand_tile,
    )
    lowered = jax.jit(fn).lower(*example_args(txn_tile, item_width, cand_tile))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--specs",
        default=None,
        help="comma-separated T:I:C triples (default: built-in sweep)",
    )
    args = ap.parse_args()

    specs = SPECS
    if args.specs:
        specs = []
        for part in args.specs.split(","):
            t, i, c = (int(x) for x in part.split(":"))
            specs.append((t, i, c))

    os.makedirs(args.out_dir, exist_ok=True)
    for t, i, c in specs:
        text = lower_spec(t, i, c)
        name = f"support_count_t{t}_i{i}_c{c}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name}: {len(text)} chars")


if __name__ == "__main__":
    main()
