//! CLI for the invariant checker. Run from anywhere in the workspace:
//!
//! ```text
//! cargo run -p pallas-lint                      # enforce against baseline
//! cargo run -p pallas-lint -- --list            # every finding, baselined or not
//! cargo run -p pallas-lint -- --rules           # the rule registry
//! cargo run -p pallas-lint -- --format json     # machine-readable report
//! cargo run -p pallas-lint -- --strict-allows   # stale suppressions fail too
//! cargo run -p pallas-lint -- --update-baseline # regenerate the ratchet
//! cargo run -p pallas-lint -- --print-baseline  # regenerated baseline to stdout
//! ```
//!
//! Exit codes: 0 clean, 1 findings above the baseline (or stale allows
//! under `--strict-allows`), 2 usage or I/O error. Stale baseline
//! entries (count above the live tree) warn without failing, so deleting
//! grandfathered code never blocks a build — CI uploads the
//! regenerated-baseline diff as an artifact instead.

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_lint::{baseline, default_baseline, json, lint_tree_full, rules, Finding};

fn usage() -> String {
    let mut s = String::from(
        "pallas-lint: determinism & concurrency invariant checker\n\n\
         USAGE: pallas-lint [--root <dir>] [--baseline <file>] [--format text|json]\n\
         \x20                [--strict-allows]\n\
         \x20                [--list | --rules | --print-baseline | --update-baseline]\n\nRULES:\n",
    );
    for r in &rules::RULES {
        s.push_str(&format!("  {:<22} {}\n", r.name, r.summary));
    }
    s
}

fn print_stale_allow_warnings(stale: &[Finding], strict: bool) {
    for f in stale {
        eprintln!("pallas-lint: {}: {f}", if strict { "error" } else { "warning" });
    }
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut baseline_path: Option<PathBuf> = None;
    let mut list = false;
    let mut list_rules = false;
    let mut print_baseline = false;
    let mut update_baseline = false;
    let mut strict_allows = false;
    let mut format_json = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--baseline" => baseline_path = args.next().map(PathBuf::from),
            "--list" => list = true,
            "--rules" => list_rules = true,
            "--print-baseline" => print_baseline = true,
            "--update-baseline" => update_baseline = true,
            "--strict-allows" => strict_allows = true,
            "--format" => match args.next().as_deref() {
                Some("json") => format_json = true,
                Some("text") => format_json = false,
                other => {
                    eprintln!("pallas-lint: --format wants `text` or `json`, got {other:?}");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                print!("{}", usage());
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("pallas-lint: unknown argument {other:?}\n\n{}", usage());
                return ExitCode::from(2);
            }
        }
    }
    if list_rules {
        for r in &rules::RULES {
            println!("{:<22} {}", r.name, r.summary);
        }
        println!("{} rule(s)", rules::RULES.len());
        return ExitCode::SUCCESS;
    }
    // Default root: two levels above this crate's manifest — the repo.
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
    });
    let baseline_path = baseline_path.unwrap_or_else(|| default_baseline(&root));

    let tree = match lint_tree_full(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("pallas-lint: scanning {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let findings = &tree.findings;

    if list {
        if format_json {
            let rows: Vec<(&Finding, bool)> = findings.iter().map(|f| (f, false)).collect();
            print!("{}", json::render(&rows, &tree.stale_allows));
        } else {
            for f in findings {
                println!("{f}");
            }
            let names: Vec<&str> = rules::RULES.iter().map(|r| r.name).collect();
            println!("{} finding(s) total (baselined included)", findings.len());
            println!("{} rule(s) active: {}", names.len(), names.join(", "));
        }
        return ExitCode::SUCCESS;
    }
    if print_baseline {
        print!("{}", baseline::render(&baseline::counts(findings)));
        return ExitCode::SUCCESS;
    }
    if update_baseline {
        let text = baseline::render(&baseline::counts(findings));
        if let Err(e) = std::fs::write(&baseline_path, text) {
            eprintln!("pallas-lint: writing {}: {e}", baseline_path.display());
            return ExitCode::from(2);
        }
        println!(
            "pallas-lint: wrote {} ({} finding(s) grandfathered)",
            baseline_path.display(),
            findings.len()
        );
        return ExitCode::SUCCESS;
    }

    let base = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => match baseline::parse(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("pallas-lint: {}: {e}", baseline_path.display());
                return ExitCode::from(2);
            }
        },
        // No baseline yet: everything is a new finding.
        Err(_) => Default::default(),
    };
    let drift = baseline::compare(findings, &base);
    for ((rule, path), budget, actual) in &drift.stale {
        eprintln!(
            "pallas-lint: stale baseline entry: {rule} {path} baselined {budget}, live {actual} \
             (regenerate with --update-baseline to ratchet down)"
        );
    }
    print_stale_allow_warnings(&tree.stale_allows, strict_allows);
    let stale_fail = strict_allows && !tree.stale_allows.is_empty();

    if format_json {
        let rows: Vec<(&Finding, bool)> =
            findings.iter().map(|f| (f, drift.new.contains(f))).collect();
        print!("{}", json::render(&rows, &tree.stale_allows));
    }
    if drift.new.is_empty() && !stale_fail {
        if !format_json {
            println!(
                "pallas-lint: clean — {} finding(s), all within the baseline",
                findings.len()
            );
        }
        return ExitCode::SUCCESS;
    }
    if !format_json {
        for f in &drift.new {
            println!("{f}");
        }
    }
    if !drift.new.is_empty() {
        eprintln!(
            "pallas-lint: {} finding(s) above the baseline. Fix them, or suppress a deliberate \
             one with `// lint:allow(<rule>): <reason>` (see DESIGN.md §10).",
            drift.new.len()
        );
    }
    if stale_fail {
        eprintln!(
            "pallas-lint: {} stale allow(s) under --strict-allows. Delete the suppression \
             comments, or fix the rule name they target.",
            tree.stale_allows.len()
        );
    }
    ExitCode::FAILURE
}
