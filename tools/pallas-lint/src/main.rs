//! CLI for the invariant checker. Run from anywhere in the workspace:
//!
//! ```text
//! cargo run -p pallas-lint                      # enforce against baseline
//! cargo run -p pallas-lint -- --list            # every finding, baselined or not
//! cargo run -p pallas-lint -- --update-baseline # regenerate the ratchet
//! cargo run -p pallas-lint -- --print-baseline  # regenerated baseline to stdout
//! ```
//!
//! Exit codes: 0 clean, 1 findings above the baseline, 2 usage or I/O
//! error. Stale baseline entries (count above the live tree) warn without
//! failing, so deleting grandfathered code never blocks a build — CI
//! uploads the regenerated-baseline diff as an artifact instead.

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_lint::{baseline, default_baseline, lint_tree, rules};

fn usage() -> String {
    let mut s = String::from(
        "pallas-lint: determinism & concurrency invariant checker\n\n\
         USAGE: pallas-lint [--root <dir>] [--baseline <file>]\n\
         \x20                [--list | --print-baseline | --update-baseline]\n\nRULES:\n",
    );
    for r in &rules::RULES {
        s.push_str(&format!("  {:<22} {}\n", r.name, r.summary));
    }
    s
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut baseline_path: Option<PathBuf> = None;
    let mut list = false;
    let mut print_baseline = false;
    let mut update_baseline = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--baseline" => baseline_path = args.next().map(PathBuf::from),
            "--list" => list = true,
            "--print-baseline" => print_baseline = true,
            "--update-baseline" => update_baseline = true,
            "--help" | "-h" => {
                print!("{}", usage());
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("pallas-lint: unknown argument {other:?}\n\n{}", usage());
                return ExitCode::from(2);
            }
        }
    }
    // Default root: two levels above this crate's manifest — the repo.
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
    });
    let baseline_path = baseline_path.unwrap_or_else(|| default_baseline(&root));

    let findings = match lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("pallas-lint: scanning {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    if list {
        for f in &findings {
            println!("{f}");
        }
        println!("{} finding(s) total (baselined included)", findings.len());
        return ExitCode::SUCCESS;
    }
    if print_baseline {
        print!("{}", baseline::render(&baseline::counts(&findings)));
        return ExitCode::SUCCESS;
    }
    if update_baseline {
        let text = baseline::render(&baseline::counts(&findings));
        if let Err(e) = std::fs::write(&baseline_path, text) {
            eprintln!("pallas-lint: writing {}: {e}", baseline_path.display());
            return ExitCode::from(2);
        }
        println!(
            "pallas-lint: wrote {} ({} finding(s) grandfathered)",
            baseline_path.display(),
            findings.len()
        );
        return ExitCode::SUCCESS;
    }

    let base = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => match baseline::parse(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("pallas-lint: {}: {e}", baseline_path.display());
                return ExitCode::from(2);
            }
        },
        // No baseline yet: everything is a new finding.
        Err(_) => Default::default(),
    };
    let drift = baseline::compare(&findings, &base);
    for ((rule, path), budget, actual) in &drift.stale {
        eprintln!(
            "pallas-lint: stale baseline entry: {rule} {path} baselined {budget}, live {actual} \
             (regenerate with --update-baseline to ratchet down)"
        );
    }
    if drift.new.is_empty() {
        println!(
            "pallas-lint: clean — {} finding(s), all within the baseline",
            findings.len()
        );
        return ExitCode::SUCCESS;
    }
    for f in &drift.new {
        println!("{f}");
    }
    eprintln!(
        "pallas-lint: {} finding(s) above the baseline. Fix them, or suppress a deliberate \
         one with `// lint:allow(<rule>): <reason>` (see DESIGN.md §10).",
        drift.new.len()
    );
    ExitCode::FAILURE
}
