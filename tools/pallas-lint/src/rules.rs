//! The line-oriented rule engine: phase 1 of the analyzer. Five checks
//! run over [`crate::lexer::Masked`] views of a single file, each
//! encoding an invariant this repo has already shipped a bug against (or
//! nearly did). The cross-file rules (phase 2) live in
//! [`crate::crossfile`] and consume the facts [`crate::facts`] extracts;
//! this module also owns the shared `lint:allow` suppression parser and
//! the registry of *all* rules, both phases. DESIGN.md §10 documents the
//! incident behind every rule and the etiquette for suppressing one.
//!
//! Scopes. Rules see three kinds of source:
//!
//! * **library** — files under `rust/src/`, minus each file's trailing
//!   `#[cfg(test)]` region (every test module in this tree is file-final;
//!   the region heuristic is "first line containing `#[cfg(test)]` to end
//!   of file");
//! * **test-ish** — `rust/tests/`, `rust/benches/`, `examples/`, and the
//!   in-file test regions;
//! * **everything** — both of the above plus `tools/`.
//!
//! Suppressions. `// lint:allow(<rule>): <reason>` on a line suppresses
//! that rule on the same line; written as a standalone comment line it
//! suppresses the next line that contains any code. Reasons are part of
//! the contract — a suppression without one should not survive review.

use crate::lexer::Masked;
use crate::Finding;

/// Static description of one rule, for `--help`/docs listings.
pub struct RuleInfo {
    /// Rule identifier, as used in `lint:allow(...)` and the baseline.
    pub name: &'static str,
    /// One-line summary of the invariant the rule protects.
    pub summary: &'static str,
}

/// Every rule this linter knows, in reporting order.
pub const RULES: [RuleInfo; 10] = [
    RuleInfo {
        name: "unspecified-hasher",
        summary: "DefaultHasher/RandomState outside util::siphash — unspecified \
                  algorithms change across toolchains (the PR 4 partitioner bug class)",
    },
    RuleInfo {
        name: "wall-clock-in-sim",
        summary: "Instant::now/SystemTime outside the metered-timing allowlist — \
                  host time must never feed simulated results (DESIGN.md §2)",
    },
    RuleInfo {
        name: "raw-thread-spawn",
        summary: "std::thread::spawn/Builder outside util::pool and the serve \
                  daemon's thread layer — parallelism must stay under the shared \
                  WorkerPool budget (DESIGN.md §9, §12)",
    },
    RuleInfo {
        name: "guard-across-notify",
        summary: "notify_all/notify_one/send while a Mutex guard bound on an \
                  earlier line is still live (the PR 4 lost-wakeup class)",
    },
    RuleInfo {
        name: "unwrap-in-library",
        summary: "unwrap/expect/panic! in non-test library code — grandfathered \
                  via the baseline; new code returns typed errors instead",
    },
    RuleInfo {
        name: "lock-order-cycle",
        summary: "a lock acquired while holding another closes a cycle in the \
                  tree-wide held-while-acquiring graph — a potential deadlock \
                  even when each file alone looks consistent",
    },
    RuleInfo {
        name: "atomic-ordering-mix",
        summary: "one atomic touched with inconsistent Ordering choices across \
                  the tree, or Relaxed on a field that gates a Condvar \
                  handshake (the PR 4 lost-wakeup class)",
    },
    RuleInfo {
        name: "blocking-in-pool-task",
        summary: "lock()/recv()/wait()/socket reads inside a closure that runs \
                  ON the shared WorkerPool — can consume the pool's own budget \
                  and deadlock it (the PR 8 serve incident class)",
    },
    RuleInfo {
        name: "counter-drift",
        summary: "a Stats-struct counter that an absorb/merge/render/snapshot \
                  handler forgets while folding all its siblings (the PR 8–9 \
                  stats-plumbing bug shape)",
    },
    RuleInfo {
        name: "stale-allow",
        summary: "a lint:allow(<rule>) that no longer suppresses anything — \
                  warning by default, a failure under --strict-allows; never \
                  baselined",
    },
];

/// True when `b` can continue an identifier (ASCII view; multi-byte chars
/// never continue the ASCII identifiers our patterns name).
fn ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Whether `line` contains `pat` with identifier boundaries respected on
/// whichever ends of `pat` are themselves identifier characters (so
/// `panic!` does not match `debug_panic!`, but `.expect(` needs no
/// boundary after its parenthesis).
pub(crate) fn has_pat(line: &str, pat: &str) -> bool {
    let need_before = ident_byte(pat.as_bytes()[0]);
    let need_after = ident_byte(*pat.as_bytes().last().expect("non-empty pattern"));
    for (pos, _) in line.match_indices(pat) {
        let before_ok = pos == 0 || !ident_byte(line.as_bytes()[pos - 1]);
        let end = pos + pat.len();
        let after_ok = end >= line.len() || !ident_byte(line.as_bytes()[end]);
        if (!need_before || before_ok) && (!need_after || after_ok) {
            return true;
        }
    }
    false
}

/// One parsed `lint:allow(<rule>)` directive.
pub(crate) struct Allow {
    /// The rule name inside the parentheses.
    pub rule: String,
    /// 0-based line the suppression applies to.
    pub target: usize,
    /// 0-based line the directive itself sits on (for `stale-allow`
    /// reporting).
    pub comment_line: usize,
}

/// Whether `rule` is *syntactically* a rule name: lowercase kebab-case.
/// Doc prose writes placeholders — `lint:allow(<rule>)`,
/// `lint:allow(...)` — and those must not parse as directives at all
/// (they would self-report as stale in the linter's own sources).
fn plausible_rule_name(rule: &str) -> bool {
    !rule.is_empty()
        && rule.as_bytes()[0].is_ascii_lowercase()
        && rule.bytes().all(|b| b == b'-' || b.is_ascii_lowercase() || b.is_ascii_digit())
}

/// Parse every `lint:allow(<rule>)` directive in the comment view. A
/// directive on a line with code applies to that line; on a comment-only
/// line it applies to the next line containing code.
pub(crate) fn allows(masked: &Masked) -> Vec<Allow> {
    let mut out = Vec::new();
    for (idx, comment) in masked.comments.iter().enumerate() {
        let mut rest: &str = comment;
        while let Some(p) = rest.find("lint:allow(") {
            rest = &rest[p + "lint:allow(".len()..];
            let Some(close) = rest.find(')') else { break };
            let rule = rest[..close].trim().to_string();
            rest = &rest[close + 1..];
            if !plausible_rule_name(&rule) {
                continue;
            }
            let target = if masked.code[idx].trim().is_empty() {
                // Standalone comment: attach to the next code-bearing line.
                (idx + 1..masked.code.len()).find(|&j| !masked.code[j].trim().is_empty())
            } else {
                Some(idx)
            };
            if let Some(t) = target {
                out.push(Allow { rule: rule.clone(), target: t, comment_line: idx });
            }
        }
    }
    out
}

/// First occurrence of the keyword `let ` (identifier boundary on the
/// left, so `booklet ` never matches).
fn find_let(l: &str) -> Option<usize> {
    l.match_indices("let ")
        .map(|(pos, _)| pos)
        .find(|&pos| pos == 0 || !ident_byte(l.as_bytes()[pos - 1]))
}

/// A live `let <name> = …lock()…` binding tracked by the
/// `guard-across-notify` heuristic.
struct Guard {
    /// The bound name when it is a plain identifier (enables
    /// `drop(<name>)` release); `None` for patterns like `Ok(g)`.
    name: Option<String>,
    /// Brace depth at the binding site; the guard dies when the running
    /// depth falls below it.
    depth: i64,
}

/// Run the five line-oriented rules over one file, **pre-suppression**:
/// `lint:allow` filtering happens in [`crate::lint_files`], after the
/// cross-file findings for the same file are merged in. `rel` is the
/// repo-relative path with `/` separators — rule scoping is path-based,
/// so callers must not pass absolute paths.
pub(crate) fn line_findings(rel: &str, masked: &Masked, raw: &[&str]) -> Vec<Finding> {
    let lines = &masked.code;
    let in_library = rel.starts_with("rust/src/");
    let test_file = rel.starts_with("rust/tests/")
        || rel.starts_with("rust/benches/")
        || rel.starts_with("examples/");
    // Trailing-test-module heuristic: everything from the first
    // `#[cfg(test)]` to end of file is test code (holds tree-wide; a
    // mid-file test module would over-exempt what follows it, which is the
    // conservative failure mode for rules that skip tests).
    let test_from = lines
        .iter()
        .position(|l| l.contains("#[cfg(test)]"))
        .unwrap_or(usize::MAX);
    let is_test = |i: usize| test_file || i >= test_from;

    let mut findings: Vec<Finding> = Vec::new();
    let mut push = |rule: &'static str, line_idx: usize| {
        let excerpt: String = raw.get(line_idx).map_or("", |l| l.trim()).chars().take(90).collect();
        findings.push(Finding {
            rule,
            path: rel.to_string(),
            line: line_idx + 1,
            excerpt,
            detail: String::new(),
        });
    };

    // ---- unspecified-hasher: everywhere but the pinned implementation ----
    if rel != "rust/src/util/siphash.rs" {
        for (i, l) in lines.iter().enumerate() {
            if has_pat(l, "DefaultHasher") || has_pat(l, "RandomState") {
                push("unspecified-hasher", i);
            }
        }
    }

    // ---- wall-clock-in-sim: library code minus the metering layer --------
    if in_library && !rel.starts_with("rust/src/bench_harness/") {
        for (i, l) in lines.iter().enumerate() {
            if !is_test(i) && (has_pat(l, "Instant::now") || has_pat(l, "SystemTime")) {
                push("wall-clock-in-sim", i);
            }
        }
    }

    // ---- raw-thread-spawn: library code minus the pool itself and the
    // serve daemon's service threads. The daemon's accept/reader/worker
    // threads block on socket I/O (or themselves submit jobs to the pool),
    // so running them ON pool workers would deadlock the very budget the
    // queries need; mining work still goes through the one shared
    // Executor (DESIGN.md §10, §12).
    if in_library && rel != "rust/src/util/pool.rs" && rel != "rust/src/serve/server.rs" {
        for (i, l) in lines.iter().enumerate() {
            if !is_test(i) && (has_pat(l, "thread::spawn") || has_pat(l, "thread::Builder")) {
                push("raw-thread-spawn", i);
            }
        }
    }

    // ---- guard-across-notify: a scope heuristic over library code --------
    if in_library {
        let mut depth: i64 = 0;
        let mut guards: Vec<Guard> = Vec::new();
        for (i, l) in lines.iter().enumerate() {
            if is_test(i) {
                break; // test modules are file-final
            }
            // 1. `drop(<name>)` releases that guard wherever it appears.
            guards.retain(|g| match &g.name {
                Some(nm) => !l.contains(&format!("drop({nm})")),
                None => true,
            });
            let opens = l.matches('{').count() as i64;
            let closes = l.matches('}').count() as i64;
            // 2. Bind: `let <name> = …lock()` on one line. The binding
            //    depth counts only braces left of the lock call, so a
            //    `{ let g = m.lock(); }` one-liner scopes correctly.
            if let Some(lockpos) = l.find(".lock()") {
                if let Some(letpos) = find_let(l) {
                    if letpos < lockpos {
                        if let Some(eqoff) = l[letpos..lockpos].find('=') {
                            let raw_name = l[letpos + 4..letpos + eqoff].trim();
                            let raw_name = raw_name.strip_prefix("mut ").unwrap_or(raw_name).trim();
                            let plain = !raw_name.is_empty()
                                && raw_name.bytes().all(ident_byte)
                                && !raw_name.as_bytes()[0].is_ascii_digit();
                            if raw_name != "_" {
                                let before = &l[..lockpos];
                                let bind_depth = depth + before.matches('{').count() as i64
                                    - before.matches('}').count() as i64;
                                guards.push(Guard {
                                    name: plain.then(|| raw_name.to_string()),
                                    depth: bind_depth,
                                });
                            }
                        }
                    }
                }
            }
            // 3. Waking or sending while any tracked guard is live is the
            //    PR 4 lost-wakeup shape: the waiter can observe the
            //    notification before the state change commits.
            let notifies = l.contains(".notify_all(")
                || l.contains(".notify_one(")
                || l.contains(".send(");
            if notifies && !guards.is_empty() {
                push("guard-across-notify", i);
            }
            // 4. Scope release at end of line.
            depth += opens - closes;
            guards.retain(|g| g.depth <= depth);
        }
    }

    // ---- unwrap-in-library: ratcheted via the baseline -------------------
    if in_library {
        for (i, l) in lines.iter().enumerate() {
            if !is_test(i)
                && (l.contains(".unwrap()") || l.contains(".expect(") || has_pat(l, "panic!"))
            {
                push("unwrap-in-library", i);
            }
        }
    }

    findings
}
