//! # pallas-lint
//!
//! An in-repo static checker for the determinism and concurrency
//! invariants the mrapriori tree promises (DESIGN.md §10): byte-identical
//! mining output across worker counts, streaming modes, fault models and
//! toolchains, with all parallelism under one shared pool budget and a
//! strict metered-vs-simulated-time split.
//!
//! The pipeline is two-phase. [`lexer::mask`] strips comments and
//! literal contents so nothing fires on prose; phase 1 runs the five
//! line-level rules ([`rules`]) and extracts per-file concurrency facts
//! ([`facts`]); phase 2 joins the facts across the whole tree into the
//! four cross-file rules ([`crossfile`]) — lock-order cycles, atomic
//! ordering mixes, blocking calls inside pool tasks, stats-counter
//! drift. `// lint:allow(<rule>): <reason>` suppresses any rule, and a
//! suppression that suppresses nothing is itself reported as
//! `stale-allow`. [`baseline`] ratchets pre-existing `unwrap-in-library`
//! findings per `(rule, file)` so new code is held to the bar without
//! rewriting every grandfathered call site in one diff; the cross-file
//! rules are never baselined. [`json`] renders the machine-readable
//! report CI turns into PR annotations.
//!
//! Zero dependencies by design: the linter must build in the same offline
//! environment as the crate it checks, and sits in tier-1 CI
//! (`cargo run -p pallas-lint`).

#![warn(missing_docs)]

pub mod baseline;
pub mod crossfile;
pub mod facts;
pub mod graph;
pub mod json;
pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

/// One rule violation at a specific source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule identifier (see [`rules::RULES`]).
    pub rule: &'static str,
    /// Repo-relative path with `/` separators.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Trimmed source excerpt (at most 90 characters).
    pub excerpt: String,
    /// Cross-file context for phase-2 findings (the cycle edge, the
    /// ordering set, the missing counters); empty for line-level rules.
    pub detail: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.path, self.line, self.rule, self.excerpt)?;
        if !self.detail.is_empty() {
            write!(f, " [{}]", self.detail)?;
        }
        Ok(())
    }
}

/// One analyzed file: the inputs phase 2 needs, produced once per file
/// by phase 1.
pub struct SourceUnit {
    /// Repo-relative path with `/` separators.
    pub rel: String,
    /// Raw source lines (for excerpts).
    pub raw: Vec<String>,
    /// Masked view (comments and literal bodies blanked).
    pub masked: lexer::Masked,
    /// Extracted concurrency facts.
    pub facts: facts::FileFacts,
}

impl SourceUnit {
    /// Run phase 1 over one file's source text.
    pub fn analyze(rel: &str, src: &str) -> SourceUnit {
        let masked = lexer::mask(src);
        let facts = facts::extract(rel, &masked);
        SourceUnit {
            rel: rel.to_string(),
            raw: src.lines().map(str::to_string).collect(),
            masked,
            facts,
        }
    }
}

/// The full result of linting a set of files.
pub struct TreeLint {
    /// Post-suppression findings, ordered by `(path, line, rule)`.
    pub findings: Vec<Finding>,
    /// `stale-allow` reports: suppressions that suppressed nothing.
    /// Kept apart from [`TreeLint::findings`] — they are warnings by
    /// default and never enter the baseline.
    pub stale_allows: Vec<Finding>,
}

/// Lint a set of `(repo-relative path, source)` files as one tree: both
/// phases, suppression, and stale-allow detection. Cross-file joins see
/// exactly the files given, so single-file callers get phase-2 findings
/// whose facts resolve within that file alone.
pub fn lint_files(files: &[(String, String)]) -> TreeLint {
    let units: Vec<SourceUnit> =
        files.iter().map(|(rel, src)| SourceUnit::analyze(rel, src)).collect();

    // Phase 1 + phase 2, grouped per file for suppression.
    let mut per_file: Vec<Vec<Finding>> = units
        .iter()
        .map(|u| {
            let raw: Vec<&str> = u.raw.iter().map(String::as_str).collect();
            rules::line_findings(&u.rel, &u.masked, &raw)
        })
        .collect();
    for f in crossfile::check(&units) {
        let ui = units.iter().position(|u| u.rel == f.path).expect("finding from known file");
        per_file[ui].push(f);
    }

    let mut findings = Vec::new();
    let mut stale_allows = Vec::new();
    for (u, file_findings) in units.iter().zip(per_file.into_iter()) {
        let allows = rules::allows(&u.masked);
        for a in &allows {
            let hits = file_findings
                .iter()
                .filter(|f| f.rule == a.rule && f.line - 1 == a.target)
                .count();
            if hits == 0 {
                let known = rules::RULES.iter().any(|r| r.name == a.rule);
                let detail = if known {
                    format!("allow(`{}`) suppresses nothing on its target line", a.rule)
                } else {
                    format!("allow(`{}`) names a rule this linter does not have", a.rule)
                };
                stale_allows.push(Finding {
                    rule: "stale-allow",
                    path: u.rel.clone(),
                    line: a.comment_line + 1,
                    excerpt: u.raw.get(a.comment_line).map_or(String::new(), |l| {
                        l.trim().chars().take(90).collect()
                    }),
                    detail,
                });
            }
        }
        findings.extend(file_findings.into_iter().filter(|f| {
            !allows.iter().any(|a| a.rule == f.rule && a.target == f.line - 1)
        }));
    }
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    stale_allows.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    TreeLint { findings, stale_allows }
}

/// Lint one file's source text against every rule (both phases, with
/// cross-file identities resolved within the single file).
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    lint_source_full(rel, src).findings
}

/// Like [`lint_source`], but also returning stale-allow reports.
pub fn lint_source_full(rel: &str, src: &str) -> TreeLint {
    lint_files(&[(rel.to_string(), src.to_string())])
}

/// The directories scanned under the repo root. `vendor/` (third-party
/// stand-ins) and `python/` (not Rust) are deliberately absent.
const SCAN_ROOTS: [&str; 5] = ["rust/src", "rust/tests", "rust/benches", "examples", "tools"];

/// Collect every `.rs` file in scope under `root`, repo-relative with `/`
/// separators, sorted for deterministic reports. Skips `target/` build
/// output and `fixtures/` (the linter's own deliberately-violating test
/// data), plus hidden directories.
pub fn scan_files(root: &Path) -> std::io::Result<Vec<String>> {
    fn walk(dir: &Path, rel: &str, out: &mut Vec<String>) -> std::io::Result<()> {
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let sub = if rel.is_empty() { name.to_string() } else { format!("{rel}/{name}") };
            let path = entry.path();
            if path.is_dir() {
                if name.starts_with('.') || name == "target" || name == "fixtures" {
                    continue;
                }
                walk(&path, &sub, out)?;
            } else if name.ends_with(".rs") {
                out.push(sub);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    for scan_root in SCAN_ROOTS {
        let dir = root.join(scan_root);
        if dir.is_dir() {
            walk(&dir, scan_root, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every in-scope file under `root`, with cross-file analysis over
/// the whole set; findings are ordered by `(path, line, rule)`.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    Ok(lint_tree_full(root)?.findings)
}

/// Like [`lint_tree`], but also returning stale-allow reports.
pub fn lint_tree_full(root: &Path) -> std::io::Result<TreeLint> {
    let mut files = Vec::new();
    for rel in scan_files(root)? {
        let src = std::fs::read_to_string(root.join(&rel))?;
        files.push((rel, src));
    }
    Ok(lint_files(&files))
}

/// The default baseline location, relative to the repo root.
pub fn default_baseline(root: &Path) -> PathBuf {
    root.join("tools/pallas-lint/baseline.txt")
}
