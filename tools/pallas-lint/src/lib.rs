//! # pallas-lint
//!
//! An in-repo static checker for the determinism and concurrency
//! invariants the mrapriori tree promises (DESIGN.md §10): byte-identical
//! mining output across worker counts, streaming modes, fault models and
//! toolchains, with all parallelism under one shared pool budget and a
//! strict metered-vs-simulated-time split.
//!
//! The pipeline: [`lexer::mask`] strips comments and literal contents so
//! rules never fire on prose; [`rules::check_file`] runs five line-level
//! checks with `// lint:allow(<rule>): <reason>` suppressions;
//! [`baseline`] ratchets pre-existing findings per `(rule, file)` so new
//! code is held to the bar without rewriting ~100 grandfathered call
//! sites in one diff.
//!
//! Zero dependencies by design: the linter must build in the same offline
//! environment as the crate it checks, and sits in tier-1 CI
//! (`cargo run -p pallas-lint`).

#![warn(missing_docs)]

pub mod baseline;
pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

/// One rule violation at a specific source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule identifier (see [`rules::RULES`]).
    pub rule: &'static str,
    /// Repo-relative path with `/` separators.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Trimmed source excerpt (at most 90 characters).
    pub excerpt: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.path, self.line, self.rule, self.excerpt)
    }
}

/// Lint one file's source text against every rule.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    rules::check_file(rel, src)
}

/// The directories scanned under the repo root. `vendor/` (third-party
/// stand-ins) and `python/` (not Rust) are deliberately absent.
const SCAN_ROOTS: [&str; 5] = ["rust/src", "rust/tests", "rust/benches", "examples", "tools"];

/// Collect every `.rs` file in scope under `root`, repo-relative with `/`
/// separators, sorted for deterministic reports. Skips `target/` build
/// output and `fixtures/` (the linter's own deliberately-violating test
/// data), plus hidden directories.
pub fn scan_files(root: &Path) -> std::io::Result<Vec<String>> {
    fn walk(dir: &Path, rel: &str, out: &mut Vec<String>) -> std::io::Result<()> {
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let sub = if rel.is_empty() { name.to_string() } else { format!("{rel}/{name}") };
            let path = entry.path();
            if path.is_dir() {
                if name.starts_with('.') || name == "target" || name == "fixtures" {
                    continue;
                }
                walk(&path, &sub, out)?;
            } else if name.ends_with(".rs") {
                out.push(sub);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    for scan_root in SCAN_ROOTS {
        let dir = root.join(scan_root);
        if dir.is_dir() {
            walk(&dir, scan_root, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every in-scope file under `root`; findings are ordered by
/// `(path, line, rule)`.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for rel in scan_files(root)? {
        let src = std::fs::read_to_string(root.join(&rel))?;
        findings.extend(lint_source(&rel, &src));
    }
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(findings)
}

/// The default baseline location, relative to the repo root.
pub fn default_baseline(root: &Path) -> PathBuf {
    root.join("tools/pallas-lint/baseline.txt")
}
