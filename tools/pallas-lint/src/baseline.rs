//! The grandfather baseline: a checked-in ratchet of pre-existing
//! findings, counted per `(rule, file)`.
//!
//! Keying on counts rather than line numbers or line text makes the
//! baseline stable under reformatting and unrelated edits in the same
//! file — moving a grandfathered `unwrap` around does not churn the file,
//! but *adding* one pushes the count over its baselined value and fails
//! the lint. Deleting one leaves the entry "stale", reported as a warning
//! until the baseline is regenerated (the ratchet only tightens; see
//! DESIGN.md §10 for the lifecycle).

use std::collections::BTreeMap;

use crate::Finding;

/// Baseline key: `(rule, repo-relative path)`.
pub type Key = (String, String);

/// Multiset view of a finding list: occurrences per `(rule, path)`.
pub fn counts(findings: &[Finding]) -> BTreeMap<Key, usize> {
    let mut map: BTreeMap<Key, usize> = BTreeMap::new();
    for f in findings {
        *map.entry((f.rule.to_string(), f.path.clone())).or_insert(0) += 1;
    }
    map
}

/// Parse a baseline file. Blank lines and `#` comments are ignored; every
/// other line is `rule<TAB>path<TAB>count`.
pub fn parse(text: &str) -> Result<BTreeMap<Key, usize>, String> {
    let mut map = BTreeMap::new();
    for (no, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split('\t');
        let (Some(rule), Some(path), Some(count)) = (parts.next(), parts.next(), parts.next())
        else {
            return Err(format!("baseline line {}: expected rule<TAB>path<TAB>count", no + 1));
        };
        let count: usize = count
            .parse()
            .map_err(|_| format!("baseline line {}: bad count {count:?}", no + 1))?;
        if map.insert((rule.to_string(), path.to_string()), count).is_some() {
            return Err(format!("baseline line {}: duplicate key {rule} {path}", no + 1));
        }
    }
    Ok(map)
}

/// Render a baseline map in the checked-in format (sorted, commented).
pub fn render(map: &BTreeMap<Key, usize>) -> String {
    let mut out = String::from(
        "# pallas-lint baseline: grandfathered findings, one `rule<TAB>path<TAB>count` per line.\n\
         # A count above its baselined value fails the lint; below it is reported stale.\n\
         # Regenerate (after removing findings, never to admit new ones):\n\
         #   cargo run -p pallas-lint -- --update-baseline\n",
    );
    for ((rule, path), count) in map {
        out.push_str(&format!("{rule}\t{path}\t{count}\n"));
    }
    out
}

/// What changed relative to the baseline.
pub struct Drift {
    /// Findings in `(rule, path)` groups whose count exceeds the baseline
    /// — the enforcement failure. Every finding of an over-budget group is
    /// listed (the linter cannot know which occurrence is "the new one").
    pub new: Vec<Finding>,
    /// `(key, baselined, actual)` for entries above the live count — the
    /// ratchet can tighten.
    pub stale: Vec<(Key, usize, usize)>,
}

/// Compare live findings against a baseline.
pub fn compare(findings: &[Finding], base: &BTreeMap<Key, usize>) -> Drift {
    let live = counts(findings);
    let mut new = Vec::new();
    for (key, &n) in &live {
        let budget = base.get(key).copied().unwrap_or(0);
        if n > budget {
            new.extend(
                findings
                    .iter()
                    .filter(|f| f.rule == key.0 && f.path == key.1)
                    .cloned(),
            );
        }
    }
    let mut stale = Vec::new();
    for (key, &budget) in base {
        let n = live.get(key).copied().unwrap_or(0);
        if n < budget {
            stale.push((key.clone(), budget, n));
        }
    }
    new.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Drift { new, stale }
}
