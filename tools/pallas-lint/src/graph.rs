//! A small directed graph over interned string nodes, with strongly
//! connected component detection (iterative Tarjan). Used by
//! [`crate::crossfile`] to find cycles in the held-while-acquiring
//! lock-order graph: every edge inside a non-trivial SCC (or any
//! self-loop) participates in a potential deadlock.

use std::collections::BTreeMap;

/// Directed graph over string-named nodes. Nodes are created lazily by
/// [`Digraph::add_edge`]; duplicate edges are kept (each carries its own
/// provenance in the caller) but do not change connectivity.
#[derive(Debug, Default)]
pub struct Digraph {
    names: Vec<String>,
    index: BTreeMap<String, usize>,
    succ: Vec<Vec<usize>>,
}

impl Digraph {
    /// Create an empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    fn node(&mut self, name: &str) -> usize {
        if let Some(&i) = self.index.get(name) {
            return i;
        }
        let i = self.names.len();
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), i);
        self.succ.push(Vec::new());
        i
    }

    /// Add the directed edge `from → to`, creating nodes as needed.
    pub fn add_edge(&mut self, from: &str, to: &str) {
        let f = self.node(from);
        let t = self.node(to);
        self.succ[f].push(t);
    }

    /// Strongly connected components, each as a sorted list of node
    /// names. Components are returned in deterministic order.
    pub fn sccs(&self) -> Vec<Vec<String>> {
        // Iterative Tarjan: an explicit stack of (node, next-successor
        // index) frames replaces recursion so pathological graphs cannot
        // overflow the thread stack.
        const UNSET: usize = usize::MAX;
        let n = self.names.len();
        let mut idx = vec![UNSET; n];
        let mut low = vec![0usize; n];
        let mut on_stack = vec![false; n];
        let mut stack: Vec<usize> = Vec::new();
        let mut counter = 0usize;
        let mut out: Vec<Vec<String>> = Vec::new();

        for root in 0..n {
            if idx[root] != UNSET {
                continue;
            }
            let mut frames: Vec<(usize, usize)> = vec![(root, 0)];
            while let Some(&(v, next)) = frames.last() {
                if next == 0 {
                    idx[v] = counter;
                    low[v] = counter;
                    counter += 1;
                    stack.push(v);
                    on_stack[v] = true;
                }
                if next < self.succ[v].len() {
                    let w = self.succ[v][next];
                    frames.last_mut().expect("frame just read").1 += 1;
                    if idx[w] == UNSET {
                        frames.push((w, 0));
                    } else if on_stack[w] {
                        low[v] = low[v].min(idx[w]);
                    }
                    continue;
                }
                frames.pop();
                if let Some(&(p, _)) = frames.last() {
                    low[p] = low[p].min(low[v]);
                }
                if low[v] == idx[v] {
                    let mut comp = Vec::new();
                    loop {
                        let w = stack.pop().expect("tarjan stack underflow");
                        on_stack[w] = false;
                        comp.push(self.names[w].clone());
                        if w == v {
                            break;
                        }
                    }
                    comp.sort();
                    out.push(comp);
                }
            }
        }
        out.sort();
        out
    }

    /// Node-name pairs `(from, to)` for which the edge lies inside a
    /// cycle: both endpoints share a non-trivial SCC, or the edge is a
    /// self-loop (`std::sync::Mutex` is not reentrant, so re-acquiring a
    /// held lock deadlocks too).
    pub fn cyclic_edges(&self) -> Vec<(String, String)> {
        let mut comp_of: BTreeMap<&str, usize> = BTreeMap::new();
        let mut comp_size: Vec<usize> = Vec::new();
        for (ci, comp) in self.sccs().iter().enumerate() {
            comp_size.push(comp.len());
            for name in comp {
                // sccs() returns owned names; key by interned index name.
                let i = self.index[name.as_str()];
                comp_of.insert(self.names[i].as_str(), ci);
            }
        }
        let mut out = Vec::new();
        for (f, succs) in self.succ.iter().enumerate() {
            for &t in succs {
                let cyclic = f == t
                    || (comp_of[self.names[f].as_str()] == comp_of[self.names[t].as_str()]
                        && comp_size[comp_of[self.names[f].as_str()]] > 1);
                if cyclic {
                    out.push((self.names[f].clone(), self.names[t].clone()));
                }
            }
        }
        out.sort();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acyclic_graph_has_no_cyclic_edges() {
        let mut g = Digraph::new();
        g.add_edge("a", "b");
        g.add_edge("b", "c");
        g.add_edge("a", "c");
        assert!(g.cyclic_edges().is_empty());
    }

    #[test]
    fn two_cycle_flags_both_edges() {
        let mut g = Digraph::new();
        g.add_edge("a", "b");
        g.add_edge("b", "a");
        g.add_edge("b", "c");
        assert_eq!(
            g.cyclic_edges(),
            vec![("a".to_string(), "b".to_string()), ("b".to_string(), "a".to_string())]
        );
    }

    #[test]
    fn self_loop_is_a_cycle() {
        let mut g = Digraph::new();
        g.add_edge("q", "q");
        assert_eq!(g.cyclic_edges(), vec![("q".to_string(), "q".to_string())]);
    }

    #[test]
    fn three_cycle_through_distinct_components() {
        let mut g = Digraph::new();
        g.add_edge("a", "b");
        g.add_edge("b", "c");
        g.add_edge("c", "a");
        g.add_edge("c", "d"); // tail out of the cycle stays clean
        assert_eq!(g.cyclic_edges().len(), 3);
        assert!(!g.cyclic_edges().contains(&("c".to_string(), "d".to_string())));
    }
}
