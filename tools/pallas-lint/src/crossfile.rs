//! Phase-2 rules: join the per-file facts from [`crate::facts`] across
//! the whole tree and emit findings no single-file scan can see.
//!
//! Identity resolution is the heart of the join. A mutex or atomic
//! *field name* declared in exactly **one** library file names the same
//! object everywhere it is locked or touched — `follows` is the serve
//! registry's follow map wherever it appears — so its facts from every
//! file merge under one global identity. A name declared in several
//! files (or never declared in-tree) is ambiguous, and each file's uses
//! stay under a file-local identity `path::name`: the analysis then
//! under-reports rather than fusing two different locks into a phantom
//! cycle.
//!
//! The rules:
//!
//! * `lock-order-cycle` — build the directed held-while-acquiring graph
//!   over resolved mutex identities and flag every acquisition that lies
//!   on a cycle (including self-loops: `std::sync::Mutex` is not
//!   reentrant). Two files each locally consistent can still compose
//!   into an AB/BA deadlock; only this join sees it.
//! * `atomic-ordering-mix` — one atomic touched with `Relaxed` in some
//!   places and stronger orderings in others, or with `SeqCst` mixed
//!   into a weaker protocol, is either a bug or under-documented; a pure
//!   Acquire/Release/AcqRel protocol is left alone. Additionally,
//!   `Relaxed` on any atomic whose owning struct also declares a
//!   `Condvar` is flagged: those fields gate wakeup handshakes (the PR 4
//!   lost-wakeup class) and need at least Acquire/Release.
//! * `blocking-in-pool-task` — a blocking call inside a closure shipped
//!   onto the shared [`WorkerPool`] can consume the pool's own worker
//!   budget and deadlock it (the PR 8 serve incident class, previously
//!   enforced only by a comment in `serve/server.rs`).
//! * `counter-drift` — a `*Stats*` struct's counters and the
//!   absorb/merge/render/snapshot-style handlers that fold them: a
//!   handler that touches *almost* every counter has almost certainly
//!   forgotten the newest one (the exact shape PRs 8–9 kept adding
//!   surface for). Handlers that touch only a couple of counters are
//!   accessors, not folds, and are not flagged.
//!
//! [`WorkerPool`]: ../../../rust/src/util/pool.rs

use std::collections::{BTreeMap, BTreeSet};

use crate::graph::Digraph;
use crate::rules::has_pat;
use crate::{Finding, SourceUnit};

/// Handler-function names that are expected to fold every counter of a
/// stats struct they mention. Chosen from the tree's own vocabulary
/// (`SessionStats::absorb`, `StatsSnapshot::render`, `Registry::stats`,
/// …); a fn outside this set is an accessor and never flagged.
const HANDLER_VERBS: [&str; 9] =
    ["absorb", "merge", "render", "snapshot", "stats", "counts", "fold", "retire", "totals"];

/// Resolve field names to identities: globally by bare name when
/// declared in exactly one file, file-locally (`path::name`) otherwise.
struct Identities {
    /// name → declaring files (sorted, deduped).
    decls: BTreeMap<String, BTreeSet<String>>,
}

impl Identities {
    fn build<'a>(names: impl Iterator<Item = (&'a str, &'a str)>) -> Self {
        let mut decls: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
        for (name, rel) in names {
            decls.entry(name.to_string()).or_default().insert(rel.to_string());
        }
        Identities { decls }
    }

    fn resolve(&self, rel: &str, name: &str) -> String {
        match self.decls.get(name) {
            Some(files) if files.len() == 1 => name.to_string(),
            _ => format!("{rel}::{name}"),
        }
    }
}

fn excerpt_of(unit: &SourceUnit, line: usize) -> String {
    unit.raw.get(line).map(|l| l.trim().to_string()).unwrap_or_default()
}

/// Run all four cross-file rules over the analyzed tree. Returned
/// findings are unsorted and unsuppressed; the caller applies
/// `lint:allow` filtering and ordering.
pub fn check(units: &[SourceUnit]) -> Vec<Finding> {
    let mut out = Vec::new();
    lock_order_cycle(units, &mut out);
    atomic_ordering_mix(units, &mut out);
    blocking_in_pool_task(units, &mut out);
    counter_drift(units, &mut out);
    out
}

fn lock_order_cycle(units: &[SourceUnit], out: &mut Vec<Finding>) {
    let ids = Identities::build(
        units
            .iter()
            .flat_map(|u| u.facts.mutex_decls.keys().map(move |k| (k.as_str(), u.rel.as_str()))),
    );
    let mut g = Digraph::new();
    // (held-id, acquired-id) → acquisition sites (unit index, line).
    let mut sites: BTreeMap<(String, String), Vec<(usize, usize)>> = BTreeMap::new();
    for (ui, u) in units.iter().enumerate() {
        for e in &u.facts.lock_edges {
            let held = ids.resolve(&u.rel, &e.held);
            let acq = ids.resolve(&u.rel, &e.acquired);
            g.add_edge(&held, &acq);
            sites.entry((held, acq)).or_default().push((ui, e.line));
        }
    }
    for (held, acq) in g.cyclic_edges() {
        for &(ui, line) in sites.get(&(held.clone(), acq.clone())).into_iter().flatten() {
            let u = &units[ui];
            out.push(Finding {
                rule: "lock-order-cycle",
                path: u.rel.clone(),
                line: line + 1,
                excerpt: excerpt_of(u, line),
                detail: format!("locks `{acq}` while holding `{held}`, closing a cycle"),
            });
        }
    }
}

fn atomic_ordering_mix(units: &[SourceUnit], out: &mut Vec<Finding>) {
    let ids = Identities::build(units.iter().flat_map(|u| {
        u.facts.atomic_decls.keys().map(move |k| (k.as_str(), u.rel.as_str()))
    }));
    // identity → set of orderings used across the tree.
    let mut orderings: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    // identity → owning struct declares a Condvar.
    let mut gates_condvar: BTreeSet<String> = BTreeSet::new();
    for u in units {
        for use_ in &u.facts.atomic_uses {
            let id = ids.resolve(&u.rel, &use_.field);
            orderings.entry(id).or_default().insert(use_.ordering.clone());
        }
        for (name, (_, owner)) in &u.facts.atomic_decls {
            if let Some(owner) = owner {
                if u.facts.condvar_structs.contains(owner) {
                    gates_condvar.insert(ids.resolve(&u.rel, name));
                }
            }
        }
    }
    for u in units {
        for use_ in &u.facts.atomic_uses {
            let id = ids.resolve(&u.rel, &use_.field);
            let set = &orderings[&id];
            let mixed = set.len() > 1 && (set.contains("Relaxed") || set.contains("SeqCst"));
            let weak_gate = use_.ordering == "Relaxed" && gates_condvar.contains(&id);
            if !(mixed || weak_gate) {
                continue;
            }
            let detail = if mixed {
                let all: Vec<&str> = set.iter().map(String::as_str).collect();
                format!("`{id}` is touched with mixed orderings: {}", all.join("+"))
            } else {
                format!("Relaxed on `{id}`, which gates a Condvar handshake")
            };
            out.push(Finding {
                rule: "atomic-ordering-mix",
                path: u.rel.clone(),
                line: use_.line + 1,
                excerpt: excerpt_of(u, use_.line),
                detail,
            });
        }
    }
}

fn blocking_in_pool_task(units: &[SourceUnit], out: &mut Vec<Finding>) {
    for u in units {
        for b in &u.facts.pool_blocking {
            out.push(Finding {
                rule: "blocking-in-pool-task",
                path: u.rel.clone(),
                line: b.line + 1,
                excerpt: excerpt_of(u, b.line),
                detail: format!("`{}` inside a closure that runs on the shared pool", b.what),
            });
        }
    }
}

fn counter_drift(units: &[SourceUnit], out: &mut Vec<Finding>) {
    // Struct name → counter fields; structs declared in several files
    // are ambiguous and skipped.
    let mut fields: BTreeMap<String, Vec<String>> = BTreeMap::new();
    let mut seen_in: BTreeMap<String, usize> = BTreeMap::new();
    for u in units {
        for (sname, flds) in &u.facts.stats_structs {
            *seen_in.entry(sname.clone()).or_insert(0) += 1;
            fields.insert(sname.clone(), flds.clone());
        }
    }
    fields.retain(|s, flds| seen_in[s] == 1 && flds.len() >= 3);

    for u in units {
        for f in &u.facts.fns {
            if !HANDLER_VERBS.contains(&f.name.as_str()) {
                continue;
            }
            let body = u.masked.code[f.start..=f.end.min(u.masked.code.len() - 1)].join("\n");
            for (sname, flds) in &fields {
                // Only handlers that even mention the struct (or live in
                // the file declaring it) are candidates for its fold.
                let declared_here = u.facts.stats_structs.contains_key(sname);
                if !declared_here && !has_pat(&body, sname) {
                    continue;
                }
                let missing: Vec<&str> = flds
                    .iter()
                    .filter(|fld| !has_pat(&body, fld))
                    .map(String::as_str)
                    .collect();
                let got = flds.len() - missing.len();
                let thresh = 2.max(flds.len().saturating_sub(2));
                if got >= thresh && got < flds.len() {
                    out.push(Finding {
                        rule: "counter-drift",
                        path: u.rel.clone(),
                        line: f.decl_line + 1,
                        excerpt: excerpt_of(u, f.decl_line),
                        detail: format!(
                            "`{}` folds {got}/{} counters of `{sname}`; missing: {}",
                            f.name,
                            flds.len(),
                            missing.join(", ")
                        ),
                    });
                }
            }
        }
    }
}
