//! A minimal Rust lexer that separates code from comments and blanks out
//! string/char-literal contents.
//!
//! The rules in [`crate::rules`] are line-oriented substring matchers; run
//! naively over raw source they would fire on prose ("... used to use
//! `DefaultHasher` ..." in a doc comment) and on data (a format string
//! mentioning `panic!`). [`mask`] therefore splits every source line into
//! two parallel views with identical line numbering:
//!
//! * **code** — source text with comments removed and the *contents* of
//!   string, raw-string, byte-string and char literals blanked to spaces
//!   (delimiters kept, so brace counting still sees the code shape);
//! * **comments** — comment text only, which is where
//!   `lint:allow(<rule>): <reason>` suppressions live.
//!
//! Text inside string literals lands in *neither* view: a directive quoted
//! in a string (as in this linter's own tests) is inert.
//!
//! Handled syntax: `//`/`///`/`//!` line comments, nested `/* */` block
//! comments, `"…"` and `b"…"` strings with escapes, `r"…"`/`r#"…"#`-style
//! raw (byte) strings with any hash count, and char/byte-char literals
//! including `'\''`/`'"'` — crucially distinguished from lifetimes
//! (`'static`) so a lifetime does not swallow code to the next quote.

/// Parallel per-line views of one source file; both vectors have exactly
/// as many entries as the input has lines.
#[derive(Debug)]
pub struct Masked {
    /// Code with comments removed and literal contents blanked.
    pub code: Vec<String>,
    /// Comment text only; everything else blanked.
    pub comments: Vec<String>,
}

/// Where a character is emitted: the code view, the comment view, or
/// neither (string-literal contents).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Sink {
    Code,
    Comment,
    Neither,
}

/// Append `c` to the view selected by `sink`, blanks to the others.
/// Newlines go to both so line numbering stays aligned.
fn put(code: &mut String, com: &mut String, c: char, sink: Sink) {
    if c == '\n' {
        code.push('\n');
        com.push('\n');
        return;
    }
    code.push(if sink == Sink::Code { c } else { ' ' });
    com.push(if sink == Sink::Comment { c } else { ' ' });
}

/// True for characters that can continue an identifier — used to decide
/// whether `r`/`b` starts a literal prefix or is just part of a name.
fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into its [`Masked`] views.
pub fn mask(src: &str) -> Masked {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code = String::with_capacity(n);
    let mut com = String::with_capacity(n);
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        let c1 = chars.get(i + 1).copied();
        // ---- line comment ------------------------------------------------
        if c == '/' && c1 == Some('/') {
            while i < n && chars[i] != '\n' {
                put(&mut code, &mut com, chars[i], Sink::Comment);
                i += 1;
            }
            continue;
        }
        // ---- block comment (Rust block comments nest) --------------------
        if c == '/' && c1 == Some('*') {
            let mut depth = 0usize;
            while i < n {
                let c = chars[i];
                let c1 = chars.get(i + 1).copied();
                if c == '/' && c1 == Some('*') {
                    depth += 1;
                    put(&mut code, &mut com, '/', Sink::Comment);
                    put(&mut code, &mut com, '*', Sink::Comment);
                    i += 2;
                    continue;
                }
                if c == '*' && c1 == Some('/') {
                    depth -= 1;
                    put(&mut code, &mut com, '*', Sink::Comment);
                    put(&mut code, &mut com, '/', Sink::Comment);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                    continue;
                }
                put(&mut code, &mut com, c, Sink::Comment);
                i += 1;
            }
            continue;
        }
        let prev_ident = i > 0 && is_ident(chars[i - 1]);
        // ---- raw strings: r"…", r#"…"#, br"…", br#"…"# --------------------
        if !prev_ident && (c == 'r' || (c == 'b' && c1 == Some('r'))) {
            let body = if c == 'r' { i + 1 } else { i + 2 };
            let mut j = body;
            while chars.get(j) == Some(&'#') {
                j += 1;
            }
            if chars.get(j) == Some(&'"') {
                let hashes = j - body;
                for k in i..=j {
                    put(&mut code, &mut com, chars[k], Sink::Code);
                }
                i = j + 1;
                while i < n {
                    let ends = chars[i] == '"'
                        && i + hashes < n
                        && chars[i + 1..=i + hashes].iter().all(|&h| h == '#');
                    if ends {
                        for k in i..=i + hashes {
                            put(&mut code, &mut com, chars[k], Sink::Code);
                        }
                        i += 1 + hashes;
                        break;
                    }
                    put(&mut code, &mut com, chars[i], Sink::Neither);
                    i += 1;
                }
                continue;
            }
            // No raw literal after all ("r" / "br" was an identifier or
            // something else): fall through to the generic handling below.
        }
        // ---- plain and byte strings: "…", b"…" ---------------------------
        if c == '"' || (!prev_ident && c == 'b' && c1 == Some('"')) {
            if c == 'b' {
                put(&mut code, &mut com, 'b', Sink::Code);
                i += 1;
            }
            put(&mut code, &mut com, '"', Sink::Code);
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    put(&mut code, &mut com, '\\', Sink::Neither);
                    if i + 1 < n {
                        put(&mut code, &mut com, chars[i + 1], Sink::Neither);
                    }
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    put(&mut code, &mut com, '"', Sink::Code);
                    i += 1;
                    break;
                }
                put(&mut code, &mut com, chars[i], Sink::Neither);
                i += 1;
            }
            continue;
        }
        // ---- char/byte-char literals vs lifetimes ------------------------
        if c == '\'' || (!prev_ident && c == 'b' && c1 == Some('\'')) {
            let q = if c == 'b' { i + 1 } else { i };
            // `'\…'` is always a char literal; `'x'` needs the closing
            // quote two ahead; anything else (`'static`, `'a>`) is a
            // lifetime and only the quote itself is consumed.
            let is_char = match chars.get(q + 1) {
                Some('\\') => true,
                Some(_) => chars.get(q + 2) == Some(&'\''),
                None => false,
            };
            if is_char {
                if c == 'b' {
                    put(&mut code, &mut com, 'b', Sink::Code);
                }
                put(&mut code, &mut com, '\'', Sink::Code);
                i = q + 1;
                while i < n {
                    if chars[i] == '\\' {
                        put(&mut code, &mut com, '\\', Sink::Neither);
                        if i + 1 < n {
                            put(&mut code, &mut com, chars[i + 1], Sink::Neither);
                        }
                        i += 2;
                        continue;
                    }
                    if chars[i] == '\'' {
                        put(&mut code, &mut com, '\'', Sink::Code);
                        i += 1;
                        break;
                    }
                    if chars[i] == '\n' {
                        // Unterminated literal; bail back to code mode.
                        put(&mut code, &mut com, '\n', Sink::Code);
                        i += 1;
                        break;
                    }
                    put(&mut code, &mut com, chars[i], Sink::Neither);
                    i += 1;
                }
                continue;
            }
            // Lifetime (or a lone `b`): emit as code, one char at a time.
            put(&mut code, &mut com, c, Sink::Code);
            i += 1;
            continue;
        }
        put(&mut code, &mut com, c, Sink::Code);
        i += 1;
    }
    let split = |s: &str| -> Vec<String> { s.split('\n').map(str::to_string).collect() };
    let mut code_lines = split(&code);
    let mut com_lines = split(&com);
    // `split('\n')` yields one trailing empty entry when the file ends in a
    // newline; drop it so indices map 1:1 onto `str::lines` numbering.
    if src.ends_with('\n') {
        code_lines.pop();
        com_lines.pop();
    }
    Masked { code: code_lines, comments: com_lines }
}
