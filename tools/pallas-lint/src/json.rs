//! `--format json`: a dependency-free JSON emitter for findings, plus a
//! deliberately small parser so the round-trip (emit → parse → annotate)
//! the CI annotation step performs is covered by tests in-repo rather
//! than only exercised on the runner.

use std::fmt::Write as _;

use crate::Finding;

/// Escape a string for a JSON string literal (quotes, backslash,
/// control characters).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn finding_obj(f: &Finding, new: bool) -> String {
    format!(
        "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"excerpt\":\"{}\",\"detail\":\"{}\",\"new\":{}}}",
        escape(f.rule),
        escape(&f.path),
        f.line,
        escape(&f.excerpt),
        escape(&f.detail),
        new
    )
}

/// Render the full machine-readable report. `new` marks findings over
/// the baseline budget (the ones that fail the run); `stale_allows` are
/// suppressions that no longer suppress anything.
pub fn render(findings: &[(&Finding, bool)], stale_allows: &[Finding]) -> String {
    let mut out = String::from("{\n  \"version\": 1,\n  \"findings\": [\n");
    for (i, (f, new)) in findings.iter().enumerate() {
        let sep = if i + 1 < findings.len() { "," } else { "" };
        let _ = writeln!(out, "    {}{sep}", finding_obj(f, *new));
    }
    out.push_str("  ],\n  \"stale_allows\": [\n");
    for (i, f) in stale_allows.iter().enumerate() {
        let sep = if i + 1 < stale_allows.len() { "," } else { "" };
        let _ = writeln!(out, "    {}{sep}", finding_obj(f, false));
    }
    let new_count = findings.iter().filter(|(_, n)| *n).count();
    let _ = write!(
        out,
        "  ],\n  \"summary\": {{\"total\": {}, \"new\": {}, \"stale_allows\": {}}}\n}}\n",
        findings.len(),
        new_count,
        stale_allows.len()
    );
    out
}

/// A parsed JSON value. Only what the report emits: objects, arrays,
/// strings, integers, booleans, null.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Numbers (integers only in our output; parsed as i64).
    Num(i64),
    /// String literal.
    Str(String),
    /// Array.
    Arr(Vec<Value>),
    /// Object, insertion-ordered.
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Member lookup on objects; `None` elsewhere.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The array items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse a JSON document. Errors carry a byte offset and a short
/// message; the parser accepts exactly the subset [`render`] emits
/// (no floats, no exponents, no `\uXXXX` surrogate pairs beyond BMP).
pub fn parse(text: &str) -> Result<Value, String> {
    let b = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && (b[*pos] as char).is_ascii_whitespace() {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected `{}` at byte {}", c as char, pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'{') => {
            *pos += 1;
            let mut members = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Value::Obj(members));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                expect(b, pos, b':')?;
                let val = parse_value(b, pos)?;
                members.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Value::Obj(members));
                    }
                    _ => return Err(format!("expected `,` or `}}` at byte {pos}")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Value::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Value::Arr(items));
                    }
                    _ => return Err(format!("expected `,` or `]` at byte {pos}")),
                }
            }
        }
        Some(b'"') => Ok(Value::Str(parse_string(b, pos)?)),
        Some(b't') if b[*pos..].starts_with(b"true") => {
            *pos += 4;
            Ok(Value::Bool(true))
        }
        Some(b'f') if b[*pos..].starts_with(b"false") => {
            *pos += 5;
            Ok(Value::Bool(false))
        }
        Some(b'n') if b[*pos..].starts_with(b"null") => {
            *pos += 4;
            Ok(Value::Null)
        }
        Some(c) if c.is_ascii_digit() || *c == b'-' => {
            let start = *pos;
            if b[*pos] == b'-' {
                *pos += 1;
            }
            while *pos < b.len() && b[*pos].is_ascii_digit() {
                *pos += 1;
            }
            let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
            text.parse::<i64>().map(Value::Num).map_err(|e| e.to_string())
        }
        _ => Err(format!("unexpected input at byte {pos}")),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}"));
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or_else(|| format!("bad \\u escape at byte {pos}"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|e| format!("bad \\u escape: {e}"))?;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {pos}")),
                }
                *pos += 1;
            }
            _ => {
                // Copy the full UTF-8 sequence starting here.
                let s = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let c = s.chars().next().ok_or("empty string tail")?;
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    Err("unterminated string".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, line: usize, detail: &str) -> Finding {
        Finding {
            rule,
            path: "rust/src/x.rs".to_string(),
            line,
            excerpt: "let g = s.a.lock().unwrap(); // \"quoted\"".to_string(),
            detail: detail.to_string(),
        }
    }

    #[test]
    fn report_round_trips() {
        let f1 = finding("lock-order-cycle", 3, "locks `b` while holding `a`, closing a cycle");
        let f2 = finding("unwrap-in-library", 9, "");
        let stale = finding("stale-allow", 12, "allow for `unspecified-hasher` suppresses nothing");
        let text = render(&[(&f1, true), (&f2, false)], std::slice::from_ref(&stale));
        let doc = parse(&text).expect("parse");
        let findings = doc.get("findings").and_then(Value::as_arr).expect("findings");
        assert_eq!(findings.len(), 2);
        assert_eq!(findings[0].get("rule").and_then(Value::as_str), Some("lock-order-cycle"));
        assert_eq!(findings[0].get("new"), Some(&Value::Bool(true)));
        assert_eq!(findings[0].get("line"), Some(&Value::Num(3)));
        assert_eq!(
            findings[0].get("excerpt").and_then(Value::as_str),
            Some("let g = s.a.lock().unwrap(); // \"quoted\"")
        );
        assert_eq!(findings[1].get("new"), Some(&Value::Bool(false)));
        let stales = doc.get("stale_allows").and_then(Value::as_arr).expect("stale");
        assert_eq!(stales.len(), 1);
        let summary = doc.get("summary").expect("summary");
        assert_eq!(summary.get("total"), Some(&Value::Num(2)));
        assert_eq!(summary.get("new"), Some(&Value::Num(1)));
        assert_eq!(summary.get("stale_allows"), Some(&Value::Num(1)));
    }

    #[test]
    fn escape_handles_controls_and_quotes() {
        assert_eq!(escape("a\"b\\c\nd\te"), "a\\\"b\\\\c\\nd\\te");
        assert_eq!(escape("\u{1}"), "\\u0001");
        let doc = parse("{\"k\":\"a\\u0041\\\"\"}").expect("parse");
        assert_eq!(doc.get("k").and_then(Value::as_str), Some("aA\""));
    }

    #[test]
    fn empty_report_parses() {
        let text = render(&[], &[]);
        let doc = parse(&text).expect("parse");
        assert_eq!(doc.get("findings").and_then(Value::as_arr).map(<[Value]>::len), Some(0));
        assert_eq!(doc.get("summary").and_then(|s| s.get("total")), Some(&Value::Num(0)));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("[1,2").is_err());
        assert!(parse("{} trailing").is_err());
    }
}
