//! Phase-1 fact extraction: one pass over a file's masked code view,
//! producing the per-file facts the cross-file rules (phase 2,
//! [`crate::crossfile`]) join across the tree.
//!
//! Facts are extracted for **library** files only (`rust/src/`, minus the
//! file-final `#[cfg(test)]` region): the four concurrency rules built on
//! them guard the shipping runtime, not test scaffolding. Extraction is
//! line-oriented and deliberately conservative — a fact the heuristics
//! cannot attribute (a multi-line call split across lines, a receiver too
//! complex to name) is *dropped*, never guessed, so phase 2 under-reports
//! rather than inventing cross-file joins.
//!
//! The facts:
//!
//! * **mutex/atomic field declarations** — `name: Mutex<…>` /
//!   `name: Atomic…` struct fields and `static NAME: Atomic…` items.
//!   Declarations are the join key for cross-file identity: a field name
//!   declared in exactly one file names the same lock/atomic everywhere
//!   (see [`crate::crossfile`]).
//! * **lock edges** — for every lock acquisition (`recv.lock()` or the
//!   serve-style `lock(&recv)` helper) made while a tracked guard is
//!   live: a directed held-while-acquiring edge. Guard liveness reuses
//!   the `guard-across-notify` machinery (brace depth + `drop(name)`),
//!   but a binding only counts as a *guard* when the lock call is the
//!   whole right-hand side (modulo `.unwrap()`/`.expect(…)`-style
//!   adapters) — `let v = lock(&m).get(k).cloned();` holds the guard for
//!   one statement only and must not poison the rest of the function.
//! * **atomic uses** — every `Ordering::X` argument attributed to the
//!   atomic method call and receiver field it appears in.
//! * **pool-task regions** — the argument extents of `pool.spawn(…)` and
//!   `.mapper(…)` calls (the two ways closures are shipped onto the
//!   shared [`WorkerPool`](../../../rust/src/util/pool.rs)), plus any
//!   blocking call inside them.
//! * **stats structs and handler fns** — counter fields of `*Stats*`
//!   structs, and the body extent of every named function, for the
//!   `counter-drift` mention scan.

use std::collections::{BTreeMap, BTreeSet};

use crate::lexer::Masked;

/// A directed held-while-acquiring edge: the guard of `held` was live on
/// the line where `acquired` was locked.
#[derive(Debug, Clone)]
pub struct LockEdge {
    /// Field name of the mutex whose guard was held.
    pub held: String,
    /// Field name of the mutex being acquired.
    pub acquired: String,
    /// 0-based line of the acquisition.
    pub line: usize,
}

/// One `Ordering::X` use attributed to an atomic field.
#[derive(Debug, Clone)]
pub struct AtomicUse {
    /// Receiver field (or static) name of the atomic.
    pub field: String,
    /// The ordering name (`Relaxed`, `Acquire`, …, `SeqCst`).
    pub ordering: String,
    /// 0-based line of the call.
    pub line: usize,
}

/// A blocking call inside a pool-task closure region.
#[derive(Debug, Clone)]
pub struct PoolBlocking {
    /// 0-based line of the blocking call.
    pub line: usize,
    /// The pattern that matched (e.g. `.recv()`).
    pub what: &'static str,
}

/// The body extent of one named function.
#[derive(Debug, Clone)]
pub struct FnRegion {
    /// The function's name.
    pub name: String,
    /// 0-based line of the `fn` keyword.
    pub decl_line: usize,
    /// 0-based first line of the body (the line carrying its `{`).
    pub start: usize,
    /// 0-based last line of the body.
    pub end: usize,
}

/// Everything phase 1 learned about one file.
#[derive(Debug, Default)]
pub struct FileFacts {
    /// Mutex-typed struct fields: name → 0-based declaration line.
    pub mutex_decls: BTreeMap<String, usize>,
    /// Atomic-typed fields/statics: name → (decl line, owning struct).
    pub atomic_decls: BTreeMap<String, (usize, Option<String>)>,
    /// Structs that declare a `Condvar` field (their atomics gate a
    /// handshake; `Relaxed` on those is a finding).
    pub condvar_structs: BTreeSet<String>,
    /// Held-while-acquiring edges observed in this file.
    pub lock_edges: Vec<LockEdge>,
    /// `Ordering::X` uses attributed to atomic fields.
    pub atomic_uses: Vec<AtomicUse>,
    /// Blocking calls inside pool-task closure regions.
    pub pool_blocking: Vec<PoolBlocking>,
    /// `*Stats*` structs: name → counter field names, in declaration order.
    pub stats_structs: BTreeMap<String, Vec<String>>,
    /// Named function body extents (innermost wins for nested items).
    pub fns: Vec<FnRegion>,
}

/// Atomic methods whose `Ordering` arguments we attribute; anything else
/// carrying an `Ordering::` token (e.g. `cmp::Ordering` in a `sort_by`)
/// is ignored.
const ATOMIC_OPS: [&str; 14] = [
    "load", "store", "swap", "fetch_add", "fetch_sub", "fetch_and", "fetch_or", "fetch_xor",
    "fetch_max", "fetch_min", "fetch_update", "fetch_nand", "compare_exchange",
    "compare_exchange_weak",
];

/// The five memory orderings.
const ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Calls that park the calling thread. Inside a closure that runs ON the
/// shared pool, any of these can deadlock the pool's own budget
/// (DESIGN.md §12 — the serve incident class).
pub(crate) const BLOCKING: [&str; 10] = [
    ".lock()",
    ".recv()",
    ".recv_timeout(",
    ".wait(",
    ".join()",
    "read_line(",
    "read_exact(",
    "read_to_end(",
    "read_to_string(",
    ".accept()",
];

fn ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// The last field-name segment of the receiver chain ending at byte
/// `end` (exclusive): `self.core.by_algorithm[i]` → `by_algorithm`,
/// `LEVEL` → `LEVEL`. `self` alone yields nothing.
fn chain_field_before(l: &[u8], end: usize) -> Option<String> {
    let mut start = end;
    let mut depth = 0i32;
    while start > 0 {
        let b = l[start - 1];
        if b == b']' {
            depth += 1;
            start -= 1;
        } else if b == b'[' {
            depth -= 1;
            start -= 1;
        } else if depth > 0 {
            start -= 1;
        } else if ident(b) || b == b'.' {
            start -= 1;
        } else {
            break;
        }
    }
    last_field_of(&l[start..end])
}

/// The last identifier segment of `chain`, with `[…]` index groups
/// removed and `self` skipped.
fn last_field_of(chain: &[u8]) -> Option<String> {
    let mut segs: Vec<String> = Vec::new();
    let mut cur = String::new();
    let mut depth = 0i32;
    for &b in chain {
        match b {
            b'[' => depth += 1,
            b']' => depth -= 1,
            _ if depth > 0 => {}
            _ if ident(b) => cur.push(b as char),
            _ => {
                if !cur.is_empty() {
                    segs.push(std::mem::take(&mut cur));
                }
            }
        }
    }
    if !cur.is_empty() {
        segs.push(cur);
    }
    segs.into_iter().rev().find(|s| s != "self" && !s.chars().next().is_some_and(|c| c.is_ascii_digit()))
}

/// The method + receiver field of the innermost call still open at byte
/// `pos` of `l`: for `x.load(Ordering::SeqCst)` with `pos` at `O`,
/// returns `("load", Some("x"))`. `None` when no call is open on this
/// line (conservative: multi-line calls are not attributed).
fn innermost_call(l: &[u8], pos: usize) -> Option<(String, Option<String>)> {
    let mut depth = 0i32;
    let mut i = pos;
    while i > 0 {
        let b = l[i - 1];
        if b == b')' {
            depth += 1;
        } else if b == b'(' {
            if depth == 0 {
                let j = i - 1;
                let mut k = j;
                while k > 0 && ident(l[k - 1]) {
                    k -= 1;
                }
                let method = String::from_utf8_lossy(&l[k..j]).into_owned();
                let recv = if k > 0 && l[k - 1] == b'.' {
                    chain_field_before(l, k - 1)
                } else {
                    None
                };
                return Some((method, recv));
            }
            depth -= 1;
        }
        i -= 1;
    }
    None
}

/// Whether the tail of a line after a lock call is only guard-preserving
/// adapters — `.unwrap()`, `.expect(…)`,
/// `.unwrap_or_else(PoisonError::into_inner)`, `?` — ending the
/// statement. Anything else (`.get(…)`, a deref, an operator) means the
/// guard is a statement-scoped temporary, not a binding.
fn only_adapters(mut rest: &str) -> bool {
    loop {
        rest = rest.trim_start();
        if let Some(r) = rest.strip_prefix(".unwrap()") {
            rest = r;
        } else if let Some(r) = rest.strip_prefix(".unwrap_or_else(PoisonError::into_inner)") {
            rest = r;
        } else if let Some(r) = rest.strip_prefix(".expect(") {
            // The argument is a (masked) string literal: skip to the
            // closing paren, rejecting nested parens.
            match r.find(')') {
                Some(close) if !r[..close].contains('(') => rest = &r[close + 1..],
                _ => return false,
            }
        } else if let Some(r) = rest.strip_prefix('?') {
            rest = r;
        } else {
            break;
        }
    }
    let rest = rest.trim();
    rest.is_empty() || rest == ";"
}

/// First `let ` keyword position (identifier boundary on the left).
fn find_let(l: &str) -> Option<usize> {
    l.match_indices("let ")
        .map(|(pos, _)| pos)
        .find(|&pos| pos == 0 || !ident(l.as_bytes()[pos - 1]))
}

/// Parse a struct-field declaration line: optional `pub`/`pub(…)`, an
/// identifier, `:`, the type text (trailing comma stripped).
fn field_decl(line: &str) -> Option<(String, String)> {
    let mut t = line.trim_start();
    if let Some(r) = t.strip_prefix("pub") {
        if let Some(r2) = r.strip_prefix('(') {
            t = r2.split_once(')')?.1.trim_start();
        } else if r.starts_with(char::is_whitespace) {
            t = r.trim_start();
        }
        // `pubX…` falls through with t unchanged: not a visibility.
    }
    let bytes = t.as_bytes();
    let mut k = 0;
    while k < bytes.len() && ident(bytes[k]) {
        k += 1;
    }
    if k == 0 || bytes[0].is_ascii_digit() {
        return None;
    }
    let name = &t[..k];
    let rest = t[k..].trim_start();
    let ty = rest.strip_prefix(':')?.trim();
    let ty = ty.strip_suffix(',').unwrap_or(ty).trim();
    if ty.is_empty() {
        return None;
    }
    Some((name.to_string(), ty.to_string()))
}

/// Strip `std::sync::` / `atomic::` qualification prefixes from a type.
fn unqualify(ty: &str) -> String {
    ty.replace("std::sync::", "").replace("atomic::", "")
}

/// Whether `ty` (unqualified) is a countable stats field: an unsigned
/// counter, an atomic counter, or a fixed array of either.
fn counter_type(ty: &str) -> bool {
    let scalar = |t: &str| {
        matches!(t, "u64" | "usize" | "u32" | "AtomicU64" | "AtomicUsize" | "AtomicU32")
    };
    if scalar(ty) {
        return true;
    }
    if let Some(inner) = ty.strip_prefix('[') {
        if let Some((elem, _)) = inner.split_once(';') {
            return scalar(elem.trim());
        }
    }
    false
}

/// The identifier immediately after keyword `kw ` in `l`, if any.
fn ident_after_kw(l: &str, kw: &str) -> Option<(String, usize)> {
    let pat = format!("{kw} ");
    for (pos, _) in l.match_indices(&pat) {
        if pos > 0 && ident(l.as_bytes()[pos - 1]) {
            continue;
        }
        let rest = &l[pos + pat.len()..];
        let rest_trim = rest.trim_start();
        let bytes = rest_trim.as_bytes();
        let mut k = 0;
        while k < bytes.len() && ident(bytes[k]) {
            k += 1;
        }
        if k > 0 && !bytes[0].is_ascii_digit() {
            return Some((rest_trim[..k].to_string(), pos));
        }
    }
    None
}

/// A live guard: the bound name (for `drop(name)` release), the mutex
/// field it guards, and the brace depth the binding lives at.
struct LiveGuard {
    name: Option<String>,
    field: String,
    depth: i64,
}

/// Extract facts from one file's masked view. Non-library files yield
/// empty facts; the file-final `#[cfg(test)]` region is skipped.
pub fn extract(rel: &str, masked: &Masked) -> FileFacts {
    let mut facts = FileFacts::default();
    if !rel.starts_with("rust/src/") {
        return facts;
    }
    let code = &masked.code;
    let test_from =
        code.iter().position(|l| l.contains("#[cfg(test)]")).unwrap_or(usize::MAX);

    let mut depth: i64 = 0;
    // (struct name, body depth)
    let mut struct_stack: Vec<(String, i64)> = Vec::new();
    // (fn name, decl line, body start line, body depth)
    let mut fn_stack: Vec<(String, usize, usize, i64)> = Vec::new();
    let mut guards: Vec<LiveGuard> = Vec::new();
    let mut pending_struct: Option<String> = None;
    let mut pending_fn: Option<(String, usize)> = None;
    // Open pool-task region: unclosed paren count.
    let mut pool_paren: i64 = 0;
    let mut in_pool = false;

    for (i, l) in code.iter().enumerate() {
        if i >= test_from {
            break;
        }
        if let Some((name, _)) = ident_after_kw(l, "struct") {
            pending_struct = Some(name);
        }
        if let Some((name, _)) = ident_after_kw(l, "fn") {
            pending_fn = Some((name, i));
        }
        let opens = l.matches('{').count() as i64;
        let closes = l.matches('}').count() as i64;

        if pending_struct.is_some() {
            if l.contains('{') {
                struct_stack.push((pending_struct.take().expect("checked"), depth + 1));
            } else if l.contains(';') {
                pending_struct = None; // unit / tuple struct
            }
        }
        if let Some((name, decl)) = pending_fn.clone() {
            if l.contains('{') {
                fn_stack.push((name, decl, i, depth + 1));
                pending_fn = None;
            } else if l.contains(';') {
                pending_fn = None; // trait method signature
            }
        }

        // ---- field declarations inside a struct body --------------------
        if let Some((sname, sdepth)) = struct_stack.last() {
            if depth >= *sdepth && !l.contains("fn ") {
                if let Some((fname, ftype)) = field_decl(l) {
                    let base = unqualify(&ftype);
                    if base.starts_with("Mutex<") {
                        facts.mutex_decls.entry(fname.clone()).or_insert(i);
                    }
                    if base.starts_with("Condvar") {
                        facts.condvar_structs.insert(sname.clone());
                    }
                    let atomicish = base.starts_with("Atomic")
                        || base.trim_start_matches('[').trim_start().starts_with("Atomic");
                    if atomicish {
                        facts
                            .atomic_decls
                            .entry(fname.clone())
                            .or_insert((i, Some(sname.clone())));
                    }
                    if sname.contains("Stats") && counter_type(&base) {
                        facts.stats_structs.entry(sname.clone()).or_default().push(fname);
                    }
                }
            }
        }
        // ---- static atomics ---------------------------------------------
        if let Some((name, _)) = ident_after_kw(l, "static") {
            let after = l.split_once(&name).map(|x| x.1).unwrap_or("");
            if unqualify(after.trim_start().trim_start_matches(':').trim_start())
                .starts_with("Atomic")
            {
                facts.atomic_decls.entry(name).or_insert((i, None));
            }
        }

        // ---- lock acquisitions + guard tracking -------------------------
        guards.retain(|g| match &g.name {
            Some(nm) => !l.contains(&format!("drop({nm})")),
            None => true,
        });
        let bytes = l.as_bytes();
        // (field, call start byte, call end byte)
        let mut acqs: Vec<(String, usize, usize)> = Vec::new();
        for (pos, _) in l.match_indices(".lock()") {
            if let Some(field) = chain_field_before(bytes, pos) {
                acqs.push((field, pos, pos + ".lock()".len()));
            }
        }
        for (pos, _) in l.match_indices("lock(") {
            // The free-function form only: reject `.lock(` (method,
            // handled above) and `unlock(`-style suffix matches.
            if pos > 0 && (ident(bytes[pos - 1]) || bytes[pos - 1] == b'.') {
                continue;
            }
            let mut k = pos + "lock(".len();
            while k < bytes.len() && (bytes[k] == b'&' || bytes[k] == b' ') {
                k += 1;
            }
            let arg_start = k;
            let mut bdepth = 0i32;
            while k < bytes.len() {
                let b = bytes[k];
                if b == b'[' {
                    bdepth += 1;
                } else if b == b']' {
                    bdepth -= 1;
                } else if bdepth == 0 && !(ident(b) || b == b'.') {
                    break;
                }
                k += 1;
            }
            let Some(field) = last_field_of(&bytes[arg_start..k]) else { continue };
            // End of the lock(...) call, for the adapter check.
            let mut pd = 1i32;
            let mut e = pos + "lock(".len();
            while e < bytes.len() && pd > 0 {
                if bytes[e] == b'(' {
                    pd += 1;
                } else if bytes[e] == b')' {
                    pd -= 1;
                }
                e += 1;
            }
            acqs.push((field, pos, e));
        }
        acqs.sort_by_key(|a| a.1);
        for (field, _, _) in &acqs {
            for g in &guards {
                facts.lock_edges.push(LockEdge {
                    held: g.field.clone(),
                    acquired: field.clone(),
                    line: i,
                });
            }
        }
        // A binding is a guard only when the lock call IS the right-hand
        // side (modulo adapters): `let g = recv.lock();`.
        if let Some(letpos) = find_let(l) {
            if let Some(eqoff) = l[letpos..].find('=') {
                let mut name = l[letpos + 4..letpos + eqoff].trim();
                name = name.strip_prefix("mut ").unwrap_or(name).trim();
                let plain = !name.is_empty()
                    && name.bytes().all(ident)
                    && !name.as_bytes()[0].is_ascii_digit();
                let mut rhs_start = letpos + eqoff + 1;
                while rhs_start < bytes.len() && bytes[rhs_start].is_ascii_whitespace() {
                    rhs_start += 1;
                }
                if plain && name != "_" {
                    for (field, pos, endpos) in &acqs {
                        // Walk back over the receiver chain to where the
                        // acquisition expression starts.
                        let mut cs = *pos;
                        while cs > 0
                            && (ident(bytes[cs - 1]) || b".[]&*".contains(&bytes[cs - 1]))
                        {
                            cs -= 1;
                        }
                        if cs <= rhs_start && rhs_start <= *pos {
                            if only_adapters(&l[*endpos..]) {
                                let before = &l[..*pos];
                                let bind_depth = depth + before.matches('{').count() as i64
                                    - before.matches('}').count() as i64;
                                guards.push(LiveGuard {
                                    name: Some(name.to_string()),
                                    field: field.clone(),
                                    depth: bind_depth,
                                });
                            }
                            break;
                        }
                    }
                }
            }
        }

        // ---- atomic ordering uses ---------------------------------------
        for (pos, _) in l.match_indices("Ordering::") {
            let rest = &l[pos + "Ordering::".len()..];
            let rb = rest.as_bytes();
            let mut k = 0;
            while k < rb.len() && ident(rb[k]) {
                k += 1;
            }
            let oname = &rest[..k];
            if !ORDERINGS.contains(&oname) {
                continue;
            }
            if let Some((method, Some(recv))) = innermost_call(bytes, pos) {
                if ATOMIC_OPS.contains(&method.as_str()) {
                    facts.atomic_uses.push(AtomicUse {
                        field: recv,
                        ordering: oname.to_string(),
                        line: i,
                    });
                }
            }
        }

        // ---- pool-task closure regions ----------------------------------
        let scan_blocking = |facts: &mut FileFacts, seg: &str, line: usize| {
            for pat in BLOCKING {
                if seg.contains(pat) {
                    facts.pool_blocking.push(PoolBlocking { line, what: pat });
                    break;
                }
            }
        };
        if !in_pool {
            let trigger = l
                .match_indices("pool.spawn(")
                .map(|(p, _)| (p, p + "pool.spawn(".len()))
                .find(|&(p, _)| p == 0 || !ident(bytes[p - 1]))
                .or_else(|| {
                    l.match_indices(".mapper(").map(|(p, _)| (p, p + ".mapper(".len())).next()
                });
            if let Some((_, after_paren)) = trigger {
                pool_paren = 1;
                let mut endcol = l.len();
                for (off, b) in l[after_paren..].bytes().enumerate() {
                    if b == b'(' {
                        pool_paren += 1;
                    } else if b == b')' {
                        pool_paren -= 1;
                        if pool_paren == 0 {
                            endcol = after_paren + off;
                            break;
                        }
                    }
                }
                scan_blocking(&mut facts, &l[after_paren..endcol], i);
                in_pool = pool_paren > 0;
            }
        } else {
            let mut endcol = l.len();
            for (off, b) in l.bytes().enumerate() {
                if b == b'(' {
                    pool_paren += 1;
                } else if b == b')' {
                    pool_paren -= 1;
                    if pool_paren == 0 {
                        endcol = off;
                        break;
                    }
                }
            }
            scan_blocking(&mut facts, &l[..endcol], i);
            in_pool = pool_paren > 0;
        }

        // ---- scope bookkeeping ------------------------------------------
        depth += opens - closes;
        guards.retain(|g| g.depth <= depth);
        while struct_stack.last().is_some_and(|(_, d)| depth < *d) {
            struct_stack.pop();
        }
        while fn_stack.last().is_some_and(|(_, _, _, d)| depth < *d) {
            let (name, decl, start, _) = fn_stack.pop().expect("checked");
            facts.fns.push(FnRegion { name, decl_line: decl, start, end: i });
        }
    }
    let eof = test_from.min(code.len()).saturating_sub(1);
    while let Some((name, decl, start, _)) = fn_stack.pop() {
        facts.fns.push(FnRegion { name, decl_line: decl, start, end: eof });
    }
    facts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::mask;

    fn facts_of(src: &str) -> FileFacts {
        extract("rust/src/fake.rs", &mask(src))
    }

    #[test]
    fn declarations_are_collected() {
        let f = facts_of(
            "pub struct Shared {\n\
             \x20   queue: Mutex<Vec<u8>>,\n\
             \x20   ready: Condvar,\n\
             \x20   done: AtomicBool,\n\
             }\n\
             static LEVEL: std::sync::atomic::AtomicU8 = AtomicU8::new(0);\n",
        );
        assert_eq!(f.mutex_decls.get("queue"), Some(&1));
        assert!(f.condvar_structs.contains("Shared"));
        assert_eq!(f.atomic_decls.get("done").map(|d| d.1.clone()), Some(Some("Shared".into())));
        assert_eq!(f.atomic_decls.get("LEVEL").map(|d| d.1.clone()), Some(None));
    }

    #[test]
    fn held_while_acquiring_makes_an_edge() {
        let f = facts_of(
            "fn f(s: &S) {\n\
             \x20   let a = s.first.lock().unwrap();\n\
             \x20   let b = s.second.lock().unwrap();\n\
             }\n",
        );
        assert_eq!(f.lock_edges.len(), 1);
        assert_eq!(f.lock_edges[0].held, "first");
        assert_eq!(f.lock_edges[0].acquired, "second");
        assert_eq!(f.lock_edges[0].line, 2);
    }

    #[test]
    fn statement_temporaries_are_not_guards() {
        // The PR 9 serve shape: `let held = lock(&m).get(k).map(clone);`
        // drops the guard at the semicolon — re-locking later is fine.
        let f = facts_of(
            "fn f(s: &S) {\n\
             \x20   let held = lock(&s.follows).get(&k).map(Arc::clone);\n\
             \x20   lock(&s.follows).insert(k, v);\n\
             }\n",
        );
        assert!(f.lock_edges.is_empty(), "{:?}", f.lock_edges);
    }

    #[test]
    fn drop_and_scope_release_guards() {
        let f = facts_of(
            "fn f(s: &S) {\n\
             \x20   let a = s.first.lock().unwrap();\n\
             \x20   drop(a);\n\
             \x20   let b = s.second.lock().unwrap();\n\
             \x20   { let c = s.third.lock().unwrap(); }\n\
             \x20   let d = s.fourth.lock().unwrap();\n\
             }\n",
        );
        // b→third (b live at c's bind), b→fourth (c died with its block).
        let pairs: Vec<(String, String)> =
            f.lock_edges.iter().map(|e| (e.held.clone(), e.acquired.clone())).collect();
        assert_eq!(
            pairs,
            vec![
                ("second".to_string(), "third".to_string()),
                ("second".to_string(), "fourth".to_string()),
            ]
        );
    }

    #[test]
    fn atomic_uses_attribute_receiver_and_ordering() {
        let f = facts_of(
            "fn f(s: &S) {\n\
             \x20   s.flag.store(true, Ordering::SeqCst);\n\
             \x20   let v = s.by_algo[i].load(Ordering::Relaxed);\n\
             \x20   xs.sort_by(|a, b| match a.partial_cmp(b) { Some(Ordering::Less) => 1, _ => 0 });\n\
             }\n",
        );
        let got: Vec<(String, String)> =
            f.atomic_uses.iter().map(|u| (u.field.clone(), u.ordering.clone())).collect();
        assert_eq!(
            got,
            vec![
                ("flag".to_string(), "SeqCst".to_string()),
                ("by_algo".to_string(), "Relaxed".to_string()),
            ]
        );
    }

    #[test]
    fn pool_regions_catch_blocking_calls() {
        let f = facts_of(
            "fn f(&self) {\n\
             \x20   self.pool.spawn(move || {\n\
             \x20       let g = self.state.lock().unwrap();\n\
             \x20       tx.send(1).ok();\n\
             \x20   });\n\
             \x20   let fine = rx.recv();\n\
             }\n",
        );
        assert_eq!(f.pool_blocking.len(), 1);
        assert_eq!(f.pool_blocking[0].line, 2);
        assert_eq!(f.pool_blocking[0].what, ".lock()");
    }

    #[test]
    fn stats_structs_and_fn_regions() {
        let f = facts_of(
            "pub struct FooStats {\n\
             \x20   pub hits: u64,\n\
             \x20   pub misses: u64,\n\
             \x20   pub label: String,\n\
             }\n\
             impl FooStats {\n\
             \x20   pub fn absorb(&mut self, o: &FooStats) {\n\
             \x20       self.hits += o.hits;\n\
             \x20   }\n\
             }\n",
        );
        assert_eq!(
            f.stats_structs.get("FooStats"),
            Some(&vec!["hits".to_string(), "misses".to_string()])
        );
        let absorb = f.fns.iter().find(|r| r.name == "absorb").expect("fn region");
        assert_eq!(absorb.decl_line, 6);
        assert!(absorb.start <= 6 && absorb.end >= 7);
    }
}
