//! The linter's reason to exist: the repo itself must be clean against
//! the checked-in baseline. This is the same check tier-1 CI enforces via
//! `cargo run -p pallas-lint`; running it as a test means `cargo test`
//! alone catches a violation before CI does.

use std::path::Path;

use pallas_lint::{baseline, default_baseline, lint_tree};

#[test]
fn repo_is_clean_against_checked_in_baseline() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let findings = lint_tree(&root).expect("scanning the repo");
    let baseline_path = default_baseline(&root);
    let text = std::fs::read_to_string(&baseline_path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", baseline_path.display()));
    let base = baseline::parse(&text).expect("checked-in baseline parses");

    let drift = baseline::compare(&findings, &base);
    // Staleness (an entry above the live count) is a warning, not a
    // failure: deleting grandfathered code must never break the build.
    // The CLI and the CI artifact surface it for the next ratchet-down.
    for (key, budget, actual) in &drift.stale {
        eprintln!("stale baseline entry: {key:?} baselined {budget}, live {actual}");
    }
    assert!(
        drift.new.is_empty(),
        "new lint findings above the baseline (fix, or suppress with a reasoned \
         `// lint:allow(<rule>): <reason>` — see DESIGN.md §10):\n{}",
        drift
            .new
            .iter()
            .map(|f| format!("  {f}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn grandfathered_rules_match_known_magnitudes() {
    // The baseline exists for exactly one rule today: unwrap-in-library.
    // The determinism/concurrency rules must be CLEAN — a baseline entry
    // appearing for one of them means a real invariant violation was
    // grandfathered instead of fixed, which defeats the tool.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let findings = lint_tree(&root).expect("scanning the repo");
    for f in &findings {
        assert_eq!(
            f.rule, "unwrap-in-library",
            "only unwrap-in-library findings may exist in-tree (suppress deliberate \
             cases inline with a reason): {f}"
        );
    }
}
