//! The linter's reason to exist: the repo itself must be clean against
//! the checked-in baseline. This is the same check tier-1 CI enforces via
//! `cargo run -p pallas-lint`; running it as a test means `cargo test`
//! alone catches a violation before CI does.

use std::path::Path;

use pallas_lint::{baseline, default_baseline, lint_tree, lint_tree_full};

#[test]
fn repo_is_clean_against_checked_in_baseline() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let findings = lint_tree(&root).expect("scanning the repo");
    let baseline_path = default_baseline(&root);
    let text = std::fs::read_to_string(&baseline_path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", baseline_path.display()));
    let base = baseline::parse(&text).expect("checked-in baseline parses");

    let drift = baseline::compare(&findings, &base);
    // Staleness (an entry above the live count) is a warning, not a
    // failure: deleting grandfathered code must never break the build.
    // The CLI and the CI artifact surface it for the next ratchet-down.
    for (key, budget, actual) in &drift.stale {
        eprintln!("stale baseline entry: {key:?} baselined {budget}, live {actual}");
    }
    assert!(
        drift.new.is_empty(),
        "new lint findings above the baseline (fix, or suppress with a reasoned \
         `// lint:allow(<rule>): <reason>` — see DESIGN.md §10):\n{}",
        drift
            .new
            .iter()
            .map(|f| format!("  {f}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn grandfathered_rules_match_known_magnitudes() {
    // The baseline exists for exactly one rule today: unwrap-in-library.
    // The determinism/concurrency rules — the original four AND the
    // cross-file four (lock-order-cycle, atomic-ordering-mix,
    // blocking-in-pool-task, counter-drift) — must be CLEAN: a finding or
    // baseline entry appearing for one of them means a real invariant
    // violation was grandfathered instead of fixed, which defeats the tool.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let findings = lint_tree(&root).expect("scanning the repo");
    for f in &findings {
        assert_eq!(
            f.rule, "unwrap-in-library",
            "only unwrap-in-library findings may exist in-tree (suppress deliberate \
             cases inline with a reason): {f}"
        );
    }
}

#[test]
fn baseline_grandfathers_unwrap_only() {
    // Belt to the test above's suspenders: even editing baseline.txt by
    // hand cannot grandfather a determinism or concurrency rule — the
    // file itself must never carry a non-unwrap key.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let baseline_path = default_baseline(&root);
    let text = std::fs::read_to_string(&baseline_path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", baseline_path.display()));
    let base = baseline::parse(&text).expect("checked-in baseline parses");
    for ((rule, path), _) in &base {
        assert_eq!(
            rule, "unwrap-in-library",
            "baseline.txt may only grandfather unwrap-in-library (offending key: \
             {rule} {path})"
        );
    }
}

#[test]
fn repo_has_no_stale_allows() {
    // Every lint:allow in the tree must suppress something. CI runs with
    // --strict-allows, so a stale suppression fails there too; catching
    // it under plain `cargo test` keeps the loop short.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let tree = lint_tree_full(&root).expect("scanning the repo");
    assert!(
        tree.stale_allows.is_empty(),
        "stale lint:allow directives (delete them or fix the rule name):\n{}",
        tree.stale_allows
            .iter()
            .map(|f| format!("  {f}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
