// Fixture: three suppressions. The first targets a line that triggers
// nothing, the second names a rule that does not exist, and the third
// genuinely suppresses a finding (and so is NOT stale).
pub fn quiet() -> u64 {
    // lint:allow(unspecified-hasher): nothing hashes here
    let x = 1 + 1;
    // lint:allow(no-such-rule): typo'd rule name
    let y = x + 1;
    // lint:allow(unwrap-in-library): fixture exercises a live suppression
    let z = maybe(y).unwrap();
    z
}
