// Fixture: consistent orderings. `flag` is SeqCst everywhere; `ready`
// uses a Release store / Acquire load pair, which is a coherent
// protocol even though the two orderings differ.
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

pub struct Core {
    flag: AtomicU64,
}

pub struct Gate {
    ready: AtomicBool,
    cv: Condvar,
    slot: Mutex<u32>,
}

pub fn raise(c: &Core) {
    c.flag.store(1, Ordering::SeqCst);
}

pub fn read(c: &Core) -> u64 {
    c.flag.load(Ordering::SeqCst)
}

pub fn open(g: &Gate) {
    g.ready.store(true, Ordering::Release);
    g.cv.notify_all();
}

pub fn opened(g: &Gate) -> bool {
    g.ready.load(Ordering::Acquire)
}
