// Fixture: unspecified-hasher must fire on both std names in code.
use std::collections::hash_map::{DefaultHasher, RandomState};
use std::hash::{BuildHasher, Hasher};

pub fn digest(bytes: &[u8]) -> u64 {
    let mut h = DefaultHasher::new();
    h.write(bytes);
    h.finish()
}

pub fn keyed(bytes: &[u8]) -> u64 {
    let s = RandomState::new();
    let mut h = s.build_hasher();
    h.write(bytes);
    h.finish()
}
