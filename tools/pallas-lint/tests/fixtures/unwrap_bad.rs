// Fixture: panicking shortcuts in library code. `unwrap_or` variants and
// `expect_err` must NOT fire — only the panicking three.
pub fn brittle(input: Option<u32>, text: &str) -> u32 {
    let n = input.unwrap();
    let m: u32 = text.parse().expect("a number");
    if n + m == 0 {
        panic!("zero");
    }
    let _soft = input.unwrap_or(0);
    let _soft2 = input.unwrap_or_else(|| 1);
    let _soft3: Result<u32, u32> = Err(3);
    n + m
}
