// Fixture: raw thread creation outside the pool must fire, for both the
// free function and the Builder route.
pub fn scatter(n: usize) {
    for _ in 0..n {
        std::thread::spawn(|| {});
    }
    let _ = std::thread::Builder::new().name("rogue".into()).spawn(|| {});
}
