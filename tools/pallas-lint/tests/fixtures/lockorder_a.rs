// Fixture (cross-file pair, 1 of 2): declares both mutexes and takes
// `reg` before `disp`. Alone this file is cycle-free; joined with
// lockorder_b.rs (which takes `disp` before `reg`) the global identities
// close the cycle and phase 2 fires in both files.
use std::sync::Mutex;

pub struct Center {
    pub reg: Mutex<u32>,
    pub disp: Mutex<u32>,
}

pub fn forward(c: &Center) {
    let gr = c.reg.lock().unwrap();
    let gd = c.disp.lock().unwrap();
    drop(gd);
    drop(gr);
}
