// Fixture: the incremental subsystem is ordinary library code — both the
// wall-clock and the panicking-shortcut rules must cover it with no
// carve-outs (delta refreshes never read host time; fallbacks surface as
// errors, they don't panic).
use std::time::Instant;

pub fn refresh_latency(prior: Option<u64>) -> f64 {
    let t0 = Instant::now();
    let _rev = prior.unwrap();
    t0.elapsed().as_secs_f64()
}
