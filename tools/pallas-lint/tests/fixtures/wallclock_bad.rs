// Fixture: wall-clock reads in library code must fire.
use std::time::{Instant, SystemTime};

pub fn simulated_phase_time() -> f64 {
    let t0 = Instant::now();
    let _stamp = SystemTime::now();
    t0.elapsed().as_secs_f64()
}
