// Fixture: AB/BA lock-order inversion inside one file. `first` locks
// `a` then `b`; `second` locks `b` then `a` — the held-while-acquiring
// graph has the cycle a -> b -> a, so both inner acquisitions fire.
use std::sync::Mutex;

pub struct Shared {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

pub fn first(s: &Shared) {
    let ga = s.a.lock().unwrap();
    let gb = s.b.lock().unwrap();
    drop(gb);
    drop(ga);
}

pub fn second(s: &Shared) {
    let gb = s.b.lock().unwrap();
    let ga = s.a.lock().unwrap();
    drop(ga);
    drop(gb);
}
