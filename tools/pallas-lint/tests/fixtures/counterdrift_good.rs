// Fixture: `absorb` folds every counter, and `failed_total` touching a
// single field is an accessor — below the fold threshold, not drift.
pub struct MineStats {
    pub started: u64,
    pub finished: u64,
    pub failed: u64,
    pub retried: u64,
}

impl MineStats {
    pub fn absorb(&mut self, other: &MineStats) {
        self.started += other.started;
        self.finished += other.finished;
        self.failed += other.failed;
        self.retried += other.retried;
    }

    pub fn failed_total(&self) -> u64 {
        self.failed
    }
}
