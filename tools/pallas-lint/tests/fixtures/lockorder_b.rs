// Fixture (cross-file pair, 2 of 2): takes `disp` before `reg`. The
// fields are declared in lockorder_a.rs only, so linted alone this
// file's names stay file-local and no cycle exists; the AB/BA deadlock
// appears only when both files are analyzed together.
use crate::lockorder_a::Center;

pub fn backward(c: &Center) {
    let gd = c.disp.lock().unwrap();
    let gr = c.reg.lock().unwrap();
    drop(gr);
    drop(gd);
}
