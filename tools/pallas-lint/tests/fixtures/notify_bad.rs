// Fixture: waking or sending while a lock guard is provably live — the
// lost-wakeup / locked-send shapes the heuristic exists for.
use std::sync::{Condvar, Mutex};

pub fn notify_under_lock(m: &Mutex<bool>, cv: &Condvar) {
    let mut flag = m.lock().unwrap();
    *flag = true;
    cv.notify_all();
}

pub fn send_under_lock(m: &Mutex<Vec<u32>>, tx: &std::sync::mpsc::Sender<u32>) {
    let queue = m.lock().unwrap();
    tx.send(queue.len() as u32).unwrap();
}

pub fn one_liner(m: &Mutex<bool>, cv: &Condvar) {
    let g = m.lock().unwrap(); cv.notify_one();
    drop(g);
}
