// Fixture: the three sanctioned shapes — drop-then-notify, scope-then-
// notify, and sending with no guard in sight.
use std::sync::{Condvar, Mutex};

pub fn drop_then_notify(m: &Mutex<bool>, cv: &Condvar) {
    let mut flag = m.lock().unwrap();
    *flag = true;
    drop(flag);
    cv.notify_all();
}

pub fn scope_then_notify(m: &Mutex<bool>, cv: &Condvar) {
    {
        let mut flag = m.lock().unwrap();
        *flag = true;
    }
    cv.notify_all();
}

pub fn unlocked_send(tx: &std::sync::mpsc::Sender<u32>) {
    tx.send(7).unwrap();
}
