// Fixture: the same panicking shortcuts are fine inside the trailing
// test module — tests are supposed to assert hard.
pub fn solid(input: Option<u32>) -> Option<u32> {
    input.map(|n| n + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asserts_hard() {
        assert_eq!(solid(Some(1)).unwrap(), 2);
        let n: u32 = "7".parse().expect("a number");
        assert_eq!(n, 7);
        if false {
            panic!("unreached");
        }
    }
}
