// Fixture: `absorb` folds three of MineStats' four counters and
// forgets `retried` — the drift shape the rule exists for.
pub struct MineStats {
    pub started: u64,
    pub finished: u64,
    pub failed: u64,
    pub retried: u64,
}

impl MineStats {
    pub fn absorb(&mut self, other: &MineStats) {
        self.started += other.started;
        self.finished += other.finished;
        self.failed += other.failed;
    }
}
