// Fixture: the pool tasks only compute and send (send never blocks on
// our unbounded channels); the blocking receive happens on the
// submitting thread, outside any pool closure.
pub fn run(&self) {
    self.pool.spawn(move || {
        let v = compute(shard);
        tx.send(v).ok();
    });
    let merged = rx.recv();
    let g = self.state.lock().unwrap();
    finish(g, merged);
}
