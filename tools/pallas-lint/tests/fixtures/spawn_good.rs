// Fixture: parallelism through the shared pool is the sanctioned shape;
// `thread::sleep` and the word `spawn` as a method name are not flagged.
use crate::util::pool::WorkerPool;

pub fn fan_out(pool: &WorkerPool, n: usize) {
    for _ in 0..n {
        pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(1)));
    }
}
