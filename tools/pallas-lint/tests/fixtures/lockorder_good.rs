// Fixture: consistent lock order. Both paths take `a` before `b`, and
// `third` re-locks `b` only after dropping its first guard — the graph
// stays acyclic and nothing fires.
use std::sync::Mutex;

pub struct Shared {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

pub fn first(s: &Shared) {
    let ga = s.a.lock().unwrap();
    let gb = s.b.lock().unwrap();
    drop(gb);
    drop(ga);
}

pub fn second(s: &Shared) {
    let ga = s.a.lock().unwrap();
    let gb = s.b.lock().unwrap();
    drop(gb);
    drop(ga);
}

pub fn third(s: &Shared) {
    let gb = s.b.lock().unwrap();
    drop(gb);
    let ga = s.a.lock().unwrap();
    drop(ga);
}
