// Fixture: the pinned hasher is fine, and prose/data mentions of
// DefaultHasher must not fire: the lexer masks comments and strings.
// (This comment says DefaultHasher and RandomState on purpose.)
use crate::util::siphash::SipHasher13;
use std::hash::Hasher;

/// Doc comment mentioning DefaultHasher, also masked.
pub fn digest(bytes: &[u8]) -> u64 {
    let mut h = SipHasher13::new();
    h.write(bytes);
    let _msg = "replaced DefaultHasher with a pinned RandomState-free hasher";
    let _raw = r#"DefaultHasher in a raw string"#;
    h.finish()
}
