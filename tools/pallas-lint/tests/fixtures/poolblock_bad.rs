// Fixture: blocking calls inside closures that run ON the shared pool.
// The first spawn takes a mutex, the second parks on a channel, and the
// mapper closure does a socket read — all three can eat the pool's own
// worker budget (the PR 8 serve deadlock class).
pub fn run(&self) {
    self.pool.spawn(move || {
        let g = self.state.lock().unwrap();
        consume(&g);
    });
    self.pool.spawn(move || {
        let v = rx.recv();
        use_value(v);
    });
}

pub fn build(&self, job: JobBuilder) {
    job.mapper(move |shard| {
        let mut buf = String::new();
        reader.read_line(&mut buf);
        emit(buf, shard)
    });
}
