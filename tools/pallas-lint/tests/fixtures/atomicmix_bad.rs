// Fixture: two atomic-ordering smells. `flag` is stored SeqCst but
// loaded Relaxed — an inconsistent protocol; `ready` gates a Condvar
// handshake in the same struct yet is touched with Relaxed.
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

pub struct Core {
    flag: AtomicU64,
}

pub struct Gate {
    ready: AtomicBool,
    cv: Condvar,
    slot: Mutex<u32>,
}

pub fn raise(c: &Core) {
    c.flag.store(1, Ordering::SeqCst);
}

pub fn read(c: &Core) -> u64 {
    c.flag.load(Ordering::Relaxed)
}

pub fn open(g: &Gate) {
    g.ready.store(true, Ordering::Relaxed);
    g.cv.notify_all();
}
