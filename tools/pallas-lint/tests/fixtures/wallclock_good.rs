// Fixture: a deliberate wall-clock meter carries an allow with a reason,
// in both the standalone-line and same-line forms.
use std::time::Instant;

pub fn metered() -> f64 {
    // lint:allow(wall-clock-in-sim): task meter — reported, never fed to the simulation
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn metered_inline() -> f64 {
    let t0 = Instant::now(); // lint:allow(wall-clock-in-sim): same-line meter
    t0.elapsed().as_secs_f64()
}
