//! Fixture-based rule tests: one good/bad source pair per rule, plus
//! lexer masking, allow-comment honoring, and baseline round-trips.

use std::collections::BTreeMap;

use pallas_lint::{baseline, lexer, lint_files, lint_source, lint_source_full, rules, Finding};

/// Read a fixture file's source text.
fn read_fixture(fixture: &str) -> String {
    let path = format!("{}/tests/fixtures/{fixture}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

/// Lint a fixture file under a fake repo-relative path (rule scoping is
/// path-based, so the path is part of the test).
fn lint_fixture(rel: &str, fixture: &str) -> Vec<Finding> {
    lint_source(rel, &read_fixture(fixture))
}

/// The `(line, …)` pairs of every finding of `rule`.
fn lines_of<'a>(findings: &'a [Finding], rule: &str) -> Vec<usize> {
    findings.iter().filter(|f| f.rule == rule).map(|f| f.line).collect()
}

// ---- one bad/good pair per rule -----------------------------------------

#[test]
fn unspecified_hasher_fires_and_spares_the_pinned_impl() {
    let bad = lint_fixture("rust/src/fake/hasher.rs", "hasher_bad.rs");
    assert_eq!(lines_of(&bad, "unspecified-hasher"), vec![2, 6, 12]);

    let good = lint_fixture("rust/src/fake/hasher.rs", "hasher_good.rs");
    assert_eq!(lines_of(&good, "unspecified-hasher"), Vec::<usize>::new());

    // The same bad source inside util::siphash itself is exempt.
    let in_impl = lint_fixture("rust/src/util/siphash.rs", "hasher_bad.rs");
    assert_eq!(lines_of(&in_impl, "unspecified-hasher"), Vec::<usize>::new());
}

#[test]
fn wall_clock_fires_in_library_and_spares_the_metering_layer() {
    let bad = lint_fixture("rust/src/cluster/fake.rs", "wallclock_bad.rs");
    assert_eq!(lines_of(&bad, "wall-clock-in-sim"), vec![2, 5, 6]);

    let good = lint_fixture("rust/src/cluster/fake.rs", "wallclock_good.rs");
    assert_eq!(lines_of(&good, "wall-clock-in-sim"), Vec::<usize>::new());

    // bench_harness is the sanctioned home of host timing.
    let harness = lint_fixture("rust/src/bench_harness/fake.rs", "wallclock_bad.rs");
    assert_eq!(lines_of(&harness, "wall-clock-in-sim"), Vec::<usize>::new());

    // Benches and tests measure wall time legitimately.
    let bench = lint_fixture("rust/benches/fake.rs", "wallclock_bad.rs");
    assert_eq!(lines_of(&bench, "wall-clock-in-sim"), Vec::<usize>::new());
}

#[test]
fn raw_thread_spawn_fires_outside_the_pool() {
    let bad = lint_fixture("rust/src/coordinator/fake.rs", "spawn_bad.rs");
    assert_eq!(lines_of(&bad, "raw-thread-spawn"), vec![5, 7]);

    let good = lint_fixture("rust/src/coordinator/fake.rs", "spawn_good.rs");
    assert_eq!(lines_of(&good, "raw-thread-spawn"), Vec::<usize>::new());

    // The pool is where threads are allowed to be born.
    let in_pool = lint_fixture("rust/src/util/pool.rs", "spawn_bad.rs");
    assert_eq!(lines_of(&in_pool, "raw-thread-spawn"), Vec::<usize>::new());

    // ... and so is the serve daemon's thread layer: its accept/reader/
    // worker threads block on socket I/O and submit INTO the pool, so
    // they cannot live on pool workers (DESIGN.md §12).
    let in_serve = lint_fixture("rust/src/serve/server.rs", "spawn_bad.rs");
    assert_eq!(lines_of(&in_serve, "raw-thread-spawn"), Vec::<usize>::new());

    // The exemption is exactly server.rs, not the whole serve module.
    let in_serve_other = lint_fixture("rust/src/serve/registry.rs", "spawn_bad.rs");
    assert_eq!(lines_of(&in_serve_other, "raw-thread-spawn"), vec![5, 7]);
}

#[test]
fn guard_across_notify_fires_on_the_lost_wakeup_shapes() {
    let bad = lint_fixture("rust/src/util/fake.rs", "notify_bad.rs");
    assert_eq!(lines_of(&bad, "guard-across-notify"), vec![8, 13, 17]);

    let good = lint_fixture("rust/src/util/fake.rs", "notify_good.rs");
    assert_eq!(lines_of(&good, "guard-across-notify"), Vec::<usize>::new());
}

#[test]
fn unwrap_fires_in_library_code_only() {
    let bad = lint_fixture("rust/src/dataset/fake.rs", "unwrap_bad.rs");
    assert_eq!(lines_of(&bad, "unwrap-in-library"), vec![4, 5, 7]);

    let good = lint_fixture("rust/src/dataset/fake.rs", "unwrap_good.rs");
    assert_eq!(lines_of(&good, "unwrap-in-library"), Vec::<usize>::new());

    // The identical panicking code is exempt in integration tests…
    let in_tests = lint_fixture("rust/tests/fake.rs", "unwrap_bad.rs");
    assert_eq!(lines_of(&in_tests, "unwrap-in-library"), Vec::<usize>::new());
    // …and outside the library tree entirely.
    let in_tools = lint_fixture("tools/fake/src/main.rs", "unwrap_bad.rs");
    assert_eq!(lines_of(&in_tools, "unwrap-in-library"), Vec::<usize>::new());
}

#[test]
fn incremental_subsystem_is_in_rule_scope_with_no_carve_outs() {
    // The delta miner is library code like any other: host-time reads and
    // panicking shortcuts both fire under rust/src/incremental/, and the
    // subsystem ships with zero baseline entries — new findings there fail
    // the lint outright.
    let bad = lint_fixture("rust/src/incremental/delta.rs", "incremental_bad.rs");
    assert_eq!(lines_of(&bad, "wall-clock-in-sim"), vec![5, 8]);
    assert_eq!(lines_of(&bad, "unwrap-in-library"), vec![9]);

    // The differential suite and the incremental bench sit outside the
    // library tree, where both rules are silent by design.
    let in_tests = lint_fixture("rust/tests/incremental_mining.rs", "incremental_bad.rs");
    assert!(in_tests.is_empty(), "integration tests are exempt: {in_tests:?}");
    let in_bench = lint_fixture("rust/benches/incremental_vs_full.rs", "incremental_bad.rs");
    assert!(in_bench.is_empty(), "benches are exempt: {in_bench:?}");
}

// ---- suppression comments ------------------------------------------------

#[test]
fn allow_comments_suppress_same_line_and_next_code_line() {
    // wallclock_good.rs carries both forms over otherwise-flagged lines.
    let good = lint_fixture("rust/src/cluster/fake.rs", "wallclock_good.rs");
    assert!(good.is_empty(), "allow comments should suppress: {good:?}");

    // An allow for a DIFFERENT rule must not suppress.
    let src = "fn f() {\n\
               // lint:allow(raw-thread-spawn): wrong rule on purpose\n\
               let t = std::time::Instant::now();\n\
               }\n";
    let f = lint_source("rust/src/fake.rs", src);
    assert_eq!(lines_of(&f, "wall-clock-in-sim"), vec![3]);
}

#[test]
fn allow_directives_inside_strings_are_inert() {
    let src = "fn f() {\n\
               let _doc = \"lint:allow(wall-clock-in-sim): quoted, not real\";\n\
               let t = std::time::Instant::now();\n\
               }\n";
    let f = lint_source("rust/src/fake.rs", src);
    assert_eq!(lines_of(&f, "wall-clock-in-sim"), vec![3]);
}

// ---- phase-2 cross-file rules --------------------------------------------

#[test]
fn lock_order_cycle_fires_on_inversion_within_one_file() {
    let bad = lint_fixture("rust/src/serve/fake.rs", "lockorder_bad.rs");
    assert_eq!(lines_of(&bad, "lock-order-cycle"), vec![13, 20]);
    let f = bad.iter().find(|f| f.rule == "lock-order-cycle").expect("finding");
    assert!(f.detail.contains("while holding"), "detail names the edge: {}", f.detail);

    let good = lint_fixture("rust/src/serve/fake.rs", "lockorder_good.rs");
    assert_eq!(lines_of(&good, "lock-order-cycle"), Vec::<usize>::new());

    // Outside the library tree the facts (and so the rule) are silent.
    let in_tests = lint_fixture("rust/tests/fake.rs", "lockorder_bad.rs");
    assert_eq!(lines_of(&in_tests, "lock-order-cycle"), Vec::<usize>::new());
}

#[test]
fn lock_order_cycle_requires_the_cross_file_join() {
    // Each file alone is consistent: lockorder_a.rs takes reg→disp,
    // lockorder_b.rs takes disp→reg, and only the phase-2 join — which
    // resolves both field names to the single declaration site in
    // lockorder_a.rs — sees the AB/BA cycle.
    let a = read_fixture("lockorder_a.rs");
    let b = read_fixture("lockorder_b.rs");
    let alone_a = lint_source("rust/src/serve/lockorder_a.rs", &a);
    assert_eq!(lines_of(&alone_a, "lock-order-cycle"), Vec::<usize>::new());
    let alone_b = lint_source("rust/src/serve/lockorder_b.rs", &b);
    assert_eq!(lines_of(&alone_b, "lock-order-cycle"), Vec::<usize>::new());

    let joined = lint_files(&[
        ("rust/src/serve/lockorder_a.rs".to_string(), a),
        ("rust/src/serve/lockorder_b.rs".to_string(), b),
    ]);
    let cycle: Vec<(&str, usize)> = joined
        .findings
        .iter()
        .filter(|f| f.rule == "lock-order-cycle")
        .map(|f| (f.path.as_str(), f.line))
        .collect();
    assert_eq!(
        cycle,
        vec![("rust/src/serve/lockorder_a.rs", 14), ("rust/src/serve/lockorder_b.rs", 9)]
    );
}

#[test]
fn atomic_ordering_mix_fires_on_mixes_and_condvar_gated_relaxed() {
    let bad = lint_fixture("rust/src/util/fake.rs", "atomicmix_bad.rs");
    assert_eq!(lines_of(&bad, "atomic-ordering-mix"), vec![18, 22, 26]);
    let gated = bad.iter().find(|f| f.line == 26).expect("condvar-gated finding");
    assert!(gated.detail.contains("Condvar"), "detail explains the gate: {}", gated.detail);

    // SeqCst-everywhere and an Acquire/Release pair are both coherent.
    let good = lint_fixture("rust/src/util/fake.rs", "atomicmix_good.rs");
    assert_eq!(lines_of(&good, "atomic-ordering-mix"), Vec::<usize>::new());
}

#[test]
fn blocking_in_pool_task_fires_inside_pool_closures_only() {
    let bad = lint_fixture("rust/src/mapreduce/fake.rs", "poolblock_bad.rs");
    assert_eq!(lines_of(&bad, "blocking-in-pool-task"), vec![7, 11, 19]);

    // The same calls outside the closure region are fine.
    let good = lint_fixture("rust/src/mapreduce/fake.rs", "poolblock_good.rs");
    assert_eq!(lines_of(&good, "blocking-in-pool-task"), Vec::<usize>::new());
}

#[test]
fn counter_drift_fires_on_a_forgotten_counter() {
    let bad = lint_fixture("rust/src/serve/fake.rs", "counterdrift_bad.rs");
    assert_eq!(lines_of(&bad, "counter-drift"), vec![11]);
    let f = bad.iter().find(|f| f.rule == "counter-drift").expect("finding");
    assert!(f.detail.contains("retried"), "detail names the missing counter: {}", f.detail);

    // Full folds and one-field accessors are both clean.
    let good = lint_fixture("rust/src/serve/fake.rs", "counterdrift_good.rs");
    assert_eq!(lines_of(&good, "counter-drift"), Vec::<usize>::new());
}

#[test]
fn cross_file_rules_respect_allow_suppressions() {
    let src = "pub struct FooStats {\n\
               \x20   pub started: u64,\n\
               \x20   pub finished: u64,\n\
               \x20   pub failed: u64,\n\
               }\n\
               impl FooStats {\n\
               \x20   // lint:allow(counter-drift): failed is folded by the caller\n\
               \x20   pub fn absorb(&mut self, o: &FooStats) {\n\
               \x20       self.started += o.started;\n\
               \x20       self.finished += o.finished;\n\
               \x20   }\n\
               }\n";
    let tree = lint_source_full("rust/src/fake.rs", src);
    assert_eq!(lines_of(&tree.findings, "counter-drift"), Vec::<usize>::new());
    assert!(tree.stale_allows.is_empty(), "the allow is live: {:?}", tree.stale_allows);

    // Without the allow the same source fires on the fn declaration.
    let bare = src.replace("// lint:allow(counter-drift): failed is folded by the caller\n", "");
    let f = lint_source("rust/src/fake.rs", &bare);
    assert_eq!(lines_of(&f, "counter-drift"), vec![7]);
}

#[test]
fn stale_allows_are_reported_but_kept_out_of_findings() {
    let tree = lint_source_full("rust/src/fake.rs", &read_fixture("staleallow_bad.rs"));
    // The pointless and misspelled allows are stale; the live one (on the
    // unwrap) is not, and its target stays suppressed.
    let stale: Vec<usize> = tree.stale_allows.iter().map(|f| f.line).collect();
    assert_eq!(stale, vec![5, 7]);
    assert!(tree.stale_allows.iter().all(|f| f.rule == "stale-allow"));
    assert_eq!(lines_of(&tree.findings, "unwrap-in-library"), Vec::<usize>::new());
    // Stale reports never mix into findings (so they can never be
    // baselined).
    assert!(tree.findings.iter().all(|f| f.rule != "stale-allow"));
}

#[test]
fn doc_prose_mentioning_allow_syntax_is_not_a_directive() {
    // The linter's own sources document `lint:allow(<rule>)` in comments;
    // placeholder "rule names" must neither suppress nor go stale.
    let src = "// Suppressions use lint:allow(<rule>) syntax, e.g. lint:allow(...).\n\
               fn f() {\n\
               \x20   let t = std::time::Instant::now();\n\
               }\n";
    let tree = lint_source_full("rust/src/fake.rs", src);
    assert_eq!(lines_of(&tree.findings, "wall-clock-in-sim"), vec![3]);
    assert!(tree.stale_allows.is_empty(), "{:?}", tree.stale_allows);
}

// ---- lexer masking -------------------------------------------------------

#[test]
fn lexer_masks_comments_strings_and_char_literals() {
    let src = "let a = \"DefaultHasher\"; // DefaultHasher in comment\n\
               let b = r#\"DefaultHasher raw\"#;\n\
               /* block DefaultHasher */ let c = 'x';\n\
               let lifetime: &'static str = \"ok\";\n";
    let m = lexer::mask(src);
    assert_eq!(m.code.len(), 4);
    for l in &m.code {
        assert!(!l.contains("DefaultHasher"), "leaked into code view: {l:?}");
    }
    // Comments land in the comment view, strings in neither.
    assert!(m.comments[0].contains("DefaultHasher in comment"));
    assert!(m.comments.iter().all(|l| !l.contains("raw")));
    // Delimiters and code shape survive masking.
    assert!(m.code[0].contains("let a = \""));
    assert!(m.code[3].contains("&'static str"));
}

#[test]
fn lexer_distinguishes_lifetimes_from_char_literals() {
    // A lifetime must not open a "char literal" that swallows the
    // DefaultHasher reference on the same line.
    let src = "fn f<'a>(x: &'a u32) { let h = DefaultHasher::new(); }\n\
               let quote = '\"'; let h2 = DefaultHasher::new();\n";
    let f = lint_source("rust/src/fake.rs", src);
    assert_eq!(lines_of(&f, "unspecified-hasher"), vec![1, 2]);
}

#[test]
fn lexer_handles_nested_block_comments() {
    let src = "/* outer /* inner */ still comment DefaultHasher */\n\
               let h = DefaultHasher::new();\n";
    let f = lint_source("rust/src/fake.rs", src);
    assert_eq!(lines_of(&f, "unspecified-hasher"), vec![2]);
}

// ---- baseline ------------------------------------------------------------

fn finding(rule: &'static str, path: &str, line: usize) -> Finding {
    Finding { rule, path: path.to_string(), line, excerpt: String::new(), detail: String::new() }
}

#[test]
fn baseline_render_parse_round_trip() {
    let findings = vec![
        finding("unwrap-in-library", "rust/src/a.rs", 3),
        finding("unwrap-in-library", "rust/src/a.rs", 9),
        finding("wall-clock-in-sim", "rust/src/b.rs", 1),
    ];
    let map = baseline::counts(&findings);
    let parsed = baseline::parse(&baseline::render(&map)).expect("round-trip");
    assert_eq!(parsed, map);
    assert_eq!(parsed[&("unwrap-in-library".into(), "rust/src/a.rs".into())], 2);
}

#[test]
fn baseline_compare_reports_additions_and_staleness() {
    let old = vec![
        finding("unwrap-in-library", "rust/src/a.rs", 3),
        finding("unwrap-in-library", "rust/src/a.rs", 9),
    ];
    let base = baseline::counts(&old);

    // Same counts: clean.
    let drift = baseline::compare(&old, &base);
    assert!(drift.new.is_empty() && drift.stale.is_empty());

    // One more unwrap in the same file: the whole group is reported.
    let mut grown = old.clone();
    grown.push(finding("unwrap-in-library", "rust/src/a.rs", 40));
    let drift = baseline::compare(&grown, &base);
    assert_eq!(drift.new.len(), 3);
    assert!(drift.stale.is_empty());

    // One removed: stale entry, nothing new. Regenerating ratchets down.
    let shrunk = vec![finding("unwrap-in-library", "rust/src/a.rs", 3)];
    let drift = baseline::compare(&shrunk, &base);
    assert!(drift.new.is_empty());
    assert_eq!(
        drift.stale,
        vec![(("unwrap-in-library".to_string(), "rust/src/a.rs".to_string()), 2, 1)]
    );
    let regenerated = baseline::counts(&shrunk);
    let drift = baseline::compare(&shrunk, &regenerated);
    assert!(drift.new.is_empty() && drift.stale.is_empty());

    // A brand-new rule/file key with no baseline entry is always new.
    let fresh = vec![finding("raw-thread-spawn", "rust/src/c.rs", 2)];
    let drift = baseline::compare(&fresh, &BTreeMap::new());
    assert_eq!(drift.new.len(), 1);
}

#[test]
fn baseline_parse_rejects_garbage() {
    assert!(baseline::parse("not a baseline line").is_err());
    assert!(baseline::parse("rule\tpath\tNaN").is_err());
    assert!(baseline::parse("r\tp\t1\nr\tp\t2\n").is_err(), "duplicate keys");
    assert!(baseline::parse("# comment only\n\n").expect("comments ok").is_empty());
}

// ---- rule registry -------------------------------------------------------

#[test]
fn every_rule_is_documented_and_distinct() {
    let mut names: Vec<&str> = rules::RULES.iter().map(|r| r.name).collect();
    assert_eq!(names.len(), 10);
    names.sort();
    names.dedup();
    assert_eq!(names.len(), 10, "duplicate rule names");
    for r in &rules::RULES {
        assert!(!r.summary.is_empty());
    }
}
