//! Minimal offline stand-in for the `anyhow` crate (1.x API subset).
//!
//! The build environment cannot fetch crates.io dependencies, so — in the
//! same spirit as `mrapriori`'s `util/` shims for `rand`/`clap`/`toml`/
//! `rayon`/`proptest` — this path dependency vendors exactly the slice of
//! `anyhow` the workspace uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait
//! for `Result` and `Option`. Code written against it is source-compatible
//! with the real crate; swap the path dependency for `anyhow = "1"` when
//! building with network access.

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>`: a `Result` defaulting to the dynamic [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus its flattened cause chain.
///
/// Unlike the real `anyhow::Error` this stores the chain as rendered
/// strings rather than live trait objects — enough for error reporting,
/// which is all this workspace does with it. Deliberately does NOT
/// implement `std::error::Error`, exactly like the real crate, so that the
/// blanket `From<E: std::error::Error>` conversion below stays coherent.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a plain message (what [`anyhow!`] expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, colon-separated, matching
            // anyhow's alternate formatting.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// Context-attachment extension for fallible values.
pub trait Context<T> {
    /// Attach a context message to the error, if any.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message to the error, if any.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn from_std_error_flattens_sources() {
        let e: Error = io_err().into();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(7).context("present").unwrap(), 7);
    }

    #[test]
    fn macros_format() {
        fn fails(x: u32) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(())
        }
        assert!(fails(1).is_ok());
        assert_eq!(format!("{}", fails(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", fails(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", anyhow!("plain {}", 5)), "plain 5");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 3);
    }
}
