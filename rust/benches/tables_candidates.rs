//! Regenerates the paper's Tables 7-9: candidates generated in each
//! MapReduce phase for SPC, VFPC, Optimized-VFPC, ETDPC, Optimized-ETDPC on
//! the three datasets at the reference supports — all five runs per dataset
//! served from one `MiningSession` (one Job1 scan per dataset).

use mrapriori::bench_harness::tables::candidates_table;
use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, MiningRequest, MiningSession};
use mrapriori::dataset::registry;

fn main() {
    let cluster = ClusterConfig::paper_cluster();
    let mut all = String::new();
    for (table_no, name) in [(7, "c20d10k"), (8, "chess"), (9, "mushroom")] {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        let session = MiningSession::for_db(&db, cluster.clone())
            .split_lines(registry::split_lines(name))
            .build()
            .expect("registry datasets are valid");
        let runs: Vec<_> = [
            Algorithm::Spc,
            Algorithm::Vfpc,
            Algorithm::OptimizedVfpc,
            Algorithm::Etdpc,
            Algorithm::OptimizedEtdpc,
        ]
        .iter()
        .map(|&a| {
            session
                .run(&MiningRequest::new(a).min_sup(min_sup))
                .expect("reference supports are valid")
        })
        .collect();
        let refs: Vec<_> = runs.iter().collect();
        let t = candidates_table(
            &refs,
            &format!(
                "Table {table_no}: candidates per MapReduce phase, {name} @ min_sup {min_sup}"
            ),
        );
        println!("{t}");
        all.push_str(&t);
        all.push('\n');

        // The paper's integrity claim: optimized generates a superset of
        // candidates yet identical frequent itemsets.
        let plain: u64 = runs[1].phases.iter().map(|p| p.candidates).sum();
        let optim: u64 = runs[2].phases.iter().map(|p| p.candidates).sum();
        let line = format!(
            "{name}: total candidates VFPC {plain} vs Optimized-VFPC {optim} (+{:.1}% un-pruned); identical frequent itemsets: {}\n\n",
            100.0 * (optim as f64 - plain as f64) / plain as f64,
            runs[1].all_frequent() == runs[2].all_frequent(),
        );
        print!("{line}");
        all.push_str(&line);
    }
    save_report("tables_candidates.txt", &all);
}
