//! Regenerates the paper's Fig. 4 (a, b): execution time of the seven
//! algorithms on mushroom for min_sup 0.35 .. 0.15.
fn main() {
    mrapriori::bench_harness::run_figure_bench("mushroom", 4);
}
