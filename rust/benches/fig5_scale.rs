//! Regenerates the paper's Fig. 5(a): scalability — execution time of the
//! four proposed algorithms on growing copies of c20d10k (min_sup 0.25,
//! 10 mappers; the InputSplit scales with the data so the map-task count
//! stays constant, §5.4) — then extends the sweep past the paper to the
//! Quest-family T*I*D* entries, mined out-of-core through the segment
//! store. Set `FIG5_QUEST` to a comma-separated name list to override the
//! default entries (e.g. `FIG5_QUEST=t10i4d100k,t10i4d1m,t40i10d1m`).

use mrapriori::bench_harness::report::{figure_csv, figure_table, Series};
use mrapriori::bench_harness::tables::{quest_scale_run, scale_json, scale_markdown, ScaleRun};
use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, CountingBackend, MiningRequest, MiningSession};
use mrapriori::dataset::registry;

fn main() {
    let base = registry::c20d10k();
    let cluster = ClusterConfig::paper_cluster();
    let algos = [
        Algorithm::Vfpc,
        Algorithm::OptimizedVfpc,
        Algorithm::Etdpc,
        Algorithm::OptimizedEtdpc,
    ];
    let sizes = [10_000usize, 50_000, 100_000, 150_000, 200_000];
    let mut series: Vec<Series> = algos.iter().map(|a| Series::new(a.name())).collect();
    for &n in &sizes {
        let db = base.scaled_to(n, format!("c20d{}k", n / 1000));
        // Split scales so the run keeps 10 map tasks (paper setup); one
        // session per size shares Job1 across the four algorithms.
        let session = MiningSession::for_db(&db, cluster.clone())
            .split_lines(n / 10)
            .build()
            .expect("valid session");
        for (ai, &algo) in algos.iter().enumerate() {
            let out = session
                .run(&MiningRequest::new(algo).min_sup(0.25))
                .expect("valid request");
            series[ai].push(n as f64 / 1000.0, out.actual_time);
            eprintln!("  {} x{}k: {:.0} s", algo.name(), n / 1000, out.actual_time);
        }
    }
    let table = figure_table(
        "Fig 5(a): execution time (s) on increasing size of dataset (c20d10k scaled, min_sup 0.25)",
        "k txns",
        &series,
    );
    println!("{table}");
    // Linear-scaling check: time(200k)/time(10k) vs 20x data.
    for s in &series {
        let first = s.points.first().unwrap().1;
        let last = s.points.last().unwrap().1;
        println!(
            "{:<18} 20x data -> {:.1}x time (sublinear because fixed per-phase overhead amortizes)",
            s.name,
            last / first
        );
    }
    save_report("fig5a_scale.csv", &figure_csv("k_txns", &series));
    save_report("fig5a_scale.txt", &table);

    // ---- beyond the paper: Quest-family entries, streamed from disk -----
    let quest: Vec<String> = match std::env::var("FIG5_QUEST") {
        Ok(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        // Default to the 100K-class entries; the 1M entries run with
        // FIG5_QUEST=t10i4d1m,t40i10d1m (several minutes each).
        Err(_) => vec!["t10i4d100k".into(), "t40i10d100k".into()],
    };
    let cache = std::path::Path::new("target/dataset-cache");
    let quest_algos = [Algorithm::Spc, Algorithm::Vfpc, Algorithm::OptimizedEtdpc];
    let mut runs: Vec<ScaleRun> = Vec::new();
    for name in &quest {
        match quest_scale_run(name, &quest_algos, CountingBackend::Auto, &cluster, cache) {
            Ok(run) => {
                for o in &run.outcomes {
                    eprintln!(
                        "  {} {}: {:.0} s simulated",
                        o.algorithm.name(),
                        name,
                        o.actual_time
                    );
                }
                runs.push(run);
            }
            Err(e) => eprintln!("skipping {name}: {e}"),
        }
    }
    if !runs.is_empty() {
        let md = scale_markdown(&quest_algos, &runs);
        println!("\n# Fig 5(a) extension: Quest-family scale entries (streamed)\n{md}");
        save_report("fig5a_quest.md", &md);
        save_report("fig5a_quest.json", &scale_json(&quest_algos, &runs));
    }
}
