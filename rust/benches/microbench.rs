//! Microbenchmarks of the L3 hot paths: trie operations, candidate
//! generation, the scheduler, and the full map-task inner loop — the
//! profile targets of EXPERIMENTS.md §Perf.

use mrapriori::apriori::gen::{apriori_gen, non_apriori_gen};
use mrapriori::bench_harness::timing::{bench, save_report};
use mrapriori::cluster::costmodel::OverheadParams;
use mrapriori::cluster::scheduler::{schedule, SimTask};
use mrapriori::dataset::registry;
use mrapriori::itemset::{Itemset, Trie};
use std::fmt::Write as _;

fn main() {
    let mut out = String::new();
    let db = registry::mushroom();
    let r = mrapriori::apriori::sequential::mine(&db, 0.15);

    // Representative mid-mining trie: L7 (peak level).
    let l7: Vec<Itemset> = r.levels[6].iter().map(|(s, _)| s.clone()).collect();
    let l7_trie = Trie::from_itemsets(7, l7.iter());
    let _ = writeln!(out, "# L3 microbenchmarks (mushroom @0.15, |L7| = {})\n", l7.len());

    // 1. Trie build.
    let s = bench(1, 7, || {
        std::hint::black_box(Trie::from_itemsets(7, l7.iter()));
    });
    let _ = writeln!(out, "trie build (|L7| inserts)        {s}");

    // 2. Membership probes (the prune hot path).
    let probes: Vec<&Itemset> = l7.iter().collect();
    let s = bench(1, 7, || {
        for p in &probes {
            std::hint::black_box(l7_trie.contains(p));
        }
    });
    let _ = writeln!(out, "trie contains x{}            {s}", probes.len());

    // 3. subset() counting over all transactions.
    let (c8, _) = apriori_gen(&l7_trie);
    let mut counting = c8.clone();
    let s = bench(1, 5, || {
        counting.clear_counts();
        for t in &db.txns {
            std::hint::black_box(counting.count_transaction(t));
        }
    });
    let _ = writeln!(out, "subset count |C8|={} x{} txns   {s}", c8.len(), db.len());

    // 4. apriori-gen vs non-apriori-gen (the §4.3 trade at generation time).
    let s = bench(1, 7, || {
        std::hint::black_box(apriori_gen(&l7_trie));
    });
    let _ = writeln!(out, "apriori-gen(L7)                  {s}");
    let s = bench(1, 7, || {
        std::hint::black_box(non_apriori_gen(&l7_trie));
    });
    let _ = writeln!(out, "non-apriori-gen(L7)              {s}");

    // 5. Scheduler throughput.
    let tasks: Vec<SimTask> = (0..500)
        .map(|i| SimTask { compute_secs: (i % 37) as f64, preferred_nodes: vec![i % 4] })
        .collect();
    let slots: Vec<(usize, f64)> = (0..16).map(|i| (i % 4, 1.0)).collect();
    let oh = OverheadParams::default();
    let s = bench(2, 9, || {
        std::hint::black_box(schedule(&tasks, &slots, &oh));
    });
    let _ = writeln!(out, "schedule 500 tasks / 16 slots    {s}");

    // 6. End-to-end mining wall time (the real-work budget of one bench run).
    let s = bench(0, 3, || {
        std::hint::black_box(mrapriori::apriori::sequential::mine(&db, 0.15));
    });
    let _ = writeln!(out, "sequential mine mushroom @0.15   {s}");

    println!("{out}");
    save_report("microbench.txt", &out);
}
