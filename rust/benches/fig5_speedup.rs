//! Regenerates the paper's Fig. 5(b): speedup of the four proposed
//! algorithms on c20d200k (min_sup 0.40, 10 mappers) as DataNodes grow
//! from 1 to 4. Speedup = T(1 node) / T(n nodes) (§5.4). One
//! `MiningSession` per cluster size; the four algorithms of each size
//! share its Job1 scan.

use mrapriori::bench_harness::report::{figure_csv, figure_table, Series};
use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, MiningRequest, MiningSession};
use mrapriori::dataset::registry;

fn main() {
    let db = registry::c20d10k().scaled_to(200_000, "c20d200k");
    // 20 map tasks on 3 slots/DataNode: keeps every cluster size short of a
    // single map wave, so the speedup curve keeps growing through 4 nodes
    // (the paper's 10-mapper setup on its unspecified slot count shows the
    // same continued growth; with 10 tasks and >=4 slots/node the curve
    // would plateau at 3 nodes).
    let algos = [
        Algorithm::Vfpc,
        Algorithm::OptimizedVfpc,
        Algorithm::Etdpc,
        Algorithm::OptimizedEtdpc,
    ];
    let mut series: Vec<Series> = algos.iter().map(|a| Series::new(a.name())).collect();
    let mut base_time = vec![0.0f64; algos.len()];
    for nodes in 1..=4usize {
        let cluster = ClusterConfig::uniform(nodes, 3);
        let session = MiningSession::for_db(&db, cluster)
            .split_lines(10_000)
            .build()
            .expect("valid session");
        for (ai, &algo) in algos.iter().enumerate() {
            let out = session
                .run(&MiningRequest::new(algo).min_sup(0.40))
                .expect("valid request");
            if nodes == 1 {
                base_time[ai] = out.actual_time;
            }
            let speedup = base_time[ai] / out.actual_time;
            series[ai].push(nodes as f64, speedup);
            eprintln!(
                "  {} on {nodes} node(s): {:.0} s (speedup {speedup:.2})",
                algo.name(),
                out.actual_time
            );
        }
    }
    let table = figure_table(
        "Fig 5(b): speedup on increasing number of DataNodes (c20d200k, min_sup 0.40)",
        "nodes",
        &series,
    );
    println!("{table}");
    for s in &series {
        let last = s.points.last().unwrap().1;
        println!(
            "{:<18} speedup at 4 nodes: {last:.2} (sublinear: serial reduce/shuffle + per-job overhead)",
            s.name
        );
    }
    save_report("fig5b_speedup.csv", &figure_csv("nodes", &series));
    save_report("fig5b_speedup.txt", &table);
}
