//! Regenerates the paper's Table 6: |L_k| per pass on the three datasets at
//! the reference supports, via the sequential oracle, side by side with the
//! paper's published counts.

use mrapriori::apriori::sequential::mine;
use mrapriori::bench_harness::timing::save_report;
use mrapriori::dataset::registry;

const PAPER: [(&str, &[usize]); 3] = [
    ("c20d10k", &[38, 319, 1349, 3545, 6352, 8163, 7615, 5230, 2607, 918, 217, 31, 3]),
    ("chess", &[29, 307, 1716, 5992, 13927, 22442, 25713, 21111, 12329, 5027, 1384, 240, 19]),
    (
        "mushroom",
        &[48, 530, 2510, 6751, 12372, 17008, 18745, 16887, 12290, 7052, 3094, 1001, 224, 31, 2],
    ),
];

fn main() {
    let mut out = String::new();
    out.push_str("# Table 6: number of frequent k-itemsets |L_k|\n");
    for (name, paper) in PAPER {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        let r = mine(&db, min_sup);
        let ours = r.lk_profile();
        out.push_str(&format!("\n{name} @ min_sup {min_sup}\n"));
        out.push_str(&format!("{:<8}", "k"));
        for k in 1..=ours.len().max(paper.len()) {
            out.push_str(&format!(" {k:>7}"));
        }
        out.push_str(&format!("\n{:<8}", "ours"));
        for k in 0..ours.len().max(paper.len()) {
            match ours.get(k) {
                Some(v) => out.push_str(&format!(" {v:>7}")),
                None => out.push_str(&format!(" {:>7}", "-")),
            }
        }
        out.push_str(&format!("\n{:<8}", "paper"));
        for k in 0..ours.len().max(paper.len()) {
            match paper.get(k) {
                Some(v) => out.push_str(&format!(" {v:>7}")),
                None => out.push_str(&format!(" {:>7}", "-")),
            }
        }
        out.push('\n');
        let (peak_k, peak) =
            ours.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, &v)| (i + 1, v)).unwrap();
        out.push_str(&format!(
            "shape: max length {} (paper {}), peak |L_{peak_k}| = {peak}\n",
            ours.len(),
            paper.len(),
        ));
    }
    println!("{out}");
    save_report("table6_lk.txt", &out);
}
