//! Regenerates the paper's Tables 3-5 (per-phase elapsed time of SPC, FPC,
//! VFPC, DPC, ETDPC) and Tables 10-12 (VFPC vs Optimized-VFPC, ETDPC vs
//! Optimized-ETDPC) at the reference supports (§5.3).
//!
//! One `MiningSession` per dataset serves all nine runs, so Job1 executes
//! once per dataset instead of nine times.

use mrapriori::bench_harness::tables::phase_time_table;
use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, MiningRequest, MiningSession};
use mrapriori::dataset::registry;

fn main() {
    let cluster = ClusterConfig::paper_cluster();
    let mut all = String::new();
    for (table_no, name) in [(3, "c20d10k"), (4, "chess"), (5, "mushroom")] {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        let session = MiningSession::for_db(&db, cluster.clone())
            .split_lines(registry::split_lines(name))
            .build()
            .expect("registry datasets are valid");
        let dpc_alpha = if name == "chess" { 3.0 } else { 2.0 };
        let mine = |algo: Algorithm| {
            session
                .run(&MiningRequest::new(algo).min_sup(min_sup).dpc_alpha(dpc_alpha))
                .expect("reference supports are valid")
        };
        let runs: Vec<_> = [
            Algorithm::Spc,
            Algorithm::Fpc,
            Algorithm::Vfpc,
            Algorithm::Dpc,
            Algorithm::Etdpc,
        ]
        .iter()
        .map(|&a| mine(a))
        .collect();
        let refs: Vec<_> = runs.iter().collect();
        let t = phase_time_table(
            &refs,
            &format!("Table {table_no}: per-phase elapsed time (s), {name} @ min_sup {min_sup}"),
        );
        println!("{t}");
        all.push_str(&t);
        all.push('\n');

        // Tables 10-12: optimized vs plain.
        let opt_runs: Vec<_> = [
            Algorithm::Vfpc,
            Algorithm::OptimizedVfpc,
            Algorithm::Etdpc,
            Algorithm::OptimizedEtdpc,
        ]
        .iter()
        .map(|&a| mine(a))
        .collect();
        let refs: Vec<_> = opt_runs.iter().collect();
        let t = phase_time_table(
            &refs,
            &format!(
                "Table {}: optimized vs plain per-phase elapsed time (s), {name} @ min_sup {min_sup}",
                table_no + 7
            ),
        );
        println!("{t}");
        all.push_str(&t);
        all.push('\n');
        let stats = session.stats();
        eprintln!(
            "{name}: Job1 ran {} time(s) for {} queries ({} cache hits)",
            stats.job1_runs, stats.queries, stats.job1_cache_hits
        );
    }
    save_report("tables_phase_time.txt", &all);
}
