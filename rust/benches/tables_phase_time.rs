//! Regenerates the paper's Tables 3-5 (per-phase elapsed time of SPC, FPC,
//! VFPC, DPC, ETDPC) and Tables 10-12 (VFPC vs Optimized-VFPC, ETDPC vs
//! Optimized-ETDPC) at the reference supports (§5.3).

use mrapriori::bench_harness::tables::phase_time_table;
use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{run_with, Algorithm, RunOptions};
use mrapriori::dataset::registry;

fn main() {
    let cluster = ClusterConfig::paper_cluster();
    let mut all = String::new();
    for (table_no, name) in [(3, "c20d10k"), (4, "chess"), (5, "mushroom")] {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        let opts = RunOptions {
            split_lines: registry::split_lines(name),
            dpc_alpha: if name == "chess" { 3.0 } else { 2.0 },
            ..Default::default()
        };
        let runs: Vec<_> = [
            Algorithm::Spc,
            Algorithm::Fpc,
            Algorithm::Vfpc,
            Algorithm::Dpc,
            Algorithm::Etdpc,
        ]
        .iter()
        .map(|&a| run_with(a, &db, min_sup, &cluster, &opts))
        .collect();
        let refs: Vec<_> = runs.iter().collect();
        let t = phase_time_table(
            &refs,
            &format!("Table {table_no}: per-phase elapsed time (s), {name} @ min_sup {min_sup}"),
        );
        println!("{t}");
        all.push_str(&t);
        all.push('\n');

        // Tables 10-12: optimized vs plain.
        let opt_runs: Vec<_> = [
            Algorithm::Vfpc,
            Algorithm::OptimizedVfpc,
            Algorithm::Etdpc,
            Algorithm::OptimizedEtdpc,
        ]
        .iter()
        .map(|&a| run_with(a, &db, min_sup, &cluster, &opts))
        .collect();
        let refs: Vec<_> = opt_runs.iter().collect();
        let t = phase_time_table(
            &refs,
            &format!(
                "Table {}: optimized vs plain per-phase elapsed time (s), {name} @ min_sup {min_sup}",
                table_no + 7
            ),
        );
        println!("{t}");
        all.push_str(&t);
        all.push('\n');
    }
    save_report("tables_phase_time.txt", &all);
}
