//! Ablation: counting backends — the paper's trie `subset()` walk vs the
//! AOT-compiled XLA bit-matrix executable (JAX/Pallas authored) vs the
//! native u64-bitset reference. Host wall-time on real candidate sets from
//! each registry dataset.

use mrapriori::apriori::gen::apriori_gen;
use mrapriori::apriori::sequential::mine;
use mrapriori::bench_harness::timing::{bench, save_report};
use mrapriori::dataset::registry;
use mrapriori::itemset::{Itemset, Trie};
use mrapriori::runtime::counting::{count_bitset_reference, XlaCounter};
use mrapriori::runtime::pjrt::{artifacts_dir, ArtifactSpec, PjrtRuntime};
use std::fmt::Write as _;

fn main() {
    let mut out = String::new();
    let _ = writeln!(out, "# Ablation: counting backend (trie vs XLA vs bitset)\n");
    let xla = match PjrtRuntime::load(&artifacts_dir(), ArtifactSpec::DEFAULT) {
        Ok(rt) => Some(XlaCounter::new(rt)),
        Err(e) => {
            let _ = writeln!(out, "XLA backend unavailable ({e}); run `make artifacts`.\n");
            None
        }
    };

    for name in registry::NAMES {
        let db = registry::load(name);
        // Take L2 -> C3 as the benchmark candidate set (biggest early pass).
        let min_sup = registry::reference_min_sup(name).unwrap();
        let r = mine(&db, min_sup);
        let l2: Vec<Itemset> = r.levels[1].iter().map(|(s, _)| s.clone()).collect();
        let l2_trie = Trie::from_itemsets(2, l2.iter());
        let (c3, _) = apriori_gen(&l2_trie);
        let cands = c3.itemsets();
        let _ = writeln!(
            out,
            "## {name}: {} candidates x {} transactions (width {})",
            cands.len(),
            db.len(),
            db.n_items
        );

        // Trie walk (the paper's backend).
        let mut trie = c3.clone();
        let trie_stats = bench(1, 5, || {
            trie.clear_counts();
            for t in &db.txns {
                std::hint::black_box(trie.count_transaction(t));
            }
        });
        let pairs = (cands.len() * db.len()) as f64;
        let _ = writeln!(
            out,
            "trie    {trie_stats}  ({:.1} M cand-txn pairs/s)",
            trie_stats.per_sec(pairs) / 1e6
        );

        // Native u64 bitset.
        let bitset_stats = bench(1, 5, || {
            std::hint::black_box(count_bitset_reference(&cands, &db.txns, db.n_items.max(64)));
        });
        let _ = writeln!(
            out,
            "bitset  {bitset_stats}  ({:.1} M pairs/s)",
            bitset_stats.per_sec(pairs) / 1e6
        );

        // XLA (interpret-lowered Pallas kernel via PJRT).
        if let Some(counter) = &xla {
            let xla_stats = bench(1, 3, || {
                std::hint::black_box(counter.count(&cands, &db.txns).unwrap());
            });
            let _ = writeln!(
                out,
                "xla     {xla_stats}  ({:.1} M pairs/s)",
                xla_stats.per_sec(pairs) / 1e6
            );
            // Cross-check equality.
            let by_xla = counter.count(&cands, &db.txns).unwrap();
            let by_bits = count_bitset_reference(&cands, &db.txns, 256);
            assert_eq!(by_xla, by_bits, "{name}: backend mismatch");
            let _ = writeln!(out, "numerics: xla == bitset == trie verified");
        }
        let _ = writeln!(out);
    }
    let _ = writeln!(
        out,
        "note: the XLA path runs the Pallas kernel interpret-lowered on the CPU\n\
         PJRT client — its wallclock is NOT a TPU estimate (see DESIGN.md\n\
         §Hardware-Adaptation for the VMEM/MXU reasoning)."
    );
    println!("{out}");
    save_report("ablation_backend.txt", &out);
}
