//! Ablation: Job2 counting backends, measured where they actually run —
//! the session hot path. For c20d10k and t10i4d100k (the paper's dense
//! and sparse reference shapes), mine with SPC once per backend (trie
//! subset walk, vertical TID-bitmap, dense triangular, `auto`) and
//! compare per-phase simulated seconds; SPC keeps every Job2 phase
//! single-pass so each phase row is one backend's counting cost. All
//! backends are asserted byte-identical before any number is reported
//! (DESIGN.md §11). Emits `BENCH_backends.json` under
//! `target/paper_results/` — the committed repo-root copy is the
//! reviewable baseline the advisory `backend-bench` CI job diffs against.
//!
//! Run: `cargo bench --bench ablation_backend`
//! Quick mode (CI telemetry): `BENCH_QUICK=1 cargo bench --bench ablation_backend`

use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{
    Algorithm, CountingBackend, MiningOutcome, MiningRequest, MiningSession,
};
use mrapriori::dataset::registry;
use mrapriori::hdfs;
use std::fmt::Write as _;

/// One dataset's four backend runs, plus the request metadata the report
/// needs to stay self-describing.
struct DatasetRuns {
    dataset: String,
    n_txns: usize,
    min_sup: f64,
    runs: Vec<(CountingBackend, MiningOutcome)>,
}

fn mine_all_backends(
    session: &MiningSession,
    min_sup: f64,
) -> Vec<(CountingBackend, MiningOutcome)> {
    CountingBackend::ALL
        .into_iter()
        .map(|b| {
            let out = session
                .run(&MiningRequest::new(Algorithm::Spc).min_sup(min_sup).backend(b))
                .expect("valid request");
            (b, out)
        })
        .collect()
}

fn json_runs(d: &DatasetRuns, out: &mut String) {
    let _ = write!(
        out,
        "    {{\"dataset\": \"{}\", \"n_txns\": {}, \"min_sup\": {}, \"algorithm\": \"SPC\", \
         \"backends\": [\n",
        d.dataset, d.n_txns, d.min_sup
    );
    for (i, (b, o)) in d.runs.iter().enumerate() {
        let _ = write!(
            out,
            "      {{\"backend\": \"{}\", \"total_time\": {:.3}, \"actual_time\": {:.3}, \
             \"wall_time\": {:.3}, \"phases\": [",
            b.name(),
            o.total_time,
            o.actual_time,
            o.wall_time
        );
        for (pi, p) in o.phases.iter().enumerate() {
            let _ = write!(
                out,
                "{}{{\"job\": \"{}\", \"backend\": \"{}\", \"candidates\": {}, \
                 \"elapsed\": {:.3}}}",
                if pi > 0 { ", " } else { "" },
                p.job,
                p.backend_label(),
                p.candidates,
                p.elapsed
            );
        }
        let _ = write!(out, "]}}{}\n", if i + 1 < d.runs.len() { "," } else { "" });
    }
    let _ = write!(out, "    ]}}");
}

fn main() {
    let quick = std::env::var_os("BENCH_QUICK").is_some();
    let cluster = ClusterConfig::paper_cluster();
    let mut datasets: Vec<DatasetRuns> = Vec::new();
    let mut table = String::new();
    let _ = writeln!(table, "# Ablation: Job2 counting backends on the session hot path\n");

    // Dense reference shape: the paper's c20d10k at its reference support.
    {
        let db = registry::c20d10k();
        let min_sup = if quick { 0.20 } else { registry::reference_min_sup("c20d10k").unwrap() };
        let session = MiningSession::for_db(&db, cluster.clone())
            .split_lines(registry::split_lines("c20d10k"))
            .build()
            .expect("valid session");
        datasets.push(DatasetRuns {
            dataset: db.name.clone(),
            n_txns: db.len(),
            min_sup,
            runs: mine_all_backends(&session, min_sup),
        });
    }

    // Sparse reference shape: Quest t10i4d100k, streamed from the segment
    // store exactly as `sweep --datasets` mines it.
    {
        let name = "t10i4d100k";
        let min_sup =
            if quick { 0.02 } else { registry::reference_min_sup(name).unwrap_or(0.01) };
        let cache = std::path::Path::new("target/dataset-cache");
        let src = registry::quest_store(name, cache).expect("quest store");
        let file = hdfs::put_segmented(
            std::sync::Arc::new(src),
            cluster.nodes.len(),
            hdfs::DEFAULT_REPLICATION,
            mrapriori::coordinator::RunOptions::default().seed,
        );
        let n_txns = file.len();
        let session =
            MiningSession::builder(file, cluster.clone()).build().expect("valid session");
        datasets.push(DatasetRuns {
            dataset: name.to_string(),
            n_txns,
            min_sup,
            runs: mine_all_backends(&session, min_sup),
        });
    }

    for d in &datasets {
        // Output invariance first: numbers from diverging runs are noise.
        let reference = d.runs[0].1.all_frequent();
        for (b, o) in &d.runs[1..] {
            assert_eq!(o.all_frequent(), reference, "{}: {b} diverges from trie", d.dataset);
        }
        let _ = writeln!(
            table,
            "## {} ({} txns, min_sup {}): {} frequent itemsets",
            d.dataset,
            d.n_txns,
            d.min_sup,
            d.runs[0].1.total_frequent()
        );
        let _ = writeln!(
            table,
            "{:<12} {:>12} {:>12} {:>10}   per-phase simulated s",
            "backend", "simulated(s)", "actual(s)", "wall(s)"
        );
        for (b, o) in &d.runs {
            let phases: Vec<String> = o
                .phases
                .iter()
                .map(|p| format!("{}[{}]={:.1}", p.job, p.backend_label(), p.elapsed))
                .collect();
            let _ = writeln!(
                table,
                "{:<12} {:>12.1} {:>12.1} {:>10.3}   {}",
                b.name(),
                o.total_time,
                o.actual_time,
                o.wall_time,
                phases.join(" ")
            );
        }
        // Headline: best per-phase bitmap-vs-trie simulated speedup.
        let trie = &d.runs[0].1;
        let bitmap = &d.runs[1].1;
        let best = trie
            .phases
            .iter()
            .zip(&bitmap.phases)
            .filter(|(t, _)| t.job.starts_with("job2"))
            .map(|(t, b)| (t.job.clone(), t.elapsed / b.elapsed))
            .max_by(|a, b| a.1.total_cmp(&b.1));
        if let Some((job, x)) = best {
            let _ = writeln!(table, "bitmap vs trie: best pass {job} at {x:.1}x\n");
        }
    }

    let mut json = String::new();
    let _ = write!(
        json,
        "{{\n  \"bench\": \"counting_backends\",\n  \"quick\": {quick},\n  \
         \"algorithm\": \"SPC\",\n  \"datasets\": [\n"
    );
    for (i, d) in datasets.iter().enumerate() {
        json_runs(d, &mut json);
        let _ = write!(json, "{}\n", if i + 1 < datasets.len() { "," } else { "" });
    }
    let _ = write!(json, "  ]\n}}\n");

    println!("{table}");
    save_report("ablation_backend.txt", &table);
    save_report("BENCH_backends.json", &json);
    print!("{json}");
}
