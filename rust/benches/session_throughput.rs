//! Seed of the session-layer perf trajectory: warm-session queries/sec
//! (repeated min_sup queries on c20d10k through one `MiningSession`, Job1
//! memoized) vs the cold path (the deprecated one-shot free functions,
//! which replay split planning and Job1 on every call). Emits
//! `BENCH_session.json` under `target/paper_results/`.
//!
//! Run: `cargo bench --bench session_throughput`

use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, MiningOutcome, MiningRequest, MiningSession, RunOptions};
use mrapriori::dataset::{registry, TransactionDb};
use std::time::Instant;

/// The pre-session baseline, isolated so the deprecation allowance stays
/// scoped to the one caller whose job is to measure the old path.
#[allow(deprecated)]
fn cold_run(
    algo: Algorithm,
    db: &TransactionDb,
    min_sup: f64,
    cluster: &ClusterConfig,
    opts: &RunOptions,
) -> MiningOutcome {
    mrapriori::coordinator::run_with(algo, db, min_sup, cluster, opts)
}

fn main() {
    let db = registry::c20d10k();
    let cluster = ClusterConfig::paper_cluster();
    let opts = RunOptions { split_lines: registry::split_lines("c20d10k"), ..Default::default() };
    // The repeated-query workload of the paper's evaluation: several
    // algorithms swept over a handful of supports on one dataset.
    let supports = [0.35, 0.30, 0.25];
    let algorithms = [Algorithm::Spc, Algorithm::Vfpc, Algorithm::OptimizedVfpc];
    let n_queries = (supports.len() * algorithms.len()) as f64;

    // Warm path: one session; each support's Job1 runs once and the other
    // algorithm queries at that support hit the cache.
    let session = MiningSession::for_db(&db, cluster.clone())
        .options(&opts)
        .build()
        .expect("valid session");
    let t0 = Instant::now();
    let mut warm_outcomes = Vec::new();
    for &ms in &supports {
        for &algo in &algorithms {
            let req = MiningRequest::from_options(algo, ms, &opts);
            warm_outcomes.push(session.run(&req).expect("valid request"));
        }
    }
    let warm_secs = t0.elapsed().as_secs_f64();
    let stats = session.stats();

    // Cold path: the pre-session free functions — every query replays
    // split planning and Job1 from scratch.
    let t0 = Instant::now();
    let mut cold_outcomes = Vec::new();
    for &ms in &supports {
        for &algo in &algorithms {
            cold_outcomes.push(cold_run(algo, &db, ms, &cluster, &opts));
        }
    }
    let cold_secs = t0.elapsed().as_secs_f64();

    // The comparison is only meaningful if both paths mine identically.
    for (w, c) in warm_outcomes.iter().zip(&cold_outcomes) {
        assert_eq!(w.all_frequent(), c.all_frequent(), "warm/cold outputs diverged");
    }

    let warm_qps = n_queries / warm_secs;
    let cold_qps = n_queries / cold_secs;
    println!(
        "session_throughput: {} queries on c20d10k ({} supports x {} algorithms)",
        warm_outcomes.len(),
        supports.len(),
        algorithms.len()
    );
    println!(
        "  warm session: {warm_secs:.2} s total, {warm_qps:.3} queries/s \
         (Job1 runs {}, cache hits {})",
        stats.job1_runs, stats.job1_cache_hits
    );
    println!("  cold free-fn: {cold_secs:.2} s total, {cold_qps:.3} queries/s");
    println!("  speedup: {:.2}x", cold_secs / warm_secs);

    let json = format!(
        "{{\n  \"bench\": \"session_throughput\",\n  \"dataset\": \"c20d10k\",\n  \
         \"queries\": {},\n  \"warm_secs\": {warm_secs:.6},\n  \"cold_secs\": {cold_secs:.6},\n  \
         \"warm_queries_per_sec\": {warm_qps:.6},\n  \"cold_queries_per_sec\": {cold_qps:.6},\n  \
         \"speedup\": {:.6},\n  \"job1_runs\": {},\n  \"job1_cache_hits\": {}\n}}\n",
        warm_outcomes.len(),
        cold_secs / warm_secs,
        stats.job1_runs,
        stats.job1_cache_hits
    );
    save_report("BENCH_session.json", &json);
    print!("{json}");
}
