//! Session-layer perf trajectory: warm-session queries/sec (repeated
//! min_sup queries on c20d10k through one `MiningSession`, Job1 memoized,
//! every job on the session's one shared executor pool) vs the cold path
//! (a fresh session per query, which replays split planning, Job1, and
//! pool construction every time — the pre-session cost model; the
//! deprecated free functions it used to measure were removed in 0.3.0).
//! Emits `BENCH_session.json` under `target/paper_results/`.
//!
//! Run: `cargo bench --bench session_throughput`
//! Quick mode (CI telemetry): `BENCH_QUICK=1 cargo bench --bench session_throughput`

use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, MiningOutcome, MiningRequest, MiningSession, RunOptions};
use mrapriori::dataset::{registry, TransactionDb};
use std::time::Instant;

/// The pre-session baseline: a throwaway session per query pays for split
/// planning, HDFS placement, a fresh executor pool, and a fresh Job1 scan
/// on every call — exactly what the retired one-shot free functions did.
fn cold_run(
    algo: Algorithm,
    db: &TransactionDb,
    min_sup: f64,
    cluster: &ClusterConfig,
    opts: &RunOptions,
) -> MiningOutcome {
    MiningSession::for_db(db, cluster.clone())
        .options(opts)
        .build()
        .expect("valid session")
        .run(&MiningRequest::from_options(algo, min_sup, opts))
        .expect("valid request")
}

fn main() {
    let db = registry::c20d10k();
    let cluster = ClusterConfig::paper_cluster();
    let opts = RunOptions { split_lines: registry::split_lines("c20d10k"), ..Default::default() };
    // The repeated-query workload of the paper's evaluation: several
    // algorithms swept over a handful of supports on one dataset. Quick
    // mode (BENCH_QUICK=1) shrinks the grid for CI telemetry runs.
    let quick = std::env::var_os("BENCH_QUICK").is_some();
    let supports: &[f64] = if quick { &[0.35, 0.30] } else { &[0.35, 0.30, 0.25] };
    let algorithms: &[Algorithm] = if quick {
        &[Algorithm::Spc, Algorithm::OptimizedVfpc]
    } else {
        &[Algorithm::Spc, Algorithm::Vfpc, Algorithm::OptimizedVfpc]
    };
    let n_queries = (supports.len() * algorithms.len()) as f64;

    // Warm path: one session; each support's Job1 runs once and the other
    // algorithm queries at that support hit the cache.
    let session = MiningSession::for_db(&db, cluster.clone())
        .options(&opts)
        .build()
        .expect("valid session");
    let t0 = Instant::now();
    let mut warm_outcomes = Vec::new();
    for &ms in supports {
        for &algo in algorithms {
            let req = MiningRequest::from_options(algo, ms, &opts);
            warm_outcomes.push(session.run(&req).expect("valid request"));
        }
    }
    let warm_secs = t0.elapsed().as_secs_f64();
    let stats = session.stats();

    // Cold path: fresh session per query.
    let t0 = Instant::now();
    let mut cold_outcomes = Vec::new();
    for &ms in supports {
        for &algo in algorithms {
            cold_outcomes.push(cold_run(algo, &db, ms, &cluster, &opts));
        }
    }
    let cold_secs = t0.elapsed().as_secs_f64();

    // The comparison is only meaningful if both paths mine identically.
    for (w, c) in warm_outcomes.iter().zip(&cold_outcomes) {
        assert_eq!(w.all_frequent(), c.all_frequent(), "warm/cold outputs diverged");
    }

    let warm_qps = n_queries / warm_secs;
    let cold_qps = n_queries / cold_secs;
    println!(
        "session_throughput: {} queries on c20d10k ({} supports x {} algorithms{})",
        warm_outcomes.len(),
        supports.len(),
        algorithms.len(),
        if quick { ", quick mode" } else { "" }
    );
    println!(
        "  warm session: {warm_secs:.2} s total, {warm_qps:.3} queries/s \
         (Job1 runs {}, cache hits {}, pool high-water {})",
        stats.job1_runs,
        stats.job1_cache_hits,
        session.executor().high_water_mark()
    );
    println!("  cold sessions: {cold_secs:.2} s total, {cold_qps:.3} queries/s");
    println!("  speedup: {:.2}x", cold_secs / warm_secs);

    let json = format!(
        "{{\n  \"bench\": \"session_throughput\",\n  \"dataset\": \"c20d10k\",\n  \
         \"quick\": {quick},\n  \"queries\": {},\n  \"warm_secs\": {warm_secs:.6},\n  \
         \"cold_secs\": {cold_secs:.6},\n  \
         \"warm_queries_per_sec\": {warm_qps:.6},\n  \"cold_queries_per_sec\": {cold_qps:.6},\n  \
         \"speedup\": {:.6},\n  \"job1_runs\": {},\n  \"job1_cache_hits\": {},\n  \
         \"pool_workers\": {},\n  \"pool_high_water\": {}\n}}\n",
        warm_outcomes.len(),
        cold_secs / warm_secs,
        stats.job1_runs,
        stats.job1_cache_hits,
        session.executor().workers(),
        session.executor().high_water_mark()
    );
    save_report("BENCH_session.json", &json);
    print!("{json}");
}
