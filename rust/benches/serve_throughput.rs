//! Serve-daemon perf trajectory: end-to-end queries/sec through a real
//! TCP connection against an in-process `pallas serve` daemon — the cold
//! first-touch pass (every query executes), the warm repeat pass (every
//! query answered from the result cache), and a concurrent identical-query
//! fan-out with coalescing on vs off. Emits `BENCH_serve.json` under
//! `target/paper_results/`; the committed repo-root baseline is what the
//! advisory CI job diffs against.
//!
//! Run: `cargo bench --bench serve_throughput`
//! Quick mode (CI telemetry): `BENCH_QUICK=1 cargo bench --bench serve_throughput`

use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::Algorithm;
use mrapriori::serve::{ServeConfig, Server};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

struct Client {
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        Client { reader: BufReader::new(TcpStream::connect(addr).expect("connect")) }
    }

    /// Send one MINE line and read the full response (header + body
    /// through the `.` terminator), panicking on an `ERR` answer.
    fn query(&mut self, line: &str) {
        let stream = self.reader.get_mut();
        stream.write_all(line.as_bytes()).expect("send");
        stream.write_all(b"\n").expect("send");
        stream.flush().expect("flush");
        let mut response = String::new();
        self.reader.read_line(&mut response).expect("header");
        assert!(response.starts_with("OK\tMINE"), "unexpected response: {response:?}");
        loop {
            response.clear();
            self.reader.read_line(&mut response).expect("body line");
            assert!(!response.is_empty(), "connection closed mid-body");
            if response == ".\n" {
                return;
            }
        }
    }
}

fn serve(coalesce: bool) -> Server {
    let mut config = ServeConfig::new(ClusterConfig::paper_cluster());
    config.query_threads = 4;
    config.coalesce = coalesce;
    Server::start(config).expect("bind an ephemeral port")
}

/// `fanout` clients concurrently issue the SAME query, fresh to the
/// daemon; returns (wall seconds, executions the registry saw).
fn identical_fanout(server: &Server, fanout: usize, line: &str) -> (f64, u64) {
    let before = server.stats().registry.totals.queries;
    let addr = server.addr();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..fanout)
            .map(|_| {
                scope.spawn(move || {
                    Client::connect(addr).query(line);
                })
            })
            .collect();
        for h in handles {
            h.join().expect("fan-out client");
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    (secs, server.stats().registry.totals.queries - before)
}

fn main() {
    let dataset = "c20d10k";
    let quick = std::env::var_os("BENCH_QUICK").is_some();
    let supports: &[f64] = if quick { &[0.35, 0.30] } else { &[0.35, 0.30, 0.25] };
    let algorithms: &[Algorithm] = if quick {
        &[Algorithm::Spc, Algorithm::OptimizedVfpc]
    } else {
        &[Algorithm::Spc, Algorithm::Vfpc, Algorithm::OptimizedVfpc]
    };
    let lines: Vec<String> = supports
        .iter()
        .flat_map(|ms| {
            algorithms
                .iter()
                .map(move |algo| format!("MINE dataset={dataset} algo={algo} min_sup={ms}"))
        })
        .collect();
    let n_queries = lines.len() as f64;
    const FANOUT: usize = 4;

    let server = serve(true);
    let mut client = Client::connect(server.addr());

    // Cold pass: every line is new — sessions open, Job1/Job2 execute.
    let t0 = Instant::now();
    for line in &lines {
        client.query(line);
    }
    let cold_secs = t0.elapsed().as_secs_f64();

    // Warm pass: the identical lines again — all served from the result
    // cache, zero new executions (asserted below via the counters).
    let t0 = Instant::now();
    for line in &lines {
        client.query(line);
    }
    let warm_secs = t0.elapsed().as_secs_f64();
    let warm_stats = server.stats();
    assert_eq!(
        warm_stats.registry.totals.queries as usize,
        lines.len(),
        "warm pass must not execute anything"
    );
    assert_eq!(warm_stats.coalesce.cache_hits as usize, lines.len());

    // Identical concurrent fan-out, coalescing ON: one execution, the
    // rest join it (or read the cache it fills).
    let fresh = format!("MINE dataset={dataset} algo=spc min_sup=0.4");
    let (coalesce_secs, coalesce_execs) = identical_fanout(&server, FANOUT, &fresh);
    let stats = server.stats();
    server.shutdown();
    server.wait();

    // Same fan-out with coalescing OFF (and a fresh daemon): concurrent
    // identical queries race past the cache and execute independently.
    let direct_server = serve(false);
    let (direct_secs, direct_execs) = identical_fanout(&direct_server, FANOUT, &fresh);
    direct_server.shutdown();
    direct_server.wait();

    let cold_qps = n_queries / cold_secs;
    let warm_qps = n_queries / warm_secs;
    let ms = |q: Option<f64>| q.map_or(f64::NAN, |s| s * 1e3);
    println!(
        "serve_throughput: {} distinct queries on {dataset} over TCP{}",
        lines.len(),
        if quick { " (quick mode)" } else { "" }
    );
    println!("  cold pass: {cold_secs:.2} s, {cold_qps:.3} queries/s (all executed)");
    println!("  warm pass: {warm_secs:.4} s, {warm_qps:.1} queries/s (all cached)");
    println!("  warm speedup: {:.1}x", cold_secs / warm_secs);
    println!(
        "  {FANOUT}-way identical fan-out: coalesce on {coalesce_secs:.2} s \
         ({coalesce_execs} executions, {} joins), off {direct_secs:.2} s \
         ({direct_execs} executions)",
        stats.coalesce.coalesced_joins
    );
    println!(
        "  latency p50 {:.3} ms, p95 {:.3} ms over {} OK responses",
        ms(stats.latency.p50()),
        ms(stats.latency.p95()),
        stats.latency.count()
    );

    let json = format!(
        "{{\n  \"bench\": \"serve_throughput\",\n  \"dataset\": \"{dataset}\",\n  \
         \"quick\": {quick},\n  \"queries\": {},\n  \"cold_secs\": {cold_secs:.6},\n  \
         \"warm_secs\": {warm_secs:.6},\n  \
         \"cold_queries_per_sec\": {cold_qps:.6},\n  \"warm_queries_per_sec\": {warm_qps:.6},\n  \
         \"warm_speedup\": {:.6},\n  \"warm_cache_hits\": {},\n  \
         \"fanout_clients\": {FANOUT},\n  \"fanout_coalesce_secs\": {coalesce_secs:.6},\n  \
         \"fanout_coalesce_executions\": {coalesce_execs},\n  \"coalesced_joins\": {},\n  \
         \"fanout_direct_secs\": {direct_secs:.6},\n  \
         \"fanout_direct_executions\": {direct_execs},\n  \
         \"latency_p50_ms\": {:.6},\n  \"latency_p95_ms\": {:.6}\n}}\n",
        lines.len(),
        cold_secs / warm_secs,
        warm_stats.coalesce.cache_hits,
        stats.coalesce.coalesced_joins,
        ms(stats.latency.p50()),
        ms(stats.latency.p95()),
    );
    save_report("BENCH_serve.json", &json);
    print!("{json}");
}
