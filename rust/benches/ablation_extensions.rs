//! Ablations for the related-work extensions:
//! 1. fused pass-1+2 (triangular matrix, ref [6]) vs the standard Job1 —
//!    per-algorithm time saving on every dataset;
//! 2. PARMA-style approximate mining (ref [14]) vs exact Optimized-VFPC —
//!    speed/recall trade;
//! 3. fault/straggler/speculation robustness through the session API:
//!    the `FaultScenario` grid end to end, with the output-invariance
//!    check and a `BENCH_faults.json` telemetry report.
//!
//! Run: `cargo bench --bench ablation_extensions`
//! Quick mode (CI fault telemetry — skips sections 1-2 and shrinks the
//! fault grid's dataset): `BENCH_QUICK=1 cargo bench --bench ablation_extensions`

use mrapriori::apriori::sampling::{mine_approximate, ParmaParams};
use mrapriori::apriori::sequential::mine;
use mrapriori::bench_harness::tables::{self, FaultScenario};
use mrapriori::bench_harness::timing::{bench, save_report};
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, MiningOutcome, MiningRequest, MiningSession};
use mrapriori::dataset::registry;
use std::fmt::Write as _;

fn fault_json(
    dataset: &str,
    min_sup: f64,
    quick: bool,
    algorithms: &[Algorithm],
    scenarios: &[FaultScenario],
    grid: &[Vec<MiningOutcome>],
) -> String {
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\n  \"bench\": \"ablation_extensions.faults\",\n  \"dataset\": \"{dataset}\",\n  \
         \"min_sup\": {min_sup},\n  \"quick\": {quick},\n  \"scenarios\": [\n"
    );
    for (si, scenario) in scenarios.iter().enumerate() {
        let _ = write!(s, "    {{\"label\": \"{}\", \"results\": [", scenario.label);
        for (ai, algo) in algorithms.iter().enumerate() {
            let out = &grid[si][ai];
            let totals = out.fault_totals().unwrap_or_default();
            let _ = write!(
                s,
                "{}{{\"algorithm\": \"{}\", \"phases\": {}, \"actual_time\": {:.3}, \
                 \"faulted_actual_time\": {:.3}, \"attempts\": {}, \"failures\": {}, \
                 \"stragglers\": {}, \"spec_launches\": {}, \"spec_wins\": {}}}",
                if ai > 0 { ", " } else { "" },
                algo.name(),
                out.n_phases(),
                out.actual_time,
                out.faulted_actual_time().unwrap_or(out.actual_time),
                totals.attempts,
                totals.failures,
                totals.stragglers,
                totals.speculative_launches,
                totals.speculative_wins,
            );
        }
        let _ = writeln!(s, "]}}{}", if si + 1 < scenarios.len() { "," } else { "" });
    }
    let _ = writeln!(s, "  ]\n}}");
    s
}

fn main() {
    let quick = std::env::var_os("BENCH_QUICK").is_some();
    let cluster = ClusterConfig::paper_cluster();
    let mut out = String::new();
    let _ = writeln!(out, "# Extension ablations");

    if !quick {
        // 1. Fused pass 1+2.
        let _ = writeln!(out, "\n## fused pass 1+2 (triangular matrix, ref [6])");
        for name in registry::NAMES {
            let db = registry::load(name);
            let min_sup = registry::reference_min_sup(name).unwrap();
            // One session; fused and unfused occupy distinct Job1 cache keys.
            let session = MiningSession::for_db(&db, cluster.clone())
                .split_lines(registry::split_lines(name))
                .build()
                .expect("valid session");
            let base = MiningRequest::new(Algorithm::OptimizedVfpc).min_sup(min_sup);
            let plain = session.run(&base).expect("valid request");
            let fused = session.run(&base.clone().fuse_pass_2(true)).expect("valid request");
            assert_eq!(plain.all_frequent(), fused.all_frequent(), "{name}: fused diverged");
            let _ = writeln!(
                out,
                "{name:<10} Opt-VFPC: {:.0} s / {} phases -> fused {:.0} s / {} phases ({:+.1}%)",
                plain.actual_time,
                plain.n_phases(),
                fused.actual_time,
                fused.n_phases(),
                100.0 * (fused.actual_time / plain.actual_time - 1.0)
            );
        }

        // 2. PARMA vs exact.
        let _ = writeln!(out, "\n## approximate mining (PARMA-style, ref [14]) vs exact");
        for name in registry::NAMES {
            let db = registry::load(name);
            // Moderate support: approximation is meant for the easy regime.
            let min_sup = registry::reference_min_sup(name).unwrap() + 0.10;
            let exact = mine(&db, min_sup).all_frequent();
            let params = ParmaParams::default();
            let approx = mine_approximate(&db, min_sup, &params);
            let t_exact = bench(0, 3, || {
                std::hint::black_box(mine(&db, min_sup));
            });
            let t_approx = bench(0, 3, || {
                std::hint::black_box(mine_approximate(&db, min_sup, &params));
            });
            let _ = writeln!(
                out,
                "{name:<10} @{min_sup:.2}: recall {:.3}, fpr {:.3}, sample {}x{}; host {:.0} ms exact vs {:.0} ms approx",
                approx.recall(&exact),
                approx.false_positive_rate(&exact),
                approx.n_samples,
                approx.sample_size,
                t_exact.median_s * 1e3,
                t_approx.median_s * 1e3,
            );
        }
    }

    // 3. Fault injection & speculative execution, end to end through the
    //    session API: every phase of every algorithm is re-timed under the
    //    scenario's model while mining output stays byte-identical.
    const QUICK_ALGOS: [Algorithm; 3] =
        [Algorithm::Spc, Algorithm::OptimizedVfpc, Algorithm::OptimizedEtdpc];
    let all = Algorithm::ALL;
    let (dataset, algorithms): (&str, &[Algorithm]) =
        if quick { ("t6i3d2k", &QUICK_ALGOS) } else { ("mushroom", &all) };
    let db = registry::try_load(dataset).expect("bench dataset is registry-resolvable");
    let min_sup = registry::reference_min_sup(dataset).unwrap_or(0.05);
    let session = MiningSession::for_db(&db, cluster.clone())
        .split_lines(registry::split_lines(dataset))
        .build()
        .expect("valid session");
    let scenarios = FaultScenario::grid(0.05, 0.15);
    let grid = tables::fault_sweep(&session, algorithms, &scenarios, |algo| {
        MiningRequest::new(algo).min_sup(min_sup)
    })
    .expect("valid fault sweep");
    // The headline invariant: faults move simulated time, never output.
    let reference = grid[0][0].all_frequent();
    for row in &grid {
        for cell in row {
            assert_eq!(
                cell.all_frequent(),
                reference,
                "{}: fault model changed mining output",
                cell.algorithm
            );
        }
    }
    let _ = writeln!(
        out,
        "\n## fault injection & speculative execution ({dataset} @ {min_sup}, session API)\n"
    );
    let _ = write!(out, "{}", tables::fault_markdown(algorithms, &scenarios, &grid));
    let _ = writeln!(
        out,
        "\noutput invariance: every scenario mined identical frequent itemsets ({} of them)",
        reference.len()
    );

    println!("{out}");
    save_report("ablation_extensions.txt", &out);
    let json = fault_json(dataset, min_sup, quick, algorithms, &scenarios, &grid);
    save_report("BENCH_faults.json", &json);
    print!("{json}");
}
