//! Ablations for the related-work extensions:
//! 1. fused pass-1+2 (triangular matrix, ref [6]) vs the standard Job1 —
//!    per-algorithm time saving on every dataset;
//! 2. PARMA-style approximate mining (ref [14]) vs exact Optimized-VFPC —
//!    speed/recall trade;
//! 3. fault/straggler/speculation study on the heaviest phase's task mix.

use mrapriori::apriori::sampling::{mine_approximate, ParmaParams};
use mrapriori::apriori::sequential::mine;
use mrapriori::bench_harness::timing::{bench, save_report};
use mrapriori::cluster::{schedule_with_faults, ClusterConfig, FaultModel, SimTask};
use mrapriori::coordinator::{Algorithm, MiningRequest, MiningSession};
use mrapriori::dataset::registry;
use std::fmt::Write as _;

fn main() {
    let cluster = ClusterConfig::paper_cluster();
    let mut out = String::new();

    // 1. Fused pass 1+2.
    let _ = writeln!(out, "# Extension ablations\n\n## fused pass 1+2 (triangular matrix, ref [6])");
    for name in registry::NAMES {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        // One session; fused and unfused occupy distinct Job1 cache keys.
        let session = MiningSession::for_db(&db, cluster.clone())
            .split_lines(registry::split_lines(name))
            .build()
            .expect("valid session");
        let base = MiningRequest::new(Algorithm::OptimizedVfpc).min_sup(min_sup);
        let plain = session.run(&base).expect("valid request");
        let fused = session.run(&base.clone().fuse_pass_2(true)).expect("valid request");
        assert_eq!(plain.all_frequent(), fused.all_frequent(), "{name}: fused diverged");
        let _ = writeln!(
            out,
            "{name:<10} Opt-VFPC: {:.0} s / {} phases -> fused {:.0} s / {} phases ({:+.1}%)",
            plain.actual_time,
            plain.n_phases(),
            fused.actual_time,
            fused.n_phases(),
            100.0 * (fused.actual_time / plain.actual_time - 1.0)
        );
    }

    // 2. PARMA vs exact.
    let _ = writeln!(out, "\n## approximate mining (PARMA-style, ref [14]) vs exact");
    for name in registry::NAMES {
        let db = registry::load(name);
        // Moderate support: approximation is meant for the easy regime.
        let min_sup = registry::reference_min_sup(name).unwrap() + 0.10;
        let exact = mine(&db, min_sup).all_frequent();
        let params = ParmaParams::default();
        let approx = mine_approximate(&db, min_sup, &params);
        let t_exact = bench(0, 3, || {
            std::hint::black_box(mine(&db, min_sup));
        });
        let t_approx = bench(0, 3, || {
            std::hint::black_box(mine_approximate(&db, min_sup, &params));
        });
        let _ = writeln!(
            out,
            "{name:<10} @{min_sup:.2}: recall {:.3}, fpr {:.3}, sample {}x{}; host {:.0} ms exact vs {:.0} ms approx",
            approx.recall(&exact),
            approx.false_positive_rate(&exact),
            approx.n_samples,
            approx.sample_size,
            t_exact.median_s * 1e3,
            t_approx.median_s * 1e3,
        );
    }

    // 3. Faults & speculation on a realistic task mix (mushroom pass-8
    //    compute seconds from the cost model, 9 tasks on the paper cluster).
    let _ = writeln!(out, "\n## fault injection & speculative execution");
    let tasks: Vec<SimTask> =
        (0..9).map(|i| SimTask { compute_secs: 20.0 + i as f64, preferred_nodes: vec![i % 4] }).collect();
    let slots: Vec<(usize, f64)> = (0..4).flat_map(|n| std::iter::repeat((n, 1.0)).take(4)).collect();
    let oh = cluster.overhead;
    for (label, model) in [
        ("clean", FaultModel::default()),
        ("5% task failures", FaultModel { fail_prob: 0.05, seed: 3, ..Default::default() }),
        (
            "15% stragglers (6x)",
            FaultModel { straggler_prob: 0.15, seed: 3, ..Default::default() },
        ),
        (
            "15% stragglers + speculation",
            FaultModel { straggler_prob: 0.15, speculation: true, seed: 3, ..Default::default() },
        ),
    ] {
        let r = schedule_with_faults(&tasks, &slots, &oh, &model);
        let _ = writeln!(
            out,
            "{label:<30} makespan {:>6.1} s  attempts {:>2}  failures {}  stragglers {}  spec launches/wins {}/{}",
            r.makespan, r.attempts, r.failures, r.stragglers, r.speculative_launches, r.speculative_wins
        );
    }

    println!("{out}");
    save_report("ablation_extensions.txt", &out);
}
