//! Replays the paper's ref [16] study (Singh, Garg & Mishra, ICCCA'16):
//! the influence of the candidate data structure — hash tree, trie, hash
//! table trie — on Apriori counting, here on real per-pass workloads from
//! the registry datasets. Build time, counting time, and memory-ish proxy
//! (node counts) per structure; all three verified to count identically.

use mrapriori::apriori::gen::apriori_gen;
use mrapriori::apriori::sequential::mine;
use mrapriori::bench_harness::timing::{bench, save_report};
use mrapriori::dataset::registry;
use mrapriori::itemset::{HashTableTrie, HashTree, Itemset, Trie};
use std::fmt::Write as _;

fn main() {
    let mut out = String::new();
    let _ = writeln!(out, "# Ablation: candidate data structure (paper ref [16])\n");
    for name in registry::NAMES {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        let r = mine(&db, min_sup);
        // Use the peak level's candidates — the heaviest counting pass.
        let (peak_k, _) = r
            .lk_profile()
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, v)| (i + 1, *v))
            .unwrap();
        let seed: Vec<Itemset> =
            r.levels[peak_k - 1].iter().map(|(s, _)| s.clone()).collect();
        let seed_trie = Trie::from_itemsets(peak_k, seed.iter());
        let (cands_trie, _) = apriori_gen(&seed_trie);
        let cands = cands_trie.itemsets();
        let k = peak_k + 1;
        let _ = writeln!(
            out,
            "## {name}: counting |C{k}| = {} over {} transactions",
            cands.len(),
            db.len()
        );

        // Build times.
        let b_trie = bench(1, 5, || {
            std::hint::black_box(Trie::from_itemsets(k, cands.iter()));
        });
        let b_htt = bench(1, 5, || {
            std::hint::black_box(HashTableTrie::from_itemsets(k, cands.iter()));
        });
        let b_ht = bench(1, 5, || {
            std::hint::black_box(HashTree::from_itemsets(k, cands.iter()));
        });
        let _ = writeln!(out, "build  trie       {b_trie}");
        let _ = writeln!(out, "build  hash-trie  {b_htt}");
        let _ = writeln!(out, "build  hash-tree  {b_ht}");

        // Counting times.
        let mut trie = Trie::from_itemsets(k, cands.iter());
        let c_trie = bench(1, 5, || {
            trie.clear_counts();
            for t in &db.txns {
                std::hint::black_box(trie.count_transaction(t));
            }
        });
        let mut htt = HashTableTrie::from_itemsets(k, cands.iter());
        let c_htt = bench(1, 5, || {
            htt.clear_counts();
            for t in &db.txns {
                std::hint::black_box(htt.count_transaction(t));
            }
        });
        // The hash tree's (node, position) recursion is combinatorial in
        // transaction width — pathological on the dense datasets (that IS
        // the [16] finding). Measure it on a 500-txn subsample and report
        // the extrapolated full-scan time.
        let sample: Vec<&Itemset> = db.txns.iter().take(500).collect();
        let scale = db.len() as f64 / sample.len() as f64;
        let mut ht = HashTree::from_itemsets(k, cands.iter());
        let c_ht = bench(0, 3, || {
            ht.clear_counts();
            for t in &sample {
                std::hint::black_box(ht.count_transaction(t));
            }
        });
        let _ = writeln!(out, "count  trie       {c_trie}");
        let _ = writeln!(out, "count  hash-trie  {c_htt}");
        let _ = writeln!(
            out,
            "count  hash-tree  {c_ht}  (500-txn sample; est. full scan {:.0} ms)",
            c_ht.median_s * scale * 1e3
        );

        // Equality of results across structures (on the sample for the
        // hash tree, full scan for the other two vs each other).
        trie.clear_counts();
        htt.clear_counts();
        ht.clear_counts();
        for t in &db.txns {
            trie.count_transaction(t);
            htt.count_transaction(t);
        }
        let by_trie: Vec<(Itemset, u64)> = trie.iter().collect();
        assert_eq!(by_trie, htt.entries(), "{name}: hash-trie counts differ");
        let mut trie_sample = Trie::from_itemsets(k, cands.iter());
        for t in &sample {
            trie_sample.count_transaction(t);
            ht.count_transaction(t);
        }
        assert_eq!(
            trie_sample.iter().collect::<Vec<_>>(),
            ht.entries(),
            "{name}: hash-tree counts differ"
        );
        let _ = writeln!(
            out,
            "nodes: trie {}, hash-trie {}, hash-tree {}; counts identical across all three\n",
            trie.node_count(),
            htt.node_count(),
            ht.node_count()
        );
    }
    let _ = writeln!(
        out,
        "note: [16] (Java/Hadoop) found hash-table-trie fastest; in this rust\n\
         implementation the sorted-vec trie's cache locality typically wins —\n\
         the study is replayed, the conclusion is runtime-dependent."
    );
    println!("{out}");
    save_report("ablation_datastructure.txt", &out);
}
