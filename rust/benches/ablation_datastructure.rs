//! Replays the paper's ref [16] question — the influence of the candidate
//! data structure on Apriori counting — where it matters in this tree: the
//! Job2 hot path, through the session API. For every registry dataset the
//! three counting structures (candidate trie walked per record, vertical
//! TID-bitmap index swept per candidate, dense triangular pair matrix for
//! k=2) mine at the reference support; the report attributes each run's
//! work to its structure-specific counter (`subset_visits`,
//! `bitmap_word_ops`, `triangle_updates`) and prices it with the cluster
//! cost model. All structures are verified to mine identically first —
//! the backend output-invariance contract (DESIGN.md §11). The original
//! in-memory trie / hash-table-trie / hash-tree micro-comparison lives on
//! in the `itemset` unit tests.
//!
//! Run: `cargo bench --bench ablation_datastructure`
//! Quick mode: `BENCH_QUICK=1 cargo bench --bench ablation_datastructure`

use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, CountingBackend, MiningRequest, MiningSession};
use mrapriori::dataset::registry;
use mrapriori::mapreduce::counters::keys;
use std::fmt::Write as _;

fn main() {
    let quick = std::env::var_os("BENCH_QUICK").is_some();
    let cluster = ClusterConfig::paper_cluster();
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# Ablation: candidate data structure in the Job2 hot path (paper ref [16])\n"
    );
    let names: &[&str] = if quick { &["chess"] } else { registry::NAMES };
    for &name in names {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        let session = MiningSession::for_db(&db, cluster.clone())
            .split_lines(registry::split_lines(name))
            .build()
            .expect("valid session");
        let structures =
            [CountingBackend::Trie, CountingBackend::Bitmap, CountingBackend::Triangular];
        let runs: Vec<_> = structures
            .into_iter()
            .map(|b| {
                let o = session
                    .run(&MiningRequest::new(Algorithm::Spc).min_sup(min_sup).backend(b))
                    .expect("valid request");
                (b, o)
            })
            .collect();
        let reference = runs[0].1.all_frequent();
        for (b, o) in &runs[1..] {
            assert_eq!(o.all_frequent(), reference, "{name}: {b} structure changed the mining");
        }
        let _ = writeln!(
            out,
            "## {name}: {} txns, min_sup {min_sup}, {} frequent itemsets",
            db.len(),
            runs[0].1.total_frequent()
        );
        let _ = writeln!(
            out,
            "{:<12} {:>12} {:>10} {:>14} {:>14} {:>14}",
            "structure", "simulated(s)", "wall(s)", "subset_visits", "bitmap_words", "tri_updates"
        );
        for (b, o) in &runs {
            let mut visits = 0u64;
            let mut words = 0u64;
            let mut updates = 0u64;
            for p in &o.phases {
                visits += p.counters.get(keys::SUBSET_VISITS);
                words += p.counters.get(keys::BITMAP_WORD_OPS);
                updates += p.counters.get(keys::TRIANGLE_UPDATES);
            }
            let _ = writeln!(
                out,
                "{:<12} {:>12.1} {:>10.3} {:>14} {:>14} {:>14}",
                b.name(),
                o.total_time,
                o.wall_time,
                visits,
                words,
                updates
            );
        }
        let _ = writeln!(out);
    }
    let _ = writeln!(
        out,
        "note: [16] (Java/Hadoop) compared trie vs hash structures per pass; here the\n\
         same question is asked of the session's per-pass backends — the dense datasets\n\
         favor the trie walk at high support (few candidates), the vertical bitmap wins\n\
         once candidate counts grow, the triangle only ever competes at k=2."
    );
    println!("{out}");
    save_report("ablation_datastructure.txt", &out);
}
