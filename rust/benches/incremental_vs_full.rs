//! Incremental-refresh cost vs cold full re-runs on a growing segment
//! store (ISSUE 9): seed a store, append `rounds` fixed-size batches, and
//! answer "what is frequent now" after each batch two ways — a warm
//! [`FollowSession`] refresh (delta blocks only when the FUP state
//! suffices) vs a cold session built over a store of the same prefix
//! (full scan every time). Both must produce byte-identical frequent
//! itemsets; the report records the wall-clock ratio and how many blocks
//! the delta path actually rescanned. Emits `BENCH_incremental.json`
//! under `target/paper_results/`.
//!
//! Run: `cargo bench --bench incremental_vs_full`
//! Quick mode (CI telemetry): `BENCH_QUICK=1 cargo bench --bench incremental_vs_full`

use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, FollowSession, MiningRequest, MiningSession, RunOptions};
use mrapriori::dataset::ibm::{generate, IbmParams};
use mrapriori::hdfs::{self, segment, segment::SegmentWriter};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let quick = std::env::var_os("BENCH_QUICK").is_some();
    let (n_txns, rounds) = if quick { (2_000, 3) } else { (6_000, 6) };
    let chunk = 250usize;
    let seed_records = n_txns - rounds * chunk;
    let block = 200usize;
    let min_sup = 0.2;
    let db = generate(&IbmParams {
        n_txns,
        n_items: 60,
        avg_txn_len: 10.0,
        avg_pattern_len: 4.0,
        n_patterns: 12,
        correlation: 0.5,
        corruption_mean: 0.3,
        corruption_sd: 0.1,
        seed: 9,
        ..Default::default()
    });
    let cluster = ClusterConfig::paper_cluster();
    let req = MiningRequest::new(Algorithm::OptimizedVfpc).min_sup(min_sup);

    let base = std::env::temp_dir().join("mrapriori_bench_incremental");
    let _ = std::fs::remove_dir_all(&base);
    let live = base.join("live");
    segment::write_store(&live, &db.name, block, db.n_items, db.txns[..seed_records].iter().cloned())
        .expect("seed store");

    // Warm path: bootstrap once (untimed — both paths pay one initial full
    // mine), then time ONLY the per-append refreshes.
    let mut follow = FollowSession::open(&live, cluster.clone()).expect("open store");
    follow.refresh(&req).expect("bootstrap");
    let mut delta_secs = 0.0;
    let mut delta_outs = Vec::new();
    let mut upto = seed_records;
    for _ in 0..rounds {
        let mut w = SegmentWriter::append(&live, db.n_items, block).expect("reopen for append");
        for t in &db.txns[upto..upto + chunk] {
            w.push(t).expect("append record");
        }
        w.finish().expect("publish grown store");
        upto += chunk;
        let t0 = Instant::now();
        let out = follow.refresh(&req).expect("refresh").expect("store moved");
        delta_secs += t0.elapsed().as_secs_f64();
        delta_outs.push(out);
    }
    let stats = follow.stats();

    // Cold path: after each append, a from-scratch session over a store
    // holding the same prefix — store construction excluded, session
    // build + full mine included (that IS the cost being avoided).
    let mut full_secs = 0.0;
    for (r, delta_out) in delta_outs.iter().enumerate() {
        let n = seed_records + (r + 1) * chunk;
        let dir = base.join(format!("cold-{r}"));
        segment::write_store(&dir, &db.name, block, db.n_items, db.txns[..n].iter().cloned())
            .expect("cold store");
        let src = Arc::new(segment::open(&dir).expect("reopen cold store"));
        let t0 = Instant::now();
        let file = hdfs::put_segmented(
            src,
            cluster.nodes.len(),
            hdfs::DEFAULT_REPLICATION,
            RunOptions::default().seed,
        );
        let session =
            MiningSession::builder(file, cluster.clone()).build().expect("cold session");
        let out = session.run(&req).expect("cold run");
        full_secs += t0.elapsed().as_secs_f64();
        assert_eq!(
            out.all_frequent(),
            delta_out.all_frequent(),
            "round {r}: incremental and cold outputs diverged"
        );
    }

    let rescanned: usize = delta_outs.iter().map(|o| o.blocks_rescanned).sum();
    let scanned_full: usize = delta_outs.iter().map(|o| o.total_blocks).sum();
    let delta_rounds = delta_outs.iter().filter(|o| o.delta).count();
    let speedup = full_secs / delta_secs.max(1e-9);
    println!(
        "incremental_vs_full: {} on a {}-node cluster \
         ({seed_records} seed + {rounds} x {chunk} appended{})",
        db.name,
        cluster.nodes.len(),
        if quick { ", quick mode" } else { "" }
    );
    println!(
        "  incremental: {delta_secs:.3} s for {rounds} refreshes \
         ({delta_rounds} delta, {} fallbacks), {rescanned}/{scanned_full} blocks rescanned",
        stats.full_fallbacks
    );
    println!("  cold full:   {full_secs:.3} s for {rounds} re-mines");
    println!("  speedup: {speedup:.2}x");

    let json = format!(
        "{{\n  \"bench\": \"incremental_vs_full\",\n  \"dataset\": \"{}\",\n  \
         \"quick\": {quick},\n  \"seed_records\": {seed_records},\n  \
         \"rounds\": {rounds},\n  \"chunk\": {chunk},\n  \"block_lines\": {block},\n  \
         \"min_sup\": {min_sup},\n  \"delta_secs\": {delta_secs:.6},\n  \
         \"full_secs\": {full_secs:.6},\n  \"speedup\": {speedup:.6},\n  \
         \"delta_rounds\": {delta_rounds},\n  \"full_fallbacks\": {},\n  \
         \"blocks_rescanned\": {rescanned},\n  \"blocks_scanned_full\": {scanned_full}\n}}\n",
        db.name, stats.full_fallbacks
    );
    save_report("BENCH_incremental.json", &json);
    print!("{json}");
    let _ = std::fs::remove_dir_all(&base);
}
