//! Ablation for the paper's §4.3 skipped-pruning analysis:
//!   1. compute-cost breakdown (join / prune / candidate-build / subset /
//!      tuple) for VFPC vs Optimized-VFPC — where the saving comes from;
//!   2. the per-record vs per-task generation charging (the paper's
//!      "apriori-gen is re-invoked for each transaction" observation);
//!   3. un-pruned candidate inflation per dataset.

use mrapriori::bench_harness::timing::save_report;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::mappers::GenMode;
use mrapriori::coordinator::{Algorithm, MiningRequest, MiningSession};
use mrapriori::dataset::registry;
use mrapriori::mapreduce::{keys, Counters};
use std::fmt::Write as _;

fn breakdown(c: &Counters, cluster: &ClusterConfig) -> [(String, f64); 5] {
    let w = &cluster.weights;
    [
        ("join".into(), w.join_pair * c.get(keys::JOIN_PAIRS) as f64),
        ("prune".into(), w.prune_check * c.get(keys::PRUNE_CHECKS) as f64),
        ("cand-build".into(), w.cand_built * c.get(keys::CANDS_BUILT) as f64),
        ("subset".into(), w.subset_visit * c.get(keys::SUBSET_VISITS) as f64),
        ("tuples".into(), w.map_tuple * c.get(keys::MAP_OUTPUT_TUPLES) as f64),
    ]
}

fn main() {
    let cluster = ClusterConfig::paper_cluster();
    let mut out = String::new();
    let _ = writeln!(out, "# Ablation: skipped pruning (§4.3)\n");

    for name in registry::NAMES {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        // Plain and optimized share one session (and one Job1 scan).
        let session = MiningSession::for_db(&db, cluster.clone())
            .split_lines(registry::split_lines(name))
            .build()
            .expect("valid session");
        let plain = session
            .run(&MiningRequest::new(Algorithm::Vfpc).min_sup(min_sup))
            .expect("valid request");
        let optim = session
            .run(&MiningRequest::new(Algorithm::OptimizedVfpc).min_sup(min_sup))
            .expect("valid request");
        let mut pc = Counters::new();
        let mut oc = Counters::new();
        for p in &plain.phases {
            pc.merge(&p.counters);
        }
        for p in &optim.phases {
            oc.merge(&p.counters);
        }
        let _ = writeln!(out, "## {name} @ min_sup {min_sup}");
        let _ = writeln!(
            out,
            "{:<12} {:>14} {:>14} {:>10}",
            "component", "VFPC (agg s)", "Opt-VFPC", "delta"
        );
        for ((label, p), (_, o)) in
            breakdown(&pc, &cluster).into_iter().zip(breakdown(&oc, &cluster))
        {
            let _ = writeln!(out, "{label:<12} {p:>14.1} {o:>14.1} {:>+10.1}", o - p);
        }
        let pcand: u64 = plain.phases.iter().map(|p| p.candidates).sum();
        let ocand: u64 = optim.phases.iter().map(|p| p.candidates).sum();
        let _ = writeln!(
            out,
            "candidates: {pcand} -> {ocand} (+{:.1}% un-pruned); time {:.0} -> {:.0} s ({:+.1}%)\n",
            100.0 * (ocand as f64 / pcand as f64 - 1.0),
            plain.actual_time,
            optim.actual_time,
            100.0 * (optim.actual_time / plain.actual_time - 1.0),
        );
    }

    // Generation-charging ablation: faithful per-record vs hoisted per-task.
    let _ = writeln!(out, "## generation charging (per-record faithful vs per-task hoisted)");
    for name in registry::NAMES {
        let db = registry::load(name);
        let min_sup = registry::reference_min_sup(name).unwrap();
        let session = MiningSession::for_db(&db, cluster.clone())
            .split_lines(registry::split_lines(name))
            .build()
            .expect("valid session");
        let mk = |gm| MiningRequest::new(Algorithm::Vfpc).min_sup(min_sup).gen_mode(gm);
        let faithful = session.run(&mk(GenMode::PerRecord)).expect("valid request");
        let hoisted = session.run(&mk(GenMode::PerTask)).expect("valid request");
        let _ = writeln!(
            out,
            "{name:<10} VFPC: per-record {:>7.0} s vs per-task {:>7.0} s ({:.1}x) — identical output: {}",
            faithful.actual_time,
            hoisted.actual_time,
            faithful.actual_time / hoisted.actual_time,
            faithful.all_frequent() == hoisted.all_frequent(),
        );
    }

    println!("{out}");
    save_report("ablation_pruning.txt", &out);
}
