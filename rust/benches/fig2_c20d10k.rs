//! Regenerates the paper's Fig. 2 (a, b): execution time of the seven
//! algorithms on c20d10k for min_sup 0.35 .. 0.15.
fn main() {
    mrapriori::bench_harness::run_figure_bench("c20d10k", 2);
}
