//! Regenerates the paper's Fig. 3 (a, b): execution time of the seven
//! algorithms on chess for min_sup 0.85 .. 0.65 (DPC α = 3.0, §5.2).
fn main() {
    mrapriori::bench_harness::run_figure_bench("chess", 3);
}
