//! Differential suite pinning the counting-backend contract (DESIGN.md
//! §11): whatever backend counts a pass — trie subset walk, vertical
//! TID-bitmap, dense triangular matrix, or the `auto` cost-model pick —
//! the mined output is byte-identical to the sequential oracle, per-pass
//! candidate counts agree, the recorded pick matches the cost model, and
//! host threading stays invisible. Backends may only move *measured* work
//! between counters, never mined output.

use mrapriori::apriori::sequential::mine;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{
    Algorithm, BackendContext, CountingBackend, MiningError, MiningOutcome, MiningRequest,
    MiningSession, RunOptions,
};
use mrapriori::dataset::ibm::{generate, IbmParams};
use mrapriori::dataset::stats::DensityProfile;
use mrapriori::dataset::TransactionDb;
use mrapriori::mapreduce::counters::keys;
use mrapriori::util::check::{forall, DbGen};

/// One-shot session run with an explicit counting backend.
fn run_b(
    algo: Algorithm,
    db: &TransactionDb,
    min_sup: f64,
    backend: CountingBackend,
    cluster: &ClusterConfig,
    split: usize,
) -> MiningOutcome {
    let o = RunOptions { split_lines: split, ..Default::default() };
    MiningSession::for_db(db, cluster.clone())
        .options(&o)
        .build()
        .expect("test session")
        .run(&MiningRequest::new(algo).min_sup(min_sup).backend(backend))
        .expect("test run")
}

/// A mid-density IBM-generator database: wide enough for 3-4 Apriori
/// passes at the suite's supports, small enough to keep 100+ runs fast.
fn ibm_db(seed: u64) -> TransactionDb {
    generate(&IbmParams {
        n_txns: 600,
        n_items: 48,
        avg_txn_len: 10.0,
        avg_pattern_len: 4.0,
        n_patterns: 12,
        seed,
        ..Default::default()
    })
}

#[test]
fn every_backend_and_algorithm_matches_the_oracle_on_random_dbs() {
    let cluster = ClusterConfig::paper_cluster();
    let gen = DbGen { universe: 24, max_txns: 40, max_width: 10 };
    forall(11, 8, &gen, |small| {
        let db = TransactionDb::new("prop", small.universe, small.txns.clone());
        let oracle = mine(&db, 0.15).all_frequent();
        let o = RunOptions { split_lines: 16, ..Default::default() };
        // One session per database: all 28 backend × algorithm runs share
        // the memoized Job1 scan.
        let session =
            MiningSession::for_db(&db, cluster.clone()).options(&o).build().expect("session");
        for backend in CountingBackend::ALL {
            for algo in Algorithm::ALL {
                let got = session
                    .run(&MiningRequest::new(algo).min_sup(0.15).backend(backend))
                    .expect("run");
                if got.all_frequent() != oracle {
                    eprintln!("{backend} diverges from the oracle under {algo}");
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn per_pass_profile_and_candidates_agree_across_backends() {
    let db = ibm_db(42);
    let cluster = ClusterConfig::paper_cluster();
    let reference = run_b(Algorithm::Fpc, &db, 0.2, CountingBackend::Trie, &cluster, 100);
    let ref_cands: Vec<u64> = reference.phases.iter().map(|p| p.candidates).collect();
    for backend in
        [CountingBackend::Bitmap, CountingBackend::Triangular, CountingBackend::Auto]
    {
        let got = run_b(Algorithm::Fpc, &db, 0.2, backend, &cluster, 100);
        assert_eq!(got.all_frequent(), reference.all_frequent(), "{backend} output diverges");
        assert_eq!(got.lk_profile(), reference.lk_profile(), "{backend} |L_k| diverges");
        let got_cands: Vec<u64> = got.phases.iter().map(|p| p.candidates).collect();
        assert_eq!(got_cands, ref_cands, "{backend} per-phase candidate counts diverge");
    }
}

#[test]
fn explicit_backends_leave_their_counter_signature() {
    let db = ibm_db(7);
    let cluster = ClusterConfig::paper_cluster();
    // SPC keeps every Job2 phase single-pass, so each phase record carries
    // exactly one resolved backend and its counters are unambiguous.
    let trie = run_b(Algorithm::Spc, &db, 0.2, CountingBackend::Trie, &cluster, 100);
    for p in trie.phases.iter().filter(|p| p.job.starts_with("job2")) {
        assert_eq!(p.backends, vec![CountingBackend::Trie], "phase {}", p.phase);
        assert!(p.counters.get(keys::SUBSET_VISITS) > 0, "phase {} walked no trie", p.phase);
        assert_eq!(p.counters.get(keys::BITMAP_WORD_OPS), 0, "phase {}", p.phase);
        assert_eq!(p.counters.get(keys::TRIANGLE_UPDATES), 0, "phase {}", p.phase);
    }
    let bitmap = run_b(Algorithm::Spc, &db, 0.2, CountingBackend::Bitmap, &cluster, 100);
    for p in bitmap.phases.iter().filter(|p| p.job.starts_with("job2")) {
        assert_eq!(p.backends, vec![CountingBackend::Bitmap], "phase {}", p.phase);
        assert!(p.counters.get(keys::BITMAP_WORD_OPS) > 0, "phase {} built no rows", p.phase);
        assert_eq!(p.counters.get(keys::SUBSET_VISITS), 0, "phase {}", p.phase);
        assert_eq!(p.counters.get(keys::TRIANGLE_UPDATES), 0, "phase {}", p.phase);
    }
    // Triangular is pairs-only: the k=2 phase goes dense, deeper phases
    // resolve back to the trie walk (and say so in the record).
    let tri = run_b(Algorithm::Spc, &db, 0.2, CountingBackend::Triangular, &cluster, 100);
    for p in tri.phases.iter().filter(|p| p.job.starts_with("job2")) {
        if p.first_pass == 2 {
            assert_eq!(p.backends, vec![CountingBackend::Triangular], "phase {}", p.phase);
            assert!(p.counters.get(keys::TRIANGLE_UPDATES) > 0, "phase {}", p.phase);
            assert_eq!(p.counters.get(keys::SUBSET_VISITS), 0, "phase {}", p.phase);
        } else {
            assert_eq!(p.backends, vec![CountingBackend::Trie], "phase {}", p.phase);
            assert!(p.counters.get(keys::SUBSET_VISITS) > 0, "phase {}", p.phase);
        }
        assert_eq!(p.counters.get(keys::BITMAP_WORD_OPS), 0, "phase {}", p.phase);
    }
    // Output invariance across all three, while the work actually moved.
    assert_eq!(trie.all_frequent(), bitmap.all_frequent());
    assert_eq!(trie.all_frequent(), tri.all_frequent());
}

#[test]
fn auto_records_the_cost_models_pick_per_phase() {
    let db = ibm_db(3);
    let cluster = ClusterConfig::paper_cluster();
    // Rebuild the exact resolution context the driver derives from Job1's
    // RECORD_ITEMS counter: same N, |I|, total item volume, same weights.
    let total_items: u64 = db.txns.iter().map(|t| t.len() as u64).sum();
    let ctx = BackendContext {
        profile: DensityProfile::from_counts(db.len(), db.n_items, total_items),
        weights: cluster.weights,
    };
    let out = run_b(Algorithm::Spc, &db, 0.15, CountingBackend::Auto, &cluster, 100);
    for p in out.phases.iter().filter(|p| p.job.starts_with("job2")) {
        assert_eq!(p.n_passes, 1, "SPC phases are single-pass");
        let expected = ctx.resolve(CountingBackend::Auto, p.first_pass, p.candidates);
        assert_eq!(
            p.backends,
            vec![expected],
            "phase {} recorded a pick the cost model would not make",
            p.phase
        );
        // The recorded pick is also the backend that actually ran.
        match expected {
            CountingBackend::Trie => assert!(p.counters.get(keys::SUBSET_VISITS) > 0),
            CountingBackend::Bitmap => assert!(p.counters.get(keys::BITMAP_WORD_OPS) > 0),
            CountingBackend::Triangular => assert!(p.counters.get(keys::TRIANGLE_UPDATES) > 0),
            CountingBackend::Auto => unreachable!("resolution never returns auto"),
        }
    }
    // On this mid-density shape the model must leave the trie at least
    // once (the k=2 pass has hundreds of candidates; the vertical sweep
    // is an order of magnitude cheaper there).
    assert!(
        out.phases.iter().any(|p| p.backends.iter().any(|&b| b != CountingBackend::Trie)),
        "auto never left the trie walk"
    );
}

#[test]
fn backends_are_deterministic_across_host_worker_counts() {
    let db = ibm_db(99);
    for backend in CountingBackend::ALL {
        let mut c1 = ClusterConfig::paper_cluster();
        c1.workers = 1;
        let mut c4 = ClusterConfig::paper_cluster();
        c4.workers = 4;
        let serial = run_b(Algorithm::OptimizedEtdpc, &db, 0.2, backend, &c1, 100);
        let threaded = run_b(Algorithm::OptimizedEtdpc, &db, 0.2, backend, &c4, 100);
        assert_eq!(serial.all_frequent(), threaded.all_frequent(), "{backend}");
        assert!(
            (serial.total_time - threaded.total_time).abs() < 1e-9,
            "{backend} simulated time depends on host threading"
        );
        // The per-phase backend record is part of the deterministic output.
        let pa: Vec<&[CountingBackend]> =
            serial.phases.iter().map(|p| p.backends.as_slice()).collect();
        let pb: Vec<&[CountingBackend]> =
            threaded.phases.iter().map(|p| p.backends.as_slice()).collect();
        assert_eq!(pa, pb, "{backend} picks depend on host threading");
    }
}

#[test]
fn triangular_rejects_oversized_universes_with_a_typed_error() {
    // 3000-item universe: over the 2048-item dense-triangle cap.
    let txns: Vec<Vec<u32>> =
        (0..60u32).map(|i| vec![0, 1, 2, 2500 + (i % 10)]).collect();
    let db = TransactionDb::new("wide", 3000, txns);
    let session =
        MiningSession::for_db(&db, ClusterConfig::uniform(2, 2)).build().expect("session");
    let err = session
        .run(
            &MiningRequest::new(Algorithm::Spc)
                .min_sup(0.2)
                .backend(CountingBackend::Triangular),
        )
        .expect_err("a 3000-item universe must exceed the triangular cap");
    assert!(matches!(err, MiningError::InvalidBackend(_)), "wrong error: {err:?}");
    assert!(err.to_string().contains("invalid counting backend"), "{err}");
    // `auto` on the same session silently excludes the dense path instead
    // of erroring — and still mines the (nonempty) answer.
    let ok = session
        .run(&MiningRequest::new(Algorithm::Spc).min_sup(0.2).backend(CountingBackend::Auto))
        .expect("auto never errors on backend choice");
    assert!(ok.total_frequent() > 0, "the 0/1/2 triple is frequent");
    assert!(
        ok.phases.iter().all(|p| p.backends.iter().all(|&b| b != CountingBackend::Triangular)),
        "auto must not pick the dense triangle over a 3000-item universe"
    );
}
