//! Streamed (segment-store) and in-memory dataset loading must be
//! indistinguishable to the miners: identical frequent itemsets, counts,
//! and phase structure — with the streamed path's resident record buffer
//! bounded by the HDFS block size, not the dataset size (ISSUE 2
//! acceptance; DESIGN.md §7).

use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, RunOptions};
use mrapriori::dataset::ibm::QuestGen;
use mrapriori::dataset::registry;
use mrapriori::hdfs::{self, RecordSource as _};
use std::path::PathBuf;
use std::sync::Arc;

/// A small Quest-family entry: big enough for several blocks and deep
/// enough mining to exercise Job2 phases, small enough for tier-1.
const NAME: &str = "t8i3d2k";
const MIN_SUP: f64 = 0.02;

mod common;
use common::{run_file_s, run_s as run_db_s};

fn tmp_cache(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("mrapriori_streaming_equiv").join(tag);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn streamed_mining_matches_in_memory() {
    let cache = tmp_cache("equiv");
    let cluster = ClusterConfig::paper_cluster();
    let src = Arc::new(registry::quest_store(NAME, &cache).unwrap());
    let file =
        hdfs::put_segmented(Arc::clone(&src), cluster.nodes.len(), hdfs::DEFAULT_REPLICATION, 1);
    let db = registry::try_load(NAME).unwrap();
    assert_eq!(file.len(), db.len());
    assert_eq!(file.n_items, db.n_items);
    let opts = RunOptions { split_lines: registry::split_lines(NAME), ..Default::default() };
    for algo in [Algorithm::Spc, Algorithm::OptimizedEtdpc] {
        let streamed = run_file_s(algo, &file, MIN_SUP, &cluster, &opts);
        let memory = run_db_s(algo, &db, MIN_SUP, &cluster, &opts);
        assert!(!streamed.all_frequent().is_empty(), "{algo}: degenerate run");
        assert_eq!(streamed.all_frequent(), memory.all_frequent(), "{algo}");
        assert_eq!(streamed.lk_profile(), memory.lk_profile(), "{algo}");
        assert_eq!(streamed.n_phases(), memory.n_phases(), "{algo}");
        assert_eq!(streamed.min_count, memory.min_count, "{algo}");
    }
    // The acceptance bound: mining touched every record of every split,
    // yet the decode buffer never held more than one block.
    assert!(src.peak_resident_records() > 0, "mining must have streamed records");
    assert!(
        src.peak_resident_records() <= file.block_lines,
        "resident buffer {} exceeds block size {}",
        src.peak_resident_records(),
        file.block_lines
    );
    assert!(file.block_lines < file.len(), "bound is only meaningful with multiple blocks");
    std::fs::remove_dir_all(&cache).unwrap();
}

#[test]
fn streamed_outcome_stable_across_worker_counts() {
    let cache = tmp_cache("workers");
    let src = Arc::new(registry::quest_store(NAME, &cache).unwrap());
    let mut cluster = ClusterConfig::paper_cluster();
    let file =
        hdfs::put_segmented(Arc::clone(&src), cluster.nodes.len(), hdfs::DEFAULT_REPLICATION, 1);
    let opts = RunOptions { split_lines: registry::split_lines(NAME), ..Default::default() };
    cluster.workers = 1;
    let baseline = run_file_s(Algorithm::OptimizedEtdpc, &file, MIN_SUP, &cluster, &opts);
    for workers in [2, 4] {
        cluster.workers = workers;
        let out = run_file_s(Algorithm::OptimizedEtdpc, &file, MIN_SUP, &cluster, &opts);
        assert_eq!(out.all_frequent(), baseline.all_frequent(), "workers={workers}");
        // Simulated time is a function of metered counters, not host
        // threads — it must not drift either.
        assert!((out.total_time - baseline.total_time).abs() < 1e-9, "workers={workers}");
    }
    std::fs::remove_dir_all(&cache).unwrap();
}

#[test]
fn generator_store_is_deterministic_per_seed() {
    let cache_a = tmp_cache("det-a");
    let cache_b = tmp_cache("det-b");
    let a = registry::quest_store(NAME, &cache_a).unwrap();
    let b = registry::quest_store(NAME, &cache_b).unwrap();
    assert_eq!(a.len(), b.len());
    // Byte-identical block files: two generations from the same name agree
    // exactly, so the disk cache can never go stale against the generator.
    let n_blocks = a.len().div_ceil(a.block_lines());
    for i in 0..n_blocks {
        let name = format!("block-{i:05}.txt");
        let ba = std::fs::read(a.dir().join(&name)).unwrap();
        let bb = std::fs::read(b.dir().join(&name)).unwrap();
        assert_eq!(ba, bb, "block {i} differs between generations");
        assert!(!ba.is_empty());
    }
    // ... and the streamed store equals the in-memory generator output.
    let p = registry::quest_params(NAME).unwrap();
    let expected: Vec<_> = QuestGen::new(&p).collect();
    let mut streamed = Vec::new();
    a.for_each(0..a.len(), &mut |_, r| streamed.push(r.clone()));
    assert_eq!(streamed, expected);
    std::fs::remove_dir_all(&cache_a).unwrap();
    std::fs::remove_dir_all(&cache_b).unwrap();
}

#[test]
fn imported_file_mines_identically() {
    use mrapriori::dataset::loader;
    let dir = tmp_cache("import");
    std::fs::create_dir_all(&dir).unwrap();
    // Round-trip an in-memory registry dataset through FIMI text and a
    // segment import; mining the import must match mining the original.
    let db = registry::load("mushroom");
    let path = dir.join("mushroom.txt");
    loader::write_file(&db, &path).unwrap();
    let src = loader::import_segmented(&path, &dir.join("store"), 1000).unwrap();
    let cluster = ClusterConfig::paper_cluster();
    let file =
        hdfs::put_segmented(Arc::new(src), cluster.nodes.len(), hdfs::DEFAULT_REPLICATION, 1);
    let opts = RunOptions { split_lines: 1000, ..Default::default() };
    let streamed = run_file_s(Algorithm::Spc, &file, 0.35, &cluster, &opts);
    let memory = run_db_s(Algorithm::Spc, &db, 0.35, &cluster, &opts);
    assert_eq!(streamed.all_frequent(), memory.all_frequent());
    std::fs::remove_dir_all(&dir).unwrap();
}
