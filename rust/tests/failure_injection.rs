//! Failure injection and edge cases: malformed inputs, degenerate datasets,
//! hostile configurations — the system must fail loudly (typed
//! `MiningError`s at the session layer) or degrade gracefully, never
//! silently mis-mine.

use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{
    Algorithm, MiningError, MiningRequest, MiningSession, RunOptions,
};
use mrapriori::dataset::{loader, TransactionDb};

mod common;
use common::run_s;

fn opts() -> RunOptions {
    RunOptions { split_lines: 10, ..Default::default() }
}

#[test]
fn single_transaction_database() {
    let db = TransactionDb::new("one", 5, vec![vec![0, 1, 2, 3, 4]]);
    let cluster = ClusterConfig::paper_cluster();
    for algo in Algorithm::ALL {
        let out = run_s(algo, &db, 1.0, &cluster, &opts());
        // Every subset of the single transaction is frequent.
        assert_eq!(out.total_frequent(), 31, "{algo}");
        assert_eq!(out.levels.len(), 5, "{algo}");
    }
}

#[test]
fn single_item_transactions() {
    let db = TransactionDb::new("singles", 3, vec![vec![0], vec![1], vec![0], vec![2]]);
    let cluster = ClusterConfig::paper_cluster();
    let out = run_s(Algorithm::OptimizedVfpc, &db, 0.5, &cluster, &opts());
    assert_eq!(out.lk_profile(), vec![1]); // only item 0 (2/4)
}

#[test]
fn nothing_frequent() {
    let db = TransactionDb::new("sparse", 10, (0..10u32).map(|i| vec![i]).collect());
    let cluster = ClusterConfig::paper_cluster();
    for algo in Algorithm::ALL {
        let out = run_s(algo, &db, 0.5, &cluster, &opts());
        assert_eq!(out.total_frequent(), 0, "{algo}");
        assert_eq!(out.n_phases(), 1, "{algo} must stop after Job1");
    }
}

#[test]
fn identical_transactions_everything_frequent() {
    let db = TransactionDb::new("dup", 6, vec![vec![0, 2, 4]; 50]);
    let cluster = ClusterConfig::paper_cluster();
    let out = run_s(Algorithm::OptimizedEtdpc, &db, 1.0, &cluster, &opts());
    assert_eq!(out.lk_profile(), vec![3, 3, 1]);
}

#[test]
fn out_of_domain_min_sup_is_a_typed_error() {
    let db = TransactionDb::new("t", 4, vec![vec![0, 1], vec![1, 2], vec![2, 3]]);
    let session = MiningSession::for_db(&db, ClusterConfig::paper_cluster())
        .options(&opts())
        .build()
        .unwrap();
    // The session API rejects min_sup outside (0, 1] up front instead of
    // mining a degenerate outcome.
    for bad in [0.0, -0.3, 1.5, f64::NAN] {
        let err = session
            .run(&MiningRequest::new(Algorithm::Spc).min_sup(bad))
            .expect_err("out-of-domain min_sup must be rejected");
        assert!(
            matches!(err, MiningError::InvalidMinSup(_)),
            "min_sup {bad}: wrong error {err:?}"
        );
        // The rendered message is a single clean line (what the CLI shows).
        let msg = err.to_string();
        assert!(msg.contains("min_sup"), "{msg}");
        assert!(!msg.contains('\n'));
    }
    // Boundary: exactly 1.0 is valid ("everything must appear everywhere").
    let out = session.run(&MiningRequest::new(Algorithm::Spc).min_sup(1.0)).unwrap();
    assert_eq!(out.total_frequent(), 0);
}

// (The deprecated `run_with` permissive-min_sup shim test lived here until
// 0.3.0 removed the legacy free functions; out-of-domain supports are now
// typed errors on every path — see `out_of_domain_min_sup_is_a_typed_error`.)

#[test]
fn invalid_tunables_are_typed_errors() {
    let db = TransactionDb::new("t", 4, vec![vec![0, 1], vec![0, 1], vec![1, 2]]);
    let session = MiningSession::for_db(&db, ClusterConfig::paper_cluster())
        .options(&opts())
        .build()
        .unwrap();
    let err = session
        .run(&MiningRequest::new(Algorithm::Fpc).min_sup(0.5).fpc_n(0))
        .expect_err("fpc_n = 0 must be rejected");
    assert_eq!(err, MiningError::InvalidFpcN);
    for bad_alpha in [0.5, 0.0, -2.0, f64::NAN, f64::INFINITY] {
        let err = session
            .run(&MiningRequest::new(Algorithm::Dpc).min_sup(0.5).dpc_alpha(bad_alpha))
            .expect_err("degenerate dpc_alpha must be rejected");
        assert!(
            matches!(err, MiningError::InvalidDpcAlpha(_)),
            "alpha {bad_alpha}: wrong error {err:?}"
        );
    }
    let err = session
        .run(&MiningRequest::new(Algorithm::Dpc).min_sup(0.5).dpc_beta(f64::NAN))
        .expect_err("non-finite dpc_beta must be rejected");
    assert!(matches!(err, MiningError::InvalidDpcBeta(_)));
}

#[test]
fn empty_dataset_and_zero_split_are_typed_errors() {
    let empty = TransactionDb::new("empty", 4, vec![]);
    let err = MiningSession::for_db(&empty, ClusterConfig::paper_cluster())
        .options(&opts())
        .build()
        .expect_err("empty dataset must be rejected");
    assert_eq!(err, MiningError::EmptyDataset("empty".into()));

    let db = TransactionDb::new("t", 4, vec![vec![0, 1]]);
    let err = MiningSession::for_db(&db, ClusterConfig::paper_cluster())
        .split_lines(0)
        .build()
        .expect_err("split_lines = 0 must be rejected");
    assert_eq!(err, MiningError::InvalidSplitLines);
}

#[test]
fn degenerate_clusters_are_typed_errors() {
    let db = TransactionDb::new("t", 4, vec![vec![0, 1]]);
    let mut no_workers = ClusterConfig::paper_cluster();
    no_workers.workers = 0;
    let err = MiningSession::for_db(&db, no_workers).build().unwrap_err();
    assert!(matches!(err, MiningError::InvalidCluster(_)));
    let mut no_reducers = ClusterConfig::paper_cluster();
    no_reducers.n_reducers = 0;
    let err = MiningSession::for_db(&db, no_reducers).build().unwrap_err();
    assert!(matches!(err, MiningError::InvalidCluster(_)));
}

#[test]
fn loader_rejects_malformed_lines() {
    assert!(loader::read_transactions("1 2\nbad token\n".as_bytes(), "x").is_err());
    assert!(loader::read_transactions("".as_bytes(), "x").is_err());
    assert!(loader::read_transactions("4294967296".as_bytes(), "x").is_err()); // > u32
}

#[test]
fn loader_accepts_messy_but_valid_input() {
    let db = loader::read_transactions("  3 1 2  \n\n# c\n5 5 5\n".as_bytes(), "x").unwrap();
    assert_eq!(db.txns, vec![vec![1, 2, 3], vec![5]]);
}

#[test]
fn config_rejects_hostile_values() {
    use mrapriori::config::cluster_from_doc;
    use mrapriori::util::tomlmini::Doc;
    for bad in [
        "[weights]\nsubset_visit = -2.0",
        "[cluster]\ndata_nodes = 2\nnode_speeds = [0.0, 1.0]",
        "[cluster]\ndata_nodes = 1\nnode_speeds = [1.0, 1.0]",
    ] {
        assert!(cluster_from_doc(&Doc::parse(bad).unwrap()).is_err(), "{bad}");
    }
}

#[test]
fn zero_sized_cluster_is_impossible_but_one_node_works() {
    let db = TransactionDb::new("t", 4, vec![vec![0, 1], vec![0, 1], vec![1, 2]]);
    let cluster = ClusterConfig::uniform(1, 1); // minimal cluster: 1 node, 1 slot
    let out = run_s(Algorithm::Vfpc, &db, 0.5, &cluster, &opts());
    assert_eq!(out.lk_profile(), vec![2, 1]); // {0},{1},{0,1}
    assert!(out.total_time > 0.0);
}

#[test]
fn split_larger_than_dataset() {
    let db = TransactionDb::new("t", 4, vec![vec![0, 1], vec![0, 1]]);
    let cluster = ClusterConfig::paper_cluster();
    let out = run_s(
        Algorithm::Spc,
        &db,
        0.5,
        &cluster,
        &RunOptions { split_lines: 1_000_000, ..Default::default() },
    );
    assert_eq!(out.lk_profile(), vec![2, 1]);
}

#[test]
fn wide_transaction_deep_mining_terminates() {
    // 18-item transactions at min_count 1: 2^18-ish itemsets would explode;
    // with min_sup 1.0 over two identical txns it must stay linear and the
    // k>64 guard must never be needed.
    let t: Vec<u32> = (0..18).collect();
    let db = TransactionDb::new("wide", 18, vec![t.clone(), t]);
    let cluster = ClusterConfig::paper_cluster();
    let out = run_s(Algorithm::Fpc, &db, 1.0, &cluster, &opts());
    assert_eq!(out.levels.len(), 18);
    assert_eq!(out.levels[17].len(), 1);
}
