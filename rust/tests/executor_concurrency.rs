//! Oversubscription and isolation regressions for the executor-backed
//! session (Engine v2): N simultaneous queries against ONE session with a
//! 2-worker [`Executor`] must (a) mine byte-identically to the sequential
//! oracle, (b) keep peak live task concurrency within the shared pool
//! budget — the old engine gave every query its own scoped thread batch,
//! so 8 queries × 2 workers peaked at 16 live task threads where the
//! executor peaks at 2 — and (c) tolerate one query being cancelled
//! *mid-job* without disturbing the others.
//!
//! [`Executor`]: mrapriori::mapreduce::Executor

use mrapriori::apriori::sequential::mine;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{
    Algorithm, CancelToken, MiningError, MiningRequest, MiningSession, PhaseEvent,
};
use mrapriori::dataset::ibm::{generate, IbmParams};
use mrapriori::dataset::TransactionDb;

fn small_db() -> TransactionDb {
    generate(&IbmParams {
        n_txns: 300,
        n_items: 40,
        avg_txn_len: 8.0,
        avg_pattern_len: 4.0,
        n_patterns: 10,
        correlation: 0.5,
        corruption_mean: 0.3,
        corruption_sd: 0.1,
        seed: 42,
        ..Default::default()
    })
}

/// One session whose executor pool holds exactly 2 host threads, with
/// 30-line splits so every job runs 10 map tasks (plenty of scheduling
/// interleaving to stress).
fn two_worker_session(db: &TransactionDb) -> MiningSession {
    let mut cluster = ClusterConfig::paper_cluster();
    cluster.workers = 2;
    MiningSession::for_db(db, cluster).split_lines(30).build().expect("valid session")
}

#[test]
fn eight_concurrent_queries_stay_inside_the_two_worker_budget() {
    const QUERIES: usize = 8;
    let db = small_db();
    let min_sup = 0.2;
    let oracle = mine(&db, min_sup).all_frequent();
    let session = two_worker_session(&db);
    assert_eq!(session.executor().workers(), 2);

    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for q in 0..QUERIES {
            let session = &session;
            let oracle = &oracle;
            joins.push(scope.spawn(move || {
                let algo = Algorithm::ALL[q % Algorithm::ALL.len()];
                let out =
                    session.run(&MiningRequest::new(algo).min_sup(min_sup)).expect("query");
                assert_eq!(
                    &out.all_frequent(),
                    oracle,
                    "{algo} diverged from the oracle under concurrency"
                );
            }));
        }
        for join in joins {
            join.join().expect("query thread panicked");
        }
    });

    // The oversubscription proof: all 8 queries' map and reduce tasks ran
    // through ONE pool, so peak live task concurrency never exceeded the
    // 2-thread budget (the pool's high-water instrument saw every task).
    let hwm = session.executor().high_water_mark();
    assert!(
        (1..=2).contains(&hwm),
        "live task high-water mark {hwm} exceeds the 2-worker pool budget"
    );
    let stats = session.stats();
    assert_eq!(stats.queries, QUERIES as u64);
    assert_eq!(stats.job1_runs, 1, "one min_count => exactly one Job1 under concurrency");
}

#[test]
fn cancelling_one_query_mid_job_does_not_disturb_the_others() {
    let db = small_db();
    let min_sup = 0.15;
    let oracle = mine(&db, min_sup).all_frequent();
    let session = two_worker_session(&db);
    let token = CancelToken::new();

    std::thread::scope(|scope| {
        // Three steady queries race the doomed one on the same pool.
        let steady: Vec<_> = [Algorithm::Spc, Algorithm::Vfpc, Algorithm::OptimizedEtdpc]
            .into_iter()
            .map(|algo| {
                let session = &session;
                scope.spawn(move || {
                    session
                        .run(&MiningRequest::new(algo).min_sup(min_sup))
                        .expect("steady query")
                })
            })
            .collect();

        let cancelled = {
            let session = &session;
            let token = &token;
            scope.spawn(move || {
                session.run_streaming(
                    &MiningRequest::new(Algorithm::Fpc).min_sup(min_sup),
                    token,
                    |ev| {
                        // Fire as soon as a phase-2 task starts executing:
                        // the cancellation lands INSIDE the running Job2
                        // (its remaining tasks are skipped), not at a
                        // phase boundary.
                        if let PhaseEvent::TaskStarted { phase, .. } = ev {
                            if phase >= 2 {
                                token.cancel();
                            }
                        }
                    },
                )
            })
        };

        let err = cancelled
            .join()
            .expect("cancelled-query thread panicked")
            .expect_err("the cancelled query must not produce an outcome");
        assert_eq!(err, MiningError::Cancelled);

        for join in steady {
            let out = join.join().expect("steady-query thread panicked");
            assert_eq!(
                out.all_frequent(),
                oracle,
                "a steady query was disturbed by its neighbor's cancellation"
            );
        }
    });

    // The pool survived the mid-job cancellation: the session keeps
    // serving queries (with a fresh, un-cancelled token).
    let out =
        session.run(&MiningRequest::new(Algorithm::Fpc).min_sup(min_sup)).expect("post-cancel");
    assert_eq!(out.all_frequent(), oracle);
    assert!(session.executor().high_water_mark() <= 2);
}
