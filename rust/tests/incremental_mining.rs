//! The incremental/windowed mining differential suite (ISSUE 9
//! acceptance; DESIGN.md §13): over randomized append schedules against
//! on-disk segment stores, every [`DeltaOutcome`] — grow-only refresh,
//! sliding window, and the min_sup-change fallback — must be
//! byte-identical to a cold run over the same effective record range,
//! for all seven algorithms; and the delta path must answer at least one
//! refresh per algorithm from fewer blocks than the store holds.

use mrapriori::apriori::sequential::mine;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, FollowSession, MiningRequest, WindowSpec};
use mrapriori::dataset::ibm::{generate, IbmParams};
use mrapriori::dataset::TransactionDb;
use mrapriori::hdfs::segment::{self, SegmentWriter};
use mrapriori::itemset::Itemset;
use mrapriori::util::rng::Rng;
use std::ops::Range;
use std::path::{Path, PathBuf};

/// Store block granularity: small enough that every schedule spans many
/// blocks, misaligned appends leave partial blocks, and windows slide.
const BLOCK: usize = 50;

/// The full transaction pool the schedules draw prefixes from.
fn pool() -> TransactionDb {
    generate(&IbmParams {
        n_txns: 600,
        n_items: 40,
        avg_txn_len: 8.0,
        avg_pattern_len: 4.0,
        n_patterns: 10,
        correlation: 0.5,
        corruption_mean: 0.3,
        corruption_sd: 0.1,
        seed: 42,
        ..Default::default()
    })
}

fn tmp_store(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("mrapriori_incremental").join(tag);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn seed_store(dir: &Path, db: &TransactionDb, n: usize) {
    segment::write_store(dir, &db.name, BLOCK, db.n_items, db.txns[..n].iter().cloned())
        .expect("seed store");
}

fn append(dir: &Path, db: &TransactionDb, range: Range<usize>) {
    let mut w = SegmentWriter::append(dir, db.n_items, BLOCK).expect("reopen for append");
    for t in &db.txns[range] {
        w.push(t).expect("append record");
    }
    w.finish().expect("publish grown store");
}

/// What a cold full run over exactly `range` of the pool yields — the
/// sequential oracle, which every cluster algorithm already matches
/// (`session_api.rs` / `driver_equivalence.rs`), over the sliced records.
fn oracle(db: &TransactionDb, range: Range<usize>, min_sup: f64) -> Vec<(Itemset, u64)> {
    let slice = TransactionDb::new("oracle", db.n_items, db.txns[range].to_vec());
    mine(&slice, min_sup).all_frequent()
}

/// Replicate the block-aligned window placement over an `n`-record store
/// (the trailing edge snaps to a `step` multiple of blocks).
fn window_of(n: usize, spec: WindowSpec) -> Range<usize> {
    let n_blocks = n.div_ceil(BLOCK);
    let end_block = (n_blocks / spec.step) * spec.step;
    let start_block = end_block.saturating_sub(spec.blocks);
    let start = start_block * BLOCK;
    start..(end_block * BLOCK).min(n).max(start)
}

/// Grow-only schedule with deliberately block-misaligned chunks, for all
/// seven algorithms: every refresh must match the cold oracle over the
/// grown prefix, and a refresh of an unmoved store must be answered on
/// the delta path from strictly fewer blocks than the store holds (here:
/// zero — nothing changed).
#[test]
fn grow_refresh_matches_cold_oracle_for_all_algorithms() {
    let db = pool();
    let min_sup = 0.25;
    let cluster = ClusterConfig::paper_cluster();
    for algo in Algorithm::ALL {
        let dir = tmp_store(&format!("grow-{}", algo.name()));
        seed_store(&dir, &db, 300);
        let mut follow = FollowSession::open(&dir, cluster.clone()).expect("open store");
        let req = MiningRequest::new(algo).min_sup(min_sup);

        let boot = follow.refresh(&req).expect("bootstrap").expect("first refresh answers");
        assert!(!boot.delta, "{algo}: the bootstrap is a full run by definition");
        assert_eq!(boot.coverage, 0..300);
        assert_eq!(boot.all_frequent(), oracle(&db, 0..300, min_sup), "{algo}: bootstrap");
        assert_eq!(boot.added.len(), boot.total_frequent(), "{algo}: everything is new");
        assert!(boot.removed.is_empty(), "{algo}");

        let mut upto = 300;
        for chunk in [70, 55, 95] {
            append(&dir, &db, upto..upto + chunk);
            upto += chunk;
            let out = follow.refresh(&req).expect("refresh").expect("store moved");
            assert_eq!(out.coverage, 0..upto, "{algo} @ {upto}");
            assert_eq!(out.total_blocks, upto.div_ceil(BLOCK), "{algo} @ {upto}");
            assert!(out.blocks_rescanned <= out.total_blocks, "{algo} @ {upto}");
            assert_eq!(
                out.all_frequent(),
                oracle(&db, 0..upto, min_sup),
                "{algo} @ {upto}: incremental output diverged from a cold run"
            );
        }

        // Refreshing the unmoved store is a zero-block delta: the held
        // counts already cover every record, so nothing is rescanned —
        // the guaranteed `blocks_rescanned < total_blocks` delta path.
        let still = follow.refresh_always(&req).expect("refresh unmoved store");
        assert!(still.delta, "{algo}: an unmoved store must not force a full run");
        assert_eq!(still.blocks_rescanned, 0, "{algo}");
        assert!(still.blocks_rescanned < still.total_blocks, "{algo}");
        assert!(!still.changed(), "{algo}: nothing appended, nothing may change");
        assert_eq!(still.all_frequent(), oracle(&db, 0..upto, min_sup), "{algo}");

        let stats = follow.stats();
        assert_eq!(stats.delta_runs, 5, "{algo}: bootstrap + 3 appends + 1 no-op");
        assert!(
            stats.blocks_rescanned >= still.total_blocks as u64,
            "{algo}: the bootstrap alone scans the whole store"
        );
        std::fs::remove_dir_all(&dir).expect("cleanup");
    }
}

/// A seeded-random append schedule (chunk sizes 20..120, so block
/// alignment varies freely): the refreshed output must stay byte-identical
/// to the cold oracle at every revision, and an unmoved store must report
/// "nothing new" through [`FollowSession::refresh`].
#[test]
fn randomized_append_schedule_stays_byte_identical() {
    let db = pool();
    let min_sup = 0.25;
    let mut rng = Rng::new(7);
    let dir = tmp_store("random");
    seed_store(&dir, &db, 250);
    let mut follow =
        FollowSession::open(&dir, ClusterConfig::paper_cluster()).expect("open store");
    let req = MiningRequest::new(Algorithm::OptimizedEtdpc).min_sup(min_sup);
    follow.refresh(&req).expect("bootstrap");

    let mut upto = 250;
    while upto < db.len() {
        let chunk = rng.range(20, 120).min(db.len() - upto);
        append(&dir, &db, upto..upto + chunk);
        upto += chunk;
        let out = follow.refresh(&req).expect("refresh").expect("store moved");
        assert_eq!(out.coverage, 0..upto, "after {upto}");
        assert_eq!(
            out.all_frequent(),
            oracle(&db, 0..upto, min_sup),
            "after {upto} records: incremental output diverged from a cold run"
        );
    }
    assert_eq!(upto, db.len());
    // The quiet poll: no growth, no answer (the --follow loop's idle tick).
    assert!(follow.refresh(&req).expect("idle refresh").is_none());

    let stats = follow.stats();
    assert!(stats.delta_runs >= 2);
    assert!(stats.full_fallbacks < stats.delta_runs, "at least the bootstrap is not a fallback");
    std::fs::remove_dir_all(&dir).expect("cleanup");
}

/// Sliding-window schedule for all seven algorithms: each refresh's
/// coverage must land exactly where the block-aligned spec says, its
/// output must match a cold run over those records alone, and refreshing
/// an unmoved window must be a zero-block delta.
#[test]
fn window_refresh_matches_cold_window_for_all_algorithms() {
    let db = pool();
    let min_sup = 0.25;
    let cluster = ClusterConfig::paper_cluster();
    let spec = WindowSpec::new(4).step(2);
    for algo in Algorithm::ALL {
        let dir = tmp_store(&format!("window-{}", algo.name()));
        seed_store(&dir, &db, 300); // 6 blocks: the window starts mid-store
        let mut follow = FollowSession::open(&dir, cluster.clone()).expect("open store");
        let req = MiningRequest::new(algo).min_sup(min_sup);

        let mut upto = 300;
        for round in 0..3 {
            let out = follow.refresh_window(&req, spec).expect("window refresh");
            let expected = window_of(upto, spec);
            assert_eq!(out.coverage, expected, "{algo} round {round}");
            assert_eq!(out.total_blocks, upto.div_ceil(BLOCK), "{algo} round {round}");
            assert_eq!(
                out.all_frequent(),
                oracle(&db, expected, min_sup),
                "{algo} round {round}: window output diverged from a cold run"
            );
            if round == 0 {
                assert!(!out.delta, "{algo}: the first window is a cold bootstrap");
            }
            // Slide by exactly one step (2 blocks) per round.
            append(&dir, &db, upto..upto + 2 * BLOCK);
            upto += 2 * BLOCK;
        }

        // Same store, same spec, no growth: the window has not moved, so
        // the delta identity applies with zero expired/arrived blocks.
        let grown = follow.refresh_window(&req, spec).expect("grown window");
        assert_eq!(grown.all_frequent(), oracle(&db, window_of(upto, spec), min_sup), "{algo}");
        let still = follow.refresh_window(&req, spec).expect("unmoved window");
        assert!(still.delta, "{algo}: an unmoved window must not force a cold mine");
        assert_eq!(still.blocks_rescanned, 0, "{algo}");
        assert!(still.blocks_rescanned < still.total_blocks, "{algo}");
        assert!(!still.changed(), "{algo}");
        std::fs::remove_dir_all(&dir).expect("cleanup");
    }
}

/// Changing `min_sup` re-thresholds the negative border unpredictably, so
/// the snapshot must NOT be reused: the refresh falls back to a full run
/// (and says so in the stats), still matching the cold oracle — and the
/// replacement snapshot is immediately delta-reusable at the new support.
#[test]
fn min_sup_change_forces_full_fallback_then_recovers() {
    let db = pool();
    let dir = tmp_store("fallback");
    seed_store(&dir, &db, 400);
    let mut follow =
        FollowSession::open(&dir, ClusterConfig::paper_cluster()).expect("open store");

    let coarse = MiningRequest::new(Algorithm::Spc).min_sup(0.3);
    follow.refresh(&coarse).expect("bootstrap").expect("first refresh answers");
    assert_eq!(follow.stats().full_fallbacks, 0, "a bootstrap is not a fallback");

    append(&dir, &db, 400..450);
    let fine = MiningRequest::new(Algorithm::Spc).min_sup(0.18);
    let out = follow.refresh(&fine).expect("refresh").expect("store moved");
    assert!(!out.delta, "changed min_sup must not answer from the stale border");
    assert_eq!(out.blocks_rescanned, out.total_blocks, "a fallback rescans everything");
    assert_eq!(out.all_frequent(), oracle(&db, 0..450, 0.18));
    assert_eq!(follow.stats().full_fallbacks, 1);

    append(&dir, &db, 450..500);
    let next = follow.refresh(&fine).expect("refresh").expect("store moved");
    assert_eq!(next.all_frequent(), oracle(&db, 0..500, 0.18));
    assert_eq!(next.coverage, 0..500);
    assert_eq!(
        follow.stats().full_fallbacks,
        if next.delta { 1 } else { 2 },
        "the fallback's snapshot seeds the next refresh at the new support"
    );
    std::fs::remove_dir_all(&dir).expect("cleanup");
}

/// The follower survives a store that grows by a partial block and then
/// completes it: manifest revisions are record counts, not block counts,
/// so a 10-record append is growth like any other.
#[test]
fn partial_block_appends_refresh_correctly() {
    let db = pool();
    let min_sup = 0.25;
    let dir = tmp_store("partial");
    seed_store(&dir, &db, 275); // 5 full blocks + one half block
    let mut follow =
        FollowSession::open(&dir, ClusterConfig::paper_cluster()).expect("open store");
    let req = MiningRequest::new(Algorithm::Vfpc).min_sup(min_sup);
    follow.refresh(&req).expect("bootstrap");

    let mut upto = 275;
    for chunk in [10, 15, 100] {
        append(&dir, &db, upto..upto + chunk);
        upto += chunk;
        let out = follow.refresh(&req).expect("refresh").expect("store moved");
        assert_eq!(out.coverage, 0..upto, "after {upto}");
        assert_eq!(out.all_frequent(), oracle(&db, 0..upto, min_sup), "after {upto}");
    }
    std::fs::remove_dir_all(&dir).expect("cleanup");
}
