//! End-to-end runtime tests: load the AOT-compiled (JAX/Pallas-authored)
//! support-count executable through PJRT and check its numerics against the
//! trie and bitset references on real mining workloads.
//!
//! Requires `make artifacts`; tests skip (with a notice) when the artifacts
//! directory is absent so bare `cargo test` stays green.

use mrapriori::apriori::sequential::mine;
use mrapriori::dataset::registry;
use mrapriori::itemset::{Itemset, Trie};
use mrapriori::runtime::counting::{count_bitset_reference, XlaCounter};
use mrapriori::runtime::pjrt::{artifacts_dir, ArtifactSpec, PjrtRuntime};

fn counter_or_skip(spec: ArtifactSpec) -> Option<XlaCounter> {
    let dir = artifacts_dir();
    if !dir.join(spec.file_name()).exists() {
        eprintln!("SKIP: {} missing — run `make artifacts`", spec.file_name());
        return None;
    }
    Some(XlaCounter::new(PjrtRuntime::load(&dir, spec).expect("artifact must compile")))
}

#[test]
fn xla_counts_simple_sets() {
    let Some(counter) = counter_or_skip(ArtifactSpec::DEFAULT) else { return };
    let cands: Vec<Itemset> = vec![vec![0, 1], vec![1, 2], vec![0, 3], vec![200, 255]];
    let txns: Vec<Itemset> = vec![vec![0, 1, 2], vec![1, 2], vec![0, 1, 3], vec![200, 255]];
    let got = counter.count(&cands, &txns).unwrap();
    assert_eq!(got, vec![2, 2, 1, 1]);
}

#[test]
fn xla_matches_bitset_reference_across_tiles() {
    // More candidates and transactions than one tile holds: exercises the
    // chunking loop in both dimensions.
    let Some(counter) = counter_or_skip(ArtifactSpec::DEFAULT) else { return };
    let mut cands: Vec<Itemset> = Vec::new();
    for i in 0..300u32 {
        cands.push(vec![i % 250, (i % 250) + 1, (i * 7) % 251]);
    }
    for c in &mut cands {
        c.sort_unstable();
        c.dedup();
    }
    cands.retain(|c| c.len() >= 2);
    let mut txns: Vec<Itemset> = Vec::new();
    for i in 0..600u32 {
        let mut t: Itemset = (0..8).map(|j| (i * 13 + j * 29) % 256).collect();
        t.sort_unstable();
        t.dedup();
        txns.push(t);
    }
    let got = counter.count(&cands, &txns).unwrap();
    let expect = count_bitset_reference(&cands, &txns, 256);
    assert_eq!(got, expect);
}

#[test]
fn xla_matches_trie_on_real_mining_pass() {
    // Take a real candidate set from mining mushroom and count a pass with
    // both backends.
    let Some(counter) = counter_or_skip(ArtifactSpec::DEFAULT) else { return };
    let db = registry::mushroom();
    let result = mine(&db, 0.35);
    // Rebuild C3's counts via both backends, seeded from L2.
    let l2: Vec<Itemset> = result.levels[1].iter().map(|(s, _)| s.clone()).collect();
    let l2_trie = Trie::from_itemsets(2, l2.iter());
    let (mut c3, _) = mrapriori::apriori::gen::apriori_gen(&l2_trie);
    for t in &db.txns {
        c3.count_transaction(t);
    }
    let by_xla = counter.count_trie(&c3, &db.txns).unwrap();
    for (set, count) in by_xla {
        assert_eq!(c3.count_of(&set), Some(count), "set {set:?}");
    }
}

#[test]
fn alternate_tile_artifacts_load_and_agree() {
    let cands: Vec<Itemset> = vec![vec![5, 9], vec![1], vec![0, 100, 200]];
    let txns: Vec<Itemset> =
        vec![vec![0, 1, 5, 9, 100, 200], vec![5, 9], vec![1, 5], vec![0, 100, 200]];
    let expect = count_bitset_reference(&cands, &txns, 256);
    for spec in [
        ArtifactSpec { txn_tile: 128, item_width: 256, cand_tile: 256 },
        ArtifactSpec { txn_tile: 512, item_width: 256, cand_tile: 256 },
        ArtifactSpec { txn_tile: 256, item_width: 256, cand_tile: 512 },
    ] {
        let Some(counter) = counter_or_skip(spec) else { return };
        let got = counter.count(&cands, &txns).unwrap();
        assert_eq!(got, expect, "spec {spec:?}");
    }
}

#[test]
fn platform_is_cpu() {
    let Some(counter) = counter_or_skip(ArtifactSpec::DEFAULT) else { return };
    assert_eq!(counter.runtime().platform(), "cpu");
}
