//! End-to-end tests of the serve daemon over real TCP connections on an
//! ephemeral port: wire output byte-identical to an in-process session
//! (the ISSUE 8 acceptance criterion, checked for all seven algorithms),
//! warm-cache answers with zero new Job1/Job2 runs, coalescing of
//! identical concurrent queries, quota and malformed-request rejections,
//! LRU session eviction observed through `STATS`, and a clean drain on
//! `SHUTDOWN`.
//!
//! Datasets are tiny Quest-family names (generated in memory,
//! deterministic by seed) so the whole suite stays in tier-1 time.

use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, MiningRequest, MiningSession};
use mrapriori::dataset::registry;
use mrapriori::serve::{protocol, MineResult, ServeConfig, Server, StatsSnapshot};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn config() -> ServeConfig {
    ServeConfig::new(ClusterConfig::paper_cluster())
}

fn start(config: ServeConfig) -> Server {
    Server::start(config).expect("bind an ephemeral port")
}

/// Spin until `pred` holds on the server's counters (the in-process stats
/// surface exists precisely so tests can sequence against the daemon's
/// internal progress without sleeping blind).
fn wait_for(server: &Server, what: &str, pred: impl Fn(&StatsSnapshot) -> bool) {
    for _ in 0..2000 {
        if pred(&server.stats()) {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("timed out waiting for {what}");
}

/// A test client: one TCP connection speaking the line protocol.
struct Client {
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to the daemon");
        Client { reader: BufReader::new(stream) }
    }

    fn send(&mut self, text: &str) {
        let stream = self.reader.get_mut();
        stream.write_all(text.as_bytes()).expect("send");
        stream.write_all(b"\n").expect("send newline");
        stream.flush().expect("flush");
    }

    fn line(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("read a response line");
        line
    }

    /// Read a multi-line body through its lone-`.` terminator, returning
    /// the raw bytes (terminator included) for byte-identity checks.
    fn body(&mut self) -> String {
        let mut body = String::new();
        loop {
            let line = self.line();
            assert!(!line.is_empty(), "connection closed mid-body");
            let done = line == ".\n";
            body.push_str(&line);
            if done {
                return body;
            }
        }
    }

    /// Read one `OK MINE` response; returns (header fields, raw body).
    fn mine_response(&mut self) -> (HashMap<String, String>, String) {
        let header = self.line();
        let mut fields = header.trim_end().split('\t');
        assert_eq!(fields.next(), Some("OK"), "not an OK response: {header:?}");
        assert_eq!(fields.next(), Some("MINE"), "not a MINE response: {header:?}");
        let map = fields
            .map(|f| {
                let (k, v) = f.split_once('=').expect("header fields are key=value");
                (k.to_string(), v.to_string())
            })
            .collect();
        (map, self.body())
    }

    /// Issue `STATS` and parse the body into a key -> value map.
    fn stats(&mut self) -> HashMap<String, String> {
        self.send("STATS");
        assert_eq!(self.line(), "OK\tSTATS\n");
        let mut map = HashMap::new();
        loop {
            let line = self.line();
            if line == ".\n" {
                return map;
            }
            let (k, v) = line.trim_end().split_once('\t').expect("key\\tvalue stats line");
            map.insert(k.to_string(), v.to_string());
        }
    }
}

/// The acceptance criterion: what arrives on the wire is byte-identical
/// to mining the same query on an in-process session, for all seven
/// algorithms, with the header metadata agreeing field by field.
#[test]
fn wire_output_matches_in_process_session_for_all_algorithms() {
    let name = "t5i2d300";
    let min_sup = 0.4;
    let db = registry::try_load(name).expect("quest dataset builds");
    let oracle = MiningSession::for_db(&db, ClusterConfig::paper_cluster())
        .build()
        .expect("oracle session");

    let server = start(config());
    let mut client = Client::connect(server.addr());
    for algo in Algorithm::ALL {
        let reference = oracle
            .run(&MiningRequest::new(algo).min_sup(min_sup))
            .expect("oracle mines");
        client.send(&format!("MINE dataset={name} algo={algo} min_sup={min_sup}"));
        let (header, body) = client.mine_response();
        assert_eq!(body, protocol::format_body(&reference), "{algo}: body diverged");
        assert_eq!(header["dataset"], name, "{algo}");
        assert_eq!(header["algo"], algo.to_string(), "{algo}");
        assert_eq!(header["min_count"], reference.min_count.to_string(), "{algo}");
        assert_eq!(header["itemsets"], reference.total_frequent().to_string(), "{algo}");
        assert_eq!(header["levels"], reference.lk_profile().len().to_string(), "{algo}");
        // MineResult::from_outcome must agree with the oracle end to end.
        let res = MineResult::from_outcome(&reference);
        assert_eq!(res.body, body, "{algo}: formatting drifted");
    }
    client.send("SHUTDOWN");
    assert_eq!(client.line(), "OK\tBYE\n");
    server.wait();
}

/// A warm daemon answers a repeated query from the result cache: the
/// response says `cached=true` and the session counters are pinned — zero
/// new Job1 OR Job2 executions for the second answer.
#[test]
fn repeated_query_hits_the_result_cache_with_no_new_jobs() {
    let server = start(config());
    let mut client = Client::connect(server.addr());
    let line = "MINE dataset=t5i2d200 algo=opt-vfpc min_sup=0.3";

    client.send(line);
    let (header, body) = client.mine_response();
    assert_eq!(header["cached"], "false");
    let after_first = server.stats();
    assert_eq!(after_first.registry.totals.queries, 1);
    assert!(after_first.registry.totals.job2_runs > 0, "a real run executed Job2 passes");

    client.send(line);
    let (header, body2) = client.mine_response();
    assert_eq!(header["cached"], "true", "second answer must come from the cache");
    assert_eq!(header["coalesced"], "false");
    assert_eq!(body2, body, "cached body must be byte-identical");
    let after_second = server.stats();
    assert_eq!(after_second.registry.totals.queries, 1, "no new session query ran");
    assert_eq!(after_second.registry.totals.job1_runs, after_first.registry.totals.job1_runs);
    assert_eq!(after_second.registry.totals.job2_runs, after_first.registry.totals.job2_runs);
    assert_eq!(after_second.coalesce.cache_hits, 1);
    assert_eq!(after_second.mine_ok, 2);

    // The key is canonical, not textual: a respelled equivalent line
    // (defaults explicit, different float spelling) is the same query.
    client.send("MINE dataset=T5I2D200 algo=optimized-vfpc min_sup=0.30 fuse12=0");
    let (header, body3) = client.mine_response();
    assert_eq!(header["cached"], "true", "respelled query must hit the same entry");
    assert_eq!(body3, body);
    assert_eq!(server.stats().registry.totals.queries, 1);

    server.shutdown();
    server.wait();
}

/// Identical concurrent queries coalesce: the session executes once, and
/// every other request joins that execution or reads the cache it filled
/// (`coalesced_joins + cache_hits == N - 1`, deterministically).
#[test]
fn identical_concurrent_queries_coalesce_into_one_execution() {
    const CLIENTS: usize = 6;
    let mut cfg = config();
    cfg.query_threads = CLIENTS; // every request may execute concurrently
    let server = start(cfg);
    let addr = server.addr();

    let bodies: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|_| {
                scope.spawn(move || {
                    let mut client = Client::connect(addr);
                    client.send("MINE dataset=t5i2d200 algo=spc min_sup=0.2");
                    client.mine_response().1
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    assert!(bodies.windows(2).all(|w| w[0] == w[1]), "all responses must be identical");

    let stats = server.stats();
    assert_eq!(stats.registry.totals.queries, 1, "exactly one execution for N requests");
    assert_eq!(stats.registry.totals.job1_runs, 1);
    assert_eq!(
        stats.coalesce.coalesced_joins + stats.coalesce.cache_hits,
        (CLIENTS - 1) as u64,
        "every non-executing request joined or read the cache"
    );
    assert_eq!(stats.mine_ok, CLIENTS as u64);

    server.shutdown();
    server.wait();
}

/// Admission control: with the per-connection quota at 1 and the single
/// query thread held by another connection's query, a client's second
/// in-flight request is rejected with `ERR quota:` while its first still
/// completes — and the rejection arrives first.
#[test]
fn quota_rejects_excess_in_flight_queries_per_connection() {
    let mut cfg = config();
    cfg.query_threads = 1;
    cfg.client_quota = 1;
    let server = start(cfg);

    // Occupy the lone query thread, and wait until the blocker was
    // actually dequeued so client A's queries cannot start.
    let mut blocker = Client::connect(server.addr());
    blocker.send("MINE dataset=t5i2d400 algo=spc min_sup=0.05 id=block");
    // `queries` increments when run_streaming starts, so this observes the
    // blocker genuinely executing, not merely queued.
    wait_for(&server, "the blocker to start executing", |s| s.registry.totals.queries == 1);

    // Both lines land in one write: the reader admits id=a, then must
    // reject id=b at the quota before anything can finish.
    let mut client = Client::connect(server.addr());
    client.send(
        "MINE dataset=t5i2d200 algo=spc min_sup=0.3 id=a\n\
         MINE dataset=t5i2d200 algo=fpc min_sup=0.3 id=b",
    );
    let rejection = client.line();
    assert!(
        rejection.starts_with("ERR\tid=b\tquota:"),
        "expected the quota rejection first, got {rejection:?}"
    );
    let (header, _) = client.mine_response();
    assert_eq!(header["id"], "a", "the admitted query still completes");
    let (header, _) = blocker.mine_response();
    assert_eq!(header["id"], "block");

    let stats = server.stats();
    assert_eq!(stats.mine_requests, 3);
    assert_eq!(stats.mine_ok, 2);
    assert_eq!(stats.errors, 1);

    server.shutdown();
    server.wait();
}

/// Protocol errors are one-line `ERR` responses on a connection that
/// stays usable, and unknown datasets are typed `dataset:` errors.
#[test]
fn malformed_requests_get_one_line_errors_and_the_connection_survives() {
    let server = start(config());
    let mut client = Client::connect(server.addr());

    client.send("PING");
    assert_eq!(client.line(), "OK\tPONG\n");
    for (request, expect) in [
        ("FROBNICATE", "ERR\tprotocol: unknown verb"),
        ("", "ERR\tprotocol: empty request line"),
        ("MINE dataset=chess", "ERR\tprotocol: missing required key \"algo\""),
        ("MINE dataset=chess algo=spc min_sup=lots", "ERR\tprotocol: key \"min_sup\""),
        ("MINE dataset=chess algo=spc algo=fpc", "ERR\tprotocol: duplicate key"),
        ("MINE dataset=atlantis algo=spc id=q1", "ERR\tid=q1\tdataset: unknown dataset"),
        ("STATS verbose", "ERR\tprotocol: STATS takes no arguments"),
    ] {
        client.send(request);
        let line = client.line();
        assert!(
            line.starts_with(expect),
            "request {request:?}: expected a line starting {expect:?}, got {line:?}"
        );
    }
    // The connection still answers after every rejection.
    client.send("PING");
    assert_eq!(client.line(), "OK\tPONG\n");
    let stats = client.stats();
    assert_eq!(stats["errors"], "7", "every rejected line above was counted");
    assert_eq!(stats["mine_requests"], "1", "only the atlantis line parsed as a MINE");
    assert_eq!(stats["mine_ok"], "0");

    server.shutdown();
    server.wait();
}

/// The session table is LRU-bounded: a third dataset evicts the coldest
/// session, visible through the `STATS` verb, and the evicted session's
/// query counters survive in the aggregates.
#[test]
fn session_table_evicts_least_recently_used_dataset() {
    let mut cfg = config();
    cfg.max_sessions = 2;
    let server = start(cfg);
    let mut client = Client::connect(server.addr());

    for (dataset, algo) in
        [("t5i2d200", "spc"), ("t5i2d300", "spc"), ("t5i2d200", "fpc"), ("t5i2d400", "spc")]
    {
        client.send(&format!("MINE dataset={dataset} algo={algo} min_sup=0.3"));
        client.mine_response();
    }
    let stats = client.stats();
    assert_eq!(stats["open_sessions"], "t5i2d400 t5i2d200", "MRU order after the churn");
    assert_eq!(stats["sessions_opened"], "3");
    assert_eq!(stats["session_hits"], "1", "the t5i2d200 re-touch");
    assert_eq!(stats["session_evictions"], "1", "t5i2d300 went cold and was evicted");
    assert_eq!(stats["session_queries"], "4", "the evicted session's query survived");
    assert_eq!(stats["queries[SPC]"], "3");
    assert_eq!(stats["queries[FPC]"], "1");
    assert_eq!(stats["mine_ok"], "4");

    server.shutdown();
    server.wait();
}

/// `SHUTDOWN` drains: queries admitted before the shutdown still execute
/// and respond, `wait` then observes every thread exiting, and admission
/// after the drain began is refused.
#[test]
fn shutdown_drains_admitted_queries_before_exiting() {
    let mut cfg = config();
    cfg.query_threads = 1; // serialize, so queries are still queued at shutdown
    let server = start(cfg);
    let mut miner = Client::connect(server.addr());
    miner.send(
        "MINE dataset=t5i2d200 algo=spc min_sup=0.2 id=q1\n\
         MINE dataset=t5i2d200 algo=vfpc min_sup=0.2 id=q2",
    );
    wait_for(&server, "both queries to be admitted", |s| s.mine_requests == 2);

    let mut killer = Client::connect(server.addr());
    killer.send("SHUTDOWN");
    assert_eq!(killer.line(), "OK\tBYE\n");

    // Both pre-shutdown queries complete with full responses.
    let (header, _) = miner.mine_response();
    assert_eq!(header["id"], "q1");
    let (header, body) = miner.mine_response();
    assert_eq!(header["id"], "q2");
    assert!(body.ends_with(".\n"));

    // ... and the daemon exits cleanly once drained.
    server.wait();
}
