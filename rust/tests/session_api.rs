//! The session/query API: cross-query Job1 reuse (the ISSUE 3 acceptance
//! criterion), phase- and task-event streaming, cooperative cancellation,
//! background handles, concurrent queries against one session, and
//! warm-vs-cold session equivalence (the executor redesign must be
//! invisible in mining output).

use mrapriori::apriori::sequential::mine;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{
    Algorithm, CancelToken, MiningError, MiningRequest, MiningSession, PhaseEvent, RunOptions,
};
use mrapriori::dataset::ibm::{generate, IbmParams};
use mrapriori::dataset::{registry, TransactionDb};

fn small_db() -> TransactionDb {
    generate(&IbmParams {
        n_txns: 300,
        n_items: 40,
        avg_txn_len: 8.0,
        avg_pattern_len: 4.0,
        n_patterns: 10,
        correlation: 0.5,
        corruption_mean: 0.3,
        corruption_sd: 0.1,
        seed: 42,
        ..Default::default()
    })
}

fn session_for(db: &TransactionDb) -> MiningSession {
    MiningSession::for_db(db, ClusterConfig::paper_cluster())
        .split_lines(50)
        .build()
        .expect("valid session")
}

/// The acceptance criterion: two consecutive queries on one session at the
/// same support run Job1 exactly once, verified via the session counters
/// AND the phase records, with identical mining output.
#[test]
fn job1_runs_once_across_queries_at_same_support() {
    let db = small_db();
    let session = session_for(&db);
    let first = session.run(&MiningRequest::new(Algorithm::Vfpc).min_sup(0.2)).unwrap();
    let second = session.run(&MiningRequest::new(Algorithm::Spc).min_sup(0.2)).unwrap();

    let stats = session.stats();
    assert_eq!(stats.queries, 2);
    assert_eq!(stats.job1_runs, 1, "Job1 must execute once for one min_count");
    assert_eq!(stats.job1_cache_hits, 1);
    // Job2 passes are never cached: every phase past each query's Job1 is
    // a fresh Job2 execution.
    assert_eq!(stats.job2_runs, (first.n_phases() + second.n_phases() - 2) as u64);
    // The per-algorithm breakdown (serve's STATS surface) saw one each.
    assert_eq!(stats.queries_by_algorithm[Algorithm::Vfpc.index()], 1);
    assert_eq!(stats.queries_by_algorithm[Algorithm::Spc.index()], 1);
    assert_eq!(stats.queries_by_algorithm.iter().sum::<u64>(), stats.queries);

    // The cached phase is the same measurement: identical job name,
    // simulated timing, and counters in both outcomes' phase records.
    let (a, b) = (&first.phases[0], &second.phases[0]);
    assert_eq!(a.job, "job1");
    assert_eq!(a.job, b.job);
    assert_eq!(a.elapsed, b.elapsed);
    assert_eq!(a.counters, b.counters);

    // And the mining output still matches the oracle exactly.
    let oracle = mine(&db, 0.2).all_frequent();
    assert_eq!(first.all_frequent(), oracle);
    assert_eq!(second.all_frequent(), oracle);
}

#[test]
fn job1_cache_keyed_by_min_count_and_fusion() {
    let db = small_db(); // 300 txns
    let session = session_for(&db);
    // 0.30 and 0.2999 both round up to min_count 90 — one Job1.
    session.run(&MiningRequest::new(Algorithm::Spc).min_sup(0.30)).unwrap();
    session.run(&MiningRequest::new(Algorithm::Vfpc).min_sup(0.2999)).unwrap();
    assert_eq!(session.stats().job1_runs, 1, "same min_count must share Job1");
    // A genuinely different support is a new key...
    session.run(&MiningRequest::new(Algorithm::Spc).min_sup(0.15)).unwrap();
    assert_eq!(session.stats().job1_runs, 2);
    // ... and so is the fused pass-1+2 variant of an existing support.
    session
        .run(&MiningRequest::new(Algorithm::Spc).min_sup(0.30).fuse_pass_2(true))
        .unwrap();
    assert_eq!(session.stats().job1_runs, 3);
}

/// The executor redesign must be invisible in the mining output: a warm
/// shared session (one pool, cached Job1) and a fresh cold session per
/// query (fresh pool, fresh Job1 — the pre-session cost model) produce
/// byte-identical outcomes for all seven algorithms, all matching the
/// sequential oracle. (This test compared against the deprecated
/// `run_with` free function until 0.3.0 removed it.)
#[test]
fn warm_and_cold_sessions_agree_for_all_algorithms() {
    let db = small_db();
    let cluster = ClusterConfig::paper_cluster();
    let opts = RunOptions { split_lines: 50, ..Default::default() };
    let shared = MiningSession::for_db(&db, cluster.clone()).options(&opts).build().unwrap();
    for min_sup in [0.3, 0.15] {
        let oracle = mine(&db, min_sup).all_frequent();
        for algo in Algorithm::ALL {
            let cold = MiningSession::for_db(&db, cluster.clone())
                .options(&opts)
                .build()
                .unwrap()
                .run(&MiningRequest::from_options(algo, min_sup, &opts))
                .unwrap();
            let warm =
                shared.run(&MiningRequest::from_options(algo, min_sup, &opts)).unwrap();
            assert_eq!(
                warm.all_frequent(),
                oracle,
                "{algo} @ {min_sup}: warm session diverged from the oracle"
            );
            assert_eq!(cold.all_frequent(), oracle, "{algo} @ {min_sup}: cold session");
            assert_eq!(warm.lk_profile(), cold.lk_profile(), "{algo} @ {min_sup}");
            assert_eq!(warm.n_phases(), cold.n_phases(), "{algo} @ {min_sup}");
            assert_eq!(warm.min_count, cold.min_count, "{algo} @ {min_sup}");
            // Simulated time is metered, not wall-clock, so it is exactly
            // reproducible across pools, cache states, and worker counts.
            assert!(
                (warm.total_time - cold.total_time).abs() < 1e-9,
                "{algo} @ {min_sup}: {} vs {}",
                warm.total_time,
                cold.total_time
            );
        }
    }
}

#[test]
fn event_stream_matches_outcome_phases() {
    let db = small_db();
    let session = session_for(&db);
    let mut started = Vec::new();
    let mut finished = Vec::new();
    let out = session
        .run_streaming(
            &MiningRequest::new(Algorithm::OptimizedVfpc).min_sup(0.2),
            &CancelToken::new(),
            |ev| match ev {
                PhaseEvent::PhaseStarted { phase, job, first_pass } => {
                    started.push((phase, job, first_pass))
                }
                PhaseEvent::PhaseFinished { record, from_cache } => {
                    finished.push((record, from_cache))
                }
                // Task-granularity events are covered by
                // `task_events_nest_inside_their_phases`.
                PhaseEvent::TaskStarted { .. } | PhaseEvent::TaskFinished { .. } => {}
            },
        )
        .unwrap();
    assert_eq!(started.len(), out.n_phases());
    assert_eq!(finished.len(), out.n_phases());
    for (i, phase) in out.phases.iter().enumerate() {
        let (ev_phase, ev_job, ev_first_pass) = &started[i];
        assert_eq!(*ev_phase, phase.phase);
        assert_eq!(ev_job, &phase.job);
        assert_eq!(*ev_first_pass, phase.first_pass);
        let (record, _) = &finished[i];
        assert_eq!(record.phase, phase.phase);
        assert_eq!(record.job, phase.job);
        assert_eq!(record.elapsed, phase.elapsed);
    }
    // A fresh session's first query computes Job1; nothing is cached.
    assert!(!finished[0].1, "first query must not report a cache hit");

    // A second streamed query reports its Job1 as served from cache.
    let mut cache_flags = Vec::new();
    session
        .run_streaming(
            &MiningRequest::new(Algorithm::Spc).min_sup(0.2),
            &CancelToken::new(),
            |ev| {
                if let PhaseEvent::PhaseFinished { from_cache, .. } = ev {
                    cache_flags.push(from_cache);
                }
            },
        )
        .unwrap();
    assert_eq!(cache_flags[0], true, "second query's Job1 must hit the cache");
    assert!(cache_flags[1..].iter().all(|&f| !f), "Job2 phases are never cached");
}

/// Engine v2 task events: each executing phase brackets its own map and
/// reduce task events; a cached Job1 streams none (nothing executed).
#[test]
fn task_events_nest_inside_their_phases() {
    let db = small_db();
    let session = session_for(&db);
    let mut events = Vec::new();
    session
        .run_streaming(
            &MiningRequest::new(Algorithm::Vfpc).min_sup(0.2),
            &CancelToken::new(),
            |ev| events.push(ev),
        )
        .unwrap();
    let mut current: Option<(usize, String)> = None;
    let mut tasks_in_phase = 0usize;
    for ev in &events {
        match ev {
            PhaseEvent::PhaseStarted { phase, job, .. } => {
                assert!(current.is_none(), "phases must not overlap");
                current = Some((*phase, job.clone()));
                tasks_in_phase = 0;
            }
            PhaseEvent::TaskStarted { phase, job, .. }
            | PhaseEvent::TaskFinished { phase, job, .. } => {
                let (cur_phase, cur_job) =
                    current.as_ref().expect("task event outside any phase");
                assert_eq!(phase, cur_phase, "task event crossed a phase boundary");
                assert_eq!(&**job, cur_job.as_str(), "task event names the wrong job");
                tasks_in_phase += 1;
            }
            PhaseEvent::PhaseFinished { record, from_cache } => {
                let (cur_phase, _) = current.take().expect("finish without start");
                assert_eq!(record.phase, cur_phase);
                if !from_cache {
                    // 300 txns / 50-line splits = 6 map tasks, plus the
                    // paper cluster's 4 reduce tasks, each started+finished.
                    assert_eq!(
                        tasks_in_phase, 20,
                        "phase {cur_phase}: wrong task event count"
                    );
                }
            }
        }
    }
    assert!(current.is_none(), "a phase never finished");

    // A second query at the same support is served Job1 from the cache:
    // its phase-1 bracket must contain NO task events.
    let mut events = Vec::new();
    session
        .run_streaming(
            &MiningRequest::new(Algorithm::Spc).min_sup(0.2),
            &CancelToken::new(),
            |ev| events.push(ev),
        )
        .unwrap();
    let phase1_tasks = events
        .iter()
        .filter(|e| {
            matches!(
                e,
                PhaseEvent::TaskStarted { phase: 1, .. }
                    | PhaseEvent::TaskFinished { phase: 1, .. }
            )
        })
        .count();
    assert_eq!(phase1_tasks, 0, "cached Job1 must stream no task events");
    assert!(events
        .iter()
        .any(|e| matches!(e, PhaseEvent::PhaseFinished { from_cache: true, .. })));
}

#[test]
fn cancellation_stops_between_phases() {
    let db = small_db();
    let session = session_for(&db);
    let token = CancelToken::new();
    let mut seen = 0usize;
    let err = session
        .run_streaming(
            &MiningRequest::new(Algorithm::Spc).min_sup(0.15),
            &token,
            |ev| {
                if let PhaseEvent::PhaseFinished { .. } = ev {
                    seen += 1;
                    token.cancel(); // cancel as soon as the first phase lands
                }
            },
        )
        .expect_err("cancelled run must not produce an outcome");
    assert_eq!(err, MiningError::Cancelled);
    assert_eq!(seen, 1, "exactly one phase should finish before the cancel lands");
    // The session stays fully usable afterwards.
    let out = session.run(&MiningRequest::new(Algorithm::Spc).min_sup(0.15)).unwrap();
    assert_eq!(out.all_frequent(), mine(&db, 0.15).all_frequent());
}

#[test]
fn submit_streams_events_and_joins_to_the_same_outcome() {
    let db = small_db();
    let session = session_for(&db);
    let reference = session.run(&MiningRequest::new(Algorithm::Vfpc).min_sup(0.2)).unwrap();

    let handle = session.submit(MiningRequest::new(Algorithm::Vfpc).min_sup(0.2)).unwrap();
    assert_eq!(handle.algorithm(), Algorithm::Vfpc);
    let events: Vec<PhaseEvent> = handle.events().collect();
    let finished = events
        .iter()
        .filter(|e| matches!(e, PhaseEvent::PhaseFinished { .. }))
        .count();
    let out = handle.join().expect("background run succeeds");
    assert_eq!(finished, out.n_phases());
    assert_eq!(out.all_frequent(), reference.all_frequent());
    assert!((out.total_time - reference.total_time).abs() < 1e-9);
}

#[test]
fn submit_rejects_invalid_requests_before_spawning() {
    let db = small_db();
    let session = session_for(&db);
    let err = session
        .submit(MiningRequest::new(Algorithm::Spc).min_sup(2.0))
        .expect_err("invalid request must fail fast");
    assert!(matches!(err, MiningError::InvalidMinSup(_)));
}

#[test]
fn handle_cancel_is_cooperative() {
    let db = small_db();
    let session = session_for(&db);
    let handle = session.submit(MiningRequest::new(Algorithm::Spc).min_sup(0.15)).unwrap();
    handle.cancel();
    // The worker may have raced past the last cancellation point; both a
    // cancelled and a completed run are legal — but nothing else.
    match handle.join() {
        Err(MiningError::Cancelled) => {}
        Ok(out) => assert_eq!(out.all_frequent(), mine(&db, 0.15).all_frequent()),
        Err(other) => panic!("unexpected error: {other}"),
    }
}

/// Concurrency stress (ISSUE 3 satellite): N threads x 7 algorithms
/// against ONE session. Every outcome must be byte-identical to the
/// sequential oracle, and Job1 must have executed exactly once for the
/// single distinct min_count.
#[test]
fn concurrent_queries_share_one_job1_and_match_the_oracle() {
    const THREADS: usize = 3;
    let db = small_db();
    let min_sup = 0.2;
    let oracle = mine(&db, min_sup).all_frequent();
    let session = session_for(&db);

    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for t in 0..THREADS {
            let session = &session;
            let oracle = &oracle;
            joins.push(scope.spawn(move || {
                // Stagger the per-thread algorithm order so the cache sees
                // genuinely interleaved keys.
                for i in 0..Algorithm::ALL.len() {
                    let algo = Algorithm::ALL[(i + t) % Algorithm::ALL.len()];
                    let out = session
                        .run(&MiningRequest::new(algo).min_sup(min_sup))
                        .expect("concurrent run");
                    assert_eq!(
                        &out.all_frequent(),
                        oracle,
                        "{algo} diverged from the oracle under concurrency"
                    );
                }
            }));
        }
        for j in joins {
            j.join().expect("stress thread panicked");
        }
    });

    let stats = session.stats();
    assert_eq!(stats.queries, (THREADS * Algorithm::ALL.len()) as u64);
    assert_eq!(stats.job1_runs, 1, "one min_count => exactly one Job1 execution");
    assert_eq!(stats.job1_cache_hits, stats.queries - 1);
    // Each thread ran every algorithm exactly once, whatever the
    // interleaving — the per-algorithm counters must agree.
    for algo in Algorithm::ALL {
        assert_eq!(
            stats.queries_by_algorithm[algo.index()],
            THREADS as u64,
            "{algo} query count skewed under concurrency"
        );
    }
}

#[test]
fn builder_defaults_follow_registry_and_block_size() {
    // In-memory sources default to the dataset's registry split size.
    let db = registry::load("chess");
    let session = MiningSession::for_db(&db, ClusterConfig::paper_cluster()).build().unwrap();
    assert_eq!(session.split_lines(), registry::split_lines("chess"));
    assert_eq!(session.file().name, "chess");
    assert_eq!(session.file().len(), db.len());

    // Pre-stored files default to their block granularity.
    let file = mrapriori::hdfs::put(&db, 123, 4, 3, 1);
    let session =
        MiningSession::builder(file, ClusterConfig::paper_cluster()).build().unwrap();
    assert_eq!(session.split_lines(), 123);
}

#[test]
fn cloned_sessions_share_the_cache() {
    let db = small_db();
    let session = session_for(&db);
    let clone = session.clone();
    session.run(&MiningRequest::new(Algorithm::Spc).min_sup(0.2)).unwrap();
    clone.run(&MiningRequest::new(Algorithm::Vfpc).min_sup(0.2)).unwrap();
    assert_eq!(session.stats().job1_runs, 1);
    assert_eq!(clone.stats().queries, 2);
}
