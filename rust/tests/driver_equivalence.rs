//! Cross-algorithm equivalence: every MapReduce driver must produce exactly
//! the frequent itemsets (sets AND counts) of the sequential oracle, on
//! every registry dataset, at several supports — the paper's Fig. 1
//! integrity argument, machine-checked.

use mrapriori::apriori::sequential::mine;
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, RunOptions};
use mrapriori::dataset::ibm::{generate, IbmParams};
use mrapriori::dataset::registry;

mod common;
use common::run_s;

fn opts(split: usize) -> RunOptions {
    RunOptions { split_lines: split, ..Default::default() }
}

#[test]
fn registry_datasets_all_algorithms_match_oracle() {
    let cluster = ClusterConfig::paper_cluster();
    // One moderate support per dataset keeps this test minutes-fast while
    // exercising multi-pass phases on all three.
    for (name, min_sup) in [("c20d10k", 0.30), ("chess", 0.80), ("mushroom", 0.30)] {
        let db = registry::load(name);
        let oracle = mine(&db, min_sup).all_frequent();
        for algo in Algorithm::ALL {
            let got =
                run_s(algo, &db, min_sup, &cluster, &opts(registry::split_lines(name)));
            assert_eq!(
                got.all_frequent(),
                oracle,
                "{algo} on {name} @ {min_sup} diverges from the oracle"
            );
        }
    }
}

#[test]
fn deep_mining_equivalence_low_support() {
    // Low support on the smallest dataset: long itemsets, many multi-pass
    // phases, optimized pruning heavily exercised.
    let cluster = ClusterConfig::paper_cluster();
    let db = registry::chess();
    let oracle = mine(&db, 0.65).all_frequent();
    for algo in [Algorithm::Vfpc, Algorithm::OptimizedVfpc, Algorithm::OptimizedEtdpc] {
        let got = run_s(algo, &db, 0.65, &cluster, &opts(400));
        assert_eq!(got.all_frequent(), oracle, "{algo} diverges at 0.65");
    }
}

#[test]
fn split_size_does_not_change_results() {
    let cluster = ClusterConfig::paper_cluster();
    let db = generate(&IbmParams {
        n_txns: 700,
        n_items: 60,
        avg_txn_len: 10.0,
        avg_pattern_len: 4.0,
        n_patterns: 15,
        seed: 77,
        ..Default::default()
    });
    let oracle = mine(&db, 0.2).all_frequent();
    for split in [50, 100, 333, 700, 1000] {
        let got = run_s(Algorithm::OptimizedVfpc, &db, 0.2, &cluster, &opts(split));
        assert_eq!(got.all_frequent(), oracle, "split {split} changes results");
    }
}

#[test]
fn cluster_size_does_not_change_results() {
    let db = generate(&IbmParams {
        n_txns: 500,
        n_items: 50,
        avg_txn_len: 9.0,
        avg_pattern_len: 4.0,
        n_patterns: 12,
        seed: 88,
        ..Default::default()
    });
    let oracle = mine(&db, 0.25).all_frequent();
    for nodes in [1, 2, 4, 8] {
        let cluster = ClusterConfig::uniform(nodes, 4);
        let got = run_s(Algorithm::Etdpc, &db, 0.25, &cluster, &opts(100));
        assert_eq!(got.all_frequent(), oracle, "{nodes} nodes changes results");
    }
    // ... but MORE nodes means LESS simulated time (speedup sanity).
    let t1 = run_s(Algorithm::Etdpc, &db, 0.25, &ClusterConfig::uniform(1, 4), &opts(50))
        .total_time;
    let t4 = run_s(Algorithm::Etdpc, &db, 0.25, &ClusterConfig::uniform(4, 4), &opts(50))
        .total_time;
    assert!(t4 < t1, "speedup missing: {t4} !< {t1}");
}

#[test]
fn host_workers_do_not_change_results() {
    // Real thread parallelism must be invisible in outputs.
    let db = generate(&IbmParams {
        n_txns: 600,
        n_items: 40,
        avg_txn_len: 8.0,
        avg_pattern_len: 4.0,
        n_patterns: 10,
        seed: 99,
        ..Default::default()
    });
    let mut c1 = ClusterConfig::paper_cluster();
    c1.workers = 1;
    let mut c4 = ClusterConfig::paper_cluster();
    c4.workers = 4;
    let a = run_s(Algorithm::OptimizedEtdpc, &db, 0.2, &c1, &opts(100));
    let b = run_s(Algorithm::OptimizedEtdpc, &db, 0.2, &c4, &opts(100));
    assert_eq!(a.all_frequent(), b.all_frequent());
    // Simulated time is deterministic regardless of host threading.
    assert!((a.total_time - b.total_time).abs() < 1e-9);
}

/// The acceptance check for the threaded shuffle/reduce path: a full
/// c20d10k-analog mining run at the paper's reference support with
/// `workers = 4` must produce byte-identical frequent itemsets to
/// `workers = 1`, and measurably lower wall time when the host actually
/// has the cores. Ignored by default (wall-clock comparison on the full
/// dataset — run with `cargo test --release -- --ignored` on a multi-core
/// host).
#[test]
#[ignore = "wall-clock comparison on the full c20d10k analog; run with --release --ignored"]
fn workers_speed_up_full_c20d10k_run() {
    let db = registry::c20d10k();
    let mut c1 = ClusterConfig::paper_cluster();
    c1.workers = 1;
    let mut c4 = ClusterConfig::paper_cluster();
    c4.workers = 4;
    let o = opts(registry::split_lines("c20d10k"));
    let serial = run_s(Algorithm::OptimizedVfpc, &db, 0.15, &c1, &o);
    let threaded = run_s(Algorithm::OptimizedVfpc, &db, 0.15, &c4, &o);
    assert_eq!(serial.all_frequent(), threaded.all_frequent());
    assert!((serial.total_time - threaded.total_time).abs() < 1e-9);
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    if cores >= 4 {
        assert!(
            threaded.wall_time < serial.wall_time,
            "workers=4 wall {:.3}s !< workers=1 wall {:.3}s on a {cores}-core host",
            threaded.wall_time,
            serial.wall_time
        );
    } else {
        eprintln!("SKIP speedup assertion: only {cores} cores available");
    }
}

#[test]
fn gen_mode_ablation_same_results_different_cost() {
    use mrapriori::coordinator::mappers::GenMode;
    let cluster = ClusterConfig::paper_cluster();
    let db = registry::mushroom();
    let faithful = run_s(
        Algorithm::Vfpc,
        &db,
        0.25,
        &cluster,
        &RunOptions { split_lines: 1000, gen_mode: GenMode::PerRecord, ..Default::default() },
    );
    let cached = run_s(
        Algorithm::Vfpc,
        &db,
        0.25,
        &cluster,
        &RunOptions { split_lines: 1000, gen_mode: GenMode::PerTask, ..Default::default() },
    );
    assert_eq!(faithful.all_frequent(), cached.all_frequent());
    assert!(
        faithful.total_time > cached.total_time * 1.5,
        "per-record generation must dominate: {} vs {}",
        faithful.total_time,
        cached.total_time
    );
}
