//! Cluster-simulation behavior: the paper's qualitative claims about
//! phases, overhead amortization, and the FPC/DPC failure modes, checked as
//! invariants on the real system.

use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{Algorithm, RunOptions};
use mrapriori::dataset::registry;

mod common;
use common::run_s;

fn opts(name: &str) -> RunOptions {
    RunOptions {
        split_lines: registry::split_lines(name),
        dpc_alpha: if name == "chess" { 3.0 } else { 2.0 },
        ..Default::default()
    }
}

/// §5.2: "execution time of SPC must be an upper bound" for the adaptive
/// algorithms (not FPC, which may cross it).
#[test]
fn spc_upper_bounds_adaptive_algorithms() {
    let cluster = ClusterConfig::paper_cluster();
    for (name, min_sup) in [("c20d10k", 0.15), ("chess", 0.65), ("mushroom", 0.15)] {
        let db = registry::load(name);
        let spc = run_s(Algorithm::Spc, &db, min_sup, &cluster, &opts(name));
        for algo in [
            Algorithm::Vfpc,
            Algorithm::Etdpc,
            Algorithm::Dpc,
            Algorithm::OptimizedVfpc,
            Algorithm::OptimizedEtdpc,
        ] {
            let out = run_s(algo, &db, min_sup, &cluster, &opts(name));
            assert!(
                out.actual_time <= spc.actual_time * 1.02,
                "{algo} on {name}: {:.0} > SPC {:.0}",
                out.actual_time,
                spc.actual_time
            );
        }
    }
}

/// §5.2/Figs 3-4(a): FPC converges to / crosses SPC at the lowest support
/// on the dense datasets (overloaded multi-pass phases).
#[test]
fn fpc_crosses_spc_on_dense_datasets_at_low_support() {
    let cluster = ClusterConfig::paper_cluster();
    for (name, min_sup) in [("chess", 0.65), ("mushroom", 0.15)] {
        let db = registry::load(name);
        let spc = run_s(Algorithm::Spc, &db, min_sup, &cluster, &opts(name));
        let fpc = run_s(Algorithm::Fpc, &db, min_sup, &cluster, &opts(name));
        // Paper Tables 4-5: FPC's actual time reaches ~99-103% of SPC's at
        // the lowest support; allow the same near-convergence band here.
        assert!(
            fpc.actual_time >= spc.actual_time * 0.90,
            "{name}: FPC {:.0} should converge to SPC {:.0}",
            fpc.actual_time,
            spc.actual_time
        );
    }
}

/// ... while at HIGH support FPC beats SPC (amortization with no overload).
#[test]
fn fpc_beats_spc_at_high_support() {
    let cluster = ClusterConfig::paper_cluster();
    let db = registry::c20d10k();
    let spc = run_s(Algorithm::Spc, &db, 0.35, &cluster, &opts("c20d10k"));
    let fpc = run_s(Algorithm::Fpc, &db, 0.35, &cluster, &opts("c20d10k"));
    assert!(
        fpc.actual_time < spc.actual_time,
        "FPC {:.0} should beat SPC {:.0} at high support",
        fpc.actual_time,
        spc.actual_time
    );
}

/// §5.3 Tables 3-5: combined algorithms finish in far fewer phases.
#[test]
fn phase_counts_match_paper_structure() {
    let cluster = ClusterConfig::paper_cluster();
    let db = registry::mushroom();
    let o = opts("mushroom");
    let spc = run_s(Algorithm::Spc, &db, 0.15, &cluster, &o);
    let fpc = run_s(Algorithm::Fpc, &db, 0.15, &cluster, &o);
    let vfpc = run_s(Algorithm::Vfpc, &db, 0.15, &cluster, &o);
    // Paper: SPC 16 phases, FPC 7, VFPC 7 (mushroom @0.15).
    assert!(spc.n_phases() >= 14, "SPC phases {}", spc.n_phases());
    assert!(fpc.n_phases() <= 8, "FPC phases {}", fpc.n_phases());
    assert!(vfpc.n_phases() <= 9, "VFPC phases {}", vfpc.n_phases());
    // All found the same number of frequent itemsets.
    assert_eq!(spc.total_frequent(), vfpc.total_frequent());
}

/// The total-vs-actual gap grows with the number of phases (§5.3).
#[test]
fn actual_total_gap_tracks_phase_count() {
    let cluster = ClusterConfig::paper_cluster();
    let db = registry::mushroom();
    let o = opts("mushroom");
    let spc = run_s(Algorithm::Spc, &db, 0.15, &cluster, &o);
    let vfpc = run_s(Algorithm::Vfpc, &db, 0.15, &cluster, &o);
    let gap_spc = spc.actual_time - spc.total_time;
    let gap_vfpc = vfpc.actual_time - vfpc.total_time;
    assert!(gap_spc > gap_vfpc, "gap {gap_spc:.0} !> {gap_vfpc:.0}");
}

/// Optimized variants generate MORE candidates but take LESS time at low
/// support (the skipped-pruning trade, Tables 7-12).
#[test]
fn skipped_pruning_trade_holds() {
    let cluster = ClusterConfig::paper_cluster();
    for (name, min_sup) in [("c20d10k", 0.15), ("mushroom", 0.15)] {
        let db = registry::load(name);
        let o = opts(name);
        let plain = run_s(Algorithm::Vfpc, &db, min_sup, &cluster, &o);
        let optim = run_s(Algorithm::OptimizedVfpc, &db, min_sup, &cluster, &o);
        let plain_cands: u64 = plain.phases.iter().map(|p| p.candidates).sum();
        let optim_cands: u64 = optim.phases.iter().map(|p| p.candidates).sum();
        assert!(optim_cands >= plain_cands, "{name}: candidates must not shrink");
        assert!(
            optim.actual_time < plain.actual_time,
            "{name}: Optimized-VFPC {:.0} !< VFPC {:.0}",
            optim.actual_time,
            plain.actual_time
        );
    }
}

/// At HIGH support everything fits in <= 3 phases and the optimized and
/// plain versions coincide (§5.2: "execution times of all four are the
/// same" at large min_sup).
#[test]
fn optimized_equals_plain_at_high_support() {
    let cluster = ClusterConfig::paper_cluster();
    let db = registry::c20d10k();
    let o = opts("c20d10k");
    let plain = run_s(Algorithm::Vfpc, &db, 0.6, &cluster, &o);
    let optim = run_s(Algorithm::OptimizedVfpc, &db, 0.6, &cluster, &o);
    let rel = (optim.actual_time - plain.actual_time).abs() / plain.actual_time;
    assert!(rel < 0.05, "high-support gap {rel:.3} should vanish");
}

/// DPC's α policy is sensitive to cluster speed; ETDPC's relative policy
/// reacts less (the robustness argument of §4.1) — measured as the change
/// in chosen npass structure across a 3x slower cluster.
#[test]
fn etdpc_more_stable_than_dpc_across_cluster_speeds() {
    let db = registry::mushroom();
    let o = opts("mushroom");
    let fast = ClusterConfig::paper_cluster();
    let mut slow = ClusterConfig::paper_cluster();
    for n in &mut slow.nodes {
        n.speed /= 3.0;
    }
    let phases = |algo, cluster: &ClusterConfig| -> Vec<usize> {
        run_s(algo, &db, 0.15, cluster, &o).phases.iter().map(|p| p.n_passes).collect()
    };
    let dpc_change = phases(Algorithm::Dpc, &fast) != phases(Algorithm::Dpc, &slow);
    let etdpc_same = phases(Algorithm::Etdpc, &fast) == phases(Algorithm::Etdpc, &slow);
    // At least one of the robustness signals must hold; both holding is the
    // expected outcome on this workload.
    assert!(
        dpc_change || etdpc_same,
        "neither DPC sensitivity nor ETDPC stability observed"
    );
}
