//! Shared helpers for the integration tests: one-shot session runs, the
//! tests' equivalent of the pre-session `run_with`/`run_on_file` free
//! functions (deprecated in 0.2.0, removed in 0.3.0). (Not a test target
//! itself — cargo only builds top-level files under `tests/` as test
//! binaries.)
#![allow(dead_code)] // each test binary uses the subset it needs

use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{
    Algorithm, MiningOutcome, MiningRequest, MiningSession, RunOptions,
};
use mrapriori::dataset::TransactionDb;
use mrapriori::hdfs::HdfsFile;

/// One-shot session run over an in-memory database (the old `run_with`).
pub fn run_s(
    algo: Algorithm,
    db: &TransactionDb,
    min_sup: f64,
    cluster: &ClusterConfig,
    o: &RunOptions,
) -> MiningOutcome {
    MiningSession::for_db(db, cluster.clone())
        .options(o)
        .build()
        .expect("test session")
        .run(&MiningRequest::from_options(algo, min_sup, o))
        .expect("test run")
}

/// One-shot session run over a pre-stored HDFS file (the old
/// `run_on_file`).
pub fn run_file_s(
    algo: Algorithm,
    file: &HdfsFile,
    min_sup: f64,
    cluster: &ClusterConfig,
    o: &RunOptions,
) -> MiningOutcome {
    MiningSession::builder(file.clone(), cluster.clone())
        .options(o)
        .build()
        .expect("test session")
        .run(&MiningRequest::from_options(algo, min_sup, o))
        .expect("test run")
}
