//! CLI smoke tests: drive the `mrapriori` binary end to end through its
//! public commands (the launcher a downstream user actually touches).

use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // cargo puts integration-test binaries in target/<profile>/deps; the
    // CLI binary lives one level up.
    let mut p = std::env::current_exe().unwrap();
    p.pop();
    if p.ends_with("deps") {
        p.pop();
    }
    p.join("mrapriori")
}

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(bin()).args(args).output().expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = run(&["help"]);
    assert!(ok);
    for cmd in ["mine", "sweep", "lk", "inspect", "generate", "calibrate"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn no_args_prints_help() {
    let (stdout, _, ok) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("Commands:"));
}

#[test]
fn unknown_command_fails() {
    let (_, stderr, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn inspect_registry_dataset() {
    let (stdout, _, ok) = run(&["inspect", "--dataset", "chess"]);
    assert!(ok);
    assert!(stdout.contains("transactions : 3196"));
    assert!(stdout.contains("items        : 75"));
}

#[test]
fn mine_small_run_end_to_end() {
    let (stdout, stderr, ok) =
        run(&["mine", "--dataset", "chess", "--algo", "opt-vfpc", "--min-sup", "0.9"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("frequent itemsets:"), "{stdout}");
    assert!(stdout.contains("Optimized-VFPC"), "{stdout}");
}

#[test]
fn mine_unknown_dataset_fails_cleanly() {
    let (_, stderr, ok) = run(&["mine", "--dataset", "nope", "--algo", "spc"]);
    assert!(!ok);
    assert!(stderr.contains("dataset"), "{stderr}");
}

#[test]
fn mine_bad_flag_fails_cleanly() {
    let (_, stderr, ok) = run(&["mine", "--dataset", "chess", "--no-such-flag"]);
    assert!(!ok);
    assert!(stderr.contains("unknown flag"), "{stderr}");
}

#[test]
fn generate_roundtrip() {
    let dir = std::env::temp_dir().join("mrapriori_cli_gen");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("chess.txt");
    let path_s = path.to_str().unwrap();
    let (stdout, stderr, ok) =
        run(&["generate", "--dataset", "chess", "--out", path_s]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("3196"));
    // Mine the generated file by path.
    let (stdout, stderr, ok) =
        run(&["mine", "--dataset", path_s, "--algo", "spc", "--min-sup", "0.95"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("frequent itemsets:"));
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn lk_profile_output() {
    let (stdout, _, ok) = run(&["lk", "--dataset", "mushroom", "--min-sup", "0.5"]);
    assert!(ok);
    assert!(stdout.contains("|L_k| ="), "{stdout}");
}

#[test]
fn subcommand_help_flags() {
    for cmd in ["mine", "sweep", "generate", "lk", "inspect", "calibrate"] {
        let (stdout, _, ok) = run(&[cmd, "--help"]);
        assert!(ok, "{cmd} --help failed");
        assert!(stdout.contains("Flags:"), "{cmd} help missing flags: {stdout}");
    }
}
