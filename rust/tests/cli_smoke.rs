//! CLI smoke tests: drive the `mrapriori` binary end to end through its
//! public commands (the launcher a downstream user actually touches).

use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // cargo puts integration-test binaries in target/<profile>/deps; the
    // CLI binary lives one level up.
    let mut p = std::env::current_exe().unwrap();
    p.pop();
    if p.ends_with("deps") {
        p.pop();
    }
    p.join("mrapriori")
}

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(bin()).args(args).output().expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = run(&["help"]);
    assert!(ok);
    for cmd in ["mine", "sweep", "lk", "inspect", "generate", "calibrate"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn no_args_prints_help() {
    let (stdout, _, ok) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("Commands:"));
}

#[test]
fn unknown_command_fails() {
    let (_, stderr, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn inspect_registry_dataset() {
    let (stdout, _, ok) = run(&["inspect", "--dataset", "chess"]);
    assert!(ok);
    assert!(stdout.contains("transactions : 3196"));
    assert!(stdout.contains("items        : 75"));
}

#[test]
fn mine_small_run_end_to_end() {
    let (stdout, stderr, ok) =
        run(&["mine", "--dataset", "chess", "--algo", "opt-vfpc", "--min-sup", "0.9"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("frequent itemsets:"), "{stdout}");
    assert!(stdout.contains("Optimized-VFPC"), "{stdout}");
}

#[test]
fn mine_algo_all_shares_one_session() {
    let (stdout, stderr, ok) =
        run(&["mine", "--dataset", "chess", "--algo", "all", "--min-sup", "0.9"]);
    assert!(ok, "stderr: {stderr}");
    // Summary row per algorithm plus the phase-time comparison table.
    for name in ["SPC", "FPC", "DPC", "VFPC", "ETDPC", "Optimized-VFPC", "Optimized-ETDPC"] {
        assert!(stdout.contains(name), "missing {name} in {stdout}");
    }
    assert!(stdout.contains("per-phase elapsed time"), "{stdout}");
    // The seven queries share one session: Job1 executed once.
    assert!(
        stdout.contains("Job1 executed 1 time(s), 6 served from cache"),
        "{stdout}"
    );
}

#[test]
fn mine_fault_flags_keep_output_and_print_fault_columns() {
    let base = ["mine", "--dataset", "chess", "--algo", "spc", "--min-sup", "0.9"];
    let (clean, stderr, ok) = run(&base);
    assert!(ok, "stderr: {stderr}");
    let mut faulted_args = base.to_vec();
    faulted_args.extend(["--fail-prob", "0.05", "--straggler-prob", "0.15", "--speculation"]);
    let (faulted, stderr, ok) = run(&faulted_args);
    assert!(ok, "stderr: {stderr}");
    // The mining result lines are byte-identical: faults only move
    // simulated time.
    let result_lines = |s: &str| -> Vec<String> {
        s.lines()
            .filter(|l| l.starts_with("frequent itemsets:") || l.starts_with("|L_k|"))
            .map(String::from)
            .collect()
    };
    assert_eq!(result_lines(&clean), result_lines(&faulted), "fault flags changed the mining");
    assert!(!result_lines(&clean).is_empty());
    // The fault view appears only under the flags.
    assert!(faulted.contains("faulted(s)"), "{faulted}");
    assert!(faulted.contains("faulted total"), "{faulted}");
    assert!(faulted.contains("speculative launches/wins"), "{faulted}");
    assert!(!clean.contains("faulted"), "{clean}");
}

#[test]
fn mine_algo_all_with_faults_prints_clean_vs_faulted_phase_table() {
    let (stdout, stderr, ok) = run(&[
        "mine",
        "--dataset",
        "chess",
        "--algo",
        "all",
        "--min-sup",
        "0.9",
        "--fail-prob",
        "0.05",
        "--straggler-prob",
        "0.15",
        "--speculation",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("clean→faulted"), "{stdout}");
    assert!(stdout.contains("attempts/fail/strag/spec"), "{stdout}");
    assert!(stdout.contains('→'), "{stdout}");
    assert!(stdout.contains("faulted(s)"), "{stdout}");
    // Still one shared session underneath.
    assert!(stdout.contains("Job1 executed 1 time(s), 6 served from cache"), "{stdout}");
}

#[test]
fn mine_invalid_fault_prob_is_a_clean_error() {
    let (_, stderr, ok) = run(&[
        "mine", "--dataset", "chess", "--algo", "spc", "--min-sup", "0.9", "--fail-prob", "1.5",
    ]);
    assert!(!ok);
    assert!(stderr.contains("invalid fault model"), "{stderr}");
    assert!(stderr.contains("fail_prob"), "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
}

#[test]
fn sweep_faults_emits_robustness_tables() {
    let (stdout, stderr, ok) =
        run(&["sweep", "--dataset", "chess", "--min-sup", "0.9", "--faults"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("fault robustness on chess"), "{stdout}");
    assert!(stdout.contains("| algorithm |"), "{stdout}");
    assert!(stdout.contains("5% failures"), "{stdout}");
    assert!(stdout.contains("stragglers + speculation"), "{stdout}");
    // All seven algorithms appear as rows.
    for name in ["SPC", "FPC", "DPC", "VFPC", "ETDPC", "Optimized-VFPC", "Optimized-ETDPC"] {
        assert!(stdout.contains(&format!("| {name} |")), "missing {name}: {stdout}");
    }
}

#[test]
fn mine_invalid_min_sup_is_a_clean_one_line_error() {
    let (_, stderr, ok) =
        run(&["mine", "--dataset", "chess", "--algo", "spc", "--min-sup", "1.5"]);
    assert!(!ok);
    assert!(stderr.contains("min_sup must lie in (0, 1]"), "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
}

#[test]
fn mine_zero_split_lines_is_a_clean_error() {
    let (_, stderr, ok) =
        run(&["mine", "--dataset", "chess", "--algo", "spc", "--split-lines", "0"]);
    assert!(!ok);
    assert!(stderr.contains("split_lines must be > 0"), "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
}

#[test]
fn mine_zero_fpc_n_is_a_clean_error() {
    let (_, stderr, ok) = run(&[
        "mine", "--dataset", "chess", "--algo", "fpc", "--min-sup", "0.9", "--fpc-n", "0",
    ]);
    assert!(!ok);
    assert!(stderr.contains("fpc_n must be > 0"), "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
}

#[test]
fn sweep_invalid_min_sups_is_a_clean_error() {
    let (_, stderr, ok) =
        run(&["sweep", "--dataset", "chess", "--min-sups", "0.9,1.5"]);
    assert!(!ok);
    assert!(stderr.contains("min_sup must lie in (0, 1]"), "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
}

#[test]
fn mine_unknown_dataset_fails_cleanly() {
    let (_, stderr, ok) = run(&["mine", "--dataset", "nope", "--algo", "spc"]);
    assert!(!ok);
    assert!(stderr.contains("dataset"), "{stderr}");
}

#[test]
fn mine_bad_flag_fails_cleanly() {
    let (_, stderr, ok) = run(&["mine", "--dataset", "chess", "--no-such-flag"]);
    assert!(!ok);
    assert!(stderr.contains("unknown flag"), "{stderr}");
}

#[test]
fn generate_roundtrip() {
    let dir = std::env::temp_dir().join("mrapriori_cli_gen");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("chess.txt");
    let path_s = path.to_str().unwrap();
    let (stdout, stderr, ok) =
        run(&["generate", "--dataset", "chess", "--out", path_s]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("3196"));
    // Mine the generated file by path.
    let (stdout, stderr, ok) =
        run(&["mine", "--dataset", path_s, "--algo", "spc", "--min-sup", "0.95"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("frequent itemsets:"));
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn mine_streamed_quest_dataset() {
    let cache = std::env::temp_dir().join("mrapriori_cli_streamed_cache");
    let _ = std::fs::remove_dir_all(&cache);
    let cache_s = cache.to_str().unwrap();
    let (stdout, stderr, ok) = run(&[
        "mine",
        "--dataset",
        "t5i2d500",
        "--algo",
        "spc",
        "--min-sup",
        "0.05",
        "--streamed",
        "--cache-dir",
        cache_s,
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("[streamed]"), "{stdout}");
    assert!(stdout.contains("frequent itemsets:"), "{stdout}");
    // The quest store was generated to the cache on first use.
    assert!(cache.join("t5i2d500").join("manifest").is_file());
    // Second run hits the cache and must agree.
    let (stdout2, stderr2, ok2) = run(&[
        "mine",
        "--dataset",
        "t5i2d500",
        "--algo",
        "spc",
        "--min-sup",
        "0.05",
        "--streamed",
        "--cache-dir",
        cache_s,
    ]);
    assert!(ok2, "stderr: {stderr2}");
    // Mining results must agree run-to-run (wall-clock lines will differ).
    let result_lines = |s: &str| -> Vec<String> {
        s.lines()
            .filter(|l| l.starts_with("frequent itemsets:") || l.starts_with("|L_k|"))
            .map(String::from)
            .collect()
    };
    assert_eq!(result_lines(&stdout), result_lines(&stdout2), "cached rerun diverged");
    assert!(!result_lines(&stdout).is_empty());
    std::fs::remove_dir_all(&cache).unwrap();
}

#[test]
fn sweep_scale_grid_emits_markdown_and_json() {
    let dir = std::env::temp_dir().join("mrapriori_cli_scale_grid");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let json = dir.join("scale.json");
    let md = dir.join("scale.md");
    let cache = dir.join("cache");
    let (stdout, stderr, ok) = run(&[
        "sweep",
        "--datasets",
        "t5i2d500,t6i2d400",
        "--algos",
        "spc,opt-etdpc",
        "--min-sup",
        "0.05",
        "--cache-dir",
        cache.to_str().unwrap(),
        "--json-out",
        json.to_str().unwrap(),
        "--md-out",
        md.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("| dataset |"), "{stdout}");
    assert!(stdout.contains("t5i2d500"), "{stdout}");
    let json_text = std::fs::read_to_string(&json).unwrap();
    assert!(json_text.contains("\"algorithms\": [\"SPC\", \"Optimized-ETDPC\"]"), "{json_text}");
    assert!(json_text.contains("\"dataset\": \"t6i2d400\""), "{json_text}");
    let md_text = std::fs::read_to_string(&md).unwrap();
    assert!(md_text.contains("Optimized-ETDPC (s)"), "{md_text}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn generate_segmented_store() {
    let dir = std::env::temp_dir().join("mrapriori_cli_gen_segmented");
    let _ = std::fs::remove_dir_all(&dir);
    let store = dir.join("store");
    let (stdout, stderr, ok) = run(&[
        "generate",
        "--dataset",
        "t5i2d300",
        "--out",
        store.to_str().unwrap(),
        "--segmented",
        "--block-lines",
        "100",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("300 transactions in 3 blocks"), "{stdout}");
    assert!(store.join("manifest").is_file());
    assert!(store.join("block-00002.txt").is_file());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn mine_backend_flag_happy_path_per_backend() {
    let result_lines = |s: &str| -> Vec<String> {
        s.lines()
            .filter(|l| l.starts_with("frequent itemsets:") || l.starts_with("|L_k|"))
            .map(String::from)
            .collect()
    };
    let mut reference: Option<Vec<String>> = None;
    for backend in ["trie", "bitmap", "triangular", "auto"] {
        let (stdout, stderr, ok) = run(&[
            "mine", "--dataset", "chess", "--algo", "spc", "--min-sup", "0.9", "--backend",
            backend,
        ]);
        assert!(ok, "--backend {backend} stderr: {stderr}");
        assert!(stdout.contains("frequent itemsets:"), "--backend {backend}: {stdout}");
        // The phase table attributes each Job2 phase to a resolved backend
        // name (explicit trie/bitmap show themselves; triangular and auto
        // resolve per pass, but always to one of the three real names).
        assert!(
            ["trie", "bitmap", "triangular"].iter().any(|n| stdout.contains(n)),
            "--backend {backend} table shows no backend column: {stdout}"
        );
        // Byte-identical mining whatever the backend.
        let lines = result_lines(&stdout);
        assert!(!lines.is_empty(), "--backend {backend}: {stdout}");
        match &reference {
            None => reference = Some(lines),
            Some(r) => assert_eq!(&lines, r, "--backend {backend} changed the mining"),
        }
    }
}

#[test]
fn mine_unknown_backend_is_a_clean_one_line_error() {
    let (_, stderr, ok) = run(&[
        "mine", "--dataset", "chess", "--algo", "spc", "--min-sup", "0.9", "--backend", "hashmap",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown counting backend"), "{stderr}");
    assert!(stderr.contains("trie, bitmap, triangular, auto"), "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
}

#[test]
fn mine_algo_all_shows_per_phase_backend_column() {
    let (stdout, stderr, ok) = run(&[
        "mine", "--dataset", "chess", "--algo", "all", "--min-sup", "0.9", "--backend", "bitmap",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("per-phase elapsed time"), "{stdout}");
    // The phase-attribution lines tag every Job2 job with its backend.
    assert!(stdout.contains("jobs:"), "{stdout}");
    assert!(stdout.contains("[bitmap]"), "{stdout}");
    // Still one shared session underneath.
    assert!(stdout.contains("Job1 executed 1 time(s), 6 served from cache"), "{stdout}");
}

#[test]
fn lk_profile_output() {
    let (stdout, _, ok) = run(&["lk", "--dataset", "mushroom", "--min-sup", "0.5"]);
    assert!(ok);
    assert!(stdout.contains("|L_k| ="), "{stdout}");
}

#[test]
fn subcommand_help_flags() {
    for cmd in ["mine", "sweep", "generate", "lk", "inspect", "calibrate"] {
        let (stdout, _, ok) = run(&[cmd, "--help"]);
        assert!(ok, "{cmd} --help failed");
        assert!(stdout.contains("Flags:"), "{cmd} help missing flags: {stdout}");
    }
}
