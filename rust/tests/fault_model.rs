//! Fault-aware mining end to end through the session API (ISSUE 5): the
//! output-invariance contract (faults only move simulated time — frequent
//! itemsets are byte-identical with or without a model), per-phase
//! clean + faulted records, run-level aggregation, Job1 cache sharing
//! across fault models, per-seed determinism, and the typed validation
//! error.

use mrapriori::apriori::sequential::mine;
use mrapriori::cluster::{ClusterConfig, FaultModel};
use mrapriori::coordinator::{
    Algorithm, CancelToken, MiningError, MiningRequest, MiningSession, PhaseEvent,
};
use mrapriori::dataset::ibm::{generate, IbmParams};
use mrapriori::dataset::TransactionDb;

fn small_db() -> TransactionDb {
    generate(&IbmParams {
        n_txns: 300,
        n_items: 40,
        avg_txn_len: 8.0,
        avg_pattern_len: 4.0,
        n_patterns: 10,
        correlation: 0.5,
        corruption_mean: 0.3,
        corruption_sd: 0.1,
        seed: 42,
        ..Default::default()
    })
}

fn session_for(db: &TransactionDb) -> MiningSession {
    MiningSession::for_db(db, ClusterConfig::paper_cluster())
        .split_lines(50)
        .build()
        .expect("valid session")
}

fn storm() -> FaultModel {
    FaultModel {
        fail_prob: 0.05,
        straggler_prob: 0.15,
        speculation: true,
        ..Default::default()
    }
}

/// The acceptance criterion: for every algorithm, a query under a fault
/// model mines byte-identical frequent itemsets to the clean query, while
/// its phase records carry both makespans plus injection counters.
#[test]
fn fault_model_never_changes_mining_output() {
    let db = small_db();
    let oracle = mine(&db, 0.2).all_frequent();
    let session = session_for(&db);
    for algo in Algorithm::ALL {
        let clean = session.run(&MiningRequest::new(algo).min_sup(0.2)).unwrap();
        let faulted =
            session.run(&MiningRequest::new(algo).min_sup(0.2).faults(storm())).unwrap();
        assert_eq!(faulted.all_frequent(), oracle, "{algo}: faults changed the output");
        assert_eq!(clean.all_frequent(), oracle, "{algo}");
        assert_eq!(faulted.lk_profile(), clean.lk_profile(), "{algo}");
        assert_eq!(faulted.min_count, clean.min_count, "{algo}");

        // Clean runs carry no fault data; faulted runs carry it everywhere.
        assert!(clean.fault_model.is_none());
        assert!(clean.phases.iter().all(|p| p.faults.is_none()), "{algo}");
        assert!(clean.faulted_total_time().is_none());
        assert!(clean.fault_totals().is_none());
        assert_eq!(faulted.fault_model, Some(storm()), "{algo}");
        assert!(faulted.phases.iter().all(|p| p.faults.is_some()), "{algo}");

        // Per-phase coherence: the faulted record shares the clean run's
        // driver-side terms and reports at least one attempt per task.
        for p in &faulted.phases {
            let f = p.faults.as_ref().unwrap();
            assert_eq!(f.timing.submit, p.timing.submit, "{algo} phase {}", p.phase);
            assert_eq!(f.timing.shuffle, p.timing.shuffle, "{algo} phase {}", p.phase);
            let totals = f.totals();
            assert!(totals.attempts > 0, "{algo} phase {}", p.phase);
            assert!(totals.attempts >= totals.failures, "{algo} phase {}", p.phase);
        }

        // Run-level aggregation is the per-phase sum.
        let total = faulted.faulted_total_time().expect("fault run has a faulted total");
        let by_hand: f64 =
            faulted.phases.iter().map(|p| p.faults.as_ref().unwrap().elapsed()).sum();
        assert!((total - by_hand).abs() < 1e-9, "{algo}");
        let actual = faulted.faulted_actual_time().unwrap();
        assert!(
            (actual - total - (faulted.actual_time - faulted.total_time)).abs() < 1e-9,
            "{algo}: faulted actual must add the same driver gaps"
        );
        let totals = faulted.fault_totals().unwrap();
        assert!((totals.makespan - total).abs() < 1e-9, "{algo}");
    }
}

/// A zero-probability, no-speculation model is observably the clean
/// schedule: same elapsed time per phase, bit for bit.
#[test]
fn zero_probability_model_matches_clean_timing() {
    let db = small_db();
    let session = session_for(&db);
    let clean = session.run(&MiningRequest::new(Algorithm::Vfpc).min_sup(0.2)).unwrap();
    let zero = session
        .run(&MiningRequest::new(Algorithm::Vfpc).min_sup(0.2).faults(FaultModel::default()))
        .unwrap();
    assert_eq!(zero.n_phases(), clean.n_phases());
    for (z, c) in zero.phases.iter().zip(&clean.phases) {
        let f = z.faults.as_ref().expect("zero-prob run still records fault data");
        assert_eq!(f.elapsed().to_bits(), c.elapsed.to_bits(), "phase {}", z.phase);
        let totals = f.totals();
        assert_eq!(totals.failures, 0);
        assert_eq!(totals.stragglers, 0);
        assert_eq!(totals.speculative_launches, 0);
    }
    assert_eq!(
        zero.faulted_total_time().unwrap().to_bits(),
        clean.total_time.to_bits(),
        "zero-probability faults must reproduce the list scheduler exactly"
    );
}

/// Queries with different fault models (or none) share one Job1 scan: the
/// fault re-timing is computed per query from the cached cost-modeled
/// tasks, never by re-executing the job.
#[test]
fn job1_cache_is_shared_across_fault_models() {
    let db = small_db();
    let session = session_for(&db);
    let clean = session.run(&MiningRequest::new(Algorithm::Spc).min_sup(0.2)).unwrap();
    let faulted = session
        .run(&MiningRequest::new(Algorithm::Spc).min_sup(0.2).faults(storm()))
        .unwrap();
    let stats = session.stats();
    assert_eq!(stats.job1_runs, 1, "fault models must not split the Job1 cache key");
    assert_eq!(stats.job1_cache_hits, 1);
    // Same cached measurement underneath...
    assert_eq!(clean.phases[0].elapsed, faulted.phases[0].elapsed);
    assert_eq!(clean.phases[0].counters, faulted.phases[0].counters);
    // ... with the fault view layered on only where requested.
    assert!(clean.phases[0].faults.is_none());
    assert!(faulted.phases[0].faults.is_some());
}

/// Deterministic per (request, seed): repeating a faulted query reproduces
/// every number; changing the seed re-draws the injection.
#[test]
fn fault_runs_are_deterministic_per_seed() {
    let db = small_db();
    let session = session_for(&db);
    let req = |seed: u64| {
        MiningRequest::new(Algorithm::OptimizedVfpc).min_sup(0.2).faults(FaultModel {
            fail_prob: 0.2,
            straggler_prob: 0.2,
            speculation: true,
            seed,
            ..Default::default()
        })
    };
    let a = session.run(&req(9)).unwrap();
    let b = session.run(&req(9)).unwrap();
    assert_eq!(
        a.faulted_total_time().unwrap().to_bits(),
        b.faulted_total_time().unwrap().to_bits()
    );
    assert_eq!(a.fault_totals().unwrap(), b.fault_totals().unwrap());
    // Across a handful of seeds the injections cannot all coincide.
    let distinct: std::collections::HashSet<u64> = (0..6)
        .map(|seed| session.run(&req(seed)).unwrap().faulted_total_time().unwrap().to_bits())
        .collect();
    assert!(distinct.len() > 1, "every seed produced one identical injection");
    // And none of it ever touches the mined output.
    assert_eq!(a.all_frequent(), b.all_frequent());
    assert_eq!(a.all_frequent(), mine(&db, 0.2).all_frequent());
}

/// The phase-event stream carries the same fault-annotated records that
/// land in the outcome.
#[test]
fn event_stream_carries_fault_records() {
    let db = small_db();
    let session = session_for(&db);
    let mut streamed = Vec::new();
    let out = session
        .run_streaming(
            &MiningRequest::new(Algorithm::Etdpc).min_sup(0.2).faults(storm()),
            &CancelToken::new(),
            |ev| {
                if let PhaseEvent::PhaseFinished { record, .. } = ev {
                    streamed.push(record);
                }
            },
        )
        .unwrap();
    assert_eq!(streamed.len(), out.n_phases());
    for (ev, ph) in streamed.iter().zip(&out.phases) {
        let (a, b) = (ev.faults.as_ref().unwrap(), ph.faults.as_ref().unwrap());
        assert_eq!(a.elapsed().to_bits(), b.elapsed().to_bits(), "phase {}", ph.phase);
        assert_eq!(a.totals(), b.totals(), "phase {}", ph.phase);
    }
}

/// Out-of-domain fault knobs are typed errors at submission, like every
/// other tunable.
#[test]
fn invalid_fault_models_are_typed_errors() {
    let db = small_db();
    let session = session_for(&db);
    for (model, why) in [
        (FaultModel { fail_prob: 1.5, ..Default::default() }, "fail_prob"),
        (FaultModel { straggler_prob: -0.1, ..Default::default() }, "straggler_prob"),
        (FaultModel { straggler_factor: 0.0, ..Default::default() }, "straggler_factor"),
        (FaultModel { max_attempts: 0, ..Default::default() }, "max_attempts"),
        (FaultModel { spec_threshold: f64::NAN, ..Default::default() }, "spec_threshold"),
    ] {
        let err = session
            .run(&MiningRequest::new(Algorithm::Spc).min_sup(0.2).faults(model))
            .expect_err("out-of-domain fault model must be rejected");
        match &err {
            MiningError::InvalidFaultModel(msg) => {
                assert!(msg.contains(why), "{err}: expected a {why} violation")
            }
            other => panic!("expected InvalidFaultModel, got {other}"),
        }
        assert!(err.to_string().contains("invalid fault model"), "{err}");
    }
    // The session keeps serving after rejected requests.
    session.run(&MiningRequest::new(Algorithm::Spc).min_sup(0.2)).unwrap();
}

/// Background handles work under fault models too, and an injected run can
/// still be cancelled between phases.
#[test]
fn submit_and_cancel_work_with_faults() {
    let db = small_db();
    let session = session_for(&db);
    let handle = session
        .submit(MiningRequest::new(Algorithm::Vfpc).min_sup(0.2).faults(storm()))
        .unwrap();
    let out = handle.join().expect("faulted background run succeeds");
    assert_eq!(out.all_frequent(), mine(&db, 0.2).all_frequent());
    assert!(out.faulted_total_time().is_some());

    let token = CancelToken::new();
    let err = session
        .run_streaming(
            &MiningRequest::new(Algorithm::Spc).min_sup(0.15).faults(storm()),
            &token,
            |ev| {
                if matches!(ev, PhaseEvent::PhaseFinished { .. }) {
                    token.cancel();
                }
            },
        )
        .expect_err("cancelled faulted run must not produce an outcome");
    assert_eq!(err, MiningError::Cancelled);
}
