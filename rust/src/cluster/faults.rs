//! Fault and straggler model for the cluster simulator: Hadoop's task
//! retry and speculative-execution semantics, in simulated time.
//!
//! The paper's cluster (§2.2) relies on MapReduce's "managing node failure"
//! properties; this module makes that substrate real: each task attempt may
//! fail (re-queued on discovery, up to [`FaultModel::max_attempts`] attempts
//! per task) or straggle (duration inflated by
//! [`FaultModel::straggler_factor`]); with speculation on, a backup attempt
//! launches for any attempt whose *duration* is projected past
//! `spec_threshold ×` the median finished-attempt duration, and the earlier
//! finisher wins — Hadoop's default policy shape, with the planned duration
//! standing in for Hadoop's progress-rate estimate.
//!
//! [`schedule_with_faults`] is an event-driven simulator over the exact
//! placement rule of [`super::scheduler::schedule`] (shared `pick_slot`):
//! with `fail_prob == straggler_prob == 0` and speculation off it performs
//! bit-identical arithmetic, so its makespan equals the list scheduler's
//! exactly. Backup attempts are subject to the same fail/straggler
//! injection as primaries, and a failed backup re-arms the task for another
//! one. Everything is deterministic from the seed, and faults only move
//! simulated time — mining output never depends on this module (the
//! output-invariance contract, DESIGN.md §6).

use super::costmodel::OverheadParams;
use super::scheduler::{pick_slot, SimTask};
use crate::util::rng::Rng;
use std::collections::BinaryHeap;

#[derive(Debug, Clone, PartialEq)]
/// Failure/straggler injection parameters.
pub struct FaultModel {
    /// Probability an attempt fails (uniform per attempt).
    pub fail_prob: f64,
    /// Probability an attempt straggles.
    pub straggler_prob: f64,
    /// Straggler duration multiplier.
    pub straggler_factor: f64,
    /// Attempts per task before the task is abandoned and the job is
    /// declared failed.
    pub max_attempts: usize,
    /// Enable speculative backup attempts.
    pub speculation: bool,
    /// Launch a backup when an attempt's projected duration exceeds this
    /// multiple of the median finished-attempt duration.
    pub spec_threshold: f64,
    /// Injection seed (fully deterministic).
    pub seed: u64,
}

impl Default for FaultModel {
    fn default() -> Self {
        Self {
            fail_prob: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 6.0,
            max_attempts: 4,
            speculation: false,
            spec_threshold: 1.5,
            seed: 7,
        }
    }
}

impl FaultModel {
    /// Domain check for user-reachable surfaces (the session API's
    /// `MiningRequest::faults` and the CLI fault flags route through
    /// this): probabilities in `[0, 1]`, multipliers finite and `>= 1`,
    /// at least one attempt per task.
    pub fn validate(&self) -> Result<(), &'static str> {
        if !(self.fail_prob >= 0.0 && self.fail_prob <= 1.0) {
            return Err("fail_prob must lie in [0, 1]");
        }
        if !(self.straggler_prob >= 0.0 && self.straggler_prob <= 1.0) {
            return Err("straggler_prob must lie in [0, 1]");
        }
        if !(self.straggler_factor.is_finite() && self.straggler_factor >= 1.0) {
            return Err("straggler_factor must be finite and >= 1");
        }
        if self.max_attempts == 0 {
            return Err("max_attempts must be > 0");
        }
        if !(self.spec_threshold.is_finite() && self.spec_threshold >= 1.0) {
            return Err("spec_threshold must be finite and >= 1");
        }
        Ok(())
    }

    /// Derive the model for one injection stream (a phase's map or reduce
    /// stage): same knobs, an independent seed mixed from `(seed, stream,
    /// stage)`. Phases and stages of one run draw from disjoint sequences
    /// while staying fully determined by the user's single seed.
    pub fn for_stream(&self, stream: u64, stage: u64) -> FaultModel {
        let mut mix = Rng::new(
            self.seed
                ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ stage.wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        FaultModel { seed: mix.next_u64(), ..self.clone() }
    }
}

#[derive(Debug, Clone, Default, PartialEq)]
/// What the fault-injected schedule produced.
pub struct FaultOutcome {
    /// Phase makespan with faults, seconds (the last completion or failure
    /// discovery — how long the stage ran before finishing or being
    /// declared failed).
    pub makespan: f64,
    /// Total attempts launched (retries and backups included).
    pub attempts: usize,
    /// Failed attempts.
    pub failures: usize,
    /// Straggling attempts.
    pub stragglers: usize,
    /// Backup attempts launched.
    pub speculative_launches: usize,
    /// Backups that finished before their original attempt.
    pub speculative_wins: usize,
    /// True if some task exhausted its attempts.
    pub job_failed: bool,
}

impl FaultOutcome {
    /// Accumulate another outcome into this one (stage → phase → run
    /// aggregation): counters add, `job_failed` ORs, and `makespan` ADDS —
    /// stages and phases run serially, so their spans sum.
    pub fn accumulate(&mut self, other: &FaultOutcome) {
        self.makespan += other.makespan;
        self.attempts += other.attempts;
        self.failures += other.failures;
        self.stragglers += other.stragglers;
        self.speculative_launches += other.speculative_launches;
        self.speculative_wins += other.speculative_wins;
        self.job_failed |= other.job_failed;
    }
}

/// One in-flight attempt. Heap order is INVERTED (earliest finish on top
/// of `std`'s max-heap), with the launch id as a deterministic tie-break.
#[derive(Debug, Clone, Copy)]
struct Attempt {
    finish: f64,
    start: f64,
    task: usize,
    speculative: bool,
    fails: bool,
    id: u64,
}

impl PartialEq for Attempt {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Attempt {}

impl PartialOrd for Attempt {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Attempt {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed on purpose: BinaryHeap::pop yields the earliest finish,
        // ties broken toward the earliest launch.
        other.finish.total_cmp(&self.finish).then(other.id.cmp(&self.id))
    }
}

/// The event loop's state: slot timelines, per-task bookkeeping, and the
/// min-heap of in-flight attempts.
struct Sim<'a> {
    tasks: &'a [SimTask],
    slots: &'a [(usize, f64)],
    overhead: &'a OverheadParams,
    model: &'a FaultModel,
    rng: Rng,
    out: FaultOutcome,
    attempts_left: Vec<usize>,
    done: Vec<bool>,
    /// Attempts currently in flight per task (primary + backup).
    inflight: Vec<usize>,
    /// Whether the task has a live backup (one speculative attempt at a
    /// time, like Hadoop; reset when a backup fails so the task can get
    /// another).
    has_backup: Vec<bool>,
    free_at: Vec<f64>,
    /// Durations (`finish - start`) of finished attempts, kept SORTED
    /// (binary-search insert per completion) so the speculation median is
    /// an index read, not a per-retire clone-and-sort. Completion *times*
    /// would misclassify every late wave as straggling (the bug this
    /// rewrite fixes).
    durations: Vec<f64>,
    heap: BinaryHeap<Attempt>,
    next_id: u64,
}

impl Sim<'_> {
    /// Launch one attempt of `task` at time `now`: place it with the list
    /// scheduler's exact rule, then roll fault injection. Failed attempts
    /// die halfway through their work; stragglers run `straggler_factor ×`
    /// long. Backups take the same rolls as primaries.
    fn launch(&mut self, task: usize, now: f64, speculative: bool) {
        debug_assert!(self.attempts_left[task] > 0, "launch past the attempt budget");
        self.attempts_left[task] -= 1;
        self.out.attempts += 1;
        let p = pick_slot(&self.tasks[task], self.slots, &self.free_at, now, self.overhead);
        let fails = self.rng.chance(self.model.fail_prob);
        let mut finish = p.finish;
        if fails {
            finish = p.start + p.dur * 0.5;
        } else if self.rng.chance(self.model.straggler_prob) {
            finish = p.start + p.dur * self.model.straggler_factor;
            self.out.stragglers += 1;
        }
        if speculative {
            self.has_backup[task] = true;
            self.out.speculative_launches += 1;
        }
        self.free_at[p.slot] = finish;
        self.inflight[task] += 1;
        self.heap
            .push(Attempt { finish, start: p.start, task, speculative, fails, id: self.next_id });
        self.next_id += 1;
    }

    /// Retire the earliest finisher: record completion or schedule the
    /// retry, then re-evaluate speculation at the new time.
    fn retire(&mut self, a: Attempt) {
        let now = a.finish;
        self.inflight[a.task] -= 1;
        if self.done[a.task] {
            // The duplicate attempt lost the race; its result is dropped.
            return;
        }
        self.out.makespan = self.out.makespan.max(now);
        if a.fails {
            self.out.failures += 1;
            if a.speculative {
                // A dead backup must not block a future one.
                self.has_backup[a.task] = false;
            }
            if self.inflight[a.task] == 0 {
                if self.attempts_left[a.task] > 0 {
                    self.launch(a.task, now, false);
                } else {
                    // Exhausted max_attempts with nothing still running.
                    self.out.job_failed = true;
                }
            }
        } else {
            self.done[a.task] = true;
            let duration = a.finish - a.start;
            let at = self.durations.partition_point(|&d| d < duration);
            self.durations.insert(at, duration);
            if a.speculative {
                self.out.speculative_wins += 1;
            }
        }
        self.speculate(now);
    }

    /// Hadoop-shaped speculation: once at least 3 attempts have finished,
    /// any running primary whose projected duration exceeds
    /// `spec_threshold ×` the median finished duration gets one backup
    /// (budget permitting), placed and injected like any other attempt.
    fn speculate(&mut self, now: f64) {
        if !self.model.speculation || self.durations.len() < 3 {
            return;
        }
        let median = self.durations[self.durations.len() / 2];
        let threshold = median * self.model.spec_threshold;
        let mut long_runners: Vec<usize> = self
            .heap
            .iter()
            .filter(|a| {
                !a.speculative
                    && !self.done[a.task]
                    && !self.has_backup[a.task]
                    && self.attempts_left[a.task] > 0
                    && a.finish - a.start > threshold
            })
            .map(|a| a.task)
            .collect();
        // Heap iteration order is arbitrary: canonicalize so the RNG draw
        // order (and thus the whole schedule) is deterministic.
        long_runners.sort_unstable();
        long_runners.dedup();
        for task in long_runners {
            self.launch(task, now, true);
        }
    }
}

/// Event-driven schedule of `tasks` onto `slots` under the fault model.
///
/// Slots are `(node, speed)` pairs as in [`super::scheduler::schedule`];
/// every attempt is placed by the same earliest-finish/locality rule
/// (`pick_slot`), so the zero-probability, no-speculation schedule equals
/// the list scheduler's exactly. A failed attempt retries on discovery
/// (unless a backup is still running); a straggler runs to completion
/// unless its speculative backup finishes first.
pub fn schedule_with_faults(
    tasks: &[SimTask],
    slots: &[(usize, f64)],
    overhead: &OverheadParams,
    model: &FaultModel,
) -> FaultOutcome {
    if tasks.is_empty() || slots.is_empty() {
        return FaultOutcome::default();
    }
    let mut sim = Sim {
        tasks,
        slots,
        overhead,
        model,
        rng: Rng::new(model.seed),
        out: FaultOutcome::default(),
        // Defensive clamp for direct (un-validated) callers: zero would
        // underflow the budget bookkeeping; the session API rejects it.
        attempts_left: vec![model.max_attempts.max(1); tasks.len()],
        done: vec![false; tasks.len()],
        inflight: vec![0; tasks.len()],
        has_backup: vec![false; tasks.len()],
        free_at: vec![0.0f64; slots.len()],
        durations: Vec::new(),
        heap: BinaryHeap::new(),
        next_id: 0,
    };
    // All tasks are runnable at t = 0, placed in submission order — the
    // list scheduler's loop, verbatim.
    for task in 0..tasks.len() {
        sim.launch(task, 0.0, false);
    }
    while let Some(attempt) = sim.heap.pop() {
        sim.retire(attempt);
    }
    if sim.done.iter().any(|d| !d) {
        sim.out.job_failed = true;
    }
    sim.out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::scheduler::schedule;
    use crate::util::check::{forall, UsizeGen, VecGen};
    use crate::util::rng::Rng as TestRng;

    fn oh() -> OverheadParams {
        OverheadParams { job_submit: 0.0, task_start: 1.0, nonlocal_penalty: 0.0, driver_gap: 0.0 }
    }

    fn tasks(n: usize, secs: f64) -> Vec<SimTask> {
        (0..n).map(|_| SimTask { compute_secs: secs, preferred_nodes: vec![] }).collect()
    }

    fn slots(n: usize) -> Vec<(usize, f64)> {
        (0..n).map(|i| (i, 1.0)).collect()
    }

    #[test]
    fn no_faults_matches_plain_makespan_exactly() {
        let t = tasks(8, 10.0);
        let s = slots(4);
        let plain = schedule(&t, &s, &oh());
        let faulty = schedule_with_faults(&t, &s, &oh(), &FaultModel::default());
        // Bit-identical, not approximately equal: same placement rule,
        // same arithmetic.
        assert_eq!(plain.makespan.to_bits(), faulty.makespan.to_bits());
        assert_eq!(faulty.attempts, 8);
        assert_eq!(faulty.failures, 0);
        assert!(!faulty.job_failed);
    }

    /// The ISSUE's equivalence criterion: over random task mixes,
    /// heterogeneous speeds, and locality preferences, the zero-probability
    /// fault schedule reproduces the list scheduler's makespan exactly.
    #[test]
    fn faults_zero_prob_matches_list_scheduler() {
        let gen = VecGen { inner: UsizeGen { lo: 1, hi: 60 }, max_len: 30 };
        let overhead = OverheadParams {
            job_submit: 0.0,
            task_start: 0.7,
            nonlocal_penalty: 0.4,
            driver_gap: 0.0,
        };
        forall(901, 120, &gen, |durs| {
            if durs.is_empty() {
                return true;
            }
            // Derive a deterministic heterogeneous cluster + replica map
            // from the generated durations themselves.
            let mut rng = TestRng::new(durs.iter().map(|&d| d as u64).sum::<u64>() ^ 0xfa17);
            let n_nodes = rng.range(1, 4);
            let slots: Vec<(usize, f64)> = (0..n_nodes)
                .flat_map(|n| {
                    let speed = 1.0 + 0.12 * (n % 3) as f64;
                    std::iter::repeat((n, speed)).take(rng.range(1, 3))
                })
                .collect();
            let tasks: Vec<SimTask> = durs
                .iter()
                .map(|&d| SimTask {
                    compute_secs: d as f64,
                    preferred_nodes: if rng.chance(0.5) {
                        vec![rng.range(0, n_nodes - 1)]
                    } else {
                        vec![]
                    },
                })
                .collect();
            let plain = schedule(&tasks, &slots, &overhead);
            let faulty =
                schedule_with_faults(&tasks, &slots, &overhead, &FaultModel::default());
            plain.makespan.to_bits() == faulty.makespan.to_bits()
                && faulty.attempts == tasks.len()
                && !faulty.job_failed
        });
    }

    #[test]
    fn failures_extend_makespan_and_retry() {
        let t = tasks(8, 10.0);
        let s = slots(4);
        let clean = schedule_with_faults(&t, &s, &oh(), &FaultModel::default());
        // Scan seeds for one that produces failures AND recovers (at
        // fail_prob 0.3 over 8 attempts nearly every seed fails somewhere,
        // and exhausting the 4-attempt budget is rare), then pin the
        // retry properties on it.
        let faulty = (0..64)
            .map(|seed| {
                schedule_with_faults(
                    &t,
                    &s,
                    &oh(),
                    &FaultModel { fail_prob: 0.3, seed, ..Default::default() },
                )
            })
            .find(|r| r.failures > 0 && !r.job_failed)
            .expect("some seed under fail_prob 0.3 must fail and recover");
        assert!(faulty.attempts > 8);
        assert!(faulty.makespan > clean.makespan);
    }

    #[test]
    fn certain_failure_fails_job() {
        let t = tasks(2, 5.0);
        let s = slots(2);
        let model = FaultModel { fail_prob: 1.0, max_attempts: 3, ..Default::default() };
        let out = schedule_with_faults(&t, &s, &oh(), &model);
        assert!(out.job_failed);
        assert_eq!(out.attempts, 6); // 2 tasks x 3 attempts
        assert_eq!(out.failures, 6);
        assert!(out.makespan > 0.0, "failure discovery still advances time");
    }

    /// With failures off, backups can only help: they never displace an
    /// already-placed attempt, so speculation's makespan is <= the plain
    /// one for every seed — and across a seed scan some backup must win
    /// outright.
    #[test]
    fn speculation_beats_stragglers() {
        let t = tasks(24, 5.0);
        let s = slots(6);
        let mut strictly_better = 0usize;
        let mut launched = 0usize;
        for seed in 0..48 {
            let base = FaultModel {
                straggler_prob: 0.15,
                straggler_factor: 10.0,
                seed,
                ..Default::default()
            };
            let without = schedule_with_faults(&t, &s, &oh(), &base);
            let with = schedule_with_faults(
                &t,
                &s,
                &oh(),
                &FaultModel { speculation: true, ..base.clone() },
            );
            assert!(
                with.makespan <= without.makespan + 1e-9,
                "seed {seed}: speculation {:.1} > plain {:.1}",
                with.makespan,
                without.makespan
            );
            launched += with.speculative_launches;
            if with.makespan < without.makespan - 1e-9 {
                assert!(with.speculative_wins > 0, "seed {seed}: a speedup needs a win");
                strictly_better += 1;
            }
        }
        assert!(launched > 0, "15% stragglers at 10x must trigger backups");
        assert!(strictly_better > 0, "no seed saw speculation cut the makespan");
    }

    /// Regression for the completion-time-vs-duration bug: a task that
    /// merely *starts* late (second wave) has a large completion time but a
    /// perfectly normal duration, and must NOT get a backup.
    #[test]
    fn late_normal_task_gets_no_backup() {
        // 6 equal tasks on 5 slots: wave 1 finishes at 11 (duration 11
        // each); task 5 runs [11, 22] — duration 11 == the median, far
        // under the 1.5x threshold, while its completion time (22) is well
        // past 1.5x the median completion (11).
        let t = tasks(6, 10.0);
        let s = slots(5);
        let model = FaultModel { speculation: true, ..Default::default() };
        let out = schedule_with_faults(&t, &s, &oh(), &model);
        assert_eq!(out.speculative_launches, 0, "late-but-normal task misclassified");
        assert_eq!(out.attempts, 6);
        let plain = schedule(&t, &s, &oh());
        assert_eq!(out.makespan.to_bits(), plain.makespan.to_bits());
    }

    /// Backups take the same straggler roll as primaries: with
    /// straggler_prob = 1 every attempt (backup included) straggles, so
    /// the straggler count must equal the attempt count even though
    /// backups launched. Tasks are bimodal so the long ones exceed the
    /// short-duration median and actually trigger speculation.
    #[test]
    fn backups_take_the_straggler_roll() {
        let mut t = tasks(8, 2.0);
        t.extend(tasks(4, 20.0));
        let s = slots(4);
        let out = schedule_with_faults(
            &t,
            &s,
            &oh(),
            &FaultModel {
                straggler_prob: 1.0,
                straggler_factor: 4.0,
                speculation: true,
                max_attempts: 8,
                ..Default::default()
            },
        );
        assert!(out.speculative_launches > 0, "long stragglers must trigger backups");
        assert_eq!(
            out.stragglers,
            out.attempts,
            "every attempt (incl. {} backups) must take the straggler roll",
            out.speculative_launches
        );
        assert!(!out.job_failed);
    }

    /// Backups take the fail roll too, and a dead backup re-arms the task:
    /// across a seed scan, some task must receive a second backup after its
    /// first one failed — impossible if `has_backup` never reset — and
    /// failed backups must never strand a task.
    #[test]
    fn failed_backup_rearms_the_task() {
        let t = tasks(10, 5.0);
        let s = slots(4);
        let mut rearmed = false;
        for seed in 0..96 {
            let model = FaultModel {
                fail_prob: 0.4,
                straggler_prob: 0.5,
                straggler_factor: 12.0,
                speculation: true,
                spec_threshold: 1.2,
                max_attempts: 16,
                seed,
                ..Default::default()
            };
            let out = schedule_with_faults(&t, &s, &oh(), &model);
            assert!(!out.job_failed, "seed {seed}: 16 attempts at p=0.4 must recover");
            // More backups than tasks means some task was re-armed after a
            // backup died (at most one live backup per task at a time).
            if out.speculative_launches > t.len() {
                rearmed = true;
            }
        }
        assert!(rearmed, "no seed saw a task get a second backup after one failed");
    }

    #[test]
    fn deterministic_per_seed() {
        let t = tasks(12, 7.0);
        let s = slots(3);
        let model = FaultModel {
            fail_prob: 0.2,
            straggler_prob: 0.2,
            speculation: true,
            seed: 5,
            ..Default::default()
        };
        let a = schedule_with_faults(&t, &s, &oh(), &model);
        let b = schedule_with_faults(&t, &s, &oh(), &model);
        assert_eq!(a, b);
    }

    /// Property: per-seed determinism and the clean lower bound over random
    /// equal-task workloads (where the list schedule is provably minimal,
    /// so injected faults can only push the makespan up).
    #[test]
    fn prop_deterministic_and_bounded_below_by_clean() {
        let gen = UsizeGen { lo: 1, hi: 400 };
        forall(902, 60, &gen, |&x| {
            let n = 1 + x % 20;
            let m = 1 + (x / 20) % 5;
            let seed = (x / 100) as u64;
            let t = tasks(n, 6.0);
            let s = slots(m);
            let model = FaultModel {
                fail_prob: 0.25,
                straggler_prob: 0.2,
                speculation: x % 2 == 0,
                max_attempts: 64,
                seed,
                ..Default::default()
            };
            let a = schedule_with_faults(&t, &s, &oh(), &model);
            let b = schedule_with_faults(&t, &s, &oh(), &model);
            let clean = schedule(&t, &s, &oh());
            a == b && a.makespan >= clean.makespan - 1e-9 && !a.job_failed
        });
    }

    /// `job_failed` iff some task exhausts `max_attempts`: certain failure
    /// always fails, and any sub-certain failure rate with a deep attempt
    /// budget always recovers (0.5^64 per task is never observed).
    #[test]
    fn prop_job_failed_iff_attempts_exhausted() {
        let gen = UsizeGen { lo: 0, hi: 10_000 };
        forall(903, 50, &gen, |&x| {
            let n = 1 + x % 9;
            let seed = x as u64;
            let t = tasks(n, 4.0);
            let s = slots(1 + x % 3);
            let certain = schedule_with_faults(
                &t,
                &s,
                &oh(),
                &FaultModel { fail_prob: 1.0, max_attempts: 2, seed, ..Default::default() },
            );
            let recoverable = schedule_with_faults(
                &t,
                &s,
                &oh(),
                &FaultModel { fail_prob: 0.5, max_attempts: 64, seed, ..Default::default() },
            );
            // Exhaustion: n tasks x 2 attempts, all failed.
            certain.job_failed
                && certain.attempts == 2 * n
                && certain.failures == 2 * n
                // Recovery: nobody exhausts 64 attempts at p = 0.5.
                && !recoverable.job_failed
                && recoverable.failures >= recoverable.attempts - n
        });
    }

    #[test]
    fn makespan_grows_with_fail_prob_on_average() {
        // Monotonicity in fail_prob, measured where it is well-defined:
        // the seed-averaged makespan over a fixed equal-task workload.
        let t = tasks(16, 8.0);
        let s = slots(4);
        let mean = |p: f64| -> f64 {
            (0..32)
                .map(|seed| {
                    schedule_with_faults(
                        &t,
                        &s,
                        &oh(),
                        &FaultModel {
                            fail_prob: p,
                            max_attempts: 64,
                            seed,
                            ..Default::default()
                        },
                    )
                    .makespan
                })
                .sum::<f64>()
                / 32.0
        };
        let (m0, m1, m2) = (mean(0.0), mean(0.2), mean(0.5));
        assert!(m0 < m1, "mean makespan {m0:.1} !< {m1:.1} at fail_prob 0.2");
        assert!(m1 < m2, "mean makespan {m1:.1} !< {m2:.1} at fail_prob 0.5");
    }

    #[test]
    fn stream_derivation_is_deterministic_and_distinct() {
        let model = FaultModel { fail_prob: 0.1, seed: 9, ..Default::default() };
        assert_eq!(model.for_stream(2, 0).seed, model.for_stream(2, 0).seed);
        assert_ne!(model.for_stream(2, 0).seed, model.for_stream(2, 1).seed);
        assert_ne!(model.for_stream(2, 0).seed, model.for_stream(3, 0).seed);
        assert_eq!(model.for_stream(5, 1).fail_prob, model.fail_prob);
    }

    #[test]
    fn validate_rejects_out_of_domain_knobs() {
        assert!(FaultModel::default().validate().is_ok());
        let bad = [
            FaultModel { fail_prob: -0.1, ..Default::default() },
            FaultModel { fail_prob: 1.5, ..Default::default() },
            FaultModel { fail_prob: f64::NAN, ..Default::default() },
            FaultModel { straggler_prob: 2.0, ..Default::default() },
            FaultModel { straggler_factor: 0.5, ..Default::default() },
            FaultModel { straggler_factor: f64::INFINITY, ..Default::default() },
            FaultModel { max_attempts: 0, ..Default::default() },
            FaultModel { spec_threshold: 0.0, ..Default::default() },
        ];
        for model in bad {
            assert!(model.validate().is_err(), "{model:?} should be rejected");
        }
    }

    #[test]
    fn accumulate_merges_counters() {
        let mut total = FaultOutcome {
            makespan: 10.0,
            attempts: 4,
            failures: 1,
            stragglers: 1,
            speculative_launches: 1,
            speculative_wins: 0,
            job_failed: false,
        };
        total.accumulate(&FaultOutcome {
            makespan: 5.0,
            attempts: 3,
            failures: 0,
            stragglers: 2,
            speculative_launches: 1,
            speculative_wins: 1,
            job_failed: true,
        });
        assert_eq!(total.makespan, 15.0);
        assert_eq!(total.attempts, 7);
        assert_eq!(total.failures, 1);
        assert_eq!(total.stragglers, 3);
        assert_eq!(total.speculative_launches, 2);
        assert_eq!(total.speculative_wins, 1);
        assert!(total.job_failed);
    }

    #[test]
    fn empty_inputs() {
        let out = schedule_with_faults(&[], &slots(2), &oh(), &FaultModel::default());
        assert_eq!(out.makespan, 0.0);
        let out = schedule_with_faults(&tasks(2, 1.0), &[], &oh(), &FaultModel::default());
        assert_eq!(out.makespan, 0.0);
    }
}
