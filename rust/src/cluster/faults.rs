//! Fault and straggler model for the cluster simulator: Hadoop's task
//! retry and speculative-execution semantics, in simulated time.
//!
//! The paper's cluster (§2.2) relies on MapReduce's "managing node failure"
//! properties; this module makes that substrate real: each task attempt may
//! fail (re-queued, up to `max_attempts`) or straggle (duration inflated);
//! with speculation on, a backup attempt launches for any task running
//! longer than `spec_threshold ×` the median finished duration, and the
//! earlier finisher wins — exactly Hadoop's default policy shape.
//!
//! Everything is deterministic from the seed, so fault experiments are
//! reproducible and results (which never depend on timing) are untouched.

use super::costmodel::OverheadParams;
use super::scheduler::SimTask;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// Failure/straggler injection parameters.
pub struct FaultModel {
    /// Probability an attempt fails (uniform per attempt).
    pub fail_prob: f64,
    /// Probability an attempt straggles.
    pub straggler_prob: f64,
    /// Straggler duration multiplier.
    pub straggler_factor: f64,
    /// Attempts per task before the job is declared failed.
    pub max_attempts: usize,
    /// Enable speculative backup attempts.
    pub speculation: bool,
    /// Launch a backup when an attempt exceeds this multiple of the median
    /// finished-attempt duration.
    pub spec_threshold: f64,
    /// Injection seed (fully deterministic).
    pub seed: u64,
}

impl Default for FaultModel {
    fn default() -> Self {
        Self {
            fail_prob: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 6.0,
            max_attempts: 4,
            speculation: false,
            spec_threshold: 1.5,
            seed: 7,
        }
    }
}

#[derive(Debug, Clone, Default)]
/// What the fault-injected schedule produced.
pub struct FaultOutcome {
    /// Phase makespan with faults, seconds.
    pub makespan: f64,
    /// Total attempts launched (retries and backups included).
    pub attempts: usize,
    /// Failed attempts.
    pub failures: usize,
    /// Straggling attempts.
    pub stragglers: usize,
    /// Backup attempts launched.
    pub speculative_launches: usize,
    /// Backups that finished before their original attempt.
    pub speculative_wins: usize,
    /// True if some task exhausted its attempts.
    pub job_failed: bool,
}

/// Event-driven schedule of `tasks` onto `slots` under the fault model.
///
/// Slots are `(node, speed)` pairs as in [`super::scheduler::schedule`];
/// a failed attempt re-queues its task at the back (Hadoop re-schedules on
/// the next free container); a straggler runs to completion unless a
/// speculative backup finishes first.
pub fn schedule_with_faults(
    tasks: &[SimTask],
    slots: &[(usize, f64)],
    overhead: &OverheadParams,
    model: &FaultModel,
) -> FaultOutcome {
    if tasks.is_empty() || slots.is_empty() {
        return FaultOutcome::default();
    }
    let mut rng = Rng::new(model.seed);
    let mut out = FaultOutcome::default();

    // Remaining attempt budget and completion flags per task.
    let mut attempts_left: Vec<usize> = vec![model.max_attempts; tasks.len()];
    let mut done = vec![false; tasks.len()];
    // Running attempts: (finish_time, task, is_speculative, will_fail).
    let mut running: Vec<(f64, usize, bool, bool)> = Vec::new();
    let mut queue: std::collections::VecDeque<usize> = (0..tasks.len()).collect();
    let mut free_at = vec![0.0f64; slots.len()];
    let mut finished_durations: Vec<f64> = Vec::new();
    // Track which tasks already have a speculative backup.
    let mut has_backup = vec![false; tasks.len()];

    // Simple event loop: repeatedly start work on the earliest-free slot,
    // then retire the earliest finisher.
    loop {
        // Launch queued tasks onto free slots (earliest-free first).
        while !queue.is_empty() {
            let (slot, _) = free_at
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, &t)| (i, t))
                .unwrap();
            // Only launch if the slot is actually free "now" relative to the
            // earliest unfinished attempt; with a pure list model we can
            // always launch (start time = slot free time).
            let task = queue.pop_front().unwrap();
            if done[task] {
                continue;
            }
            if attempts_left[task] == 0 {
                out.job_failed = true;
                continue;
            }
            attempts_left[task] -= 1;
            out.attempts += 1;
            let (node, speed) = slots[slot];
            let local = tasks[task].preferred_nodes.is_empty()
                || tasks[task].preferred_nodes.contains(&node);
            let mut dur = overhead.task_start + tasks[task].compute_secs / speed;
            if !local {
                dur += overhead.nonlocal_penalty;
            }
            let will_fail = rng.chance(model.fail_prob);
            if !will_fail && rng.chance(model.straggler_prob) {
                dur *= model.straggler_factor;
                out.stragglers += 1;
            }
            let start = free_at[slot];
            // Failed attempts die halfway through their duration.
            let finish = if will_fail { start + dur * 0.5 } else { start + dur };
            free_at[slot] = finish;
            running.push((finish, task, false, will_fail));
        }

        if running.is_empty() {
            break;
        }

        // Speculation: if enabled and we have history, launch backups for
        // attempts projected to run long.
        if model.speculation && finished_durations.len() >= 3 {
            let mut sorted = finished_durations.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = sorted[sorted.len() / 2];
            let threshold = median * model.spec_threshold;
            let long_runners: Vec<usize> = running
                .iter()
                .filter(|&&(finish, task, spec, failed)| {
                    !spec && !failed && !done[task] && !has_backup[task] && finish > threshold
                })
                .map(|&(_, task, _, _)| task)
                .collect();
            for task in long_runners {
                // Backup goes to the earliest-free slot.
                let (slot, _) = free_at
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, &t)| (i, t))
                    .unwrap();
                let (_, speed) = slots[slot];
                let dur = overhead.task_start + tasks[task].compute_secs / speed;
                let start = free_at[slot];
                free_at[slot] = start + dur;
                running.push((start + dur, task, true, false));
                has_backup[task] = true;
                out.speculative_launches += 1;
            }
        }

        // Retire the earliest finisher.
        running.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let (finish, task, speculative, failed) = running.pop().unwrap();
        if failed {
            out.failures += 1;
            if !done[task] {
                queue.push_back(task);
            }
            continue;
        }
        if !done[task] {
            done[task] = true;
            finished_durations.push(finish); // proxy: completion time
            out.makespan = out.makespan.max(finish);
            if speculative {
                out.speculative_wins += 1;
            }
        }
    }

    if done.iter().any(|d| !d) {
        out.job_failed = true;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oh() -> OverheadParams {
        OverheadParams { job_submit: 0.0, task_start: 1.0, nonlocal_penalty: 0.0, driver_gap: 0.0 }
    }

    fn tasks(n: usize, secs: f64) -> Vec<SimTask> {
        (0..n).map(|_| SimTask { compute_secs: secs, preferred_nodes: vec![] }).collect()
    }

    fn slots(n: usize) -> Vec<(usize, f64)> {
        (0..n).map(|i| (i, 1.0)).collect()
    }

    #[test]
    fn no_faults_matches_plain_makespan() {
        let t = tasks(8, 10.0);
        let s = slots(4);
        let plain = crate::cluster::scheduler::schedule(&t, &s, &oh());
        let faulty = schedule_with_faults(&t, &s, &oh(), &FaultModel::default());
        assert!((plain.makespan - faulty.makespan).abs() < 1e-9);
        assert_eq!(faulty.attempts, 8);
        assert_eq!(faulty.failures, 0);
        assert!(!faulty.job_failed);
    }

    #[test]
    fn failures_extend_makespan_and_retry() {
        let t = tasks(8, 10.0);
        let s = slots(4);
        let model = FaultModel { fail_prob: 0.3, seed: 11, ..Default::default() };
        let faulty = schedule_with_faults(&t, &s, &oh(), &model);
        let clean = schedule_with_faults(&t, &s, &oh(), &FaultModel::default());
        assert!(faulty.failures > 0, "seed should produce failures");
        assert!(faulty.attempts > 8);
        assert!(faulty.makespan > clean.makespan);
        assert!(!faulty.job_failed, "retries should recover");
    }

    #[test]
    fn certain_failure_fails_job() {
        let t = tasks(2, 5.0);
        let s = slots(2);
        let model = FaultModel { fail_prob: 1.0, max_attempts: 3, ..Default::default() };
        let out = schedule_with_faults(&t, &s, &oh(), &model);
        assert!(out.job_failed);
        assert_eq!(out.attempts, 6); // 2 tasks x 3 attempts
    }

    #[test]
    fn speculation_beats_stragglers() {
        // Many short tasks + straggler chance: speculation should cut the
        // makespan relative to no-speculation under the same seed.
        let t = tasks(24, 5.0);
        let s = slots(6);
        let base = FaultModel {
            straggler_prob: 0.15,
            straggler_factor: 10.0,
            seed: 21,
            ..Default::default()
        };
        let without = schedule_with_faults(&t, &s, &oh(), &base);
        let with = schedule_with_faults(
            &t,
            &s,
            &oh(),
            &FaultModel { speculation: true, ..base.clone() },
        );
        assert!(without.stragglers > 0);
        assert!(with.speculative_launches > 0);
        assert!(
            with.makespan < without.makespan,
            "speculation {:.1} !< plain {:.1}",
            with.makespan,
            without.makespan
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let t = tasks(12, 7.0);
        let s = slots(3);
        let model = FaultModel { fail_prob: 0.2, straggler_prob: 0.2, seed: 5, ..Default::default() };
        let a = schedule_with_faults(&t, &s, &oh(), &model);
        let b = schedule_with_faults(&t, &s, &oh(), &model);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.attempts, b.attempts);
        assert_eq!(a.failures, b.failures);
    }

    #[test]
    fn empty_inputs() {
        let out = schedule_with_faults(&[], &slots(2), &oh(), &FaultModel::default());
        assert_eq!(out.makespan, 0.0);
        let out = schedule_with_faults(&tasks(2, 1.0), &[], &oh(), &FaultModel::default());
        assert_eq!(out.makespan, 0.0);
    }
}
