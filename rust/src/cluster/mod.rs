//! The simulated Hadoop cluster: node topology, deterministic cost model,
//! slot-based list scheduler, and per-job timing.
//!
//! Why a simulator: the paper's result is about *scheduling-overhead
//! amortization* (fewer MapReduce jobs -> fewer fixed job-submit costs) and
//! *mapper workload balance* across a 5-node cluster (Table 1). Both are
//! functions of (number of jobs, per-task work, slots) — not of the host
//! machine this code happens to run on (a single-core CI box). The engine
//! executes the real mining work and meters it with operation counters; this
//! module converts those counters into simulated cluster seconds with a
//! calibrated linear cost model and a faithful slot scheduler. See DESIGN.md
//! §3 and §6.

pub mod costmodel;
pub mod faults;
pub mod scheduler;

pub use costmodel::{CostWeights, OverheadParams};
pub use faults::{schedule_with_faults, FaultModel, FaultOutcome};
pub use scheduler::{schedule, ScheduleOutcome, SimTask};

use crate::mapreduce::engine::TaskMeter;

/// One DataNode: relative speed and task slots (Table 1's heterogeneous
/// cluster: two physical 2 GB nodes, two faster virtual 4 GB nodes).
#[derive(Debug, Clone)]
pub struct NodeSpec {
    /// Node name (DN1, DN2, ...).
    pub name: String,
    /// Relative compute speed (1.0 = baseline physical node).
    pub speed: f64,
    /// Concurrent map tasks the node can run.
    pub map_slots: usize,
    /// Concurrent reduce tasks the node can run.
    pub reduce_slots: usize,
}

/// Full cluster + cost-model configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// DataNode topology.
    pub nodes: Vec<NodeSpec>,
    /// Cost-model weights (operation counters -> seconds).
    pub weights: CostWeights,
    /// Fixed overheads (job submit, task start, ...).
    pub overhead: OverheadParams,
    /// Reduce tasks per job.
    pub n_reducers: usize,
    /// Host threads for the real execution (independent of simulated slots).
    pub workers: usize,
}

impl ClusterConfig {
    /// The paper's Table 1 cluster: 4 DataNodes, 4 cores each; DN1/DN2
    /// physical (baseline), DN3/DN4 virtual on faster Xeons.
    pub fn paper_cluster() -> Self {
        let mut nodes = Vec::new();
        for (name, speed) in
            [("DN1", 1.0), ("DN2", 1.0), ("DN3", 1.12), ("DN4", 1.12)]
        {
            nodes.push(NodeSpec { name: name.into(), speed, map_slots: 4, reduce_slots: 2 });
        }
        Self {
            nodes,
            weights: CostWeights::default(),
            overhead: OverheadParams::default(),
            n_reducers: 4,
            workers: 1,
        }
    }

    /// Homogeneous cluster of `n` DataNodes (Fig 5(b) speedup sweeps).
    pub fn uniform(n: usize, map_slots: usize) -> Self {
        let nodes = (0..n)
            .map(|i| NodeSpec {
                name: format!("DN{}", i + 1),
                speed: 1.0,
                map_slots,
                reduce_slots: 2,
            })
            .collect();
        Self {
            nodes,
            weights: CostWeights::default(),
            overhead: OverheadParams::default(),
            n_reducers: 4,
            workers: 1,
        }
    }

    /// Sum of map slots across all nodes.
    pub fn total_map_slots(&self) -> usize {
        self.nodes.iter().map(|n| n.map_slots).sum()
    }

    /// Sum of reduce slots across all nodes.
    pub fn total_reduce_slots(&self) -> usize {
        self.nodes.iter().map(|n| n.reduce_slots).sum()
    }
}

/// Simulated timing of one MapReduce job (one "phase" of the paper).
#[derive(Debug, Clone, Copy, Default)]
pub struct JobTiming {
    /// Fixed job-submit overhead, seconds.
    pub submit: f64,
    /// Map-phase makespan, seconds.
    pub map_makespan: f64,
    /// Serialized shuffle time, seconds.
    pub shuffle: f64,
    /// Reduce-phase makespan, seconds.
    pub reduce_makespan: f64,
}

impl JobTiming {
    /// The phase's elapsed simulated seconds (a Table 3-5 cell).
    pub fn elapsed(&self) -> f64 {
        self.submit + self.map_makespan + self.shuffle + self.reduce_makespan
    }
}

/// One slot per `map_slots` entry of every node: the map stage's container
/// pool.
fn map_slot_list(cluster: &ClusterConfig) -> Vec<(usize, f64)> {
    cluster
        .nodes
        .iter()
        .enumerate()
        .flat_map(|(i, n)| std::iter::repeat((i, n.speed)).take(n.map_slots))
        .collect()
}

/// One slot per `reduce_slots` entry of every node.
fn reduce_slot_list(cluster: &ClusterConfig) -> Vec<(usize, f64)> {
    cluster
        .nodes
        .iter()
        .enumerate()
        .flat_map(|(i, n)| std::iter::repeat((i, n.speed)).take(n.reduce_slots))
        .collect()
}

/// The cost-modeled form of one MapReduce job — per-task simulated compute
/// seconds (with replica placements) plus the serialized shuffle term.
/// This is exactly what the schedulers consume; sessions retain one per
/// phase so alternative timing models (the fault simulator) can re-time a
/// finished phase without re-executing any mining work.
#[derive(Debug, Clone, Default)]
pub struct SimJob {
    /// Cost-modeled map tasks, in task order.
    pub map_tasks: Vec<SimTask>,
    /// Cost-modeled reduce tasks, in task order.
    pub reduce_tasks: Vec<SimTask>,
    /// Serialized shuffle seconds (all combine-output tuples cross the
    /// network).
    pub shuffle: f64,
}

impl SimJob {
    /// Convert metered tasks into their cost-modeled form on `cluster`
    /// (weights only; scheduling happens in [`SimJob::timing`]).
    pub fn from_meters(
        map_meters: &[TaskMeter],
        reduce_meters: &[TaskMeter],
        cluster: &ClusterConfig,
    ) -> Self {
        let w = &cluster.weights;
        let map_tasks = map_meters
            .iter()
            .map(|m| SimTask {
                compute_secs: w.map_compute_secs(&m.counters),
                preferred_nodes: m.preferred_nodes.clone(),
            })
            .collect();
        let shuffle_tuples: u64 = map_meters
            .iter()
            .map(|m| m.counters.get(crate::mapreduce::counters::keys::COMBINE_OUTPUT_TUPLES))
            .sum();
        let reduce_tasks = reduce_meters
            .iter()
            .map(|m| SimTask {
                compute_secs: w.reduce_compute_secs(&m.counters),
                preferred_nodes: Vec::new(),
            })
            .collect();
        Self { map_tasks, reduce_tasks, shuffle: shuffle_tuples as f64 * w.shuffle_tuple }
    }

    /// Clean job timing: list-schedule both stages on `cluster`'s slots.
    pub fn timing(&self, cluster: &ClusterConfig) -> JobTiming {
        let oh = &cluster.overhead;
        let map_sched = schedule(&self.map_tasks, &map_slot_list(cluster), oh);
        let reduce_sched = schedule(&self.reduce_tasks, &reduce_slot_list(cluster), oh);
        JobTiming {
            submit: oh.job_submit,
            map_makespan: map_sched.makespan,
            shuffle: self.shuffle,
            reduce_makespan: reduce_sched.makespan,
        }
    }

    /// Fault-injected job timing: run both stages through
    /// [`schedule_with_faults`], drawing the map and reduce stages of
    /// phase `stream` from independent deterministic injection streams of
    /// the model's one seed. Returns the faulted timing (same submit and
    /// shuffle terms — faults strike task execution, not the driver) plus
    /// each stage's [`FaultOutcome`]. With zero probabilities and
    /// speculation off this reproduces [`SimJob::timing`] exactly.
    pub fn faulted_timing(
        &self,
        cluster: &ClusterConfig,
        model: &FaultModel,
        stream: u64,
    ) -> (JobTiming, FaultOutcome, FaultOutcome) {
        let oh = &cluster.overhead;
        let map = schedule_with_faults(
            &self.map_tasks,
            &map_slot_list(cluster),
            oh,
            &model.for_stream(stream, 0),
        );
        let reduce = schedule_with_faults(
            &self.reduce_tasks,
            &reduce_slot_list(cluster),
            oh,
            &model.for_stream(stream, 1),
        );
        let timing = JobTiming {
            submit: oh.job_submit,
            map_makespan: map.makespan,
            shuffle: self.shuffle,
            reduce_makespan: reduce.makespan,
        };
        (timing, map, reduce)
    }
}

/// Convert metered tasks into simulated job timing on `cluster`.
pub fn simulate_job(
    map_meters: &[TaskMeter],
    reduce_meters: &[TaskMeter],
    cluster: &ClusterConfig,
) -> JobTiming {
    SimJob::from_meters(map_meters, reduce_meters, cluster).timing(cluster)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::counters::{keys, Counters};

    fn meter(visits: u64, combine_out: u64, nodes: Vec<usize>) -> TaskMeter {
        let mut counters = Counters::new();
        counters.add(keys::SUBSET_VISITS, visits);
        counters.add(keys::COMBINE_OUTPUT_TUPLES, combine_out);
        TaskMeter {
            task_id: 0,
            job: "test".into(),
            counters,
            preferred_nodes: nodes,
            wall_secs: 0.0,
        }
    }

    fn reduce_meter(tuples: u64) -> TaskMeter {
        let mut counters = Counters::new();
        counters.add(keys::REDUCE_INPUT_TUPLES, tuples);
        TaskMeter {
            task_id: 0,
            job: "test".into(),
            counters,
            preferred_nodes: vec![],
            wall_secs: 0.0,
        }
    }

    #[test]
    fn paper_cluster_shape() {
        let c = ClusterConfig::paper_cluster();
        assert_eq!(c.nodes.len(), 4);
        assert_eq!(c.total_map_slots(), 16);
        assert!(c.nodes[2].speed > c.nodes[0].speed);
    }

    #[test]
    fn one_wave_vs_three_waves() {
        // 10 equal tasks: on 4 nodes x 4 slots -> 1 wave; on 1 node -> 3 waves.
        let tasks: Vec<TaskMeter> = (0..10).map(|_| meter(1_000_000, 10, vec![])).collect();
        let reduce = vec![reduce_meter(10)];
        let big = ClusterConfig::uniform(4, 4);
        let small = ClusterConfig::uniform(1, 4);
        let t_big = simulate_job(&tasks, &reduce, &big);
        let t_small = simulate_job(&tasks, &reduce, &small);
        assert!(t_small.map_makespan > 2.0 * t_big.map_makespan);
        assert_eq!(t_big.submit, t_small.submit);
    }

    #[test]
    fn elapsed_is_sum_of_stages() {
        let tasks = vec![meter(100, 5, vec![0])];
        let reduce = vec![reduce_meter(5)];
        let c = ClusterConfig::paper_cluster();
        let t = simulate_job(&tasks, &reduce, &c);
        let total = t.elapsed();
        assert!(
            (total - (t.submit + t.map_makespan + t.shuffle + t.reduce_makespan)).abs() < 1e-12
        );
        assert!(total > c.overhead.job_submit);
    }

    #[test]
    fn more_work_more_time() {
        let c = ClusterConfig::paper_cluster();
        let light = simulate_job(&[meter(1_000, 1, vec![])], &[reduce_meter(1)], &c);
        let heavy = simulate_job(&[meter(100_000_000, 1, vec![])], &[reduce_meter(1)], &c);
        assert!(heavy.map_makespan > 10.0 * light.map_makespan);
    }

    #[test]
    fn shuffle_scales_with_tuples() {
        let c = ClusterConfig::paper_cluster();
        let a = simulate_job(&[meter(0, 1_000, vec![])], &[], &c);
        let b = simulate_job(&[meter(0, 100_000, vec![])], &[], &c);
        assert!(b.shuffle > 50.0 * a.shuffle);
    }

    #[test]
    fn faulted_timing_zero_prob_equals_clean() {
        let tasks: Vec<TaskMeter> =
            (0..10).map(|i| meter(500_000 + i as u64 * 40_000, 20, vec![i % 4])).collect();
        let reduce = vec![reduce_meter(50), reduce_meter(80)];
        let c = ClusterConfig::paper_cluster();
        let sim = SimJob::from_meters(&tasks, &reduce, &c);
        let clean = sim.timing(&c);
        let (faulted, map, red) = sim.faulted_timing(&c, &FaultModel::default(), 3);
        assert_eq!(clean.elapsed().to_bits(), faulted.elapsed().to_bits());
        assert_eq!(map.attempts, 10);
        assert_eq!(red.attempts, 2);
        assert!(!map.job_failed && !red.job_failed);
    }

    #[test]
    fn faulted_timing_injects_and_streams_are_independent() {
        let tasks: Vec<TaskMeter> =
            (0..12).map(|i| meter(600_000 + i as u64 * 90_000, 20, vec![i % 4])).collect();
        let reduce = vec![reduce_meter(500)];
        let c = ClusterConfig::paper_cluster();
        let sim = SimJob::from_meters(&tasks, &reduce, &c);
        let clean = sim.timing(&c);
        let model = FaultModel { fail_prob: 0.4, max_attempts: 8, seed: 2, ..Default::default() };
        let (t1, m1, _) = sim.faulted_timing(&c, &model, 1);
        let (t1b, m1b, _) = sim.faulted_timing(&c, &model, 1);
        // Deterministic per (seed, stream).
        assert_eq!(t1.elapsed().to_bits(), t1b.elapsed().to_bits());
        assert_eq!(m1, m1b);
        // Distinct phase streams draw distinct injections (same knobs,
        // different attempt fates): across several streams the outcomes
        // cannot all coincide.
        let mut distinct = std::collections::HashSet::new();
        for stream in 1..=6u64 {
            let (t, m, _) = sim.faulted_timing(&c, &model, stream);
            // Submit and shuffle are driver-side terms, untouched by faults.
            assert_eq!(t.submit, clean.submit);
            assert_eq!(t.shuffle, clean.shuffle);
            distinct.insert((m.failures, t.map_makespan.to_bits()));
        }
        assert!(distinct.len() > 1, "phase streams replayed one injection sequence");
    }

    #[test]
    fn simulate_job_is_sim_job_timing() {
        let tasks = vec![meter(1_000_000, 30, vec![0]), meter(2_000_000, 40, vec![1])];
        let reduce = vec![reduce_meter(70)];
        let c = ClusterConfig::paper_cluster();
        let direct = simulate_job(&tasks, &reduce, &c);
        let via_sim = SimJob::from_meters(&tasks, &reduce, &c).timing(&c);
        assert_eq!(direct.elapsed().to_bits(), via_sim.elapsed().to_bits());
    }
}
