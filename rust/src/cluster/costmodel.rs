//! The linear cost model converting measured operation counters into
//! simulated seconds, plus the fixed overhead parameters.
//!
//! Default weights are calibrated against the SPC column of the paper's
//! Table 3 (c20d10k, min_sup 0.15) — one global weight set shared by every
//! algorithm, so relative results are never fitted per-algorithm. Re-run the
//! calibration with `mrapriori calibrate`. Weights can also be loaded from a
//! TOML file (`config::load_cost_weights`).

use crate::mapreduce::counters::{keys, Counters};

/// Seconds-per-operation weights for map/reduce task compute.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostWeights {
    /// Per input record fed to map() (read + parse + dispatch).
    pub record: f64,
    /// Per raw map-output tuple (serialize + collect).
    pub map_tuple: f64,
    /// Per join pair considered in apriori-gen / non-apriori-gen.
    pub join_pair: f64,
    /// Per prune subset-membership probe.
    pub prune_check: f64,
    /// Per candidate-trie insertion.
    pub cand_built: f64,
    /// Per trie-node visit during subset() counting.
    pub subset_visit: f64,
    /// Per u64-word operation of the TID-bitmap backend (build OR, or
    /// AND+popcount). A word op touches 64 transaction slots at once, so it
    /// is priced well below a per-candidate trie visit but above a plain
    /// arithmetic op (memory traffic dominates).
    pub bitmap_word: f64,
    /// Per O(1) increment of the dense triangular pair matrix (fused
    /// pass-1/2 job and the `triangular` k=2 backend).
    pub triangle_update: f64,
    /// Per tuple leaving the combiner (sort/spill).
    pub combine_tuple: f64,
    /// Per tuple crossing the network in shuffle.
    pub shuffle_tuple: f64,
    /// Per tuple processed by a reducer.
    pub reduce_tuple: f64,
}

impl Default for CostWeights {
    fn default() -> Self {
        // Calibrated: `mrapriori calibrate` against Table 3 (see DESIGN.md §6).
        Self {
            record: 1.0e-4,
            map_tuple: 4.75e-6,
            join_pair: 6.0e-7,
            prune_check: 1.5e-6,
            cand_built: 2.5e-7,
            subset_visit: 4.75e-6,
            bitmap_word: 7.5e-7,
            triangle_update: 2.5e-7,
            combine_tuple: 1.0e-5,
            shuffle_tuple: 5.0e-6,
            reduce_tuple: 1.0e-5,
        }
    }
}

impl CostWeights {
    /// Compute seconds for one map task from its counters.
    pub fn map_compute_secs(&self, c: &Counters) -> f64 {
        self.record * c.get(keys::MAP_INPUT_RECORDS) as f64
            + self.map_tuple * c.get(keys::MAP_OUTPUT_TUPLES) as f64
            + self.join_pair * c.get(keys::JOIN_PAIRS) as f64
            + self.prune_check * c.get(keys::PRUNE_CHECKS) as f64
            + self.cand_built * c.get(keys::CANDS_BUILT) as f64
            + self.subset_visit * c.get(keys::SUBSET_VISITS) as f64
            + self.bitmap_word * c.get(keys::BITMAP_WORD_OPS) as f64
            + self.triangle_update * c.get(keys::TRIANGLE_UPDATES) as f64
            + self.combine_tuple * c.get(keys::COMBINE_OUTPUT_TUPLES) as f64
    }

    /// Compute seconds for one reduce task.
    pub fn reduce_compute_secs(&self, c: &Counters) -> f64 {
        self.reduce_tuple * c.get(keys::REDUCE_INPUT_TUPLES) as f64
            + self.reduce_tuple * c.get(keys::REDUCE_OUTPUT_RECORDS) as f64
    }
}

/// Fixed scheduling overheads (the quantities FPC/DPC/VFPC/ETDPC amortize).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverheadParams {
    /// Per-job submission / scheduling / JVM-spinup cost (seconds). The
    /// dominant fixed cost the paper's pass-combining attacks.
    pub job_submit: f64,
    /// Per-task startup cost (container launch).
    pub task_start: f64,
    /// Extra startup for a non-data-local map task.
    pub nonlocal_penalty: f64,
    /// Driver-side gap between consecutive jobs (the paper's "actual" minus
    /// "total" time grows with the number of phases, §5.3).
    pub driver_gap: f64,
}

impl Default for OverheadParams {
    fn default() -> Self {
        Self { job_submit: 15.0, task_start: 0.8, nonlocal_penalty: 0.4, driver_gap: 5.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_compute_linear_in_counters() {
        let w = CostWeights::default();
        let mut c = Counters::new();
        c.add(keys::SUBSET_VISITS, 1_000_000);
        let t1 = w.map_compute_secs(&c);
        c.add(keys::SUBSET_VISITS, 1_000_000);
        let t2 = w.map_compute_secs(&c);
        assert!((t2 - 2.0 * t1).abs() < 1e-12);
    }

    #[test]
    fn all_weights_contribute() {
        let w = CostWeights::default();
        for key in [
            keys::MAP_INPUT_RECORDS,
            keys::MAP_OUTPUT_TUPLES,
            keys::JOIN_PAIRS,
            keys::PRUNE_CHECKS,
            keys::CANDS_BUILT,
            keys::SUBSET_VISITS,
            keys::BITMAP_WORD_OPS,
            keys::TRIANGLE_UPDATES,
            keys::COMBINE_OUTPUT_TUPLES,
        ] {
            let mut c = Counters::new();
            c.add(key, 1_000_000);
            assert!(w.map_compute_secs(&c) > 0.0, "weight for {key} is zero");
        }
    }

    #[test]
    fn reduce_compute() {
        let w = CostWeights::default();
        let mut c = Counters::new();
        c.add(keys::REDUCE_INPUT_TUPLES, 10_000);
        c.add(keys::REDUCE_OUTPUT_RECORDS, 100);
        assert!((w.reduce_compute_secs(&c) - (10_100.0 * w.reduce_tuple)).abs() < 1e-12);
    }

    #[test]
    fn default_overheads_sane() {
        let oh = OverheadParams::default();
        assert!(oh.job_submit > oh.task_start);
        assert!(oh.driver_gap > 0.0);
    }
}
