//! Slot-based list scheduler: assigns simulated tasks to (node, slot) pairs
//! the way the YARN capacity scheduler fills container requests, producing
//! the phase makespan.
//!
//! Tasks are placed in submission order; each goes to the slot that can
//! *finish* it earliest, accounting for node speed, task startup cost, and a
//! data-locality penalty when the chosen node holds no replica of the
//! task's split.

use super::costmodel::OverheadParams;

/// A simulated task: pure compute seconds (already cost-modeled) plus the
/// nodes holding its input replicas.
#[derive(Debug, Clone)]
pub struct SimTask {
    /// Cost-modeled compute seconds.
    pub compute_secs: f64,
    /// Nodes holding the task's input replicas.
    pub preferred_nodes: Vec<usize>,
}

/// Placement decision for one task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Assignment {
    /// Chosen DataNode.
    pub node: usize,
    /// Slot index on that node.
    pub slot: usize,
    /// Start time, seconds into the phase.
    pub start: f64,
    /// Finish time, seconds into the phase.
    pub finish: f64,
    /// Whether the placement was data-local.
    pub local: bool,
}

#[derive(Debug, Clone, Default)]
/// Full placement of one phase.
pub struct ScheduleOutcome {
    /// Per-task placements, in submission order.
    pub assignments: Vec<Assignment>,
    /// When the last task finishes, seconds.
    pub makespan: f64,
}

/// One placement decision of [`pick_slot`]: where an attempt would run and
/// what it would cost there.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Placement {
    /// Chosen slot index.
    pub slot: usize,
    /// Start time (the slot's free time, clamped to "now").
    pub start: f64,
    /// Clean attempt duration on that slot (startup + compute + locality).
    pub dur: f64,
    /// Projected finish time (`start + dur`).
    pub finish: f64,
    /// Whether the placement is data-local.
    pub local: bool,
}

/// THE placement rule of this simulator: the slot that *finishes* `task`
/// earliest once it becomes runnable at `now`, accounting for node speed,
/// startup cost, and the non-locality penalty, with exact-tie preference
/// for data-local slots. Shared by [`schedule`] and
/// [`super::faults::schedule_with_faults`] so the two schedulers cannot
/// drift: with no faults injected they perform bit-identical arithmetic.
pub(crate) fn pick_slot(
    task: &SimTask,
    slots: &[(usize, f64)],
    free_at: &[f64],
    now: f64,
    overhead: &OverheadParams,
) -> Placement {
    let mut best: Option<Placement> = None;
    for (i, &(node, speed)) in slots.iter().enumerate() {
        let local = task.preferred_nodes.is_empty() || task.preferred_nodes.contains(&node);
        let start = free_at[i].max(now);
        let mut dur = overhead.task_start + task.compute_secs / speed;
        if !local {
            dur += overhead.nonlocal_penalty;
        }
        let finish = start + dur;
        let better = match best {
            None => true,
            Some(Placement { finish: bf, local: bl, .. }) => {
                finish < bf - 1e-12 || ((finish - bf).abs() <= 1e-12 && local && !bl)
            }
        };
        if better {
            best = Some(Placement { slot: i, start, dur, finish, local });
        }
    }
    // lint:allow(unwrap-in-library): the loop above assigns `best` on the
    // first slot, and schedule() never calls in with zero slots — an empty
    // cluster is a configuration bug, not a runtime condition.
    best.expect("pick_slot requires at least one slot")
}

/// Schedule `tasks` onto `slots` (pairs of `(node_id, node_speed)`, one entry
/// per slot). Returns per-task placements and the makespan.
pub fn schedule(
    tasks: &[SimTask],
    slots: &[(usize, f64)],
    overhead: &OverheadParams,
) -> ScheduleOutcome {
    if tasks.is_empty() || slots.is_empty() {
        return ScheduleOutcome::default();
    }
    // free_at[i] = when slot i next becomes available.
    let mut free_at = vec![0.0f64; slots.len()];
    let mut assignments = Vec::with_capacity(tasks.len());
    let mut makespan = 0.0f64;

    for task in tasks {
        let p = pick_slot(task, slots, &free_at, 0.0, overhead);
        free_at[p.slot] = p.finish;
        makespan = makespan.max(p.finish);
        assignments.push(Assignment {
            node: slots[p.slot].0,
            slot: p.slot,
            start: p.start,
            finish: p.finish,
            local: p.local,
        });
    }
    ScheduleOutcome { assignments, makespan }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{forall, UsizeGen, VecGen};

    fn oh() -> OverheadParams {
        OverheadParams { job_submit: 0.0, task_start: 1.0, nonlocal_penalty: 0.5, driver_gap: 0.0 }
    }

    fn task(secs: f64) -> SimTask {
        SimTask { compute_secs: secs, preferred_nodes: vec![] }
    }

    #[test]
    fn single_wave_makespan_is_slowest_task() {
        let tasks = vec![task(10.0), task(5.0), task(7.0)];
        let slots = vec![(0, 1.0), (1, 1.0), (2, 1.0), (3, 1.0)];
        let out = schedule(&tasks, &slots, &oh());
        assert!((out.makespan - 11.0).abs() < 1e-9); // 10 + 1 start
    }

    #[test]
    fn serial_on_one_slot() {
        let tasks = vec![task(2.0), task(3.0), task(4.0)];
        let slots = vec![(0, 1.0)];
        let out = schedule(&tasks, &slots, &oh());
        assert!((out.makespan - (2.0 + 3.0 + 4.0 + 3.0)).abs() < 1e-9); // + 3 starts
        // Tasks run back to back.
        assert!((out.assignments[1].start - out.assignments[0].finish).abs() < 1e-9);
    }

    #[test]
    fn faster_node_attracts_work() {
        let tasks = vec![task(10.0)];
        let slots = vec![(0, 1.0), (1, 2.0)];
        let out = schedule(&tasks, &slots, &oh());
        assert_eq!(out.assignments[0].node, 1);
        assert!((out.makespan - (1.0 + 5.0)).abs() < 1e-9);
    }

    #[test]
    fn locality_breaks_ties() {
        let tasks = vec![SimTask { compute_secs: 4.0, preferred_nodes: vec![1] }];
        let slots = vec![(0, 1.0), (1, 1.0)];
        let out = schedule(&tasks, &slots, &oh());
        assert_eq!(out.assignments[0].node, 1);
        assert!(out.assignments[0].local);
    }

    #[test]
    fn nonlocal_penalty_applied() {
        let tasks = vec![SimTask { compute_secs: 4.0, preferred_nodes: vec![9] }];
        let slots = vec![(0, 1.0)];
        let out = schedule(&tasks, &slots, &oh());
        assert!((out.makespan - (1.0 + 4.0 + 0.5)).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(schedule(&[], &[(0, 1.0)], &oh()).makespan, 0.0);
        assert_eq!(schedule(&[task(1.0)], &[], &oh()).makespan, 0.0);
    }

    #[test]
    fn prop_makespan_bounds() {
        // Lower bound: max task duration and total-work/slots; upper bound:
        // greedy list scheduling is within 2x of LPT-optimal-ish bound
        // (we check the classic (total/m + max) bound).
        let gen = VecGen { inner: UsizeGen { lo: 1, hi: 50 }, max_len: 40 };
        forall(601, 120, &gen, |durs| {
            if durs.is_empty() {
                return true;
            }
            let tasks: Vec<SimTask> = durs.iter().map(|&d| task(d as f64)).collect();
            let m = 4usize;
            let slots: Vec<(usize, f64)> = (0..m).map(|i| (i, 1.0)).collect();
            let out = schedule(
                &tasks,
                &slots,
                &OverheadParams {
                    job_submit: 0.0,
                    task_start: 0.0,
                    nonlocal_penalty: 0.0,
                    driver_gap: 0.0,
                },
            );
            let total: f64 = durs.iter().map(|&d| d as f64).sum();
            let maxd = durs.iter().map(|&d| d as f64).fold(0.0, f64::max);
            let lower = (total / m as f64).max(maxd);
            out.makespan >= lower - 1e-9 && out.makespan <= total / m as f64 + maxd + 1e-9
        });
    }

    #[test]
    fn prop_no_slot_overlap() {
        let gen = VecGen { inner: UsizeGen { lo: 1, hi: 20 }, max_len: 30 };
        forall(602, 80, &gen, |durs| {
            let tasks: Vec<SimTask> = durs.iter().map(|&d| task(d as f64)).collect();
            let slots: Vec<(usize, f64)> = (0..3).map(|i| (i, 1.0)).collect();
            let out = schedule(&tasks, &slots, &oh());
            // Group assignments per slot and check intervals do not overlap.
            for s in 0..3 {
                let mut ivs: Vec<(f64, f64)> = out
                    .assignments
                    .iter()
                    .filter(|a| a.slot == s)
                    .map(|a| (a.start, a.finish))
                    .collect();
                ivs.sort_by(|a, b| a.0.total_cmp(&b.0));
                for w in ivs.windows(2) {
                    if w[0].1 > w[1].0 + 1e-9 {
                        return false;
                    }
                }
            }
            true
        });
    }
}
