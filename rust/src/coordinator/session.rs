//! Mining-as-a-service: the session/query layer over the Layer-3 driver.
//!
//! The paper's drivers are one-shot scripts, but both its evaluation and
//! the companion study (Singh et al., *Observations on Factors Affecting
//! Performance...*) sweep the **same dataset** across many supports,
//! algorithms, and cluster shapes. A [`MiningSession`] binds a dataset
//! ([`HdfsFile`], either [`crate::hdfs::RecordSource`] backend) plus a
//! [`ClusterConfig`] once, then serves many queries concurrently (`&self`,
//! `Sync`):
//!
//! * the input-split plan is computed once per session;
//! * Job1 (frequent 1-itemsets, Algorithm 1) is memoized per
//!   `(min_count, fuse_pass_2)` key, so a seven-algorithm comparison or a
//!   support sweep pays for the first dataset scan exactly once — the
//!   reuse is observable through [`MiningSession::stats`];
//! * queries are [`MiningRequest`] builder values validated into typed
//!   [`MiningError`]s (no panics on zero `split_lines`, out-of-domain
//!   `min_sup`, empty datasets);
//! * every query's MapReduce jobs are submitted to the session's one
//!   [`Executor`] (Engine v2, DESIGN.md §9), so N concurrent queries share
//!   ONE bounded worker pool instead of spawning N thread batches;
//! * execution either runs inline ([`MiningSession::run`] /
//!   [`MiningSession::run_streaming`]) or on a background thread behind a
//!   [`RunHandle`] that streams [`PhaseEvent`]s — phase boundaries plus
//!   per-task progress — and supports cooperative cancellation
//!   ([`CancelToken`]), which the executor honors *inside* a running Job2
//!   at task granularity.
//!
//! The legacy `coordinator::run*` free functions, deprecated in 0.2.0,
//! were removed in 0.3.0 (see DESIGN.md §8).

use super::drivers::PhaseObservation;
use super::mappers::{self, CountingBackend, GenMode, Job2Mapper, OneItemsetMapper};
use super::{
    controller_for, debug_assert_aux_agreement, Algorithm, DeltaOutcome, MiningOutcome,
    PhaseFaults, PhaseRecord, RunOptions,
};
use crate::apriori::sequential::Level;
use crate::cluster::{ClusterConfig, FaultModel, SimJob};
use crate::dataset::stats::DensityProfile;
use crate::dataset::{registry, TransactionDb};
use crate::hdfs::{self, HdfsFile, InputSplit};
use crate::incremental::{DeltaMiner, WindowSpec};
use crate::itemset::Trie;
use crate::mapreduce::api::{MinSupportReducer, SumCombiner};
use crate::mapreduce::counters::keys;
use crate::mapreduce::executor::{Executor, JobBuilder, TaskEvent};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::Instant;

pub use crate::mapreduce::executor::{CancelToken, TaskKind};

// ---------------------------------------------------------------------------
// Error taxonomy
// ---------------------------------------------------------------------------

/// Typed failure modes of the session/query API — everything the legacy
/// free functions either panicked on or silently mis-handled.
#[derive(Debug, Clone, PartialEq)]
pub enum MiningError {
    /// The bound dataset holds no transactions.
    EmptyDataset(String),
    /// `split_lines == 0`: a split plan cannot be built.
    InvalidSplitLines,
    /// `min_sup` outside `(0, 1]` (or NaN): no meaningful support count.
    InvalidMinSup(f64),
    /// `fpc_n == 0`: an FPC phase must combine at least one pass.
    InvalidFpcN,
    /// `dpc_alpha` non-finite or `< 1.0`: DPC's candidate budget
    /// `α · |L|` would admit fewer candidates than the seed level itself,
    /// silently degenerating every phase to zero passes.
    InvalidDpcAlpha(f64),
    /// `dpc_beta` non-finite or negative: the elapsed-time threshold is a
    /// duration in seconds.
    InvalidDpcBeta(f64),
    /// The cluster cannot execute jobs (no DataNodes, zero reducers, or
    /// zero host workers).
    InvalidCluster(&'static str),
    /// The request's [`FaultModel`] is out of domain (probability outside
    /// `[0, 1]`, multiplier below 1, or a zero attempt budget); carries
    /// the specific violation.
    InvalidFaultModel(&'static str),
    /// The requested [`CountingBackend`] cannot run on the session's
    /// dataset (the dense triangular matrix is capped at
    /// [`mappers::TRIANGULAR_MAX_ITEMS`] items); carries the violation.
    /// `auto` never errors — inapplicable backends simply drop out of its
    /// per-pass pick.
    InvalidBackend(&'static str),
    /// The run was cancelled through its [`CancelToken`] before finishing.
    Cancelled,
    /// A [`WindowSpec`](crate::incremental::WindowSpec) is out of domain
    /// (zero-block window, zero or over-wide step) or windowed mining was
    /// asked of a session without block-addressable storage (an in-memory
    /// `for_db` session); carries the violation.
    InvalidWindow(&'static str),
}

impl std::fmt::Display for MiningError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MiningError::EmptyDataset(name) => {
                write!(f, "dataset {name:?} holds no transactions")
            }
            MiningError::InvalidSplitLines => write!(f, "split_lines must be > 0"),
            MiningError::InvalidMinSup(v) => {
                write!(f, "min_sup must lie in (0, 1], got {v}")
            }
            MiningError::InvalidFpcN => write!(f, "fpc_n must be > 0"),
            MiningError::InvalidDpcAlpha(v) => {
                write!(f, "dpc_alpha must be finite and >= 1.0, got {v}")
            }
            MiningError::InvalidDpcBeta(v) => {
                write!(f, "dpc_beta must be finite and >= 0, got {v}")
            }
            MiningError::InvalidCluster(why) => write!(f, "invalid cluster config: {why}"),
            MiningError::InvalidFaultModel(why) => write!(f, "invalid fault model: {why}"),
            MiningError::InvalidBackend(why) => write!(f, "invalid counting backend: {why}"),
            MiningError::Cancelled => write!(f, "mining run cancelled"),
            MiningError::InvalidWindow(why) => write!(f, "invalid mining window: {why}"),
        }
    }
}

impl std::error::Error for MiningError {}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// A mining query against a [`MiningSession`], built fluently:
///
/// ```no_run
/// # use mrapriori::coordinator::{Algorithm, MiningRequest};
/// let req = MiningRequest::new(Algorithm::Vfpc).min_sup(0.02).fpc_n(3);
/// ```
///
/// Defaults mirror the paper's §5.2 settings: `min_sup` 0.25, `fpc_n` 3,
/// `dpc_alpha` 2.0, `dpc_beta` 60 s, unfused passes 1/2, faithful
/// per-record candidate generation. Domain validation happens when the
/// request is submitted, returning [`MiningError`] instead of panicking.
#[derive(Debug, Clone, PartialEq)]
pub struct MiningRequest {
    algorithm: Algorithm,
    min_sup: f64,
    fpc_n: usize,
    dpc_alpha: f64,
    dpc_beta: f64,
    fuse_pass_2: bool,
    gen_mode: GenMode,
    faults: Option<FaultModel>,
    backend: CountingBackend,
}

impl MiningRequest {
    /// A request for `algorithm` with the paper-default tunables.
    pub fn new(algorithm: Algorithm) -> Self {
        let d = RunOptions::default();
        Self {
            algorithm,
            min_sup: 0.25,
            fpc_n: d.fpc_n,
            dpc_alpha: d.dpc_alpha,
            dpc_beta: d.dpc_beta,
            fuse_pass_2: d.fuse_pass_2,
            gen_mode: d.gen_mode,
            faults: None,
            backend: CountingBackend::default(),
        }
    }

    /// Carry the tunables of a legacy [`RunOptions`] into a request (the
    /// migration path for pre-session callers). Performs no validation.
    pub fn from_options(algorithm: Algorithm, min_sup: f64, opts: &RunOptions) -> Self {
        Self {
            algorithm,
            min_sup,
            fpc_n: opts.fpc_n,
            dpc_alpha: opts.dpc_alpha,
            dpc_beta: opts.dpc_beta,
            fuse_pass_2: opts.fuse_pass_2,
            gen_mode: opts.gen_mode,
            faults: None,
            backend: CountingBackend::default(),
        }
    }

    /// Fractional minimum support, in `(0, 1]`.
    pub fn min_sup(mut self, min_sup: f64) -> Self {
        self.min_sup = min_sup;
        self
    }

    /// FPC's fixed pass count per phase (paper: "generally 3").
    pub fn fpc_n(mut self, fpc_n: usize) -> Self {
        self.fpc_n = fpc_n;
        self
    }

    /// DPC's fast-phase α (paper: 2.0 for c20d10k/mushroom, 3.0 for chess).
    pub fn dpc_alpha(mut self, dpc_alpha: f64) -> Self {
        self.dpc_alpha = dpc_alpha;
        self
    }

    /// DPC's β elapsed-time threshold in seconds (paper: 60).
    pub fn dpc_beta(mut self, dpc_beta: f64) -> Self {
        self.dpc_beta = dpc_beta;
        self
    }

    /// Fuse passes 1+2 into one job with a triangular-matrix counter
    /// (Kovacs & Illes, the paper's ref [6]); Job2 then starts at k = 3.
    pub fn fuse_pass_2(mut self, fuse: bool) -> Self {
        self.fuse_pass_2 = fuse;
        self
    }

    /// Faithful per-record candidate generation vs once-per-task (ablation).
    pub fn gen_mode(mut self, gen_mode: GenMode) -> Self {
        self.gen_mode = gen_mode;
        self
    }

    /// Run the query under a [`FaultModel`]: every phase is additionally
    /// re-timed through the fault simulator, so each [`PhaseRecord`]
    /// carries clean *and* faulted makespans plus the injection counters
    /// ([`PhaseFaults`]), and time-driven controllers (DPC/ETDPC) observe
    /// the faulted times — the environment they would actually live in.
    /// Frequent-itemset output is byte-identical with or without a model:
    /// faults only move simulated time (DESIGN.md §6).
    pub fn faults(mut self, model: FaultModel) -> Self {
        self.faults = Some(model);
        self
    }

    /// Count candidates with the given [`CountingBackend`] in every Job2
    /// pass: the default trie subset-walk, vertical TID bitmaps, the dense
    /// triangular matrix (k = 2 only), or a per-pass `auto` pick driven by
    /// the cluster cost model. All backends mine byte-identical output;
    /// only the simulated counting cost moves (DESIGN.md §11).
    pub fn backend(mut self, backend: CountingBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Which algorithm this request runs.
    pub fn algorithm(&self) -> Algorithm {
        self.algorithm
    }

    /// The request's Job2 counting backend.
    pub fn counting_backend(&self) -> CountingBackend {
        self.backend
    }

    /// The request's fractional minimum support.
    pub fn min_sup_value(&self) -> f64 {
        self.min_sup
    }

    /// The request's fault model, if one was set.
    pub fn fault_model(&self) -> Option<&FaultModel> {
        self.faults.as_ref()
    }

    /// Check every tunable's domain, the library-level validation layer.
    pub fn validate(&self) -> Result<(), MiningError> {
        if !(self.min_sup > 0.0 && self.min_sup <= 1.0) {
            return Err(MiningError::InvalidMinSup(self.min_sup));
        }
        if self.fpc_n == 0 {
            return Err(MiningError::InvalidFpcN);
        }
        if !self.dpc_alpha.is_finite() || self.dpc_alpha < 1.0 {
            return Err(MiningError::InvalidDpcAlpha(self.dpc_alpha));
        }
        if !self.dpc_beta.is_finite() || self.dpc_beta < 0.0 {
            return Err(MiningError::InvalidDpcBeta(self.dpc_beta));
        }
        if let Some(model) = &self.faults {
            model.validate().map_err(MiningError::InvalidFaultModel)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Phase events and cancellation
// ---------------------------------------------------------------------------

/// One step of a query's lifecycle, streamed while the run executes.
///
/// Phase-level events bracket each MapReduce job; within an *executing*
/// phase (never a cached one), task-level events report every map and
/// reduce task the executor starts and finishes, in true execution order —
/// the session's forwarding of [`TaskEvent`].
#[derive(Debug, Clone)]
pub enum PhaseEvent {
    /// A MapReduce phase is about to execute.
    PhaseStarted {
        /// 1-based phase index (phase 1 = Job1).
        phase: usize,
        /// Name of the MapReduce job about to run (e.g. `job2-k3`).
        job: String,
        /// Apriori pass number of the phase's first pass.
        first_pass: usize,
    },
    /// A worker began executing one task of the current phase's job.
    TaskStarted {
        /// 1-based phase index the task belongs to.
        phase: usize,
        /// Name of the job the task belongs to (shared, not copied —
        /// large jobs stream two events per task).
        job: Arc<str>,
        /// Map or reduce.
        kind: TaskKind,
        /// Task index within its phase.
        task: usize,
        /// Total tasks in that phase.
        of: usize,
    },
    /// One task of the current phase's job ran to completion.
    TaskFinished {
        /// 1-based phase index the task belongs to.
        phase: usize,
        /// Name of the job the task belongs to (shared, not copied).
        job: Arc<str>,
        /// Map or reduce.
        kind: TaskKind,
        /// Task index within its phase.
        task: usize,
        /// Total tasks in that phase.
        of: usize,
    },
    /// A MapReduce phase finished; carries its full metrics row.
    PhaseFinished {
        /// The phase's metrics (identical to the outcome's entry).
        record: PhaseRecord,
        /// Whether the result came from the session's Job1 cache instead
        /// of executing the job again.
        from_cache: bool,
    },
}

/// Forward one executor task event into the session's phase-event stream.
fn task_event(phase: usize, ev: TaskEvent) -> PhaseEvent {
    match ev {
        TaskEvent::Started { job, kind, task, of } => {
            PhaseEvent::TaskStarted { phase, job, kind, task, of }
        }
        TaskEvent::Finished { job, kind, task, of } => {
            PhaseEvent::TaskFinished { phase, job, kind, task, of }
        }
    }
}

/// The session-layer cancellation check (between phases; the executor
/// additionally checks the same token between tasks inside each Job2).
fn check(token: &CancelToken) -> Result<(), MiningError> {
    if token.is_cancelled() {
        Err(MiningError::Cancelled)
    } else {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// Observability counters of one session (see [`MiningSession::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SessionStats {
    /// Queries that started executing (including cancelled ones).
    pub queries: u64,
    /// Times Job1 actually executed (one per distinct cache key).
    pub job1_runs: u64,
    /// Queries served from the Job1 cache instead of re-scanning.
    pub job1_cache_hits: u64,
    /// Times a Job2 phase job actually executed. Job2 is never cached at
    /// the session layer, so this moves on every query that reaches the
    /// candidate passes — the counter that proves a `serve` result-cache
    /// hit re-ran nothing (DESIGN.md §12).
    pub job2_runs: u64,
    /// Delta refreshes answered through [`MiningSession::mine_incremental`]
    /// or [`MiningSession::mine_window`] (bootstrap, delta and fallback
    /// paths all count — this is "refresh calls", not "delta hits").
    pub delta_runs: u64,
    /// Blocks actually rescanned across all refreshes. On the delta path
    /// this moves by the delta blocks only; the differential suite pins it
    /// strictly below the store's block count (DESIGN.md §13).
    pub blocks_rescanned: u64,
    /// Refreshes that held a prior snapshot but had to re-mine from
    /// scratch anyway (promotion cascade, changed `min_sup`, shrunk or
    /// incompatible coverage).
    pub full_fallbacks: u64,
    /// Queries per algorithm, indexed by [`Algorithm::index`] (the order
    /// of [`Algorithm::ALL`]).
    pub queries_by_algorithm: [u64; 7],
}

impl SessionStats {
    /// Total jobs this session actually executed (Job1 + Job2 phases) —
    /// the "did any work happen" scalar the serve-layer tests pin.
    pub fn jobs_executed(&self) -> u64 {
        self.job1_runs + self.job2_runs
    }

    /// Fold `other` into `self` field-by-field — how the serve registry
    /// and [`FollowSession`](crate::incremental::FollowSession) keep
    /// totals across retired sessions.
    pub fn absorb(&mut self, other: &SessionStats) {
        self.queries += other.queries;
        self.job1_runs += other.job1_runs;
        self.job1_cache_hits += other.job1_cache_hits;
        self.job2_runs += other.job2_runs;
        self.delta_runs += other.delta_runs;
        self.blocks_rescanned += other.blocks_rescanned;
        self.full_fallbacks += other.full_fallbacks;
        for (into, v) in self.queries_by_algorithm.iter_mut().zip(other.queries_by_algorithm) {
            *into += v;
        }
    }
}

/// Job1's reusable result: frequent 1-itemsets (plus 2-itemsets when the
/// pass-1/2 fusion ran), the phase metrics row, and the cost-modeled task
/// form (so per-query fault models can re-time the cached scan without
/// re-executing it).
struct Job1Data {
    l1: Level,
    l2: Level,
    record: PhaseRecord,
    sim: SimJob,
}

struct SessionCore {
    file: HdfsFile,
    cluster: ClusterConfig,
    split_lines: usize,
    splits: Vec<InputSplit>,
    /// The session's job-submission service: ONE worker pool shared by
    /// every query's map and reduce tasks, so N concurrent queries stay
    /// inside a single `workers`-sized host budget (DESIGN.md §9).
    executor: Executor,
    /// Memoized Job1 keyed by `(min_count, fuse_pass_2)`. The
    /// [`OnceLock`] per key gives exactly-once execution under concurrent
    /// queries: racers block until the first initializer finishes.
    job1_cache: Mutex<HashMap<(u64, bool), Arc<OnceLock<Job1Data>>>>,
    queries: AtomicU64,
    job1_runs: AtomicU64,
    job1_cache_hits: AtomicU64,
    job2_runs: AtomicU64,
    delta_runs: AtomicU64,
    blocks_rescanned: AtomicU64,
    full_fallbacks: AtomicU64,
    by_algorithm: [AtomicU64; 7],
    /// Whether the session was built from an in-memory [`TransactionDb`]
    /// (`for_db`). Such files carry a synthetic block size, so windowed
    /// mining — which is defined over store blocks — refuses them.
    from_db: bool,
}

/// A long-lived mining service over one dataset and one cluster: create it
/// with [`MiningSession::builder`] (an existing [`HdfsFile`], either
/// storage backend) or [`MiningSession::for_db`] (an in-memory
/// [`TransactionDb`], stored through [`hdfs::put`]), then issue
/// [`MiningRequest`]s from any number of threads.
///
/// Cloning is cheap and shares the session (split plan, Job1 cache,
/// counters) — clones are how a session crosses thread boundaries when
/// scoped borrows are inconvenient.
///
/// The Job1 cache retains one `L1` (plus `L2` when fused) per distinct
/// `(min_count, fuse_pass_2)` key for the session's lifetime. Support
/// sweeps touch a handful of keys, so this is small in practice; a caller
/// serving *unbounded* distinct supports should recycle sessions
/// periodically (drop and rebuild) to bound memory.
#[derive(Clone)]
pub struct MiningSession {
    core: Arc<SessionCore>,
}

/// Configures and validates a [`MiningSession`]; see
/// [`MiningSession::builder`] / [`MiningSession::for_db`]. Borrows an
/// in-memory source until [`build`](SessionBuilder::build), which stores
/// it as an HDFS file exactly once (no intermediate copy).
pub struct SessionBuilder<'a> {
    source: SessionSource<'a>,
    cluster: ClusterConfig,
    split_lines: Option<usize>,
    seed: u64,
    executor: Option<Executor>,
}

enum SessionSource<'a> {
    File(HdfsFile),
    Db(&'a TransactionDb),
}

impl SessionBuilder<'_> {
    /// Lines per input split (the paper's `setNumLinesPerSplit`). Defaults
    /// to the dataset's registry setting for in-memory sources and to the
    /// file's block size for pre-stored files (segment stores decode one
    /// block per task, so finer splits would re-decode whole blocks).
    pub fn split_lines(mut self, split_lines: usize) -> Self {
        self.split_lines = Some(split_lines);
        self
    }

    /// Placement seed for HDFS replicas of an in-memory source.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Carry `split_lines` and `seed` from a legacy [`RunOptions`] (the
    /// migration path for pre-session callers).
    pub fn options(self, opts: &RunOptions) -> Self {
        self.split_lines(opts.split_lines).seed(opts.seed)
    }

    /// Share a pre-built [`Executor`] instead of letting the session spawn
    /// its own pool of `cluster.workers` threads — how several sessions
    /// (or a whole process) run on ONE host-thread budget. The executor's
    /// pool size then governs host execution; `cluster.workers` still
    /// must be non-zero (it remains the simulated-cluster sanity check).
    pub fn executor(mut self, executor: Executor) -> Self {
        self.executor = Some(executor);
        self
    }

    /// Validate and build the session: cluster shape, split size, and
    /// dataset emptiness are checked here, once, instead of panicking
    /// somewhere inside a run.
    pub fn build(self) -> Result<MiningSession, MiningError> {
        if self.cluster.nodes.is_empty() {
            return Err(MiningError::InvalidCluster("no DataNodes"));
        }
        if self.cluster.n_reducers == 0 {
            return Err(MiningError::InvalidCluster("n_reducers must be > 0"));
        }
        if self.cluster.workers == 0 {
            return Err(MiningError::InvalidCluster("workers must be > 0"));
        }
        let split_lines = self.split_lines.unwrap_or_else(|| match &self.source {
            SessionSource::File(f) => f.block_lines,
            SessionSource::Db(db) => registry::split_lines(&db.name),
        });
        if split_lines == 0 {
            return Err(MiningError::InvalidSplitLines);
        }
        let from_db = matches!(self.source, SessionSource::Db(_));
        let file = match self.source {
            SessionSource::File(f) => f,
            SessionSource::Db(db) => hdfs::put(
                db,
                split_lines,
                self.cluster.nodes.len(),
                hdfs::DEFAULT_REPLICATION,
                self.seed,
            ),
        };
        if file.is_empty() {
            return Err(MiningError::EmptyDataset(file.name.clone()));
        }
        let workers = self.cluster.workers;
        let executor = self.executor.unwrap_or_else(|| Executor::new(workers));
        Ok(MiningSession {
            core: Arc::new(SessionCore::new(file, self.cluster, split_lines, executor, from_db)),
        })
    }
}

impl MiningSession {
    /// Serve queries over an already-stored HDFS file (either
    /// [`crate::hdfs::RecordSource`] backend — this is the out-of-core
    /// entry point for segment stores).
    pub fn builder(file: HdfsFile, cluster: ClusterConfig) -> SessionBuilder<'static> {
        SessionBuilder {
            source: SessionSource::File(file),
            cluster,
            split_lines: None,
            seed: 1,
            executor: None,
        }
    }

    /// Serve queries over an in-memory database, stored as an HDFS file at
    /// [`SessionBuilder::build`] time (the session does not borrow `db`
    /// after `build`).
    pub fn for_db(db: &TransactionDb, cluster: ClusterConfig) -> SessionBuilder<'_> {
        SessionBuilder {
            source: SessionSource::Db(db),
            cluster,
            split_lines: None,
            seed: 1,
            executor: None,
        }
    }

    /// Execute a query inline and return its outcome. The session is
    /// `&self` — any number of threads may call this concurrently.
    pub fn run(&self, req: &MiningRequest) -> Result<MiningOutcome, MiningError> {
        req.validate()?;
        self.core.execute(req, &CancelToken::new(), &mut |_| {})
    }

    /// Execute a query inline, streaming [`PhaseEvent`]s to `on_event` as
    /// phases start and finish, under an external [`CancelToken`].
    pub fn run_streaming(
        &self,
        req: &MiningRequest,
        token: &CancelToken,
        mut on_event: impl FnMut(PhaseEvent),
    ) -> Result<MiningOutcome, MiningError> {
        req.validate()?;
        self.core.execute(req, token, &mut on_event)
    }

    /// Execute a query on a background thread: returns a [`RunHandle`]
    /// immediately; events stream through the handle while the run
    /// proceeds, and [`RunHandle::join`] yields the outcome.
    pub fn submit(&self, req: MiningRequest) -> Result<RunHandle, MiningError> {
        req.validate()?;
        let algorithm = req.algorithm;
        let core = Arc::clone(&self.core);
        let token = CancelToken::new();
        let thread_token = token.clone();
        let (tx, rx) = mpsc::channel();
        // lint:allow(raw-thread-spawn): RunHandle's miner thread is
        // long-lived and joins at handle scope; parking it in the fixed
        // worker pool would deadlock nested submits (DESIGN.md §10).
        let join = std::thread::Builder::new()
            .name(format!("mine-{}", algorithm.name().to_ascii_lowercase()))
            .spawn(move || {
                core.execute(&req, &thread_token, &mut |ev| {
                    let _ = tx.send(ev); // receiver may have been dropped
                })
            })
            .expect("spawning a mining worker thread");
        Ok(RunHandle { algorithm, token, events: rx, join: Some(join) })
    }

    /// The dataset this session serves.
    pub fn file(&self) -> &HdfsFile {
        &self.core.file
    }

    /// The cluster every query of this session simulates.
    pub fn cluster(&self) -> &ClusterConfig {
        &self.core.cluster
    }

    /// Lines per input split of the cached split plan.
    pub fn split_lines(&self) -> usize {
        self.core.split_lines
    }

    /// The session's [`Executor`]: every query's map and reduce tasks run
    /// on its one shared worker pool. Inspect
    /// [`Executor::workers`] / [`Executor::high_water_mark`] to verify the
    /// host-thread budget held under concurrent queries.
    pub fn executor(&self) -> &Executor {
        &self.core.executor
    }

    /// FUP-style incremental refresh over the whole store: answers "what
    /// changed since the last refresh" from the delta blocks alone when
    /// `miner` holds a compatible snapshot (same `min_sup`, same item
    /// universe, coverage a prefix of this session's records); otherwise —
    /// bootstrap, promotion cascade, changed support — it falls back to a
    /// bounded full run through [`MiningSession::run`]. The outcome's
    /// frequent levels are byte-identical to a cold full run at the same
    /// support over the same records, for every [`Algorithm`]
    /// (DESIGN.md §13).
    pub fn mine_incremental(
        &self,
        req: &MiningRequest,
        miner: &mut DeltaMiner,
    ) -> Result<DeltaOutcome, MiningError> {
        crate::incremental::delta::mine_incremental(self, req, miner)
    }

    /// Block-aligned sliding-window refresh: mine the last `spec.blocks`
    /// store blocks ending at the greatest filled `spec.step` multiple,
    /// reusing `miner`'s snapshot via the range-counts delta identity when
    /// the old and new windows overlap. Requires a store-backed session
    /// (windows are defined over segment blocks);
    /// [`MiningError::InvalidWindow`] otherwise (DESIGN.md §13).
    pub fn mine_window(
        &self,
        req: &MiningRequest,
        spec: WindowSpec,
        miner: &mut DeltaMiner,
    ) -> Result<DeltaOutcome, MiningError> {
        crate::incremental::delta::mine_window(self, req, spec, miner)
    }

    /// Record one incremental/window refresh against the session counters.
    pub(crate) fn record_delta(&self, blocks: u64, fallback: bool) {
        self.core.delta_runs.fetch_add(1, Ordering::SeqCst);
        self.core.blocks_rescanned.fetch_add(blocks, Ordering::SeqCst);
        if fallback {
            self.core.full_fallbacks.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Whether this session was built from an in-memory `TransactionDb`
    /// (no block-addressable store behind it).
    pub(crate) fn is_db_backed(&self) -> bool {
        self.core.from_db
    }

    /// Snapshot of the session's query/cache counters — how a caller (or a
    /// test) proves that cross-query Job1 reuse actually happened.
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            queries: self.core.queries.load(Ordering::SeqCst),
            job1_runs: self.core.job1_runs.load(Ordering::SeqCst),
            job1_cache_hits: self.core.job1_cache_hits.load(Ordering::SeqCst),
            job2_runs: self.core.job2_runs.load(Ordering::SeqCst),
            delta_runs: self.core.delta_runs.load(Ordering::SeqCst),
            blocks_rescanned: self.core.blocks_rescanned.load(Ordering::SeqCst),
            full_fallbacks: self.core.full_fallbacks.load(Ordering::SeqCst),
            queries_by_algorithm: std::array::from_fn(|i| {
                self.core.by_algorithm[i].load(Ordering::SeqCst)
            }),
        }
    }
}

impl std::fmt::Debug for MiningSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MiningSession")
            .field("dataset", &self.core.file.name)
            .field("records", &self.core.file.len())
            .field("split_lines", &self.core.split_lines)
            .field("stats", &self.stats())
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Run handles
// ---------------------------------------------------------------------------

/// A query executing on a background thread (see [`MiningSession::submit`]):
/// stream its [`PhaseEvent`]s, cancel it cooperatively, and
/// [`join`](RunHandle::join) it into the final [`MiningOutcome`].
///
/// Dropping the handle without joining cancels the run (best effort — the
/// worker notices before its next phase).
#[derive(Debug)]
pub struct RunHandle {
    algorithm: Algorithm,
    token: CancelToken,
    events: mpsc::Receiver<PhaseEvent>,
    join: Option<std::thread::JoinHandle<Result<MiningOutcome, MiningError>>>,
}

impl RunHandle {
    /// Which algorithm this run executes.
    pub fn algorithm(&self) -> Algorithm {
        self.algorithm
    }

    /// Request cooperative cancellation; the run stops before its next
    /// phase and [`join`](RunHandle::join) returns
    /// [`MiningError::Cancelled`].
    pub fn cancel(&self) {
        self.token.cancel();
    }

    /// A clone of the run's cancellation token.
    pub fn cancel_token(&self) -> CancelToken {
        self.token.clone()
    }

    /// Block until the next event arrives; `None` once the run finished
    /// and all events were drained.
    pub fn next_event(&self) -> Option<PhaseEvent> {
        self.events.recv().ok()
    }

    /// Non-blocking poll for the next event.
    pub fn try_next_event(&self) -> Option<PhaseEvent> {
        self.events.try_recv().ok()
    }

    /// Iterate the remaining events, blocking until the run finishes.
    pub fn events(&self) -> impl Iterator<Item = PhaseEvent> + '_ {
        std::iter::from_fn(move || self.next_event())
    }

    /// Wait for the run and return its outcome (or its error). Propagates
    /// a worker panic.
    pub fn join(mut self) -> Result<MiningOutcome, MiningError> {
        let handle = self.join.take().expect("join handle taken only once");
        match handle.join() {
            Ok(result) => result,
            Err(panic) => std::panic::resume_unwind(panic),
        }
    }
}

impl Drop for RunHandle {
    fn drop(&mut self) {
        if self.join.is_some() {
            // Un-joined handle: stop the detached worker at its next
            // cancellation point rather than mining into the void.
            self.token.cancel();
        }
    }
}

// ---------------------------------------------------------------------------
// Execution core (the ported driver loop)
// ---------------------------------------------------------------------------

impl SessionCore {
    fn new(
        file: HdfsFile,
        cluster: ClusterConfig,
        split_lines: usize,
        executor: Executor,
        from_db: bool,
    ) -> Self {
        let splits = hdfs::nline_splits(&file, split_lines);
        Self {
            file,
            cluster,
            split_lines,
            splits,
            executor,
            job1_cache: Mutex::new(HashMap::new()),
            queries: AtomicU64::new(0),
            job1_runs: AtomicU64::new(0),
            job1_cache_hits: AtomicU64::new(0),
            job2_runs: AtomicU64::new(0),
            delta_runs: AtomicU64::new(0),
            blocks_rescanned: AtomicU64::new(0),
            full_fallbacks: AtomicU64::new(0),
            by_algorithm: std::array::from_fn(|_| AtomicU64::new(0)),
            from_db,
        }
    }

    /// Job1 through the cache: exactly-once execution per
    /// `(min_count, fused)` key, concurrent callers blocking on the
    /// initializer. Returns the shared slot plus whether this call hit.
    /// Only the query that actually executes the job sees its task events
    /// (cache hits replay no execution, so there is nothing to stream).
    fn job1(
        &self,
        min_count: u64,
        fused: bool,
        sink: &mut dyn FnMut(PhaseEvent),
    ) -> (Arc<OnceLock<Job1Data>>, bool) {
        let slot = {
            let mut cache = self.job1_cache.lock().expect("job1 cache poisoned");
            Arc::clone(cache.entry((min_count, fused)).or_default())
        };
        let mut ran = false;
        slot.get_or_init(|| {
            ran = true;
            self.run_job1(min_count, fused, sink)
        });
        if ran {
            self.job1_runs.fetch_add(1, Ordering::SeqCst);
        } else {
            self.job1_cache_hits.fetch_add(1, Ordering::SeqCst);
        }
        (slot, !ran)
    }

    /// Execute Job1 (Algorithm 1), optionally fused with pass 2 via the
    /// triangular-matrix counter (ref [6]).
    ///
    /// Job1 runs WITHOUT a cancel token: its result is memoized and shared
    /// with every other query at the same cache key, so one query's
    /// cancellation must not abort work its peers are blocking on. A
    /// cancelled query still stops right after (the between-phase check).
    fn run_job1(
        &self,
        min_count: u64,
        fused: bool,
        sink: &mut dyn FnMut(PhaseEvent),
    ) -> Job1Data {
        // lint:allow(wall-clock-in-sim): host-side meter for the phase
        // record's `wall` field, kept apart from simulated time (§2).
        let wall = Instant::now();
        let n_items = self.file.n_items;
        let job = if fused {
            JobBuilder::new("job1+2")
                .splits(self.splits.clone())
                .mapper(move |_| mappers::FusedOneTwoMapper::new(n_items))
        } else {
            JobBuilder::new("job1")
                .splits(self.splits.clone())
                .mapper(|_| OneItemsetMapper)
        };
        let out = self
            .executor
            .submit(
                job.combiner(SumCombiner)
                    .reducer(MinSupportReducer { min_count })
                    .reducers(self.cluster.n_reducers),
            )
            .wait_with(|ev| sink(task_event(1, ev)))
            .expect("job1 carries no cancel token, so it cannot be cancelled");
        debug_assert_aux_agreement(&out);
        let sim = SimJob::from_meters(&out.map_meters, &out.reduce_meters, &self.cluster);
        let timing = sim.timing(&self.cluster);
        let mut l1: Level = Vec::new();
        let mut l2: Level = Vec::new();
        for (set, count) in out.outputs {
            match set.len() {
                1 => l1.push((set, count)),
                _ => l2.push((set, count)),
            }
        }
        l1.sort();
        l2.sort();
        let record = PhaseRecord {
            phase: 1,
            job: out.name,
            first_pass: 1,
            n_passes: if fused { 2 } else { 1 },
            candidates: 0,
            backends: if fused { vec![CountingBackend::Triangular] } else { Vec::new() },
            elapsed: timing.elapsed(),
            timing,
            wall: wall.elapsed().as_secs_f64(),
            counters: out.counters,
            faults: None,
        };
        Job1Data { l1, l2, record, sim }
    }

    /// Re-time one phase's cost-modeled tasks under the query's fault
    /// model, if any. `phase` doubles as the injection stream, so every
    /// phase of a run draws independent faults from the one user seed.
    fn phase_faults(
        &self,
        sim: &SimJob,
        model: Option<&FaultModel>,
        phase: usize,
    ) -> Option<PhaseFaults> {
        let model = model?;
        let (timing, map, reduce) = sim.faulted_timing(&self.cluster, model, phase as u64);
        Some(PhaseFaults { timing, map, reduce })
    }

    fn outcome(
        &self,
        req: &MiningRequest,
        min_count: u64,
        levels: Vec<Level>,
        phases: Vec<PhaseRecord>,
        run_start: Instant,
    ) -> MiningOutcome {
        let total_time: f64 = phases.iter().map(|p| p.elapsed).sum();
        let actual_time = total_time + self.cluster.overhead.driver_gap * phases.len() as f64;
        MiningOutcome {
            algorithm: req.algorithm,
            dataset: self.file.name.clone(),
            min_sup: req.min_sup,
            min_count,
            levels,
            phases,
            total_time,
            actual_time,
            wall_time: run_start.elapsed().as_secs_f64(),
            fault_model: req.faults.clone(),
        }
    }

    /// The driver loop: Job1 through the cache, then Job2 phases under the
    /// algorithm's pass-combining controller — identical computation to the
    /// pre-session `run_on_file`, plus events and cancellation points.
    fn execute(
        &self,
        req: &MiningRequest,
        token: &CancelToken,
        sink: &mut dyn FnMut(PhaseEvent),
    ) -> Result<MiningOutcome, MiningError> {
        if req.backend == CountingBackend::Triangular
            && self.file.n_items > mappers::TRIANGULAR_MAX_ITEMS
        {
            return Err(MiningError::InvalidBackend(
                "triangular is capped at 2048 items for this dataset; \
                 use trie, bitmap, or auto",
            ));
        }
        self.queries.fetch_add(1, Ordering::SeqCst);
        self.by_algorithm[req.algorithm.index()].fetch_add(1, Ordering::SeqCst);
        // lint:allow(wall-clock-in-sim): host-side meter for the
        // outcome's `wall_time` field, not simulated time (§2).
        let run_start = Instant::now();
        let algo = req.algorithm;
        let min_count = self.file.min_count(req.min_sup);

        let mut levels: Vec<Level> = Vec::new();
        let mut phases: Vec<PhaseRecord> = Vec::new();

        // ---- Job1 (memoized) ---------------------------------------------
        check(token)?;
        let job1_name = if req.fuse_pass_2 { "job1+2" } else { "job1" };
        sink(PhaseEvent::PhaseStarted {
            phase: 1,
            job: job1_name.to_string(),
            first_pass: 1,
        });
        let (slot, from_cache) = self.job1(min_count, req.fuse_pass_2, sink);
        let job1 = slot.get().expect("job1 slot initialized");
        // The cached record is fault-free; the fault re-timing is computed
        // per query from the cached cost-modeled tasks, so queries with
        // different fault models still share one Job1 execution.
        let mut record = job1.record.clone();
        record.faults = self.phase_faults(&job1.sim, req.faults.as_ref(), 1);
        // Time-driven controllers (DPC/ETDPC, Algorithm 4 line 3) observe
        // the time of the environment the query models: faulted when a
        // fault model is active, clean otherwise.
        let job1_elapsed = record.faults.as_ref().map_or(record.elapsed, |f| f.elapsed());
        phases.push(record.clone());
        sink(PhaseEvent::PhaseFinished { record, from_cache });

        let mut controller = controller_for(algo, req.fpc_n, req.dpc_alpha, req.dpc_beta);
        // DPC/ETDPC initialize their elapsed-time feedback from Job1
        // (Algorithm 4 line 3) — without changing their initial α.
        controller.init_job1(job1_elapsed);

        if job1.l1.is_empty() {
            return Ok(self.outcome(req, min_count, levels, phases, run_start));
        }
        let mut l_prev = Arc::new(Trie::from_itemsets(1, job1.l1.iter().map(|(s, _)| s)));
        levels.push(job1.l1.clone());
        let mut k = 2usize; // first pass of the upcoming phase
        if req.fuse_pass_2 {
            if job1.l2.is_empty() {
                // Fused phase already proved nothing larger exists.
                return Ok(self.outcome(req, min_count, levels, phases, run_start));
            }
            l_prev = Arc::new(Trie::from_itemsets(2, job1.l2.iter().map(|(s, _)| s)));
            levels.push(job1.l2.clone());
            k = 3;
        }

        // ---- Job2 phases --------------------------------------------------
        let optimized = algo.optimized();
        // The auto-pick's dataset shape comes from Job1's counters (no
        // second scan even for streamed sources, DESIGN.md §11); the same
        // context prices explicit backend requests for the phase records.
        let backend_ctx = mappers::BackendContext {
            profile: DensityProfile::from_counts(
                self.file.len(),
                self.file.n_items,
                job1.record.counters.get(keys::RECORD_ITEMS),
            ),
            weights: self.cluster.weights,
        };
        let n_items = self.file.n_items;
        loop {
            if l_prev.is_empty() || k > 64 {
                break;
            }
            check(token)?;
            let policy = controller.next_policy(l_prev.len() as u64);
            // lint:allow(wall-clock-in-sim): host-side meter for the phase
            // record's `wall` field, not simulated time (§2).
            let phase_wall = Instant::now();
            let phase_no = phases.len() + 1;
            sink(PhaseEvent::PhaseStarted {
                phase: phase_no,
                job: format!("job2-k{k}"),
                first_pass: k,
            });
            // Build the phase's candidate tries once per job and share them
            // read-only across tasks (distributed-cache pattern); the
            // faithful per-record generation *cost* is still charged by the
            // mapper.
            let mut plan = mappers::PhasePlan::build(&l_prev, policy, optimized);
            plan.resolve_backends(req.backend, &backend_ctx);
            let pass_backends = plan.backends.clone();
            let plan = Arc::new(plan);
            let gen_mode = req.gen_mode;
            // Job2 carries the query's token: the executor checks it
            // between tasks, so cancellation lands mid-job, not just at
            // the next phase boundary.
            let out = self
                .executor
                .submit(
                    JobBuilder::new(format!("job2-k{k}"))
                        .splits(self.splits.clone())
                        .mapper(move |_| Job2Mapper::new(Arc::clone(&plan), gen_mode, n_items))
                        .combiner(SumCombiner)
                        .reducer(MinSupportReducer { min_count })
                        .reducers(self.cluster.n_reducers)
                        .cancel_token(token.clone()),
                )
                .wait_with(|ev| sink(task_event(phase_no, ev)))
                .map_err(|_cancelled| MiningError::Cancelled)?;
            self.job2_runs.fetch_add(1, Ordering::SeqCst);
            debug_assert_aux_agreement(&out);
            let sim = SimJob::from_meters(&out.map_meters, &out.reduce_meters, &self.cluster);
            let timing = sim.timing(&self.cluster);
            let candidates = out.aux.get(keys::CANDIDATES).copied().unwrap_or(0);
            let npass = out.aux.get(keys::NPASS).copied().unwrap_or(0) as usize;

            let elapsed = timing.elapsed();
            let faults = self.phase_faults(&sim, req.faults.as_ref(), phase_no);
            // Time-driven controllers observe the faulted elapsed time when
            // a fault model is active (see init_job1 above).
            let observed_elapsed = faults.as_ref().map_or(elapsed, |f| f.elapsed());
            let record = PhaseRecord {
                phase: phases.len() + 1,
                job: out.name,
                first_pass: k,
                n_passes: npass,
                candidates,
                backends: pass_backends,
                elapsed,
                timing,
                wall: phase_wall.elapsed().as_secs_f64(),
                counters: out.counters,
                faults,
            };
            sink(PhaseEvent::PhaseFinished { record: record.clone(), from_cache: false });
            phases.push(record);
            controller.observe(PhaseObservation { candidates, npass, elapsed: observed_elapsed });

            if npass == 0 {
                break; // no candidates could be generated at all
            }

            // Group phase output by itemset size into levels k .. k+npass-1.
            let mut by_size: std::collections::BTreeMap<usize, Level> = Default::default();
            for (set, count) in out.outputs {
                by_size.entry(set.len()).or_default().push((set, count));
            }
            for (size, mut level) in by_size {
                level.sort();
                debug_assert!(size >= 2, "Job2 must not emit 1-itemsets");
                if levels.len() < size {
                    levels.resize(size, Vec::new());
                }
                levels[size - 1] = level;
            }

            // Seed for the next phase: the longest-sized frequent itemsets
            // of this phase. If empty, downward closure says we are done.
            let last_size = k + npass - 1;
            let seed_level = levels.get(last_size - 1).filter(|l| !l.is_empty());
            match seed_level {
                Some(level) => {
                    l_prev =
                        Arc::new(Trie::from_itemsets(last_size, level.iter().map(|(s, _)| s)));
                }
                None => break,
            }
            k = last_size + 1;
        }

        // Trim trailing empty levels (possible when a phase overshoots).
        while levels.last().is_some_and(|l| l.is_empty()) {
            levels.pop();
        }

        Ok(self.outcome(req, min_count, levels, phases, run_start))
    }
}
