//! Driver-side phase control: the per-algorithm logic that decides how many
//! Apriori passes the next MapReduce phase combines (Algorithms 2–4).
//!
//! Each controller sees only what the paper's drivers see — the candidate
//! count and elapsed time of preceding phases plus |L_prev| — and returns a
//! [`PassPolicy`] for the next Job2.

use super::mappers::PassPolicy;

/// Observation handed to a controller after each finished phase.
#[derive(Debug, Clone, Copy)]
pub struct PhaseObservation {
    /// Candidates generated in the phase (`candidateCount`).
    pub candidates: u64,
    /// Passes the phase combined (`npass` — relevant for dynamic policies).
    pub npass: usize,
    /// Simulated elapsed seconds of the phase. When the query carries a
    /// [`FaultModel`](crate::cluster::FaultModel) this is the *faulted*
    /// elapsed time — time-driven controllers (DPC/ETDPC) adapt to the
    /// cluster conditions they would actually observe, which is exactly
    /// the robustness question the fault scenarios probe.
    pub elapsed: f64,
}

/// Phase-control strategy of one algorithm family.
pub trait PhaseController {
    /// Policy for the next phase; `l_prev_len` = |L_{k-1}|, the number of
    /// longest-sized frequent itemsets of the previous phase.
    fn next_policy(&mut self, l_prev_len: u64) -> PassPolicy;
    /// Feed back the finished phase.
    fn observe(&mut self, obs: PhaseObservation);
    /// Seed elapsed-time state from Job1 (Algorithm 4 line 3); most
    /// controllers ignore it.
    fn init_job1(&mut self, _elapsed: f64) {}
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------

/// SPC: one pass per phase (Algorithm 2).
pub struct SpcController;

impl PhaseController for SpcController {
    fn next_policy(&mut self, _l: u64) -> PassPolicy {
        PassPolicy::Fixed(1)
    }
    fn observe(&mut self, _obs: PhaseObservation) {}
    fn name(&self) -> &'static str {
        "SPC"
    }
}

/// FPC: a fixed number of passes per phase ("generally 3", Lin et al.).
pub struct FpcController {
    /// Fixed passes per phase (the paper quotes 3).
    pub n: usize,
}

impl Default for FpcController {
    fn default() -> Self {
        Self { n: 3 }
    }
}

impl PhaseController for FpcController {
    fn next_policy(&mut self, _l: u64) -> PassPolicy {
        PassPolicy::Fixed(self.n)
    }
    fn observe(&mut self, _obs: PhaseObservation) {}
    fn name(&self) -> &'static str {
        "FPC"
    }
}

/// DPC (Lin et al.): candidate threshold `ct = α · |L_prev|`, with α raised
/// above 1 only when the previous phase ran faster than the β threshold —
/// the execution-time dependence the paper criticizes (§1, §4).
pub struct DpcController {
    /// α used when the previous phase was fast (paper: 1.2, 2 or 3).
    pub alpha_fast: f64,
    /// β, seconds (paper: 60).
    pub beta: f64,
    /// Elapsed time of the previous phase (initialized from Job1).
    pub et_prev: f64,
}

impl DpcController {
    /// Build with the paper's fast-phase α and β threshold.
    pub fn new(alpha_fast: f64, beta: f64) -> Self {
        Self { alpha_fast, beta, et_prev: 0.0 }
    }
}

impl PhaseController for DpcController {
    fn next_policy(&mut self, l_prev_len: u64) -> PassPolicy {
        let alpha = if self.et_prev < self.beta { self.alpha_fast } else { 1.0 };
        PassPolicy::Dynamic { ct: (alpha * l_prev_len as f64).floor() as u64 }
    }
    fn observe(&mut self, obs: PhaseObservation) {
        self.et_prev = obs.elapsed;
    }
    fn init_job1(&mut self, elapsed: f64) {
        self.et_prev = elapsed;
    }
    fn name(&self) -> &'static str {
        "DPC"
    }
}

/// VFPC (Algorithm 3): start with 2-pass phases; when the per-phase
/// candidate count starts decreasing, combine 3 more passes per phase.
pub struct VfpcController {
    npass: usize,
    num_cands_prev: u64,
}

impl Default for VfpcController {
    fn default() -> Self {
        Self { npass: 2, num_cands_prev: 0 }
    }
}

impl PhaseController for VfpcController {
    fn next_policy(&mut self, _l: u64) -> PassPolicy {
        PassPolicy::Fixed(self.npass)
    }
    fn observe(&mut self, obs: PhaseObservation) {
        if obs.candidates < self.num_cands_prev {
            self.npass += 3;
        } else {
            self.npass = 2;
        }
        self.num_cands_prev = obs.candidates;
    }
    fn name(&self) -> &'static str {
        "VFPC"
    }
}

/// ETDPC (Algorithm 4): candidate threshold with α driven by the *relative*
/// elapsed time of the two preceding phases (β₁ = 40 s, β₂ = 60 s).
pub struct EtdpcController {
    /// Current candidate-threshold multiplier α.
    pub alpha: f64,
    /// β₁ threshold in seconds (paper: 40).
    pub beta1: f64,
    /// β₂ threshold in seconds (paper: 60).
    pub beta2: f64,
    /// Elapsed time of the phase before the last (ETprev).
    pub et_prev: f64,
    /// Whether we have seen at least one Job2 phase.
    started: bool,
}

impl EtdpcController {
    /// Start with α = 1 and the paper's β₁/β₂ thresholds.
    pub fn new() -> Self {
        Self { alpha: 1.0, beta1: 40.0, beta2: 60.0, et_prev: 0.0, started: false }
    }

    /// Initialize ETprev from Job1's elapsed time (Algorithm 4 line 3).
    pub fn init_et_prev(&mut self, job1_elapsed: f64) {
        self.et_prev = job1_elapsed;
    }
}

impl Default for EtdpcController {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseController for EtdpcController {
    fn next_policy(&mut self, l_prev_len: u64) -> PassPolicy {
        PassPolicy::Dynamic { ct: (self.alpha * l_prev_len as f64).floor() as u64 }
    }
    fn observe(&mut self, obs: PhaseObservation) {
        let et = obs.elapsed;
        if self.et_prev < et {
            // Workload rising: combine more only if phases are still cheap.
            self.alpha = if et <= self.beta1 {
                3.0
            } else if et < self.beta2 {
                2.0
            } else {
                1.0
            };
        } else {
            // Workload falling: safe to combine aggressively.
            self.alpha = if self.et_prev >= 1.5 * et { 3.0 } else { 2.0 };
        }
        self.et_prev = et;
        self.started = true;
    }
    fn init_job1(&mut self, elapsed: f64) {
        self.init_et_prev(elapsed);
    }
    fn name(&self) -> &'static str {
        "ETDPC"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(candidates: u64, elapsed: f64) -> PhaseObservation {
        PhaseObservation { candidates, npass: 1, elapsed }
    }

    #[test]
    fn spc_always_one() {
        let mut c = SpcController;
        assert_eq!(c.next_policy(100), PassPolicy::Fixed(1));
        c.observe(obs(5, 50.0));
        assert_eq!(c.next_policy(1), PassPolicy::Fixed(1));
    }

    #[test]
    fn fpc_fixed_n() {
        let mut c = FpcController { n: 3 };
        assert_eq!(c.next_policy(7), PassPolicy::Fixed(3));
        c.observe(obs(1_000_000, 500.0)); // FPC never adapts — the flaw
        assert_eq!(c.next_policy(7), PassPolicy::Fixed(3));
    }

    #[test]
    fn vfpc_ramps_after_decrease() {
        let mut c = VfpcController::default();
        assert_eq!(c.next_policy(0), PassPolicy::Fixed(2));
        c.observe(obs(100, 10.0)); // 100 >= 0 -> stay 2
        assert_eq!(c.next_policy(0), PassPolicy::Fixed(2));
        c.observe(obs(500, 10.0)); // rising -> stay 2
        assert_eq!(c.next_policy(0), PassPolicy::Fixed(2));
        c.observe(obs(300, 10.0)); // falling -> 2+3 = 5
        assert_eq!(c.next_policy(0), PassPolicy::Fixed(5));
        c.observe(obs(50, 10.0)); // still falling -> 5+3 = 8
        assert_eq!(c.next_policy(0), PassPolicy::Fixed(8));
        c.observe(obs(60, 10.0)); // rising again -> reset to 2
        assert_eq!(c.next_policy(0), PassPolicy::Fixed(2));
    }

    #[test]
    fn dpc_alpha_depends_on_absolute_time() {
        let mut c = DpcController::new(2.0, 60.0);
        c.et_prev = 20.0; // fast phase -> alpha 2
        assert_eq!(c.next_policy(100), PassPolicy::Dynamic { ct: 200 });
        c.observe(obs(0, 120.0)); // slow phase -> alpha 1
        assert_eq!(c.next_policy(100), PassPolicy::Dynamic { ct: 100 });
    }

    #[test]
    fn etdpc_alpha_table() {
        let mut c = EtdpcController::new();
        c.init_et_prev(16.0);
        // Default alpha 1 before any Job2 feedback.
        assert_eq!(c.next_policy(10), PassPolicy::Dynamic { ct: 10 });

        // Rising, cheap (et <= 40): alpha 3.
        c.observe(obs(0, 30.0)); // 16 < 30, 30 <= 40
        assert_eq!(c.next_policy(10), PassPolicy::Dynamic { ct: 30 });

        // Rising, moderate (40 < et < 60): alpha 2.
        c.observe(obs(0, 50.0));
        assert_eq!(c.next_policy(10), PassPolicy::Dynamic { ct: 20 });

        // Rising, expensive (et >= 60): alpha 1.
        c.observe(obs(0, 80.0));
        assert_eq!(c.next_policy(10), PassPolicy::Dynamic { ct: 10 });

        // Falling steeply (etprev >= 1.5 et): alpha 3.
        c.observe(obs(0, 40.0)); // 80 >= 60
        assert_eq!(c.next_policy(10), PassPolicy::Dynamic { ct: 30 });

        // Falling gently: alpha 2.
        c.observe(obs(0, 35.0)); // 40 < 1.5*35
        assert_eq!(c.next_policy(10), PassPolicy::Dynamic { ct: 20 });
    }

    #[test]
    fn etdpc_relative_vs_dpc_absolute() {
        // Scale every elapsed time 10x (a slower cluster): DPC's policy
        // changes (it compares to the absolute 60 s threshold on both ends);
        // ETDPC's falling-phase policy is scale-free.
        let mut d_fast = DpcController::new(2.0, 60.0);
        let mut d_slow = DpcController::new(2.0, 60.0);
        d_fast.observe(obs(0, 30.0));
        d_slow.observe(obs(0, 300.0));
        assert_ne!(d_fast.next_policy(10), d_slow.next_policy(10));

        let mut e_fast = EtdpcController::new();
        let mut e_slow = EtdpcController::new();
        e_fast.init_et_prev(16.0);
        e_slow.init_et_prev(160.0);
        // Falling workload on both clusters, same 2x ratio.
        e_fast.observe(obs(0, 8.0));
        e_slow.observe(obs(0, 80.0));
        assert_eq!(e_fast.next_policy(10), e_slow.next_policy(10));
    }
}
