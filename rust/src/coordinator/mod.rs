//! The Layer-3 coordinator: drives the seven MapReduce Apriori algorithms
//! (SPC, FPC, DPC, VFPC, ETDPC, Optimized-VFPC, Optimized-ETDPC) over the
//! MapReduce engine and the simulated cluster, producing per-phase metrics
//! that regenerate the paper's tables and figures.

pub mod drivers;
pub mod mappers;

use crate::apriori::sequential::Level;
use crate::cluster::{simulate_job, ClusterConfig, JobTiming};
use crate::dataset::TransactionDb;
use crate::hdfs;
use crate::itemset::{Itemset, Trie};
use crate::mapreduce::api::{HashPartitioner, MinSupportReducer, SumCombiner};
use crate::mapreduce::counters::{keys, Counters};
use crate::mapreduce::engine::{run_job, JobSpec};
use drivers::{
    DpcController, EtdpcController, FpcController, PhaseController, PhaseObservation,
    SpcController, VfpcController,
};
use mappers::{GenMode, Job2Mapper, OneItemsetMapper};
use std::sync::Arc;

/// The seven algorithms of the paper's evaluation (§5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Single Pass Counting: one MapReduce job per Apriori pass.
    Spc,
    /// Fixed Passes Combined-counting: every phase combines n passes.
    Fpc,
    /// Dynamic Passes Combined-counting: candidate-count threshold α·|L|.
    Dpc,
    /// Variable-size FPC: pass count grows per phase (2, 3, 4, ...).
    Vfpc,
    /// Elapsed-Time-based DPC: α driven by preceding phase times.
    Etdpc,
    /// VFPC with pruning skipped after a phase's first pass (§4.2).
    OptimizedVfpc,
    /// ETDPC with pruning skipped after a phase's first pass (§4.2).
    OptimizedEtdpc,
}

impl Algorithm {
    /// All seven algorithms, in the paper's presentation order.
    pub const ALL: [Algorithm; 7] = [
        Algorithm::Spc,
        Algorithm::Fpc,
        Algorithm::Dpc,
        Algorithm::Vfpc,
        Algorithm::Etdpc,
        Algorithm::OptimizedVfpc,
        Algorithm::OptimizedEtdpc,
    ];

    /// The paper's display name (e.g. "Optimized-VFPC").
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Spc => "SPC",
            Algorithm::Fpc => "FPC",
            Algorithm::Dpc => "DPC",
            Algorithm::Vfpc => "VFPC",
            Algorithm::Etdpc => "ETDPC",
            Algorithm::OptimizedVfpc => "Optimized-VFPC",
            Algorithm::OptimizedEtdpc => "Optimized-ETDPC",
        }
    }

    /// Parse an algorithm name (case- and punctuation-insensitive).
    pub fn parse(s: &str) -> Option<Algorithm> {
        let norm = s.to_ascii_lowercase().replace(['-', '_'], "");
        Some(match norm.as_str() {
            "spc" => Algorithm::Spc,
            "fpc" => Algorithm::Fpc,
            "dpc" => Algorithm::Dpc,
            "vfpc" => Algorithm::Vfpc,
            "etdpc" => Algorithm::Etdpc,
            "optimizedvfpc" | "optvfpc" => Algorithm::OptimizedVfpc,
            "optimizedetdpc" | "optetdpc" => Algorithm::OptimizedEtdpc,
            _ => return None,
        })
    }

    /// Whether Job2 phases skip pruning after their first pass (§4.2).
    pub fn optimized(&self) -> bool {
        matches!(self, Algorithm::OptimizedVfpc | Algorithm::OptimizedEtdpc)
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Tunables shared by a mining run.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Lines per input split (the paper's `setNumLinesPerSplit`).
    pub split_lines: usize,
    /// Faithful per-record generation cost vs once-per-task (ablation).
    pub gen_mode: GenMode,
    /// FPC's fixed pass count (paper: "generally 3").
    pub fpc_n: usize,
    /// DPC's fast-phase α (paper: 2.0 for c20d10k/mushroom, 3.0 for chess).
    pub dpc_alpha: f64,
    /// DPC's β threshold in seconds (paper: 60).
    pub dpc_beta: f64,
    /// Fuse passes 1 and 2 into a single job with a triangular-matrix
    /// counter (Kovacs & Illes, the paper's ref [6]); Job2 then starts at
    /// k = 3, saving one MapReduce job.
    pub fuse_pass_2: bool,
    /// Placement seed for HDFS replicas.
    pub seed: u64,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            split_lines: 1000,
            gen_mode: GenMode::PerRecord,
            fpc_n: 3,
            dpc_alpha: 2.0,
            dpc_beta: 60.0,
            fuse_pass_2: false,
            seed: 1,
        }
    }
}

/// Metrics of one MapReduce phase (one row slice of Tables 3-5 / 10-12).
#[derive(Debug, Clone)]
pub struct PhaseRecord {
    /// 1-based phase index (phase 1 = Job1).
    pub phase: usize,
    /// Name of the MapReduce job that ran this phase (e.g. `job1`,
    /// `job2-k3`), propagated from [`crate::mapreduce::JobSpec::name`]
    /// through the engine's task meters.
    pub job: String,
    /// Apriori pass number of the first pass in this phase (1 for Job1).
    pub first_pass: usize,
    /// Number of passes this phase combined.
    pub n_passes: usize,
    /// Candidates generated in this phase (Tables 7-9; 0 for Job1).
    pub candidates: u64,
    /// Simulated elapsed seconds (a Tables 3-5 / 10-12 cell).
    pub elapsed: f64,
    /// Simulated timing breakdown.
    pub timing: JobTiming,
    /// Real host wall-clock seconds spent executing the phase.
    pub wall: f64,
    /// Merged job counters.
    pub counters: Counters,
}

/// Result of one full mining run.
#[derive(Debug, Clone)]
pub struct MiningOutcome {
    /// Which algorithm produced this outcome.
    pub algorithm: Algorithm,
    /// Name of the mined dataset.
    pub dataset: String,
    /// Fractional minimum support of the run.
    pub min_sup: f64,
    /// Absolute minimum support count (ceil of min_sup · N).
    pub min_count: u64,
    /// `levels[k-1]` = frequent k-itemsets (identical to the oracle's).
    pub levels: Vec<Level>,
    /// Per-phase metrics, in execution order.
    pub phases: Vec<PhaseRecord>,
    /// Sum of per-phase simulated elapsed times ("Total" in Tables 3-5).
    pub total_time: f64,
    /// Total plus per-phase driver gaps ("Actual" in Tables 3-5).
    pub actual_time: f64,
    /// Real host wall-clock for the whole run.
    pub wall_time: f64,
}

impl MiningOutcome {
    /// Total frequent itemsets across all levels.
    pub fn total_frequent(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// |L_k| per level (the shape of the paper's Table 6).
    pub fn lk_profile(&self) -> Vec<usize> {
        self.levels.iter().map(|l| l.len()).collect()
    }

    /// Number of MapReduce phases the run took.
    pub fn n_phases(&self) -> usize {
        self.phases.len()
    }

    /// Flattened sorted `(itemset, count)` list (oracle-comparable).
    pub fn all_frequent(&self) -> Vec<(Itemset, u64)> {
        let mut out: Vec<(Itemset, u64)> =
            self.levels.iter().flat_map(|l| l.iter().cloned()).collect();
        out.sort();
        out
    }
}

/// Every map task of an Apriori job computes its aux values (`npass`,
/// `candidateCount`) from the same shared [`mappers::PhasePlan`], so the
/// engine's max-merge is exact. The engine now *detects* divergence instead
/// of silently masking it; here — where the agreement invariant actually
/// holds — divergence is a driver bug, so debug builds fail fast.
fn debug_assert_aux_agreement<O>(out: &crate::mapreduce::JobOutput<O>) {
    debug_assert!(
        out.aux_divergence.is_empty(),
        "Apriori map tasks must agree on aux values; diverged on {:?} in job {}",
        out.aux_divergence,
        out.name
    );
}

fn controller_for(algo: Algorithm, opts: &RunOptions) -> Box<dyn PhaseController> {
    match algo {
        Algorithm::Spc => Box::new(SpcController),
        Algorithm::Fpc => Box::new(FpcController { n: opts.fpc_n }),
        Algorithm::Dpc => Box::new(DpcController::new(opts.dpc_alpha, opts.dpc_beta)),
        Algorithm::Vfpc | Algorithm::OptimizedVfpc => Box::new(VfpcController::default()),
        Algorithm::Etdpc | Algorithm::OptimizedEtdpc => Box::new(EtdpcController::new()),
    }
}

/// Run `algo` on `db` with default options (paper's split size must be
/// passed; see [`crate::dataset::registry::split_lines`]).
pub fn run(
    algo: Algorithm,
    db: &TransactionDb,
    min_sup: f64,
    cluster: &ClusterConfig,
    split_lines: usize,
) -> MiningOutcome {
    run_with(algo, db, min_sup, cluster, &RunOptions { split_lines, ..Default::default() })
}

/// Run `algo` on an in-memory `db` with explicit options: stores the
/// database as an in-memory HDFS file, then mines it via [`run_on_file`].
pub fn run_with(
    algo: Algorithm,
    db: &TransactionDb,
    min_sup: f64,
    cluster: &ClusterConfig,
    opts: &RunOptions,
) -> MiningOutcome {
    let file =
        hdfs::put(db, opts.split_lines, cluster.nodes.len(), hdfs::DEFAULT_REPLICATION, opts.seed);
    run_on_file(algo, &file, min_sup, cluster, opts)
}

/// Run `algo` over an already-stored HDFS file — the out-of-core entry
/// point. The file may be backed by either [`hdfs::RecordSource`] backend;
/// with a segment store ([`hdfs::put_segmented`]) the driver never
/// materializes the dataset, and each map task's resident record buffer is
/// bounded by the HDFS block size. Output is byte-identical to mining the
/// materialized database through [`run_with`].
pub fn run_on_file(
    algo: Algorithm,
    file: &hdfs::HdfsFile,
    min_sup: f64,
    cluster: &ClusterConfig,
    opts: &RunOptions,
) -> MiningOutcome {
    let run_start = std::time::Instant::now();
    let min_count = file.min_count(min_sup);
    let splits = hdfs::nline_splits(file, opts.split_lines);

    let mut levels: Vec<Level> = Vec::new();
    let mut phases: Vec<PhaseRecord> = Vec::new();

    // ---- Job1: frequent 1-itemsets (Algorithm 1), optionally fused with
    // pass 2 via the triangular-matrix counter (ref [6]) ------------------
    let job1_wall = std::time::Instant::now();
    let n_items = file.n_items;
    let out = if opts.fuse_pass_2 {
        run_job(JobSpec {
            name: "job1+2".into(),
            splits: splits.clone(),
            mapper_factory: Box::new(move |_| mappers::FusedOneTwoMapper::new(n_items)),
            combiner: Some(Box::new(SumCombiner)),
            reducer: MinSupportReducer { min_count },
            partitioner: Box::new(HashPartitioner),
            n_reducers: cluster.n_reducers,
            workers: cluster.workers,
        })
    } else {
        run_job(JobSpec {
            name: "job1".into(),
            splits: splits.clone(),
            mapper_factory: Box::new(|_| OneItemsetMapper),
            combiner: Some(Box::new(SumCombiner)),
            reducer: MinSupportReducer { min_count },
            partitioner: Box::new(HashPartitioner),
            n_reducers: cluster.n_reducers,
            workers: cluster.workers,
        })
    };
    debug_assert_aux_agreement(&out);
    let timing = simulate_job(&out.map_meters, &out.reduce_meters, cluster);
    let mut l1: Level = Vec::new();
    let mut l2: Level = Vec::new();
    for (set, count) in out.outputs {
        match set.len() {
            1 => l1.push((set, count)),
            _ => l2.push((set, count)),
        }
    }
    l1.sort();
    l2.sort();
    phases.push(PhaseRecord {
        phase: 1,
        job: out.name,
        first_pass: 1,
        n_passes: if opts.fuse_pass_2 { 2 } else { 1 },
        candidates: 0,
        elapsed: timing.elapsed(),
        timing,
        wall: job1_wall.elapsed().as_secs_f64(),
        counters: out.counters,
    });

    let mut controller = controller_for(algo, opts);
    // DPC/ETDPC initialize their elapsed-time feedback from Job1
    // (Algorithm 4 line 3) — without changing their initial α.
    controller.init_job1(phases[0].elapsed);

    if l1.is_empty() {
        let wall_time = run_start.elapsed().as_secs_f64();
        let total_time: f64 = phases.iter().map(|p| p.elapsed).sum();
        let actual_time = total_time + cluster.overhead.driver_gap * phases.len() as f64;
        return MiningOutcome {
            algorithm: algo,
            dataset: file.name.clone(),
            min_sup,
            min_count,
            levels,
            phases,
            total_time,
            actual_time,
            wall_time,
        };
    }
    let mut l_prev = Arc::new(Trie::from_itemsets(1, l1.iter().map(|(s, _)| s)));
    levels.push(l1);
    let mut k = 2usize; // first pass of the upcoming phase
    if opts.fuse_pass_2 {
        if l2.is_empty() {
            // Fused phase already proved nothing larger exists.
            let total_time: f64 = phases.iter().map(|p| p.elapsed).sum();
            let actual_time = total_time + cluster.overhead.driver_gap * phases.len() as f64;
            return MiningOutcome {
                algorithm: algo,
                dataset: file.name.clone(),
                min_sup,
                min_count,
                levels,
                phases,
                total_time,
                actual_time,
                wall_time: run_start.elapsed().as_secs_f64(),
            };
        }
        l_prev = Arc::new(Trie::from_itemsets(2, l2.iter().map(|(s, _)| s)));
        levels.push(l2);
        k = 3;
    }

    // ---- Job2 phases ------------------------------------------------------
    let optimized = algo.optimized();
    loop {
        if l_prev.is_empty() || k > 64 {
            break;
        }
        let policy = controller.next_policy(l_prev.len() as u64);
        let phase_wall = std::time::Instant::now();
        // Build the phase's candidate tries once per job and share them
        // read-only across tasks (distributed-cache pattern); the faithful
        // per-record generation *cost* is still charged by the mapper.
        let plan = Arc::new(mappers::PhasePlan::build(&l_prev, policy, optimized));
        let gen_mode = opts.gen_mode;
        let plan_for_tasks = Arc::clone(&plan);
        let out = run_job(JobSpec {
            name: format!("job2-k{k}"),
            splits: splits.clone(),
            mapper_factory: Box::new(move |_| {
                Job2Mapper::new(Arc::clone(&plan_for_tasks), gen_mode)
            }),
            combiner: Some(Box::new(SumCombiner)),
            reducer: MinSupportReducer { min_count },
            partitioner: Box::new(HashPartitioner),
            n_reducers: cluster.n_reducers,
            workers: cluster.workers,
        });
        debug_assert_aux_agreement(&out);
        let timing = simulate_job(&out.map_meters, &out.reduce_meters, cluster);
        let candidates = out.aux.get(keys::CANDIDATES).copied().unwrap_or(0);
        let npass = out.aux.get(keys::NPASS).copied().unwrap_or(0) as usize;

        let elapsed = timing.elapsed();
        phases.push(PhaseRecord {
            phase: phases.len() + 1,
            job: out.name,
            first_pass: k,
            n_passes: npass,
            candidates,
            elapsed,
            timing,
            wall: phase_wall.elapsed().as_secs_f64(),
            counters: out.counters,
        });
        controller.observe(PhaseObservation { candidates, npass, elapsed });

        if npass == 0 {
            break; // no candidates could be generated at all
        }

        // Group phase output by itemset size into levels k .. k+npass-1.
        let mut by_size: std::collections::BTreeMap<usize, Level> = Default::default();
        for (set, count) in out.outputs {
            by_size.entry(set.len()).or_default().push((set, count));
        }
        for (size, mut level) in by_size {
            level.sort();
            debug_assert!(size >= 2, "Job2 must not emit 1-itemsets");
            if levels.len() < size {
                levels.resize(size, Vec::new());
            }
            levels[size - 1] = level;
        }

        // Seed for the next phase: the longest-sized frequent itemsets of
        // this phase. If empty, downward closure says we are done.
        let last_size = k + npass - 1;
        let seed_level = levels.get(last_size - 1).filter(|l| !l.is_empty());
        match seed_level {
            Some(level) => {
                l_prev = Arc::new(Trie::from_itemsets(last_size, level.iter().map(|(s, _)| s)));
            }
            None => break,
        }
        k = last_size + 1;
    }

    // Trim trailing empty levels (possible when a phase overshoots).
    while levels.last().is_some_and(|l| l.is_empty()) {
        levels.pop();
    }

    let total_time: f64 = phases.iter().map(|p| p.elapsed).sum();
    let actual_time = total_time + cluster.overhead.driver_gap * phases.len() as f64;
    MiningOutcome {
        algorithm: algo,
        dataset: file.name.clone(),
        min_sup,
        min_count,
        levels,
        phases,
        total_time,
        actual_time,
        wall_time: run_start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::sequential::mine;
    use crate::dataset::ibm::{generate, IbmParams};

    fn small_db() -> TransactionDb {
        generate(&IbmParams {
            n_txns: 300,
            n_items: 40,
            avg_txn_len: 8.0,
            avg_pattern_len: 4.0,
            n_patterns: 10,
            correlation: 0.5,
            corruption_mean: 0.3,
            corruption_sd: 0.1,
            seed: 42,
            ..Default::default()
        })
    }

    fn opts() -> RunOptions {
        RunOptions { split_lines: 50, ..Default::default() }
    }

    #[test]
    fn every_algorithm_matches_oracle() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        for min_sup in [0.3, 0.15] {
            let oracle = mine(&db, min_sup).all_frequent();
            for algo in Algorithm::ALL {
                let got = run_with(algo, &db, min_sup, &cluster, &opts());
                assert_eq!(
                    got.all_frequent(),
                    oracle,
                    "{algo} at min_sup {min_sup} diverges from oracle"
                );
            }
        }
    }

    #[test]
    fn spc_has_one_pass_per_phase() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let out = run_with(Algorithm::Spc, &db, 0.2, &cluster, &opts());
        assert!(out.phases.iter().all(|p| p.n_passes <= 1));
        // SPC phases = 1 (Job1) + one per pass that generated candidates.
        let oracle = mine(&db, 0.2);
        assert!(out.n_phases() >= oracle.max_len());
    }

    #[test]
    fn combined_algorithms_use_fewer_phases() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let spc = run_with(Algorithm::Spc, &db, 0.15, &cluster, &opts());
        for algo in [Algorithm::Fpc, Algorithm::Vfpc, Algorithm::OptimizedVfpc] {
            let out = run_with(algo, &db, 0.15, &cluster, &opts());
            assert!(
                out.n_phases() < spc.n_phases(),
                "{algo}: {} phases vs SPC {}",
                out.n_phases(),
                spc.n_phases()
            );
        }
    }

    #[test]
    fn actual_exceeds_total_by_driver_gaps() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let out = run_with(Algorithm::Vfpc, &db, 0.2, &cluster, &opts());
        let expect = out.total_time + cluster.overhead.driver_gap * out.n_phases() as f64;
        assert!((out.actual_time - expect).abs() < 1e-9);
    }

    #[test]
    fn optimized_generates_at_least_as_many_candidates() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let plain = run_with(Algorithm::Vfpc, &db, 0.15, &cluster, &opts());
        let opt = run_with(Algorithm::OptimizedVfpc, &db, 0.15, &cluster, &opts());
        let plain_c: u64 = plain.phases.iter().map(|p| p.candidates).sum();
        let opt_c: u64 = opt.phases.iter().map(|p| p.candidates).sum();
        assert!(opt_c >= plain_c, "optimized {opt_c} < plain {plain_c}");
        // ... and the same frequent itemsets.
        assert_eq!(plain.all_frequent(), opt.all_frequent());
    }

    #[test]
    fn phase_records_are_consistent() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let out = run_with(Algorithm::Etdpc, &db, 0.2, &cluster, &opts());
        // Phases numbered 1.., passes contiguous.
        let mut next_pass = 2;
        for (i, p) in out.phases.iter().enumerate() {
            assert_eq!(p.phase, i + 1);
            if i == 0 {
                assert_eq!(p.first_pass, 1);
            } else {
                assert_eq!(p.first_pass, next_pass, "phase {} starts wrong", p.phase);
                next_pass += p.n_passes;
            }
            assert!(p.elapsed > 0.0);
        }
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for algo in Algorithm::ALL {
            assert_eq!(Algorithm::parse(algo.name()), Some(algo));
        }
        assert_eq!(Algorithm::parse("optimized_vfpc"), Some(Algorithm::OptimizedVfpc));
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn fused_pass2_matches_oracle_and_saves_a_phase() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        for algo in [Algorithm::Spc, Algorithm::OptimizedVfpc] {
            let plain = run_with(algo, &db, 0.2, &cluster, &opts());
            let fused = run_with(
                algo,
                &db,
                0.2,
                &cluster,
                &RunOptions { fuse_pass_2: true, ..opts() },
            );
            assert_eq!(fused.all_frequent(), plain.all_frequent(), "{algo}");
            assert!(fused.n_phases() < plain.n_phases(), "{algo} phases not saved");
            assert!(fused.actual_time < plain.actual_time, "{algo} fused not faster");
            // Fused phase 1 covers passes 1-2.
            assert_eq!(fused.phases[0].n_passes, 2);
            assert_eq!(fused.phases.get(1).map(|p| p.first_pass), Some(3));
        }
    }

    #[test]
    fn high_min_sup_trivial_run() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let out = run_with(Algorithm::OptimizedEtdpc, &db, 0.999, &cluster, &opts());
        // Nothing (or almost nothing) frequent; must terminate cleanly.
        assert!(out.levels.len() <= 1);
    }
}
