//! The Layer-3 coordinator: drives the seven MapReduce Apriori algorithms
//! (SPC, FPC, DPC, VFPC, ETDPC, Optimized-VFPC, Optimized-ETDPC) over the
//! MapReduce engine and the simulated cluster, producing per-phase metrics
//! that regenerate the paper's tables and figures.

pub mod drivers;
pub mod mappers;
pub mod session;

pub use crate::incremental::{DeltaMiner, FollowSession, WindowSpec};
pub use mappers::{BackendContext, CountingBackend, ParseBackendError, TRIANGULAR_MAX_ITEMS};
pub use session::{
    CancelToken, MiningError, MiningRequest, MiningSession, PhaseEvent, RunHandle,
    SessionBuilder, SessionStats, TaskKind,
};

use crate::apriori::sequential::Level;
use crate::cluster::{ClusterConfig, FaultModel, FaultOutcome, JobTiming};
use crate::itemset::Itemset;
use crate::mapreduce::counters::Counters;
use drivers::{
    DpcController, EtdpcController, FpcController, PhaseController, SpcController,
    VfpcController,
};
use mappers::GenMode;

/// The seven algorithms of the paper's evaluation (§5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Single Pass Counting: one MapReduce job per Apriori pass.
    Spc,
    /// Fixed Passes Combined-counting: every phase combines n passes.
    Fpc,
    /// Dynamic Passes Combined-counting: candidate-count threshold α·|L|.
    Dpc,
    /// Variable-size FPC: pass count grows per phase (2, 3, 4, ...).
    Vfpc,
    /// Elapsed-Time-based DPC: α driven by preceding phase times.
    Etdpc,
    /// VFPC with pruning skipped after a phase's first pass (§4.2).
    OptimizedVfpc,
    /// ETDPC with pruning skipped after a phase's first pass (§4.2).
    OptimizedEtdpc,
}

impl Algorithm {
    /// All seven algorithms, in the paper's presentation order.
    pub const ALL: [Algorithm; 7] = [
        Algorithm::Spc,
        Algorithm::Fpc,
        Algorithm::Dpc,
        Algorithm::Vfpc,
        Algorithm::Etdpc,
        Algorithm::OptimizedVfpc,
        Algorithm::OptimizedEtdpc,
    ];

    /// The paper's display name (e.g. "Optimized-VFPC").
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Spc => "SPC",
            Algorithm::Fpc => "FPC",
            Algorithm::Dpc => "DPC",
            Algorithm::Vfpc => "VFPC",
            Algorithm::Etdpc => "ETDPC",
            Algorithm::OptimizedVfpc => "Optimized-VFPC",
            Algorithm::OptimizedEtdpc => "Optimized-ETDPC",
        }
    }

    /// Parse an algorithm name (case- and punctuation-insensitive).
    ///
    /// The trait-based spellings — `s.parse::<Algorithm>()` via
    /// [`std::str::FromStr`], or `Algorithm::try_from(s)` — are the
    /// idiomatic entry points and carry a typed
    /// [`ParseAlgorithmError`]; this inherent method is their shared
    /// `Option`-shaped core.
    pub fn parse(s: &str) -> Option<Algorithm> {
        let norm = s.to_ascii_lowercase().replace(['-', '_'], "");
        Some(match norm.as_str() {
            "spc" => Algorithm::Spc,
            "fpc" => Algorithm::Fpc,
            "dpc" => Algorithm::Dpc,
            "vfpc" => Algorithm::Vfpc,
            "etdpc" => Algorithm::Etdpc,
            "optimizedvfpc" | "optvfpc" => Algorithm::OptimizedVfpc,
            "optimizedetdpc" | "optetdpc" => Algorithm::OptimizedEtdpc,
            _ => return None,
        })
    }

    /// Whether Job2 phases skip pruning after their first pass (§4.2).
    pub fn optimized(&self) -> bool {
        matches!(self, Algorithm::OptimizedVfpc | Algorithm::OptimizedEtdpc)
    }

    /// This algorithm's position in [`Algorithm::ALL`] — the index of its
    /// slot in per-algorithm counter arrays
    /// (`SessionStats::queries_by_algorithm`).
    pub fn index(&self) -> usize {
        match self {
            Algorithm::Spc => 0,
            Algorithm::Fpc => 1,
            Algorithm::Dpc => 2,
            Algorithm::Vfpc => 3,
            Algorithm::Etdpc => 4,
            Algorithm::OptimizedVfpc => 5,
            Algorithm::OptimizedEtdpc => 6,
        }
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Error of parsing an [`Algorithm`] name: carries the rejected input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseAlgorithmError(
    /// The input string that matched no algorithm name.
    pub String,
);

impl std::fmt::Display for ParseAlgorithmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown algorithm {:?}; expected one of spc, fpc, dpc, vfpc, etdpc, \
             optimized-vfpc (opt-vfpc), optimized-etdpc (opt-etdpc)",
            self.0
        )
    }
}

impl std::error::Error for ParseAlgorithmError {}

impl std::str::FromStr for Algorithm {
    type Err = ParseAlgorithmError;

    /// `"opt-vfpc".parse::<Algorithm>()` — same normalization as
    /// [`Algorithm::parse`], with a typed error for CLI/config surfaces.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Algorithm::parse(s).ok_or_else(|| ParseAlgorithmError(s.to_string()))
    }
}

impl TryFrom<&str> for Algorithm {
    type Error = ParseAlgorithmError;

    fn try_from(s: &str) -> Result<Self, Self::Error> {
        s.parse()
    }
}

/// Tunables shared by a mining run.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Lines per input split (the paper's `setNumLinesPerSplit`).
    pub split_lines: usize,
    /// Faithful per-record generation cost vs once-per-task (ablation).
    pub gen_mode: GenMode,
    /// FPC's fixed pass count (paper: "generally 3").
    pub fpc_n: usize,
    /// DPC's fast-phase α (paper: 2.0 for c20d10k/mushroom, 3.0 for chess).
    pub dpc_alpha: f64,
    /// DPC's β threshold in seconds (paper: 60).
    pub dpc_beta: f64,
    /// Fuse passes 1 and 2 into a single job with a triangular-matrix
    /// counter (Kovacs & Illes, the paper's ref [6]); Job2 then starts at
    /// k = 3, saving one MapReduce job.
    pub fuse_pass_2: bool,
    /// Placement seed for HDFS replicas.
    pub seed: u64,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            split_lines: 1000,
            gen_mode: GenMode::PerRecord,
            fpc_n: 3,
            dpc_alpha: 2.0,
            dpc_beta: 60.0,
            fuse_pass_2: false,
            seed: 1,
        }
    }
}

/// Fault-injected re-timing of one phase, carried by [`PhaseRecord`] when
/// the query ran under a [`FaultModel`]: the same cost-modeled tasks
/// scheduled through the fault simulator, so every record holds both the
/// clean makespan ([`PhaseRecord::elapsed`]) and the faulted one — mining
/// output itself is untouched by construction (the output-invariance
/// contract, DESIGN.md §6).
#[derive(Debug, Clone)]
pub struct PhaseFaults {
    /// Faulted timing breakdown (same submit/shuffle terms as the clean
    /// [`PhaseRecord::timing`]; map/reduce makespans re-scheduled under
    /// injection).
    pub timing: JobTiming,
    /// Map-stage injection outcome.
    pub map: FaultOutcome,
    /// Reduce-stage injection outcome.
    pub reduce: FaultOutcome,
}

impl PhaseFaults {
    /// The phase's faulted elapsed seconds (the counterpart of
    /// [`PhaseRecord::elapsed`]).
    pub fn elapsed(&self) -> f64 {
        self.timing.elapsed()
    }

    /// Merged map + reduce injection counters of the phase; the returned
    /// outcome's `makespan` is the phase's faulted elapsed time.
    pub fn totals(&self) -> FaultOutcome {
        FaultOutcome {
            makespan: self.elapsed(),
            attempts: self.map.attempts + self.reduce.attempts,
            failures: self.map.failures + self.reduce.failures,
            stragglers: self.map.stragglers + self.reduce.stragglers,
            speculative_launches: self.map.speculative_launches
                + self.reduce.speculative_launches,
            speculative_wins: self.map.speculative_wins + self.reduce.speculative_wins,
            job_failed: self.map.job_failed || self.reduce.job_failed,
        }
    }
}

/// Metrics of one MapReduce phase (one row slice of Tables 3-5 / 10-12).
#[derive(Debug, Clone)]
pub struct PhaseRecord {
    /// 1-based phase index (phase 1 = Job1).
    pub phase: usize,
    /// Name of the MapReduce job that ran this phase (e.g. `job1`,
    /// `job2-k3`), propagated from the job's
    /// [`crate::mapreduce::JobBuilder`] name through the executor's task
    /// meters.
    pub job: String,
    /// Apriori pass number of the first pass in this phase (1 for Job1).
    pub first_pass: usize,
    /// Number of passes this phase combined.
    pub n_passes: usize,
    /// Candidates generated in this phase (Tables 7-9; 0 for Job1).
    pub candidates: u64,
    /// Resolved counting backend per pass of the phase, in pass order
    /// (never [`CountingBackend::Auto`] — resolution happened on the
    /// driver). Empty for an unfused Job1, `[Triangular]` for a fused one.
    pub backends: Vec<CountingBackend>,
    /// Simulated elapsed seconds (a Tables 3-5 / 10-12 cell).
    pub elapsed: f64,
    /// Simulated timing breakdown.
    pub timing: JobTiming,
    /// Real host wall-clock seconds spent executing the phase.
    pub wall: f64,
    /// Merged job counters.
    pub counters: Counters,
    /// Fault-injected re-timing of the phase — `Some` iff the query
    /// carried a [`FaultModel`] (`MiningRequest::faults`).
    pub faults: Option<PhaseFaults>,
}

impl PhaseRecord {
    /// Compact display label for the phase's counting backends: `-` when
    /// none were recorded (unfused Job1), the backend name when every pass
    /// used the same one, else the per-pass names joined with `+`
    /// (e.g. `triangular+trie`).
    pub fn backend_label(&self) -> String {
        match self.backends.as_slice() {
            [] => "-".to_string(),
            [first, rest @ ..] if rest.iter().all(|b| b == first) => first.name().to_string(),
            all => all.iter().map(CountingBackend::name).collect::<Vec<_>>().join("+"),
        }
    }
}

/// Result of one full mining run.
#[derive(Debug, Clone)]
pub struct MiningOutcome {
    /// Which algorithm produced this outcome.
    pub algorithm: Algorithm,
    /// Name of the mined dataset.
    pub dataset: String,
    /// Fractional minimum support of the run.
    pub min_sup: f64,
    /// Absolute minimum support count (ceil of min_sup · N).
    pub min_count: u64,
    /// `levels[k-1]` = frequent k-itemsets (identical to the oracle's).
    pub levels: Vec<Level>,
    /// Per-phase metrics, in execution order.
    pub phases: Vec<PhaseRecord>,
    /// Sum of per-phase simulated elapsed times ("Total" in Tables 3-5).
    pub total_time: f64,
    /// Total plus per-phase driver gaps ("Actual" in Tables 3-5).
    pub actual_time: f64,
    /// Real host wall-clock for the whole run.
    pub wall_time: f64,
    /// The fault model the run carried, if any — when `Some`, every phase
    /// record holds a [`PhaseFaults`] and the `faulted_*` accessors return
    /// values.
    pub fault_model: Option<FaultModel>,
}

impl MiningOutcome {
    /// Total frequent itemsets across all levels.
    pub fn total_frequent(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// |L_k| per level (the shape of the paper's Table 6).
    pub fn lk_profile(&self) -> Vec<usize> {
        self.levels.iter().map(|l| l.len()).collect()
    }

    /// Number of MapReduce phases the run took.
    pub fn n_phases(&self) -> usize {
        self.phases.len()
    }

    /// Flattened sorted `(itemset, count)` list (oracle-comparable).
    pub fn all_frequent(&self) -> Vec<(Itemset, u64)> {
        let mut out: Vec<(Itemset, u64)> =
            self.levels.iter().flat_map(|l| l.iter().cloned()).collect();
        out.sort();
        out
    }

    /// Sum of per-phase *faulted* elapsed times — the fault-model
    /// counterpart of [`MiningOutcome::total_time`]. `None` when the run
    /// carried no fault model.
    pub fn faulted_total_time(&self) -> Option<f64> {
        self.fault_model.as_ref()?;
        Some(self.phases.iter().filter_map(|p| p.faults.as_ref()).map(|f| f.elapsed()).sum())
    }

    /// Faulted counterpart of [`MiningOutcome::actual_time`]: the faulted
    /// total plus the same per-phase driver gaps the clean run paid.
    pub fn faulted_actual_time(&self) -> Option<f64> {
        Some(self.faulted_total_time()? + (self.actual_time - self.total_time))
    }

    /// Run-level fault aggregate: every phase's merged map + reduce
    /// injection counters accumulated (the returned `makespan` is the
    /// faulted total time). `None` when the run carried no fault model.
    pub fn fault_totals(&self) -> Option<FaultOutcome> {
        self.fault_model.as_ref()?;
        let mut totals = FaultOutcome::default();
        for faults in self.phases.iter().filter_map(|p| p.faults.as_ref()) {
            totals.accumulate(&faults.totals());
        }
        Some(totals)
    }
}

/// Result of one incremental or windowed refresh
/// ([`MiningSession::mine_incremental`] / [`MiningSession::mine_window`]):
/// the full frequent output over the effective record range — byte-identical
/// to a cold run over the same range — plus the delta bookkeeping
/// (what changed since the previous refresh, and how much of the store was
/// actually rescanned to find out). See DESIGN.md §13.
#[derive(Debug, Clone)]
pub struct DeltaOutcome {
    /// Which algorithm the refresh was issued for. On the delta path no
    /// MapReduce phases run, but fallback/bootstrap runs use exactly this
    /// algorithm — and every algorithm yields the same frequent output.
    pub algorithm: Algorithm,
    /// Name of the mined dataset.
    pub dataset: String,
    /// Fractional minimum support of the refresh.
    pub min_sup: f64,
    /// Absolute minimum support count over the effective range.
    pub min_count: u64,
    /// The record range this outcome covers: `0..n` for grow-mode
    /// refreshes, the current block-aligned window otherwise.
    pub coverage: std::ops::Range<usize>,
    /// `levels[k-1]` = frequent k-itemsets over `coverage` — identical to
    /// a cold [`MiningSession::run`] over the same records.
    pub levels: Vec<Level>,
    /// Itemsets frequent now but not in the previous refresh, with their
    /// new counts (everything, on a bootstrap refresh).
    pub added: Vec<(Itemset, u64)>,
    /// Itemsets frequent in the previous refresh but not anymore.
    pub removed: Vec<Itemset>,
    /// Itemsets frequent in both refreshes.
    pub retained: usize,
    /// `true` when the refresh was answered from the delta blocks alone;
    /// `false` on bootstrap and fallback (full re-mine).
    pub delta: bool,
    /// Store blocks scanned by this refresh — strictly below
    /// `total_blocks` whenever `delta` is `true` and the delta was
    /// smaller than the store.
    pub blocks_rescanned: usize,
    /// Store blocks the session's file holds in total.
    pub total_blocks: usize,
}

impl DeltaOutcome {
    /// Total frequent itemsets across all levels.
    pub fn total_frequent(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// |L_k| per level.
    pub fn lk_profile(&self) -> Vec<usize> {
        self.levels.iter().map(|l| l.len()).collect()
    }

    /// Flattened sorted `(itemset, count)` list (oracle-comparable, same
    /// shape as [`MiningOutcome::all_frequent`]).
    pub fn all_frequent(&self) -> Vec<(Itemset, u64)> {
        let mut out: Vec<(Itemset, u64)> =
            self.levels.iter().flat_map(|l| l.iter().cloned()).collect();
        out.sort();
        out
    }

    /// Did anything change relative to the previous refresh?
    pub fn changed(&self) -> bool {
        !self.added.is_empty() || !self.removed.is_empty()
    }
}

/// Every map task of an Apriori job computes its aux values (`npass`,
/// `candidateCount`) from the same shared [`mappers::PhasePlan`], so the
/// engine's max-merge is exact. The engine now *detects* divergence instead
/// of silently masking it; here — where the agreement invariant actually
/// holds — divergence is a driver bug, so debug builds fail fast.
fn debug_assert_aux_agreement<O>(out: &crate::mapreduce::JobOutput<O>) {
    debug_assert!(
        out.aux_divergence.is_empty(),
        "Apriori map tasks must agree on aux values; diverged on {:?} in job {}",
        out.aux_divergence,
        out.name
    );
}

fn controller_for(
    algo: Algorithm,
    fpc_n: usize,
    dpc_alpha: f64,
    dpc_beta: f64,
) -> Box<dyn PhaseController> {
    match algo {
        Algorithm::Spc => Box::new(SpcController),
        Algorithm::Fpc => Box::new(FpcController { n: fpc_n }),
        Algorithm::Dpc => Box::new(DpcController::new(dpc_alpha, dpc_beta)),
        Algorithm::Vfpc | Algorithm::OptimizedVfpc => Box::new(VfpcController::default()),
        Algorithm::Etdpc | Algorithm::OptimizedEtdpc => Box::new(EtdpcController::new()),
    }
}

// The deprecated one-shot free functions `run` / `run_with` / `run_on_file`
// (shims over a validation-free session since 0.2.0) were REMOVED in 0.3.0:
// build a `MiningSession` and submit `MiningRequest`s instead (DESIGN.md §8
// documents the one-to-one migration).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::sequential::mine;
    use crate::dataset::ibm::{generate, IbmParams};
    use crate::dataset::TransactionDb;

    fn small_db() -> TransactionDb {
        generate(&IbmParams {
            n_txns: 300,
            n_items: 40,
            avg_txn_len: 8.0,
            avg_pattern_len: 4.0,
            n_patterns: 10,
            correlation: 0.5,
            corruption_mean: 0.3,
            corruption_sd: 0.1,
            seed: 42,
            ..Default::default()
        })
    }

    fn opts() -> RunOptions {
        RunOptions { split_lines: 50, ..Default::default() }
    }

    /// One-shot session run — the tests' equivalent of the old `run_with`.
    fn mine_s(
        algo: Algorithm,
        db: &TransactionDb,
        min_sup: f64,
        cluster: &ClusterConfig,
        o: &RunOptions,
    ) -> MiningOutcome {
        MiningSession::for_db(db, cluster.clone())
            .options(o)
            .build()
            .expect("test session")
            .run(&MiningRequest::from_options(algo, min_sup, o))
            .expect("test run")
    }

    #[test]
    fn every_algorithm_matches_oracle() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        for min_sup in [0.3, 0.15] {
            let oracle = mine(&db, min_sup).all_frequent();
            for algo in Algorithm::ALL {
                let got = mine_s(algo, &db, min_sup, &cluster, &opts());
                assert_eq!(
                    got.all_frequent(),
                    oracle,
                    "{algo} at min_sup {min_sup} diverges from oracle"
                );
            }
        }
    }

    #[test]
    fn spc_has_one_pass_per_phase() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let out = mine_s(Algorithm::Spc, &db, 0.2, &cluster, &opts());
        assert!(out.phases.iter().all(|p| p.n_passes <= 1));
        // SPC phases = 1 (Job1) + one per pass that generated candidates.
        let oracle = mine(&db, 0.2);
        assert!(out.n_phases() >= oracle.max_len());
    }

    #[test]
    fn combined_algorithms_use_fewer_phases() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let spc = mine_s(Algorithm::Spc, &db, 0.15, &cluster, &opts());
        for algo in [Algorithm::Fpc, Algorithm::Vfpc, Algorithm::OptimizedVfpc] {
            let out = mine_s(algo, &db, 0.15, &cluster, &opts());
            assert!(
                out.n_phases() < spc.n_phases(),
                "{algo}: {} phases vs SPC {}",
                out.n_phases(),
                spc.n_phases()
            );
        }
    }

    #[test]
    fn actual_exceeds_total_by_driver_gaps() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let out = mine_s(Algorithm::Vfpc, &db, 0.2, &cluster, &opts());
        let expect = out.total_time + cluster.overhead.driver_gap * out.n_phases() as f64;
        assert!((out.actual_time - expect).abs() < 1e-9);
    }

    #[test]
    fn optimized_generates_at_least_as_many_candidates() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let plain = mine_s(Algorithm::Vfpc, &db, 0.15, &cluster, &opts());
        let opt = mine_s(Algorithm::OptimizedVfpc, &db, 0.15, &cluster, &opts());
        let plain_c: u64 = plain.phases.iter().map(|p| p.candidates).sum();
        let opt_c: u64 = opt.phases.iter().map(|p| p.candidates).sum();
        assert!(opt_c >= plain_c, "optimized {opt_c} < plain {plain_c}");
        // ... and the same frequent itemsets.
        assert_eq!(plain.all_frequent(), opt.all_frequent());
    }

    #[test]
    fn phase_records_are_consistent() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let out = mine_s(Algorithm::Etdpc, &db, 0.2, &cluster, &opts());
        // Phases numbered 1.., passes contiguous.
        let mut next_pass = 2;
        for (i, p) in out.phases.iter().enumerate() {
            assert_eq!(p.phase, i + 1);
            if i == 0 {
                assert_eq!(p.first_pass, 1);
            } else {
                assert_eq!(p.first_pass, next_pass, "phase {} starts wrong", p.phase);
                next_pass += p.n_passes;
            }
            assert!(p.elapsed > 0.0);
        }
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for algo in Algorithm::ALL {
            assert_eq!(Algorithm::parse(algo.name()), Some(algo));
        }
        assert_eq!(Algorithm::parse("optimized_vfpc"), Some(Algorithm::OptimizedVfpc));
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn algorithm_fromstr_tryfrom_display_roundtrip() {
        for algo in Algorithm::ALL {
            // Display -> FromStr / TryFrom round-trip over the paper names.
            let displayed = algo.to_string();
            assert_eq!(displayed.parse::<Algorithm>(), Ok(algo), "{displayed}");
            assert_eq!(Algorithm::try_from(displayed.as_str()), Ok(algo), "{displayed}");
            // Same normalization as the inherent parser.
            assert_eq!(displayed.to_ascii_lowercase().parse::<Algorithm>(), Ok(algo));
        }
        let err = "nope".parse::<Algorithm>().expect_err("unknown name must error");
        assert_eq!(err, ParseAlgorithmError("nope".into()));
        let msg = err.to_string();
        assert!(msg.contains("unknown algorithm") && msg.contains("opt-vfpc"), "{msg}");
        assert!(Algorithm::try_from("").is_err());
    }

    #[test]
    fn fused_pass2_matches_oracle_and_saves_a_phase() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        for algo in [Algorithm::Spc, Algorithm::OptimizedVfpc] {
            let plain = mine_s(algo, &db, 0.2, &cluster, &opts());
            let fused = mine_s(
                algo,
                &db,
                0.2,
                &cluster,
                &RunOptions { fuse_pass_2: true, ..opts() },
            );
            assert_eq!(fused.all_frequent(), plain.all_frequent(), "{algo}");
            assert!(fused.n_phases() < plain.n_phases(), "{algo} phases not saved");
            assert!(fused.actual_time < plain.actual_time, "{algo} fused not faster");
            // Fused phase 1 covers passes 1-2.
            assert_eq!(fused.phases[0].n_passes, 2);
            assert_eq!(fused.phases.get(1).map(|p| p.first_pass), Some(3));
        }
    }

    #[test]
    fn high_min_sup_trivial_run() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let out = mine_s(Algorithm::OptimizedEtdpc, &db, 0.999, &cluster, &opts());
        // Nothing (or almost nothing) frequent; must terminate cleanly.
        assert!(out.levels.len() <= 1);
    }

    #[test]
    fn incremental_bootstrap_matches_full_run() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let session = MiningSession::for_db(&db, cluster)
            .options(&opts())
            .build()
            .expect("test session");
        let req = MiningRequest::new(Algorithm::Spc).min_sup(0.2);
        let full = session.run(&req).expect("full run");
        let mut miner = DeltaMiner::new();
        let boot = session.mine_incremental(&req, &mut miner).expect("bootstrap refresh");
        assert!(!boot.delta, "bootstrap must be a full pass");
        assert_eq!(boot.all_frequent(), full.all_frequent());
        assert_eq!(boot.added.len(), boot.total_frequent());
        assert!(boot.removed.is_empty());
        // Unchanged store, same support: the second refresh is a pure
        // delta over zero grown records.
        let again = session.mine_incremental(&req, &mut miner).expect("idle refresh");
        assert!(again.delta);
        assert_eq!(again.blocks_rescanned, 0);
        assert!(!again.changed());
        assert_eq!(again.all_frequent(), full.all_frequent());
        let stats = session.stats();
        assert_eq!(stats.delta_runs, 2);
        assert_eq!(stats.full_fallbacks, 0);
    }

    #[test]
    fn changed_min_sup_forces_full_fallback() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let session = MiningSession::for_db(&db, cluster)
            .options(&opts())
            .build()
            .expect("test session");
        let mut miner = DeltaMiner::new();
        session
            .mine_incremental(&MiningRequest::new(Algorithm::Spc).min_sup(0.3), &mut miner)
            .expect("bootstrap refresh");
        let out = session
            .mine_incremental(&MiningRequest::new(Algorithm::Spc).min_sup(0.15), &mut miner)
            .expect("re-supported refresh");
        assert!(!out.delta, "a changed min_sup cannot reuse the snapshot");
        assert_eq!(session.stats().full_fallbacks, 1);
        let oracle = mine(&db, 0.15).all_frequent();
        assert_eq!(out.all_frequent(), oracle);
    }

    #[test]
    fn window_mining_rejects_db_backed_sessions_and_bad_specs() {
        let db = small_db();
        let cluster = ClusterConfig::paper_cluster();
        let session = MiningSession::for_db(&db, cluster)
            .options(&opts())
            .build()
            .expect("test session");
        let req = MiningRequest::new(Algorithm::Spc).min_sup(0.2);
        let mut miner = DeltaMiner::new();
        for (spec, why) in [
            (WindowSpec { blocks: 0, step: 1 }, "zero-block window"),
            (WindowSpec { blocks: 3, step: 0 }, "zero step"),
            (WindowSpec::new(2).step(3), "step wider than window"),
        ] {
            assert!(
                matches!(
                    session.mine_window(&req, spec, &mut miner),
                    Err(MiningError::InvalidWindow(_))
                ),
                "{why} must be rejected"
            );
        }
        // A well-formed spec still fails on an in-memory session: windows
        // are defined over store blocks.
        let err = session
            .mine_window(&req, WindowSpec::new(2), &mut miner)
            .expect_err("for_db session must refuse windows");
        assert!(matches!(err, MiningError::InvalidWindow(_)));
        assert!(err.to_string().contains("store-backed"), "{err}");
    }
}
