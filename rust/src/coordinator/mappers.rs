//! The paper's Mapper implementations (Algorithms 1–5):
//! `OneItemsetMapper` (Job1), and a parameterized `Job2Mapper` covering
//! `SPCItemsetMapper`, `VFPCItemsetMapper`, `ETDPCItemsetMapper`, and their
//! Optimized variants (skipped pruning after the first pass of a phase).
//!
//! ## Faithful cost metering
//!
//! In the paper's Hadoop implementation `apriori-gen()` runs inside `map()`
//! and is therefore re-invoked *for every transaction* (§4.3 — the very
//! observation that motivates `non-apriori-gen()`). Re-executing identical
//! generation work per record would only heat the host CPU, so the mapper
//! executes generation once per task and, in [`GenMode::PerRecord`], charges
//! its metered cost multiplied by the record count — cost-identical to the
//! faithful re-invocation, bit-identical in output. [`GenMode::PerTask`]
//! charges it once (a hand-optimized implementation) and exists as an
//! ablation (`cargo bench --bench ablation_pruning`).

use crate::apriori::gen::{apriori_gen, non_apriori_gen, GenStats};
use crate::itemset::{Itemset, Trie};
use crate::mapreduce::api::{Context, Mapper};
use crate::mapreduce::counters::keys;
use std::sync::Arc;

/// Algorithm 1: emits `(item, 1)` per item of each transaction.
pub struct OneItemsetMapper;

impl Mapper for OneItemsetMapper {
    type K = Itemset;
    type V = u64;

    fn map(&mut self, _offset: usize, record: &Itemset, ctx: &mut Context<Itemset, u64>) {
        for &item in record {
            ctx.write(vec![item], 1);
        }
    }
}

/// Kovacs & Illes' fused first phase (paper ref [6]): count all 1-itemsets
/// AND 2-itemsets in one scan with an in-mapper triangular matrix, saving
/// an entire MapReduce job. Enabled by `RunOptions::fuse_pass_2`.
pub struct FusedOneTwoMapper {
    counter: crate::apriori::triangular::TriangularCounter,
    raw_writes: u64,
}

impl FusedOneTwoMapper {
    /// Counter over the dense universe `0..n_items`.
    pub fn new(n_items: usize) -> Self {
        Self { counter: crate::apriori::triangular::TriangularCounter::new(n_items), raw_writes: 0 }
    }
}

impl Mapper for FusedOneTwoMapper {
    type K = Itemset;
    type V = u64;

    fn map(&mut self, _offset: usize, record: &Itemset, ctx: &mut Context<Itemset, u64>) {
        self.counter.add_transaction(record);
        // Faithful raw write count: (item, 1) per item + (pair, 1) per pair.
        let w = record.len() as u64;
        let updates = w + w * (w - 1) / 2;
        self.raw_writes += updates;
        // Each triangle update is one O(1) counting op for the cost model.
        ctx.counters.add(keys::SUBSET_VISITS, updates);
    }

    fn cleanup(&mut self, ctx: &mut Context<Itemset, u64>) {
        // Faithful raw write volume, attributed once.
        ctx.counters.add(keys::MAP_OUTPUT_TUPLES, self.raw_writes);
        // Emit aggregated counts (in-mapper combining via the dense matrix).
        let (l1, l2) = self.counter.frequent(1);
        for (set, count) in l1.into_iter().chain(l2) {
            ctx.write_combined(set, count, 0);
        }
    }
}

/// How many Apriori passes this phase combines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PassPolicy {
    /// Combine exactly `n` passes (SPC: 1, FPC: 3, VFPC: driver-chosen).
    Fixed(usize),
    /// Combine passes until the cumulative candidate count exceeds `ct`
    /// (DPC/ETDPC: `ct = α · |L_prev|`, do-while semantics).
    Dynamic { ct: u64 },
}

/// Whether generation cost is charged per record (faithful to the paper's
/// MapReduce implementation) or once per task (hand-optimized variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GenMode {
    /// Charge generation cost once per record (the paper's mappers).
    #[default]
    PerRecord,
    /// Charge generation cost once per task (hand-optimized ablation).
    PerTask,
}

/// The phase's candidate-generation result, per Algorithms 2–5. Built once
/// per *job* by [`PhasePlan::build`] and shared read-only by every map task
/// of that job — the distributed-cache pattern: the paper's Hadoop mappers
/// each rebuild this per map() call; the cluster cost model still charges
/// that faithful cost (see [`GenMode`]), but the host only executes the
/// generation once (§Perf log).
pub struct PhasePlan {
    /// One candidate trie per combined pass, levels k, k+1, ...
    pub tries: Vec<Trie>,
    /// Metered generation work for ONE invocation of the in-map generation.
    pub gen_once: GenStats,
    /// Total candidates generated in this phase (paper's `candidateCount`).
    pub candidate_count: u64,
    /// Passes actually combined (paper's `npass`).
    pub npass: usize,
}

impl PhasePlan {
    /// Execute the phase's candidate generation, per Algorithms 2–5.
    pub fn build(l_prev: &Trie, policy: PassPolicy, optimized: bool) -> PhasePlan {
        let mut tries: Vec<Trie> = Vec::new();
        let mut gen_once = GenStats::default();
        let mut candidate_count = 0u64;
        let mut npass = 0usize;
        loop {
            // First pass generates from L_{k-1} with full apriori-gen;
            // later passes generate from the previous pass's *candidates* —
            // with pruning for the plain variants, join-only when optimized.
            let source = if npass == 0 { l_prev } else { tries.last().unwrap() };
            let (trie, stats) = if npass == 0 || !optimized {
                apriori_gen(source)
            } else {
                non_apriori_gen(source)
            };
            gen_once.merge(&stats);
            if trie.is_empty() {
                // No candidates at this level: nothing larger can exist.
                break;
            }
            candidate_count += trie.len() as u64;
            npass += 1;
            tries.push(trie);
            match policy {
                PassPolicy::Fixed(n) => {
                    if npass >= n {
                        break;
                    }
                }
                PassPolicy::Dynamic { ct } => {
                    // do-while(candidateCount <= ct): the pass that crosses
                    // `ct` still runs (it was just counted); stop after it.
                    if candidate_count > ct {
                        break;
                    }
                }
            }
        }
        PhasePlan { tries, gen_once, candidate_count, npass }
    }
}

/// Job2 mapper for every algorithm variant.
pub struct Job2Mapper {
    plan: Arc<PhasePlan>,
    gen_mode: GenMode,
    /// Per-task support counters, one buffer per pass trie.
    counts: Vec<Vec<u64>>,
    scratch: Vec<(u32, usize, usize)>,
    records: u64,
}

impl Job2Mapper {
    /// Mapper executing `plan`, with one count buffer per pass trie.
    pub fn new(plan: Arc<PhasePlan>, gen_mode: GenMode) -> Self {
        let counts = plan.tries.iter().map(|t| vec![0u64; t.node_count()]).collect();
        Self { plan, gen_mode, counts, scratch: Vec::new(), records: 0 }
    }

    /// Convenience used by tests: build the plan inline.
    pub fn standalone(
        l_prev: Arc<Trie>,
        policy: PassPolicy,
        optimized: bool,
        gen_mode: GenMode,
    ) -> Self {
        Self::new(Arc::new(PhasePlan::build(&l_prev, policy, optimized)), gen_mode)
    }
}

impl Mapper for Job2Mapper {
    type K = Itemset;
    type V = u64;

    fn map(&mut self, _offset: usize, record: &Itemset, ctx: &mut Context<Itemset, u64>) {
        self.records += 1;
        let mut visits = 0u64;
        for (trie, counts) in self.plan.tries.iter().zip(&mut self.counts) {
            let (v, _hits) = trie.count_transaction_into(record, counts, &mut self.scratch);
            visits += v;
        }
        ctx.counters.add(keys::SUBSET_VISITS, visits);
    }

    fn cleanup(&mut self, ctx: &mut Context<Itemset, u64>) {
        // Charge generation cost: per record (faithful re-invocation of
        // apriori-gen inside map(), §4.3) or once per task (hand-optimized
        // implementation); an empty split still builds the trie once.
        let times = match self.gen_mode {
            GenMode::PerRecord => self.records.max(1),
            GenMode::PerTask => 1,
        };
        ctx.counters.add(keys::JOIN_PAIRS, self.plan.gen_once.join_pairs * times);
        ctx.counters.add(keys::PRUNE_CHECKS, self.plan.gen_once.prune_checks * times);
        ctx.counters.add(keys::CANDS_BUILT, self.plan.gen_once.kept * times);

        // Emit locally-aggregated candidate counts (in-mapper combining: the
        // per-task counter buffers play the Combiner's role; `raw` restores
        // the faithful write(c, 1)-per-hit tuple count for the cost model).
        for (trie, counts) in self.plan.tries.iter().zip(&self.counts) {
            for (set, count) in trie.iter_with_counts(counts) {
                if count > 0 {
                    ctx.write_combined(set, count, count);
                }
            }
        }

        // Driver side-channel, as in Algorithms 3–5.
        ctx.set_aux(keys::CANDIDATES, self.plan.candidate_count);
        ctx.set_aux(keys::NPASS, self.plan.npass as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l_of(k: usize, sets: &[&[u32]]) -> Arc<Trie> {
        let owned: Vec<Itemset> = sets.iter().map(|s| s.to_vec()).collect();
        Arc::new(Trie::from_itemsets(k, owned.iter()))
    }

    fn run_mapper(mapper: &mut Job2Mapper, txns: &[&[u32]]) -> Context<Itemset, u64> {
        let mut ctx = Context::new();
        for (i, t) in txns.iter().enumerate() {
            mapper.map(i, &t.to_vec(), &mut ctx);
        }
        mapper.cleanup(&mut ctx);
        ctx
    }

    #[test]
    fn spc_single_pass_counts() {
        // L1 = {1},{2},{3}; txns: [1,2,3], [1,2] -> C2 counts: 12:2, 13:1, 23:1
        let mut m = Job2Mapper::standalone(
            l_of(1, &[&[1], &[2], &[3]]),
            PassPolicy::Fixed(1),
            false,
            GenMode::PerRecord,
        );
        let mut ctx = run_mapper(&mut m, &[&[1, 2, 3], &[1, 2]]);
        let mut out = ctx.take_output();
        out.sort();
        assert_eq!(out, vec![(vec![1, 2], 2), (vec![1, 3], 1), (vec![2, 3], 1)]);
        assert_eq!(ctx.aux[keys::NPASS], 1);
        assert_eq!(ctx.aux[keys::CANDIDATES], 3);
    }

    #[test]
    fn multipass_counts_multiple_levels() {
        // Combine 2 passes: C2 from L1, C3 from C2.
        let mut m = Job2Mapper::standalone(
            l_of(1, &[&[1], &[2], &[3]]),
            PassPolicy::Fixed(2),
            false,
            GenMode::PerRecord,
        );
        let mut ctx = run_mapper(&mut m, &[&[1, 2, 3]]);
        let mut out = ctx.take_output();
        out.sort();
        assert_eq!(
            out,
            vec![
                (vec![1, 2], 1),
                (vec![1, 2, 3], 1),
                (vec![1, 3], 1),
                (vec![2, 3], 1)
            ]
        );
        assert_eq!(ctx.aux[keys::CANDIDATES], 4); // 3 pairs + 1 triple
        assert_eq!(ctx.aux[keys::NPASS], 2);
    }

    #[test]
    fn fixed_policy_stops_on_empty_level() {
        // L1 = {1},{2}: C2={12}, C3 from C2 empty -> npass stops at 2 even
        // though Fixed(5) asked for more.
        let mut m = Job2Mapper::standalone(
            l_of(1, &[&[1], &[2]]),
            PassPolicy::Fixed(5),
            false,
            GenMode::PerRecord,
        );
        let ctx = run_mapper(&mut m, &[&[1, 2]]);
        assert_eq!(ctx.aux[keys::NPASS], 1);
        assert_eq!(ctx.aux[keys::CANDIDATES], 1);
    }

    #[test]
    fn dynamic_policy_do_while_semantics() {
        // L1 = 4 items -> C2 has 6, C3 has 4, C4 has 1.
        let l1 = l_of(1, &[&[1], &[2], &[3], &[4]]);
        // ct = 5: first pass (6 cands) exceeds ct AFTER being counted -> stop: npass=1.
        let mut m = Job2Mapper::standalone(
            Arc::clone(&l1),
            PassPolicy::Dynamic { ct: 5 },
            false,
            GenMode::PerRecord,
        );
        let ctx = run_mapper(&mut m, &[&[1, 2, 3, 4]]);
        assert_eq!(ctx.aux[keys::NPASS], 1);
        // ct = 6: 6 <= 6 -> second pass runs (6+4=10 > 6) -> npass=2.
        let mut m = Job2Mapper::standalone(
            Arc::clone(&l1),
            PassPolicy::Dynamic { ct: 6 },
            false,
            GenMode::PerRecord,
        );
        let ctx = run_mapper(&mut m, &[&[1, 2, 3, 4]]);
        assert_eq!(ctx.aux[keys::NPASS], 2);
        assert_eq!(ctx.aux[keys::CANDIDATES], 10);
        // Huge ct: runs to exhaustion (passes 2,3,4 -> 6+4+1 = 11).
        let mut m =
            Job2Mapper::standalone(l1, PassPolicy::Dynamic { ct: 1000 }, false, GenMode::PerRecord);
        let ctx = run_mapper(&mut m, &[&[1, 2, 3, 4]]);
        assert_eq!(ctx.aux[keys::NPASS], 3);
        assert_eq!(ctx.aux[keys::CANDIDATES], 11);
    }

    #[test]
    fn optimized_produces_superset_candidates_same_frequents() {
        // From the paper's Fig. 1 argument: optimized phases generate
        // un-pruned extras, but counting them changes nothing after the
        // min-support filter. Here L2 over items {1..4} missing {2,4}:
        let l2 = l_of(2, &[&[1, 2], &[1, 3], &[1, 4], &[2, 3], &[3, 4]]);
        let txns: &[&[u32]] = &[&[1, 2, 3], &[1, 3, 4], &[1, 2, 3, 4]];

        let mut plain = Job2Mapper::standalone(
            Arc::clone(&l2),
            PassPolicy::Fixed(2),
            false,
            GenMode::PerRecord,
        );
        let mut ctx_p = run_mapper(&mut plain, txns);
        let mut opt = Job2Mapper::standalone(l2, PassPolicy::Fixed(2), true, GenMode::PerRecord);
        let mut ctx_o = run_mapper(&mut opt, txns);

        assert!(ctx_o.aux[keys::CANDIDATES] >= ctx_p.aux[keys::CANDIDATES]);
        // Same counted supports on the shared candidates.
        let mut po = ctx_p.take_output();
        let mut oo = ctx_o.take_output();
        po.sort();
        oo.sort();
        for (set, count) in &po {
            let in_opt = oo.iter().find(|(s, _)| s == set);
            assert_eq!(in_opt.map(|(_, c)| *c), Some(*count), "set {set:?}");
        }
    }

    #[test]
    fn gen_mode_changes_charged_cost_not_output() {
        let l1 = l_of(1, &[&[1], &[2], &[3]]);
        let txns: &[&[u32]] = &[&[1, 2, 3], &[1, 2], &[2, 3]];
        let mut per_rec = Job2Mapper::standalone(
            Arc::clone(&l1),
            PassPolicy::Fixed(2),
            false,
            GenMode::PerRecord,
        );
        let mut per_task =
            Job2Mapper::standalone(l1, PassPolicy::Fixed(2), false, GenMode::PerTask);
        let mut ctx_r = run_mapper(&mut per_rec, txns);
        let mut ctx_t = run_mapper(&mut per_task, txns);
        assert_eq!(
            ctx_r.counters.get(keys::JOIN_PAIRS),
            3 * ctx_t.counters.get(keys::JOIN_PAIRS)
        );
        let mut a = ctx_r.take_output();
        let mut b = ctx_t.take_output();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn subset_visits_metered() {
        let mut m = Job2Mapper::standalone(
            l_of(1, &[&[1], &[2], &[3]]),
            PassPolicy::Fixed(1),
            false,
            GenMode::PerRecord,
        );
        let ctx = run_mapper(&mut m, &[&[1, 2, 3]]);
        assert!(ctx.counters.get(keys::SUBSET_VISITS) > 0);
    }

    #[test]
    fn one_itemset_mapper_emits_per_item() {
        let mut m = OneItemsetMapper;
        let mut ctx = Context::new();
        m.map(0, &vec![3, 5, 9], &mut ctx);
        let out = ctx.take_output();
        assert_eq!(out, vec![(vec![3], 1), (vec![5], 1), (vec![9], 1)]);
    }
}
