//! The paper's Mapper implementations (Algorithms 1–5):
//! `OneItemsetMapper` (Job1), and a parameterized `Job2Mapper` covering
//! `SPCItemsetMapper`, `VFPCItemsetMapper`, `ETDPCItemsetMapper`, and their
//! Optimized variants (skipped pruning after the first pass of a phase).
//!
//! ## Faithful cost metering
//!
//! In the paper's Hadoop implementation `apriori-gen()` runs inside `map()`
//! and is therefore re-invoked *for every transaction* (§4.3 — the very
//! observation that motivates `non-apriori-gen()`). Re-executing identical
//! generation work per record would only heat the host CPU, so the mapper
//! executes generation once per task and, in [`GenMode::PerRecord`], charges
//! its metered cost multiplied by the record count — cost-identical to the
//! faithful re-invocation, bit-identical in output. [`GenMode::PerTask`]
//! charges it once (a hand-optimized implementation) and exists as an
//! ablation (`cargo bench --bench ablation_pruning`).

use crate::apriori::gen::{apriori_gen, non_apriori_gen, GenStats};
use crate::apriori::triangular::TriangularCounter;
use crate::cluster::CostWeights;
use crate::dataset::stats::DensityProfile;
use crate::itemset::bitmap::BitVec64;
use crate::itemset::{Itemset, Trie};
use crate::mapreduce::api::{Context, Mapper};
use crate::mapreduce::counters::keys;
use std::sync::Arc;

pub use crate::runtime::counting::{CountingBackend, ParseBackendError};

/// Algorithm 1: emits `(item, 1)` per item of each transaction.
pub struct OneItemsetMapper;

impl Mapper for OneItemsetMapper {
    type K = Itemset;
    type V = u64;

    fn map(&mut self, _offset: usize, record: &Itemset, ctx: &mut Context<Itemset, u64>) {
        for &item in record {
            ctx.write(vec![item], 1);
        }
        // Width bookkeeping for the dataset density profile (`auto` pick).
        ctx.counters.add(keys::RECORD_ITEMS, record.len() as u64);
    }
}

/// Kovacs & Illes' fused first phase (paper ref [6]): count all 1-itemsets
/// AND 2-itemsets in one scan with an in-mapper triangular matrix, saving
/// an entire MapReduce job. Enabled by `RunOptions::fuse_pass_2`.
pub struct FusedOneTwoMapper {
    counter: crate::apriori::triangular::TriangularCounter,
    raw_writes: u64,
}

impl FusedOneTwoMapper {
    /// Counter over the dense universe `0..n_items`.
    pub fn new(n_items: usize) -> Self {
        Self { counter: crate::apriori::triangular::TriangularCounter::new(n_items), raw_writes: 0 }
    }
}

impl Mapper for FusedOneTwoMapper {
    type K = Itemset;
    type V = u64;

    fn map(&mut self, _offset: usize, record: &Itemset, ctx: &mut Context<Itemset, u64>) {
        self.counter.add_transaction(record);
        // Faithful raw write count: (item, 1) per item + (pair, 1) per pair.
        let w = record.len() as u64;
        let updates = w + w * (w - 1) / 2;
        self.raw_writes += updates;
        // Each triangle update is one O(1) counting op for the cost model —
        // the same key (and weight) the `triangular` Job2 backend charges.
        ctx.counters.add(keys::TRIANGLE_UPDATES, updates);
        ctx.counters.add(keys::RECORD_ITEMS, w);
    }

    fn cleanup(&mut self, ctx: &mut Context<Itemset, u64>) {
        // Faithful raw write volume, attributed once.
        ctx.counters.add(keys::MAP_OUTPUT_TUPLES, self.raw_writes);
        // Emit aggregated counts (in-mapper combining via the dense matrix).
        let (l1, l2) = self.counter.frequent(1);
        for (set, count) in l1.into_iter().chain(l2) {
            ctx.write_combined(set, count, 0);
        }
    }
}

/// How many Apriori passes this phase combines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PassPolicy {
    /// Combine exactly `n` passes (SPC: 1, FPC: 3, VFPC: driver-chosen).
    Fixed(usize),
    /// Combine passes until the cumulative candidate count exceeds `ct`
    /// (DPC/ETDPC: `ct = α · |L_prev|`, do-while semantics).
    Dynamic { ct: u64 },
}

/// Whether generation cost is charged per record (faithful to the paper's
/// MapReduce implementation) or once per task (hand-optimized variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GenMode {
    /// Charge generation cost once per record (the paper's mappers).
    #[default]
    PerRecord,
    /// Charge generation cost once per task (hand-optimized ablation).
    PerTask,
}

/// Largest dense universe the triangular backend accepts: the per-task
/// triangle holds |I|²/2 u64 cells, so 2048 items ≈ 16 MiB per map task —
/// beyond that the dense matrix loses to both other backends anyway.
pub const TRIANGULAR_MAX_ITEMS: usize = 2048;

/// Word-block size of the bitmap backend's cache-blocked candidate sweep:
/// 512 words = 4 KiB per TID row per block, so a candidate's k rows plus
/// its accumulator stay L1/L2-resident while the block is swept.
const BITMAP_WORDS_PER_BLOCK: usize = 512;

/// Inputs of the per-pass backend resolution (DESIGN.md §11): the dataset's
/// density profile and the cluster's cost weights. `auto` estimates each
/// applicable backend's map-side counting compute for a pass from candidate
/// count × dataset density and picks the cheapest — the same linear model
/// that later prices the counters the chosen backend actually charges.
#[derive(Debug, Clone, Copy)]
pub struct BackendContext {
    /// Dataset shape (N, |I|, avg width, density).
    pub profile: DensityProfile,
    /// The cluster's cost-model weights.
    pub weights: CostWeights,
}

impl BackendContext {
    /// Whether the dense triangular matrix applies to a pass: pairs only,
    /// and a universe small enough for the per-task triangle.
    pub fn triangular_applies(&self, k: usize) -> bool {
        k == 2 && self.profile.n_items <= TRIANGULAR_MAX_ITEMS
    }

    /// Estimated map-side counting seconds for one pass of `n_cands`
    /// k-itemset candidates over the whole dataset (`backend` must be
    /// resolved, not `Auto`). Estimates, not measurements — each backend's
    /// dominant counter priced by the cluster's weights:
    ///
    /// * trie: visits ≈ N · |C| · Σ_{j=1..k} density^j (a depth-j node is
    ///   reached when its j-prefix is contained in the transaction, which
    ///   for an average transaction happens with probability ≈ density^j);
    /// * bitmap: N·w̄ build word-ORs + |C| · k · ⌈N/64⌉ AND+popcount words;
    /// * triangular: N · (w̄ + w̄(w̄−1)/2) matrix increments.
    pub fn estimate_secs(&self, backend: CountingBackend, k: usize, n_cands: u64) -> f64 {
        let n = self.profile.n_txns as f64;
        let w = self.profile.avg_width;
        let d = self.profile.density.clamp(0.0, 1.0);
        match backend {
            CountingBackend::Trie => {
                let depth_sum: f64 = (1..=k as u32).map(|j| d.powi(j as i32)).sum();
                self.weights.subset_visit * n * n_cands as f64 * depth_sum
            }
            CountingBackend::Bitmap => {
                let words = (n / 64.0).ceil();
                self.weights.bitmap_word * (n * w + n_cands as f64 * k as f64 * words)
            }
            CountingBackend::Triangular => {
                self.weights.triangle_update * n * (w + w * (w - 1.0).max(0.0) / 2.0)
            }
            CountingBackend::Auto => unreachable!("estimate_secs takes resolved backends"),
        }
    }

    /// Resolve the requested backend for one pass trie of level `k` with
    /// `n_cands` candidates. Never returns `Auto`; `Triangular` falls back
    /// to the trie walk where the dense matrix does not apply.
    pub fn resolve(&self, requested: CountingBackend, k: usize, n_cands: u64) -> CountingBackend {
        match requested {
            CountingBackend::Trie => CountingBackend::Trie,
            CountingBackend::Bitmap => CountingBackend::Bitmap,
            CountingBackend::Triangular => {
                if self.triangular_applies(k) {
                    CountingBackend::Triangular
                } else {
                    CountingBackend::Trie
                }
            }
            CountingBackend::Auto => {
                let mut choices = vec![CountingBackend::Trie, CountingBackend::Bitmap];
                if self.triangular_applies(k) {
                    choices.push(CountingBackend::Triangular);
                }
                // Deterministic argmin: strict < keeps the earlier choice
                // on ties, so the pick is stable across platforms.
                let mut best = choices[0];
                let mut best_cost = self.estimate_secs(best, k, n_cands);
                for &b in &choices[1..] {
                    let cost = self.estimate_secs(b, k, n_cands);
                    if cost < best_cost {
                        best = b;
                        best_cost = cost;
                    }
                }
                best
            }
        }
    }
}

/// The phase's candidate-generation result, per Algorithms 2–5. Built once
/// per *job* by [`PhasePlan::build`] and shared read-only by every map task
/// of that job — the distributed-cache pattern: the paper's Hadoop mappers
/// each rebuild this per map() call; the cluster cost model still charges
/// that faithful cost (see [`GenMode`]), but the host only executes the
/// generation once (§Perf log).
pub struct PhasePlan {
    /// One candidate trie per combined pass, levels k, k+1, ...
    pub tries: Vec<Trie>,
    /// The resolved counting backend per pass trie (parallel to `tries`,
    /// never `Auto`). [`PhasePlan::build`] defaults every pass to the trie
    /// walk; [`PhasePlan::resolve_backends`] applies a request's choice.
    pub backends: Vec<CountingBackend>,
    /// Metered generation work for ONE invocation of the in-map generation.
    pub gen_once: GenStats,
    /// Total candidates generated in this phase (paper's `candidateCount`).
    pub candidate_count: u64,
    /// Passes actually combined (paper's `npass`).
    pub npass: usize,
}

impl PhasePlan {
    /// Execute the phase's candidate generation, per Algorithms 2–5.
    pub fn build(l_prev: &Trie, policy: PassPolicy, optimized: bool) -> PhasePlan {
        let mut tries: Vec<Trie> = Vec::new();
        let mut gen_once = GenStats::default();
        let mut candidate_count = 0u64;
        let mut npass = 0usize;
        loop {
            // First pass generates from L_{k-1} with full apriori-gen;
            // later passes generate from the previous pass's *candidates* —
            // with pruning for the plain variants, join-only when optimized.
            // lint:allow(unwrap-in-library): pass n>0 always has the previous
            // pass's trie — the loop pushes one per iteration.
            let source = if npass == 0 { l_prev } else { tries.last().unwrap() };
            let (trie, stats) = if npass == 0 || !optimized {
                apriori_gen(source)
            } else {
                non_apriori_gen(source)
            };
            gen_once.merge(&stats);
            if trie.is_empty() {
                // No candidates at this level: nothing larger can exist.
                break;
            }
            candidate_count += trie.len() as u64;
            npass += 1;
            tries.push(trie);
            match policy {
                PassPolicy::Fixed(n) => {
                    if npass >= n {
                        break;
                    }
                }
                PassPolicy::Dynamic { ct } => {
                    // do-while(candidateCount <= ct): the pass that crosses
                    // `ct` still runs (it was just counted); stop after it.
                    if candidate_count > ct {
                        break;
                    }
                }
            }
        }
        let backends = vec![CountingBackend::Trie; tries.len()];
        PhasePlan { tries, backends, gen_once, candidate_count, npass }
    }

    /// Resolve `requested` into a concrete backend for every pass of this
    /// plan (see [`BackendContext::resolve`]); called once per job by the
    /// session driver, before the plan is shared across map tasks — so
    /// every task counts the same way and the aux agreement holds.
    pub fn resolve_backends(&mut self, requested: CountingBackend, ctx: &BackendContext) {
        self.backends =
            self.tries.iter().map(|t| ctx.resolve(requested, t.level(), t.len() as u64)).collect();
    }
}

/// One pass's counting strategy inside a [`Job2Mapper`] — the per-task
/// state behind the [`CountingBackend`] the plan resolved for that pass.
enum PassCounter {
    /// Trie subset walk: per-node count buffer, filled record by record.
    Trie {
        /// External per-task count buffer (`count_transaction_into`).
        counts: Vec<u64>,
    },
    /// Vertical TID-bitmap: nothing per pass — all bitmap passes share the
    /// mapper's TID-list index and count at cleanup.
    Bitmap,
    /// Dense triangular pair matrix, filled record by record.
    Triangular(Box<TriangularCounter>),
}

/// Job2 mapper for every algorithm variant, counting each pass with the
/// backend its [`PhasePlan`] resolved. Whatever the backend, the mapper
/// emits the SAME `(candidate, count)` tuples in the SAME trie iteration
/// order through the same combining write path — the output-invariance
/// contract (DESIGN.md §11): backends may only move measured work
/// (`subset_visits` vs `bitmap_word_ops` vs `triangle_updates`), never
/// mined output.
pub struct Job2Mapper {
    plan: Arc<PhasePlan>,
    gen_mode: GenMode,
    /// Per-task counting state, one per pass trie.
    passes: Vec<PassCounter>,
    /// Per-item TID-lists over this split's record indices (raw words;
    /// wrapped as [`BitVec64`] at cleanup) — `Some` iff any pass counts
    /// via the bitmap backend.
    tid_rows: Option<Vec<Vec<u64>>>,
    scratch: Vec<(u32, usize, usize)>,
    records: u64,
}

impl Job2Mapper {
    /// Mapper executing `plan` over the dense universe `0..n_items`, with
    /// per-pass counting state sized by the plan's resolved backends.
    pub fn new(plan: Arc<PhasePlan>, gen_mode: GenMode, n_items: usize) -> Self {
        let passes = plan
            .tries
            .iter()
            .zip(&plan.backends)
            .map(|(t, b)| match b {
                CountingBackend::Trie => PassCounter::Trie { counts: vec![0u64; t.node_count()] },
                CountingBackend::Bitmap => PassCounter::Bitmap,
                CountingBackend::Triangular => {
                    debug_assert_eq!(t.level(), 2, "triangular backend is k=2 only");
                    PassCounter::Triangular(Box::new(TriangularCounter::new(n_items)))
                }
                CountingBackend::Auto => unreachable!("plans carry resolved backends"),
            })
            .collect::<Vec<_>>();
        let tid_rows = passes
            .iter()
            .any(|p| matches!(p, PassCounter::Bitmap))
            .then(|| vec![Vec::new(); n_items]);
        Self { plan, gen_mode, passes, tid_rows, scratch: Vec::new(), records: 0 }
    }

    /// Convenience used by tests: build the plan inline (trie backend).
    pub fn standalone(
        l_prev: Arc<Trie>,
        policy: PassPolicy,
        optimized: bool,
        gen_mode: GenMode,
    ) -> Self {
        Self::new(Arc::new(PhasePlan::build(&l_prev, policy, optimized)), gen_mode, 0)
    }
}

impl Mapper for Job2Mapper {
    type K = Itemset;
    type V = u64;

    fn map(&mut self, _offset: usize, record: &Itemset, ctx: &mut Context<Itemset, u64>) {
        // This record's index within the split = its TID-list bit.
        let idx = self.records as usize;
        self.records += 1;
        if let Some(rows) = &mut self.tid_rows {
            let (word, bit) = (idx / 64, idx % 64);
            for &item in record {
                let row = &mut rows[item as usize];
                if row.len() <= word {
                    row.resize(word + 1, 0);
                }
                row[word] |= 1u64 << bit;
            }
            // One word-OR per item occurrence (the TID-list build cost).
            ctx.counters.add(keys::BITMAP_WORD_OPS, record.len() as u64);
        }
        let mut visits = 0u64;
        let mut triangle = 0u64;
        for (pass, trie) in self.passes.iter_mut().zip(&self.plan.tries) {
            match pass {
                PassCounter::Trie { counts } => {
                    let (v, _hits) = trie.count_transaction_into(record, counts, &mut self.scratch);
                    visits += v;
                }
                PassCounter::Bitmap => {}
                PassCounter::Triangular(counter) => {
                    counter.add_transaction(record);
                    let w = record.len() as u64;
                    triangle += w + w * (w - 1) / 2;
                }
            }
        }
        if visits > 0 {
            ctx.counters.add(keys::SUBSET_VISITS, visits);
        }
        if triangle > 0 {
            ctx.counters.add(keys::TRIANGLE_UPDATES, triangle);
        }
    }

    fn cleanup(&mut self, ctx: &mut Context<Itemset, u64>) {
        // Charge generation cost: per record (faithful re-invocation of
        // apriori-gen inside map(), §4.3) or once per task (hand-optimized
        // implementation); an empty split still builds the trie once.
        let times = match self.gen_mode {
            GenMode::PerRecord => self.records.max(1),
            GenMode::PerTask => 1,
        };
        ctx.counters.add(keys::JOIN_PAIRS, self.plan.gen_once.join_pairs * times);
        ctx.counters.add(keys::PRUNE_CHECKS, self.plan.gen_once.prune_checks * times);
        ctx.counters.add(keys::CANDS_BUILT, self.plan.gen_once.kept * times);

        // Seal the TID-list index (shared by every bitmap pass): row width
        // = records seen by THIS task, so candidate intersections count
        // exactly this split's transactions.
        let width = self.records as usize;
        let tid: Option<Vec<BitVec64>> = self
            .tid_rows
            .take()
            .map(|rows| rows.into_iter().map(|w| BitVec64::from_words(w, width)).collect());

        // Emit locally-aggregated candidate counts (in-mapper combining:
        // the per-task counting state plays the Combiner's role; `raw`
        // restores the faithful write(c, 1)-per-hit tuple count for the
        // cost model). Every backend walks the SAME trie order and the
        // same `count > 0` filter — byte-identical output by construction.
        for (pass, trie) in self.passes.iter().zip(&self.plan.tries) {
            match pass {
                PassCounter::Trie { counts } => {
                    for (set, count) in trie.iter_with_counts(counts) {
                        if count > 0 {
                            ctx.write_combined(set, count, count);
                        }
                    }
                }
                PassCounter::Bitmap => {
                    // lint:allow(unwrap-in-library): plan construction pairs
                    // PassCounter::Bitmap with materialized TID rows.
                    let rows = tid.as_ref().expect("bitmap pass implies TID rows");
                    let sets = trie.itemsets();
                    let mut counts = vec![0u64; sets.len()];
                    let words = width.div_ceil(64);
                    let mut word_ops = 0u64;
                    let mut cand_rows: Vec<&BitVec64> = Vec::new();
                    // Cache-blocked sweep: all candidates consume one block
                    // of TID words before the next block is touched.
                    let mut lo = 0usize;
                    while lo < words {
                        let hi = (lo + BITMAP_WORDS_PER_BLOCK).min(words);
                        for (set, slot) in sets.iter().zip(&mut counts) {
                            cand_rows.clear();
                            cand_rows.extend(set.iter().map(|&i| &rows[i as usize]));
                            *slot += BitVec64::intersect_count_words(&cand_rows, lo, hi);
                            word_ops += ((hi - lo) * set.len()) as u64;
                        }
                        lo = hi;
                    }
                    ctx.counters.add(keys::BITMAP_WORD_OPS, word_ops);
                    for (set, count) in sets.into_iter().zip(counts) {
                        if count > 0 {
                            ctx.write_combined(set, count, count);
                        }
                    }
                }
                PassCounter::Triangular(counter) => {
                    for set in trie.itemsets() {
                        let count = counter.pair_count(set[0], set[1]);
                        if count > 0 {
                            ctx.write_combined(set, count, count);
                        }
                    }
                }
            }
        }

        // Driver side-channel, as in Algorithms 3–5.
        ctx.set_aux(keys::CANDIDATES, self.plan.candidate_count);
        ctx.set_aux(keys::NPASS, self.plan.npass as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l_of(k: usize, sets: &[&[u32]]) -> Arc<Trie> {
        let owned: Vec<Itemset> = sets.iter().map(|s| s.to_vec()).collect();
        Arc::new(Trie::from_itemsets(k, owned.iter()))
    }

    fn run_mapper(mapper: &mut Job2Mapper, txns: &[&[u32]]) -> Context<Itemset, u64> {
        let mut ctx = Context::new();
        for (i, t) in txns.iter().enumerate() {
            mapper.map(i, &t.to_vec(), &mut ctx);
        }
        mapper.cleanup(&mut ctx);
        ctx
    }

    #[test]
    fn spc_single_pass_counts() {
        // L1 = {1},{2},{3}; txns: [1,2,3], [1,2] -> C2 counts: 12:2, 13:1, 23:1
        let mut m = Job2Mapper::standalone(
            l_of(1, &[&[1], &[2], &[3]]),
            PassPolicy::Fixed(1),
            false,
            GenMode::PerRecord,
        );
        let mut ctx = run_mapper(&mut m, &[&[1, 2, 3], &[1, 2]]);
        let mut out = ctx.take_output();
        out.sort();
        assert_eq!(out, vec![(vec![1, 2], 2), (vec![1, 3], 1), (vec![2, 3], 1)]);
        assert_eq!(ctx.aux[keys::NPASS], 1);
        assert_eq!(ctx.aux[keys::CANDIDATES], 3);
    }

    #[test]
    fn multipass_counts_multiple_levels() {
        // Combine 2 passes: C2 from L1, C3 from C2.
        let mut m = Job2Mapper::standalone(
            l_of(1, &[&[1], &[2], &[3]]),
            PassPolicy::Fixed(2),
            false,
            GenMode::PerRecord,
        );
        let mut ctx = run_mapper(&mut m, &[&[1, 2, 3]]);
        let mut out = ctx.take_output();
        out.sort();
        assert_eq!(
            out,
            vec![
                (vec![1, 2], 1),
                (vec![1, 2, 3], 1),
                (vec![1, 3], 1),
                (vec![2, 3], 1)
            ]
        );
        assert_eq!(ctx.aux[keys::CANDIDATES], 4); // 3 pairs + 1 triple
        assert_eq!(ctx.aux[keys::NPASS], 2);
    }

    #[test]
    fn fixed_policy_stops_on_empty_level() {
        // L1 = {1},{2}: C2={12}, C3 from C2 empty -> npass stops at 2 even
        // though Fixed(5) asked for more.
        let mut m = Job2Mapper::standalone(
            l_of(1, &[&[1], &[2]]),
            PassPolicy::Fixed(5),
            false,
            GenMode::PerRecord,
        );
        let ctx = run_mapper(&mut m, &[&[1, 2]]);
        assert_eq!(ctx.aux[keys::NPASS], 1);
        assert_eq!(ctx.aux[keys::CANDIDATES], 1);
    }

    #[test]
    fn dynamic_policy_do_while_semantics() {
        // L1 = 4 items -> C2 has 6, C3 has 4, C4 has 1.
        let l1 = l_of(1, &[&[1], &[2], &[3], &[4]]);
        // ct = 5: first pass (6 cands) exceeds ct AFTER being counted -> stop: npass=1.
        let mut m = Job2Mapper::standalone(
            Arc::clone(&l1),
            PassPolicy::Dynamic { ct: 5 },
            false,
            GenMode::PerRecord,
        );
        let ctx = run_mapper(&mut m, &[&[1, 2, 3, 4]]);
        assert_eq!(ctx.aux[keys::NPASS], 1);
        // ct = 6: 6 <= 6 -> second pass runs (6+4=10 > 6) -> npass=2.
        let mut m = Job2Mapper::standalone(
            Arc::clone(&l1),
            PassPolicy::Dynamic { ct: 6 },
            false,
            GenMode::PerRecord,
        );
        let ctx = run_mapper(&mut m, &[&[1, 2, 3, 4]]);
        assert_eq!(ctx.aux[keys::NPASS], 2);
        assert_eq!(ctx.aux[keys::CANDIDATES], 10);
        // Huge ct: runs to exhaustion (passes 2,3,4 -> 6+4+1 = 11).
        let mut m =
            Job2Mapper::standalone(l1, PassPolicy::Dynamic { ct: 1000 }, false, GenMode::PerRecord);
        let ctx = run_mapper(&mut m, &[&[1, 2, 3, 4]]);
        assert_eq!(ctx.aux[keys::NPASS], 3);
        assert_eq!(ctx.aux[keys::CANDIDATES], 11);
    }

    #[test]
    fn optimized_produces_superset_candidates_same_frequents() {
        // From the paper's Fig. 1 argument: optimized phases generate
        // un-pruned extras, but counting them changes nothing after the
        // min-support filter. Here L2 over items {1..4} missing {2,4}:
        let l2 = l_of(2, &[&[1, 2], &[1, 3], &[1, 4], &[2, 3], &[3, 4]]);
        let txns: &[&[u32]] = &[&[1, 2, 3], &[1, 3, 4], &[1, 2, 3, 4]];

        let mut plain = Job2Mapper::standalone(
            Arc::clone(&l2),
            PassPolicy::Fixed(2),
            false,
            GenMode::PerRecord,
        );
        let mut ctx_p = run_mapper(&mut plain, txns);
        let mut opt = Job2Mapper::standalone(l2, PassPolicy::Fixed(2), true, GenMode::PerRecord);
        let mut ctx_o = run_mapper(&mut opt, txns);

        assert!(ctx_o.aux[keys::CANDIDATES] >= ctx_p.aux[keys::CANDIDATES]);
        // Same counted supports on the shared candidates.
        let mut po = ctx_p.take_output();
        let mut oo = ctx_o.take_output();
        po.sort();
        oo.sort();
        for (set, count) in &po {
            let in_opt = oo.iter().find(|(s, _)| s == set);
            assert_eq!(in_opt.map(|(_, c)| *c), Some(*count), "set {set:?}");
        }
    }

    #[test]
    fn gen_mode_changes_charged_cost_not_output() {
        let l1 = l_of(1, &[&[1], &[2], &[3]]);
        let txns: &[&[u32]] = &[&[1, 2, 3], &[1, 2], &[2, 3]];
        let mut per_rec = Job2Mapper::standalone(
            Arc::clone(&l1),
            PassPolicy::Fixed(2),
            false,
            GenMode::PerRecord,
        );
        let mut per_task =
            Job2Mapper::standalone(l1, PassPolicy::Fixed(2), false, GenMode::PerTask);
        let mut ctx_r = run_mapper(&mut per_rec, txns);
        let mut ctx_t = run_mapper(&mut per_task, txns);
        assert_eq!(
            ctx_r.counters.get(keys::JOIN_PAIRS),
            3 * ctx_t.counters.get(keys::JOIN_PAIRS)
        );
        let mut a = ctx_r.take_output();
        let mut b = ctx_t.take_output();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn subset_visits_metered() {
        let mut m = Job2Mapper::standalone(
            l_of(1, &[&[1], &[2], &[3]]),
            PassPolicy::Fixed(1),
            false,
            GenMode::PerRecord,
        );
        let ctx = run_mapper(&mut m, &[&[1, 2, 3]]);
        assert!(ctx.counters.get(keys::SUBSET_VISITS) > 0);
    }

    #[test]
    fn one_itemset_mapper_emits_per_item() {
        let mut m = OneItemsetMapper;
        let mut ctx = Context::new();
        m.map(0, &vec![3, 5, 9], &mut ctx);
        let out = ctx.take_output();
        assert_eq!(out, vec![(vec![3], 1), (vec![5], 1), (vec![9], 1)]);
    }

    // ---- counting-backend strategy layer ----------------------------------

    fn test_ctx(n_items: usize) -> BackendContext {
        BackendContext {
            profile: DensityProfile::from_counts(100, n_items, 300),
            weights: CostWeights::default(),
        }
    }

    fn mapper_with_backend(
        l_prev: &Arc<Trie>,
        policy: PassPolicy,
        backend: CountingBackend,
        n_items: usize,
    ) -> Job2Mapper {
        let mut plan = PhasePlan::build(l_prev, policy, false);
        plan.resolve_backends(backend, &test_ctx(n_items));
        Job2Mapper::new(Arc::new(plan), GenMode::PerRecord, n_items)
    }

    #[test]
    fn backends_emit_identical_tuples_in_identical_order() {
        let l1 = l_of(1, &[&[1], &[2], &[3], &[5]]);
        let txns: &[&[u32]] = &[&[1, 2, 3, 5], &[1, 2], &[2, 3, 5], &[1], &[1, 2, 5]];
        let mut outputs = Vec::new();
        for backend in [CountingBackend::Trie, CountingBackend::Bitmap, CountingBackend::Triangular]
        {
            // Fixed(2): pass 1 is k=2 (triangular-eligible), pass 2 is k=3
            // (falls back to trie under the triangular request).
            let mut m = mapper_with_backend(&l1, PassPolicy::Fixed(2), backend, 6);
            let mut ctx = run_mapper(&mut m, txns);
            // UNsorted: emission order itself must match the trie walk.
            outputs.push((backend, ctx.take_output()));
        }
        let (_, trie_out) = &outputs[0];
        assert!(!trie_out.is_empty());
        for (backend, out) in &outputs[1..] {
            assert_eq!(out, trie_out, "{backend} output diverges from trie walk");
        }
    }

    #[test]
    fn triangular_resolves_to_trie_beyond_pairs() {
        let l1 = l_of(1, &[&[1], &[2], &[3]]);
        let mut plan = PhasePlan::build(&l1, PassPolicy::Fixed(2), false);
        plan.resolve_backends(CountingBackend::Triangular, &test_ctx(4));
        assert_eq!(plan.backends, vec![CountingBackend::Triangular, CountingBackend::Trie]);
        // Oversized universe: even the k=2 pass falls back.
        let mut plan = PhasePlan::build(&l1, PassPolicy::Fixed(1), false);
        let ctx = BackendContext {
            profile: DensityProfile::from_counts(100, TRIANGULAR_MAX_ITEMS + 1, 300),
            weights: CostWeights::default(),
        };
        plan.resolve_backends(CountingBackend::Triangular, &ctx);
        assert_eq!(plan.backends, vec![CountingBackend::Trie]);
    }

    #[test]
    fn auto_always_resolves_and_is_deterministic() {
        let l1 = l_of(1, &[&[1], &[2], &[3], &[4]]);
        let mut plan = PhasePlan::build(&l1, PassPolicy::Dynamic { ct: 1000 }, false);
        plan.resolve_backends(CountingBackend::Auto, &test_ctx(5));
        assert!(!plan.backends.is_empty());
        assert!(plan.backends.iter().all(|b| *b != CountingBackend::Auto));
        let mut again = PhasePlan::build(&l1, PassPolicy::Dynamic { ct: 1000 }, false);
        again.resolve_backends(CountingBackend::Auto, &test_ctx(5));
        assert_eq!(plan.backends, again.backends);
    }

    #[test]
    fn backend_cost_keys_metered() {
        let l1 = l_of(1, &[&[1], &[2], &[3]]);
        let txns: &[&[u32]] = &[&[1, 2, 3], &[1, 2]];
        let mut m = mapper_with_backend(&l1, PassPolicy::Fixed(1), CountingBackend::Bitmap, 4);
        let ctx = run_mapper(&mut m, txns);
        assert!(ctx.counters.get(keys::BITMAP_WORD_OPS) > 0);
        assert_eq!(ctx.counters.get(keys::SUBSET_VISITS), 0);
        let mut m = mapper_with_backend(&l1, PassPolicy::Fixed(1), CountingBackend::Triangular, 4);
        let ctx = run_mapper(&mut m, txns);
        assert!(ctx.counters.get(keys::TRIANGLE_UPDATES) > 0);
        assert_eq!(ctx.counters.get(keys::BITMAP_WORD_OPS), 0);
    }

    #[test]
    fn bitmap_counts_exact_across_word_boundaries() {
        // 70 records: TID rows span a word boundary (64) and a ragged tail.
        let l1 = l_of(1, &[&[0], &[1]]);
        let txns: Vec<Vec<u32>> =
            (0..70u32).map(|i| if i % 3 == 0 { vec![0, 1] } else { vec![0] }).collect();
        let refs: Vec<&[u32]> = txns.iter().map(|t| t.as_slice()).collect();
        let mut m = mapper_with_backend(&l1, PassPolicy::Fixed(1), CountingBackend::Bitmap, 2);
        let mut ctx = run_mapper(&mut m, &refs);
        // {0,1} appears in records 0, 3, 6, ..., 69 -> 24 of 70.
        assert_eq!(ctx.take_output(), vec![(vec![0, 1], 24)]);
    }

    #[test]
    fn auto_estimates_favor_bitmap_on_many_candidates() {
        // Dense candidate space: the bitmap's 64-way word parallelism beats
        // per-candidate trie visits. Tiny candidate count on a huge sparse
        // dataset: the build cost dominates and the trie walk stays.
        let ctx = test_ctx(100);
        let many = ctx.resolve(CountingBackend::Auto, 3, 50_000);
        assert_eq!(many, CountingBackend::Bitmap);
        // At k=2 on a small dense universe the triangle is cheaper still.
        assert_eq!(ctx.resolve(CountingBackend::Auto, 2, 50_000), CountingBackend::Triangular);
        let sparse = BackendContext {
            profile: DensityProfile::from_counts(1_000_000, 10_000, 2_000_000),
            weights: CostWeights::default(),
        };
        assert_eq!(sparse.resolve(CountingBackend::Auto, 5, 1), CountingBackend::Trie);
    }
}
