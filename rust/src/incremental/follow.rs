//! [`FollowSession`]: tail a growing segment store. Reopens the store per
//! refresh, detects growth via the manifest revision, and rebuilds the
//! [`MiningSession`] per revision — so the Job1 cache invalidates per
//! appended block, not per query — while every revision's session shares
//! ONE [`Executor`] (DESIGN.md §13).

use super::delta::DeltaMiner;
use super::WindowSpec;
use crate::cluster::ClusterConfig;
use crate::coordinator::{DeltaOutcome, MiningError, MiningRequest, MiningSession, RunOptions, SessionStats};
use crate::hdfs::segment::{self, SegmentError, SegmentSource};
use crate::hdfs::{self};
use crate::mapreduce::executor::Executor;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Errors from following a store: the store itself (missing directory,
/// bad manifest) or the mining layer (invalid request, empty revision).
#[derive(Debug)]
pub enum FollowError {
    /// Opening or reading the segment store failed.
    Store(SegmentError),
    /// Building the per-revision session or mining it failed.
    Mining(MiningError),
}

impl std::fmt::Display for FollowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FollowError::Store(e) => write!(f, "follow: {e}"),
            FollowError::Mining(e) => write!(f, "follow: {e}"),
        }
    }
}

impl std::error::Error for FollowError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FollowError::Store(e) => Some(e),
            FollowError::Mining(e) => Some(e),
        }
    }
}

impl From<SegmentError> for FollowError {
    fn from(e: SegmentError) -> Self {
        FollowError::Store(e)
    }
}

impl From<MiningError> for FollowError {
    fn from(e: MiningError) -> Self {
        FollowError::Mining(e)
    }
}

/// A warm mining session over a segment store that other writers keep
/// appending to. [`FollowSession::refresh`] answers "what changed since
/// the last batch" from the delta blocks alone whenever the
/// [`DeltaMiner`]'s state allows; [`FollowSession::refresh_window`] does
/// the same for a block-aligned sliding window.
///
/// Session lifecycle: a [`MiningSession`] binds an immutable file
/// snapshot, so each observed store revision gets a fresh session (and
/// with it a fresh Job1 cache — invalidated per appended block). All
/// revisions share one [`Executor`], and retired sessions' counters fold
/// into [`FollowSession::stats`].
pub struct FollowSession {
    dir: PathBuf,
    cluster: ClusterConfig,
    seed: u64,
    executor: Executor,
    session: MiningSession,
    grow: DeltaMiner,
    window: DeltaMiner,
    rev: usize,
    retired: SessionStats,
}

impl FollowSession {
    /// Open the store at `dir` and bind the first revision's session. The
    /// store must exist and hold at least one record (an empty dataset
    /// cannot seed a session; poll externally until the first batch
    /// lands).
    pub fn open(dir: impl Into<PathBuf>, cluster: ClusterConfig) -> Result<Self, FollowError> {
        let dir = dir.into();
        let src = segment::open(&dir)?;
        let rev = src.manifest_rev();
        let seed = RunOptions::default().seed;
        let file =
            hdfs::put_segmented(Arc::new(src), cluster.nodes.len(), hdfs::DEFAULT_REPLICATION, seed);
        let session = MiningSession::builder(file, cluster.clone()).build()?;
        let executor = session.executor().clone();
        Ok(FollowSession {
            dir,
            cluster,
            seed,
            executor,
            session,
            grow: DeltaMiner::new(),
            window: DeltaMiner::new(),
            rev,
            retired: SessionStats::default(),
        })
    }

    /// The store directory being followed.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The manifest revision (record count) of the bound session.
    pub fn rev(&self) -> usize {
        self.rev
    }

    /// The session bound to the last observed revision.
    pub fn session(&self) -> &MiningSession {
        &self.session
    }

    /// Session counters accumulated across every revision this follower
    /// has bound (retired revisions' totals plus the current session's).
    pub fn stats(&self) -> SessionStats {
        let mut total = self.retired;
        total.absorb(&self.session.stats());
        total
    }

    /// Reopen the store; rebind the session if the revision moved.
    fn sync(&mut self) -> Result<bool, FollowError> {
        let src = segment::open(&self.dir)?;
        if src.manifest_rev() == self.rev {
            return Ok(false);
        }
        self.rebind(src)?;
        Ok(true)
    }

    fn rebind(&mut self, src: SegmentSource) -> Result<(), FollowError> {
        let rev = src.manifest_rev();
        let file = hdfs::put_segmented(
            Arc::new(src),
            self.cluster.nodes.len(),
            hdfs::DEFAULT_REPLICATION,
            self.seed,
        );
        let session = MiningSession::builder(file, self.cluster.clone())
            .executor(self.executor.clone())
            .build()?;
        self.retired.absorb(&self.session.stats());
        self.session = session;
        self.rev = rev;
        Ok(())
    }

    /// Incremental refresh over the whole (growing) store: `Ok(None)`
    /// when the store has not grown since the last refresh, otherwise the
    /// delta outcome against the new revision. The first call always
    /// mines (the bootstrap full run that seeds the state).
    pub fn refresh(&mut self, req: &MiningRequest) -> Result<Option<DeltaOutcome>, FollowError> {
        let moved = self.sync()?;
        if !moved && self.grow.state().is_some() {
            return Ok(None);
        }
        let out = self.session.mine_incremental(req, &mut self.grow)?;
        Ok(Some(out))
    }

    /// Like [`FollowSession::refresh`] but always answers, even when the
    /// store has not moved — the unchanged case is a zero-block delta
    /// (nothing rescanned, chain rebuilt from the held counts). The serve
    /// layer's `REFRESH` verb uses this: the wire always needs a response.
    pub fn refresh_always(&mut self, req: &MiningRequest) -> Result<DeltaOutcome, FollowError> {
        self.sync()?;
        let out = self.session.mine_incremental(req, &mut self.grow)?;
        Ok(out)
    }

    /// Sliding-window refresh. Always mines (an unchanged window is a
    /// zero-block delta — cheap — and `spec`/`min_sup` may have changed
    /// since the last call), after rebinding to the latest revision.
    pub fn refresh_window(
        &mut self,
        req: &MiningRequest,
        spec: WindowSpec,
    ) -> Result<DeltaOutcome, FollowError> {
        self.sync()?;
        let out = self.session.mine_window(req, spec, &mut self.window)?;
        Ok(out)
    }
}

impl std::fmt::Debug for FollowSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FollowSession")
            .field("dir", &self.dir)
            .field("rev", &self.rev)
            .field("stats", &self.stats())
            .finish()
    }
}
