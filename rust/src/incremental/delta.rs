//! The [`DeltaMiner`]: FUP-style refresh over a grown store prefix and
//! block-aligned sliding windows, both answering from the
//! [`IncrementalState`] whenever the delta blocks alone suffice
//! (DESIGN.md §13).

use super::state::{
    apply_counts, blocks_touched, count_range, diff_frequent, mine_range, rebuild_chain,
    snapshot_tracked, Coverage,
};
use super::{IncrementalState, WindowSpec};
use crate::coordinator::{DeltaOutcome, MiningError, MiningRequest, MiningSession};
use std::ops::Range;

/// Owner of the [`IncrementalState`] across refreshes. Holds no session —
/// the caller passes each refresh's session explicitly, so the state can
/// follow a store across per-revision session rebuilds (the
/// [`FollowSession`](super::FollowSession) pattern).
#[derive(Debug, Default)]
pub struct DeltaMiner {
    state: Option<IncrementalState>,
}

impl DeltaMiner {
    /// A miner with no snapshot yet: the first refresh bootstraps with a
    /// full run.
    pub fn new() -> Self {
        DeltaMiner { state: None }
    }

    /// The current snapshot, if any refresh completed.
    pub fn state(&self) -> Option<&IncrementalState> {
        self.state.as_ref()
    }

    /// Drop the snapshot: the next refresh bootstraps from scratch.
    pub fn clear(&mut self) {
        self.state = None;
    }
}

/// Build the outcome for a refresh, diffing against the pre-refresh
/// frequent output and installing the new state into the miner.
#[allow(clippy::too_many_arguments)]
fn finish(
    miner: &mut DeltaMiner,
    session: &MiningSession,
    req: &MiningRequest,
    prior: Option<&IncrementalState>,
    next: IncrementalState,
    min_count: u64,
    delta: bool,
    blocks_rescanned: usize,
    total_blocks: usize,
) -> DeltaOutcome {
    let old = prior.map(|s| s.all_frequent()).unwrap_or_default();
    let new = next.all_frequent();
    let (added, removed, retained) = diff_frequent(&old, &new);
    let out = DeltaOutcome {
        algorithm: req.algorithm(),
        dataset: session.file().name.clone(),
        min_sup: next.min_sup,
        min_count,
        coverage: next.coverage.clone(),
        levels: next.frequent.clone(),
        added,
        removed,
        retained,
        delta,
        blocks_rescanned,
        total_blocks,
    };
    miner.state = Some(next);
    out
}

/// FUP-style incremental refresh over the session's (possibly grown)
/// store prefix; see [`MiningSession::mine_incremental`] for the contract.
pub(crate) fn mine_incremental(
    session: &MiningSession,
    req: &MiningRequest,
    miner: &mut DeltaMiner,
) -> Result<DeltaOutcome, MiningError> {
    req.validate()?;
    let file = session.file();
    let n = file.len();
    let block_lines = file.block_lines.max(1);
    let total_blocks = n.div_ceil(block_lines);
    let min_sup = req.min_sup_value();
    let min_count = file.min_count(min_sup);

    let prior = miner.state.take();
    if let Some(state) = &prior {
        if state.reusable(min_sup, file, Coverage::Grow) && state.coverage.start == 0 {
            let grown = state.coverage.end..n;
            let rescanned = blocks_touched(&grown, block_lines);
            let mut singles = state.singles.clone();
            let mut tracked = state.tracked.clone();
            let counts = count_range(file, grown, &tracked);
            let applied = apply_counts(&mut singles, &mut tracked, &counts, true);
            if applied {
                if let Some(levels) = rebuild_chain(&singles, &tracked, min_count) {
                    let next = IncrementalState {
                        min_sup,
                        n_items: file.n_items,
                        coverage: 0..n,
                        mode: Coverage::Grow,
                        singles,
                        tracked,
                        frequent: levels,
                    };
                    session.record_delta(rescanned as u64, false);
                    return Ok(finish(
                        miner,
                        session,
                        req,
                        prior.as_ref(),
                        next,
                        min_count,
                        true,
                        rescanned,
                        total_blocks,
                    ));
                }
            }
        }
    }

    // Full path: bootstrap (no prior state) or fallback (cascade, changed
    // min_sup, changed universe) — the ordinary algorithm-aware session
    // run, then one streaming pass to recount the candidate borders.
    let out = session.run(req)?;
    let (singles, tracked) = snapshot_tracked(file, 0..n, &out.levels);
    let next = IncrementalState {
        min_sup,
        n_items: file.n_items,
        coverage: 0..n,
        mode: Coverage::Grow,
        singles,
        tracked,
        frequent: out.levels,
    };
    session.record_delta(total_blocks as u64, prior.is_some());
    Ok(finish(
        miner,
        session,
        req,
        prior.as_ref(),
        next,
        min_count,
        false,
        total_blocks,
        total_blocks,
    ))
}

/// The current block-aligned window over an `n`-record store: the last
/// `spec.blocks` blocks ending at the greatest `spec.step` multiple the
/// store has filled.
fn window_range(n: usize, block_lines: usize, spec: &WindowSpec) -> Range<usize> {
    let n_blocks = n.div_ceil(block_lines);
    let end_block = (n_blocks / spec.step) * spec.step;
    let start_block = end_block.saturating_sub(spec.blocks);
    let start = start_block * block_lines;
    let end = (end_block * block_lines).min(n);
    start..end.max(start)
}

/// Sliding-window refresh; see [`MiningSession::mine_window`] for the
/// contract.
pub(crate) fn mine_window(
    session: &MiningSession,
    req: &MiningRequest,
    spec: WindowSpec,
    miner: &mut DeltaMiner,
) -> Result<DeltaOutcome, MiningError> {
    req.validate()?;
    spec.validate()?;
    if session.is_db_backed() {
        return Err(MiningError::InvalidWindow(
            "windowed mining needs a store-backed session (build over a segment store, \
             not for_db)",
        ));
    }
    let file = session.file();
    let n = file.len();
    let block_lines = file.block_lines.max(1);
    let total_blocks = n.div_ceil(block_lines);
    let min_sup = req.min_sup_value();
    let window = window_range(n, block_lines, &spec);
    // min_count over the window's own record count — exactly what a cold
    // session over those records would use (HdfsFile::min_count formula).
    let min_count = ((min_sup * window.len() as f64).ceil() as u64).max(1);

    let prior = miner.state.take();
    if let Some(state) = &prior {
        let old = state.coverage.clone();
        // The delta identity counts(c..d) = counts(a..b) − counts(a..c)
        // + counts(b..d) needs a ≤ c ≤ b ≤ d.
        if state.reusable(min_sup, file, Coverage::Window)
            && old.start <= window.start
            && window.start <= old.end
            && old.end <= window.end
        {
            let expired = old.start..window.start;
            let arrived = old.end..window.end;
            let rescanned =
                blocks_touched(&expired, block_lines) + blocks_touched(&arrived, block_lines);
            let mut singles = state.singles.clone();
            let mut tracked = state.tracked.clone();
            let sub = count_range(file, expired, &tracked);
            let add = count_range(file, arrived, &tracked);
            let applied = apply_counts(&mut singles, &mut tracked, &sub, false)
                && apply_counts(&mut singles, &mut tracked, &add, true);
            if applied {
                if let Some(levels) = rebuild_chain(&singles, &tracked, min_count) {
                    let next = IncrementalState {
                        min_sup,
                        n_items: file.n_items,
                        coverage: window,
                        mode: Coverage::Window,
                        singles,
                        tracked,
                        frequent: levels,
                    };
                    session.record_delta(rescanned as u64, false);
                    return Ok(finish(
                        miner,
                        session,
                        req,
                        prior.as_ref(),
                        next,
                        min_count,
                        true,
                        rescanned,
                        total_blocks,
                    ));
                }
            }
        }
    }

    // Cold window (bootstrap or fallback): the canonical sequential chain
    // over the window records only.
    let rescanned = blocks_touched(&window, block_lines);
    let (levels, singles, tracked) = mine_range(file, window.clone(), min_count);
    let next = IncrementalState {
        min_sup,
        n_items: file.n_items,
        coverage: window,
        mode: Coverage::Window,
        singles,
        tracked,
        frequent: levels,
    };
    session.record_delta(rescanned as u64, prior.is_some());
    Ok(finish(
        miner,
        session,
        req,
        prior.as_ref(),
        next,
        min_count,
        false,
        rescanned,
        total_blocks,
    ))
}
