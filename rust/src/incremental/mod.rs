//! Incremental and windowed mining over growing segment stores
//! (DESIGN.md §13).
//!
//! A [`DeltaMiner`] snapshots a completed run's per-k frequent itemsets
//! *with counts* — plus the negative border (candidates that were
//! generated but fell short of `min_count`) — into an
//! [`IncrementalState`]. When new records are appended to the backing
//! store, [`MiningSession::mine_incremental`] rescans **only the delta
//! records** FUP-style: exact counts for every tracked set are updated
//! from the new data alone, border sets whose counts now clear the
//! (grown) `min_count` are promoted, frequent sets that fall below it are
//! demoted, and the per-k chain is rebuilt from the updated counts. Only
//! when a promotion *cascades* — a newly frequent set spawns candidates
//! the state never counted — does the miner fall back to a bounded full
//! re-run through the ordinary session path.
//!
//! [`MiningSession::mine_window`] layers block-aligned sliding windows
//! ([`WindowSpec`]) on the same state machinery: a slid window subtracts
//! the expiring blocks' counts and adds the arriving ones, touching only
//! the blocks that entered or left.
//!
//! Correctness contract: for every algorithm, the incremental / windowed
//! frequent-itemset output is byte-identical to a cold full run over the
//! same effective record range — pinned by `tests/incremental_mining.rs`.
//!
//! [`FollowSession`] packages the polling loop the `mine --follow` CLI
//! verb and the serve daemon's `REFRESH` verb share: reopen the store,
//! detect growth via [`manifest_rev`](crate::hdfs::segment::SegmentSource::manifest_rev),
//! rebuild the session per revision (so the Job1 cache invalidates per
//! appended block, not per query) while every revision's session shares
//! one [`Executor`](crate::mapreduce::executor::Executor).
//!
//! [`MiningSession::mine_incremental`]: crate::coordinator::MiningSession::mine_incremental
//! [`MiningSession::mine_window`]: crate::coordinator::MiningSession::mine_window

pub mod delta;
pub mod follow;
pub mod state;

pub use delta::DeltaMiner;
pub use follow::{FollowError, FollowSession};
pub use state::IncrementalState;

use crate::coordinator::MiningError;

/// A block-aligned sliding window: the mining coverage is the last
/// `blocks` store blocks, advancing in strides of `step` blocks (the
/// window's trailing edge stays aligned to a `step` multiple, so every
/// refresh sees either the same window or one slid by whole steps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowSpec {
    /// Window width, in store blocks (>= 1).
    pub blocks: usize,
    /// Slide stride, in store blocks (>= 1, <= `blocks`).
    pub step: usize,
}

impl WindowSpec {
    /// A window of `blocks` blocks sliding one block at a time.
    pub fn new(blocks: usize) -> Self {
        WindowSpec { blocks, step: 1 }
    }

    /// Set the slide stride.
    pub fn step(mut self, step: usize) -> Self {
        self.step = step;
        self
    }

    /// Check the spec's domain: a window must span at least one block and
    /// slide by at least one but at most `blocks` (a stride beyond the
    /// width would skip records unseen by any window).
    pub fn validate(&self) -> Result<(), MiningError> {
        if self.blocks == 0 {
            return Err(MiningError::InvalidWindow("window must span at least one block"));
        }
        if self.step == 0 {
            return Err(MiningError::InvalidWindow("window step must be at least one block"));
        }
        if self.step > self.blocks {
            return Err(MiningError::InvalidWindow(
                "window step must not exceed the window width",
            ));
        }
        Ok(())
    }
}
