//! The [`IncrementalState`] snapshot and the counting passes that keep it
//! exact: one scan over the delta (or expiring/arriving) records updates
//! every tracked itemset's count, and the per-k chain is rebuilt from the
//! updated counts alone (DESIGN.md §13).

use crate::apriori::gen::apriori_gen;
use crate::apriori::sequential::Level;
use crate::hdfs::HdfsFile;
use crate::itemset::{Itemset, Trie};
use std::ops::Range;

/// What the state's record coverage means.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Coverage {
    /// The counts describe the store prefix `0..coverage.end` — the
    /// grow-only (`mine_incremental`) mode.
    Grow,
    /// The counts describe exactly the `coverage` record range — the
    /// sliding-window (`mine_window`) mode.
    Window,
}

/// A completed run's mining state, snapshotted with enough counted
/// context to absorb appended records without a full re-run:
///
/// * the exact count of **every single item** (so `L_1` and its border
///   are always reconstructible),
/// * for each `k >= 2`, every candidate `apriori_gen` produced from the
///   frequent `L_{k-1}` — the frequent sets *and* the negative border —
///   with exact counts,
/// * the frequent output itself, kept for added/removed delta reporting.
///
/// Counts are exact over [`Self::coverage`]; a refresh that cannot reuse
/// them (different `min_sup`, different item universe, a shrunk store, or
/// a promotion cascade) is answered by a full re-run that replaces the
/// state wholesale.
#[derive(Debug, Clone)]
pub struct IncrementalState {
    /// Fractional minimum support the state was built for (a changed
    /// `min_sup` re-thresholds the border unpredictably, forcing a full
    /// fallback).
    pub min_sup: f64,
    /// Item-universe size the `singles` table spans.
    pub n_items: usize,
    /// Record range of the backing file the counts describe.
    pub coverage: Range<usize>,
    /// Whether `coverage` is a store prefix (grow) or a window.
    pub mode: Coverage,
    /// Exact count of every item `0..n_items` over `coverage`.
    pub singles: Vec<u64>,
    /// `tracked[k - 2]`: every size-`k` candidate generated from the
    /// frequent `L_{k-1}`, with exact counts — sorted by itemset, the
    /// frequent sets and the negative border together.
    pub tracked: Vec<Level>,
    /// The frequent levels (`frequent[k - 1]` = `L_k`) as of the last
    /// refresh, for added/removed reporting.
    pub frequent: Vec<Level>,
}

impl IncrementalState {
    /// Whether this state's counts can seed a delta pass for a refresh at
    /// `min_sup` over `file` in `mode`. Float support compares by bit
    /// pattern — any rounding difference re-thresholds the border, and a
    /// spurious fallback is merely slow, never wrong.
    pub(crate) fn reusable(&self, min_sup: f64, file: &HdfsFile, mode: Coverage) -> bool {
        self.mode == mode
            && self.min_sup.to_bits() == min_sup.to_bits()
            && self.n_items == file.n_items
            && self.coverage.end <= file.len()
    }

    /// Flatten the frequent levels into one sorted `(itemset, count)`
    /// list — the comparison key for added/removed reporting.
    pub(crate) fn all_frequent(&self) -> Vec<(Itemset, u64)> {
        flatten(&self.frequent)
    }
}

/// Flatten `levels` into one sorted `(itemset, count)` list (the same
/// shape [`crate::coordinator::MiningOutcome::all_frequent`] returns).
pub(crate) fn flatten(levels: &[Level]) -> Vec<(Itemset, u64)> {
    let mut out: Vec<(Itemset, u64)> = levels.iter().flat_map(|l| l.iter().cloned()).collect();
    out.sort();
    out
}

/// Number of store blocks a record range touches.
pub(crate) fn blocks_touched(range: &Range<usize>, block_lines: usize) -> usize {
    if range.is_empty() {
        0
    } else {
        (range.end - 1) / block_lines - range.start / block_lines + 1
    }
}

/// One scan's worth of counts over a record range: the singles table plus
/// one scratch trie per tracked level.
pub(crate) struct RangeCounts {
    /// Per-item counts over the scanned range.
    pub singles: Vec<u64>,
    /// `tries[i]` holds the size-`i + 2` tracked sets with their counts
    /// over the scanned range.
    pub tries: Vec<Trie>,
    /// An item at or beyond `n_items` appeared — the universe grew under
    /// the state, so its counts cannot be trusted for this range.
    pub overflow: bool,
}

/// Count every tracked itemset (and all singles) over `range` of `file`
/// in ONE streaming pass — the delta scan. Memory is the scratch tries;
/// the store decodes one block at a time.
pub(crate) fn count_range(file: &HdfsFile, range: Range<usize>, tracked: &[Level]) -> RangeCounts {
    let n_items = file.n_items;
    let mut singles = vec![0u64; n_items];
    let mut tries: Vec<Trie> = tracked
        .iter()
        .enumerate()
        .map(|(i, lvl)| Trie::from_itemsets(i + 2, lvl.iter().map(|(s, _)| s)))
        .collect();
    let mut overflow = false;
    file.source.for_each(range, &mut |_, txn| {
        for &item in txn {
            match singles.get_mut(item as usize) {
                Some(slot) => *slot += 1,
                None => overflow = true,
            }
        }
        for t in &mut tries {
            t.count_transaction(txn);
        }
    });
    RangeCounts { singles, tries, overflow }
}

/// Merge one range's counts into the state's tables: `add` for arriving
/// records, subtract for expiring ones. Returns `false` (fall back) on
/// overflow or on a subtraction underflow — both mean the state and the
/// store disagree about history, and a full re-run is the safe answer.
pub(crate) fn apply_counts(
    singles: &mut [u64],
    tracked: &mut [Level],
    counts: &RangeCounts,
    add: bool,
) -> bool {
    if counts.overflow || singles.len() != counts.singles.len() {
        return false;
    }
    for (slot, delta) in singles.iter_mut().zip(&counts.singles) {
        match if add { slot.checked_add(*delta) } else { slot.checked_sub(*delta) } {
            Some(v) => *slot = v,
            None => return false,
        }
    }
    for (lvl, trie) in tracked.iter_mut().zip(&counts.tries) {
        for (set, count) in lvl.iter_mut() {
            let delta = trie.count_of(set).unwrap_or(0);
            match if add { count.checked_add(delta) } else { count.checked_sub(delta) } {
                Some(v) => *count = v,
                None => return false,
            }
        }
    }
    true
}

/// Rebuild the frequent chain from the state's updated counts, without
/// touching the data: `L_1` from the singles table, then for each `k` the
/// candidates `apriori_gen(L_{k-1})` looked up in the tracked counts.
///
/// Returns `None` on a **promotion cascade**: a candidate generated from
/// the new `L_{k-1}` that the state never counted (a promoted border set
/// opened a join the previous run never formed). Exact counts for such a
/// set would need a rescan of the whole coverage, so the caller falls
/// back to a full run.
pub(crate) fn rebuild_chain(
    singles: &[u64],
    tracked: &[Level],
    min_count: u64,
) -> Option<Vec<Level>> {
    let mut levels: Vec<Level> = Vec::new();
    let l1: Level = singles
        .iter()
        .enumerate()
        .filter(|(_, &c)| c >= min_count)
        .map(|(i, &c)| (vec![i as u32], c))
        .collect();
    if l1.is_empty() {
        return Some(levels);
    }
    levels.push(l1);
    loop {
        let k = levels.len() + 1;
        let prev = levels.last()?;
        let prev_trie = Trie::from_itemsets(k - 1, prev.iter().map(|(s, _)| s));
        let (cand, _) = apriori_gen(&prev_trie);
        if cand.is_empty() {
            break;
        }
        let Some(track) = tracked.get(k - 2) else {
            return None; // cascade: the chain outgrew the tracked depth
        };
        let mut lk: Level = Vec::new();
        for set in cand.itemsets() {
            let pos = track.binary_search_by(|(s, _)| s.as_slice().cmp(set.as_slice())).ok()?;
            let c = track[pos].1;
            if c >= min_count {
                lk.push((set, c));
            }
        }
        if lk.is_empty() {
            break;
        }
        lk.sort();
        levels.push(lk);
    }
    Some(levels)
}

/// Build the tracked candidate levels for `levels` (each level's
/// `apriori_gen` closure over its predecessor) and count them — plus all
/// singles — over `range` in one streaming pass. Shared by the full-run
/// snapshot and the windowed cold path.
pub(crate) fn snapshot_tracked(
    file: &HdfsFile,
    range: Range<usize>,
    levels: &[Level],
) -> (Vec<u64>, Vec<Level>) {
    let mut cand_tries: Vec<Trie> = Vec::new();
    for (i, lvl) in levels.iter().enumerate() {
        let prev_trie = Trie::from_itemsets(i + 1, lvl.iter().map(|(s, _)| s));
        let (cand, _) = apriori_gen(&prev_trie);
        if cand.is_empty() {
            break;
        }
        cand_tries.push(cand);
    }
    let mut singles = vec![0u64; file.n_items];
    file.source.for_each(range, &mut |_, txn| {
        for &item in txn {
            if let Some(slot) = singles.get_mut(item as usize) {
                *slot += 1;
            }
        }
        for t in &mut cand_tries {
            t.count_transaction(txn);
        }
    });
    let tracked = cand_tries
        .iter()
        .map(|t| {
            let mut lvl = t.frequent(0);
            lvl.sort();
            lvl
        })
        .collect();
    (singles, tracked)
}

/// Mine `range` of `file` from scratch with the canonical sequential
/// chain, producing both the frequent levels and a fresh state snapshot's
/// tables — the windowed cold path (`min_count` is over the range's own
/// record count, mirroring [`HdfsFile::min_count`]'s formula).
pub(crate) fn mine_range(
    file: &HdfsFile,
    range: Range<usize>,
    min_count: u64,
) -> (Vec<Level>, Vec<u64>, Vec<Level>) {
    let mut singles = vec![0u64; file.n_items];
    file.source.for_each(range.clone(), &mut |_, txn| {
        for &item in txn {
            if let Some(slot) = singles.get_mut(item as usize) {
                *slot += 1;
            }
        }
    });
    let mut levels: Vec<Level> = Vec::new();
    let mut tracked: Vec<Level> = Vec::new();
    let l1: Level = singles
        .iter()
        .enumerate()
        .filter(|(_, &c)| c >= min_count)
        .map(|(i, &c)| (vec![i as u32], c))
        .collect();
    if l1.is_empty() {
        return (levels, singles, tracked);
    }
    levels.push(l1);
    loop {
        let k = levels.len() + 1;
        let Some(prev) = levels.last() else { break };
        let prev_trie = Trie::from_itemsets(k - 1, prev.iter().map(|(s, _)| s));
        let (mut cand, _) = apriori_gen(&prev_trie);
        if cand.is_empty() {
            break;
        }
        file.source.for_each(range.clone(), &mut |_, txn| {
            cand.count_transaction(txn);
        });
        let mut all = cand.frequent(0);
        all.sort();
        let mut lk: Level = all.iter().filter(|(_, c)| *c >= min_count).cloned().collect();
        tracked.push(all);
        if lk.is_empty() {
            break;
        }
        lk.sort();
        levels.push(lk);
    }
    (levels, singles, tracked)
}

/// Compare two frequent outputs: `(added, removed, retained)` where added
/// carries the new counts and removed lists the itemsets that fell out.
pub(crate) fn diff_frequent(
    old: &[(Itemset, u64)],
    new: &[(Itemset, u64)],
) -> (Vec<(Itemset, u64)>, Vec<Itemset>, usize) {
    let mut added = Vec::new();
    let mut removed = Vec::new();
    let mut retained = 0usize;
    let (mut i, mut j) = (0usize, 0usize);
    while i < old.len() || j < new.len() {
        match (old.get(i), new.get(j)) {
            (Some((os, _)), Some((ns, nc))) => match os.cmp(ns) {
                std::cmp::Ordering::Less => {
                    removed.push(os.clone());
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    added.push((ns.clone(), *nc));
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    retained += 1;
                    i += 1;
                    j += 1;
                }
            },
            (Some((os, _)), None) => {
                removed.push(os.clone());
                i += 1;
            }
            (None, Some((ns, nc))) => {
                added.push((ns.clone(), *nc));
                j += 1;
            }
            (None, None) => break,
        }
    }
    (added, removed, retained)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_touched_counts_block_spans() {
        assert_eq!(blocks_touched(&(0..0), 10), 0);
        assert_eq!(blocks_touched(&(0..10), 10), 1);
        assert_eq!(blocks_touched(&(0..11), 10), 2);
        assert_eq!(blocks_touched(&(9..11), 10), 2);
        assert_eq!(blocks_touched(&(10..20), 10), 1);
        assert_eq!(blocks_touched(&(25..26), 10), 1);
    }

    #[test]
    fn diff_frequent_reports_symmetric_difference() {
        let old = vec![(vec![1u32], 5u64), (vec![2], 4), (vec![1, 2], 3)];
        let new = vec![(vec![1u32], 6u64), (vec![3], 4)];
        let (added, removed, retained) = diff_frequent(&old, &new);
        assert_eq!(added, vec![(vec![3u32], 4u64)]);
        assert_eq!(removed, vec![vec![2u32], vec![1, 2]]);
        assert_eq!(retained, 1);
    }

    #[test]
    fn rebuild_chain_promotes_and_demotes_from_counts() {
        // Singles: items 0,1,2 with counts 6,5,2. Tracked pairs with
        // counts. min_count 4: L1 = {0},{1}; C2 = {0,1} tracked at 4 →
        // frequent. min_count 5: {0,1} (count 4) demotes, chain stops.
        let singles = vec![6u64, 5, 2];
        let tracked = vec![vec![(vec![0u32, 1], 4u64), (vec![0, 2], 2), (vec![1, 2], 1)]];
        let levels = rebuild_chain(&singles, &tracked, 4).expect("no cascade");
        assert_eq!(levels.len(), 2);
        assert_eq!(levels[1], vec![(vec![0u32, 1], 4u64)]);
        let levels = rebuild_chain(&singles, &tracked, 5).expect("no cascade");
        assert_eq!(levels.len(), 1);
        assert_eq!(levels[0], vec![(vec![0u32], 6u64), (vec![1], 5)]);
    }

    #[test]
    fn rebuild_chain_detects_promotion_cascade() {
        // min_count 2 promotes item 2 into L1, so C2 holds {0,2},{1,2} —
        // {1,2} was never tracked below, so the chain must refuse.
        let singles = vec![6u64, 5, 2];
        let tracked = vec![vec![(vec![0u32, 1], 4u64), (vec![0, 2], 2)]];
        assert!(rebuild_chain(&singles, &tracked, 2).is_none());
    }

    #[test]
    fn rebuild_chain_refuses_untracked_depth() {
        // Chain wants C2 but nothing of size 2 was ever tracked.
        let singles = vec![6u64, 5];
        assert!(rebuild_chain(&singles, &[], 4).is_none());
    }
}
