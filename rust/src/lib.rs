//! # mrapriori
//!
//! Reproduction of *"Performance Optimization of MapReduce-based Apriori
//! Algorithm on Hadoop Cluster"* (Singh, Garg & Mishra, Computers &
//! Electrical Engineering 67, 2018): the SPC/FPC/DPC baselines and the
//! paper's VFPC, ETDPC, Optimized-VFPC and Optimized-ETDPC frequent-itemset
//! miners, running on a from-scratch MapReduce framework over a simulated
//! multi-node Hadoop-like cluster, with an AOT-compiled XLA (PJRT) support-
//! counting backend authored in JAX/Pallas.
//!
//! Layer map (see DESIGN.md at the repository root):
//! * L3 (this crate): drivers + MapReduce engine + cluster simulator.
//! * L2/L1 (python/compile): JAX counting graph + Pallas kernel, AOT-lowered
//!   to `artifacts/*.hlo.txt`, loaded at runtime by [`runtime`].
//!
//! The engine runs map AND reduce tasks on one shared executor-owned
//! worker pool with a map-side partitioned shuffle; outputs are
//! deterministic regardless of the worker count, and N concurrent queries
//! stay within ONE host-thread budget (DESIGN.md §4, §9). Storage is
//! pluggable behind
//! [`hdfs::RecordSource`]: datasets either live in memory or stream from
//! an on-disk segment store with per-block decoding, which is how the
//! Quest-family T*I*D* entries (up to millions of transactions) are mined
//! out-of-core (DESIGN.md §7).
//!
//! Quick start — bind a dataset + cluster to a [`coordinator::MiningSession`]
//! once, then serve any number of queries (Job1 and the split plan are
//! shared across them; see DESIGN.md §8):
//! ```no_run
//! use mrapriori::cluster::ClusterConfig;
//! use mrapriori::coordinator::{Algorithm, MiningRequest, MiningSession};
//! use mrapriori::dataset::registry;
//!
//! let db = registry::load("mushroom");
//! let session = MiningSession::for_db(&db, ClusterConfig::paper_cluster())
//!     .build()
//!     .expect("valid session");
//! let outcome = session
//!     .run(&MiningRequest::new(Algorithm::OptimizedVfpc).min_sup(0.15))
//!     .expect("valid request");
//! println!("{} frequent itemsets in {:.0} simulated s",
//!          outcome.total_frequent(), outcome.actual_time);
//! ```

#![warn(missing_docs)]
// `clippy::suspicious` (plus the always-deny `correctness`) is ENFORCED
// crate-wide: the gate started module-scoped on coordinator/mapreduce and
// was promoted once the rest of the tree came clean. A crate-level
// attribute keeps it traveling with the code rather than living in CI
// incantations; pallas-lint covers the invariants clippy cannot see
// (DESIGN.md §10).
#![deny(clippy::suspicious)]

pub mod apriori;
pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod hdfs;
pub mod incremental;
pub mod itemset;
pub mod mapreduce;
pub mod runtime;
pub mod serve;
pub mod util;
