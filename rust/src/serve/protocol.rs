//! Wire protocol of the serve daemon: request parsing, canonical query
//! keys, and response formatting (grammar in DESIGN.md §12).
//!
//! Requests are single lines, `VERB [key=value]*`, verbs case-insensitive.
//! Responses are tab-separated: a one-line header (`OK\t...` or
//! `ERR\t...`), then for multi-line replies a body terminated by a lone
//! `.` line — so a client needs nothing beyond "read lines until `.`".
//!
//! Formatting is centralized here on purpose: [`format_body`] is the ONE
//! producer of a mined response body, used both by the daemon and by the
//! integration tests' in-process oracle, which is what makes the
//! byte-identity contract ("wire output equals
//! [`MiningOutcome::all_frequent`]") checkable rather than aspirational.

use super::ServeError;
use crate::coordinator::{
    Algorithm, CountingBackend, DeltaOutcome, MiningOutcome, MiningRequest, RunOptions,
};
use crate::dataset::registry;

/// One parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// `MINE key=value...` — run (or coalesce into, or answer from cache)
    /// a mining query.
    Mine(MineParams),
    /// `REFRESH key=value...` — incremental/windowed refresh over a
    /// growing segment store the daemon follows (DESIGN.md §13).
    Refresh(RefreshParams),
    /// `STATS` — snapshot the daemon's counters.
    Stats,
    /// `PING` — liveness probe, answered inline with `OK PONG`.
    Ping,
    /// `SHUTDOWN` — drain every admitted query, then exit.
    Shutdown,
}

/// Raw tunables of a `MINE` line, before defaults are resolved. Absent
/// keys stay `None` so resolution can consult the dataset registry
/// (reference min_sup, paper α) exactly like the CLI does.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MineParams {
    /// `dataset=` — required.
    pub dataset: String,
    /// `algo=` — required; any spelling [`Algorithm::parse`] accepts.
    pub algorithm: Option<Algorithm>,
    /// `min_sup=` — fractional support in `(0, 1]`.
    pub min_sup: Option<f64>,
    /// `fpc_n=` — FPC's fixed pass count.
    pub fpc_n: Option<usize>,
    /// `dpc_alpha=` — DPC's fast-phase α.
    pub dpc_alpha: Option<f64>,
    /// `dpc_beta=` — DPC's β threshold (seconds).
    pub dpc_beta: Option<f64>,
    /// `fuse12=` — fuse passes 1+2 into one triangular-counted job.
    pub fuse12: Option<bool>,
    /// `backend=` — Job2 counting backend.
    pub backend: Option<CountingBackend>,
    /// `id=` — opaque client tag, echoed in the response header and in
    /// errors; NOT part of the coalescing/cache key.
    pub id: Option<String>,
}

/// Raw tunables of a `REFRESH` line. The daemon keeps one follow session
/// per `store` path, so consecutive `REFRESH` lines for the same store
/// answer from the delta blocks alone whenever the snapshot allows
/// (DESIGN.md §13). Refresh responses are never cached or coalesced —
/// their whole point is observing the store's current revision.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RefreshParams {
    /// `store=` — required; filesystem path of the segment store to
    /// follow (the daemon serves stores, not registry names, here: a
    /// growing store is a local artifact some producer appends to).
    pub store: String,
    /// `algo=` — algorithm for bootstrap/fallback runs (the delta path is
    /// algorithm-free). Defaults to Optimized-VFPC.
    pub algorithm: Option<Algorithm>,
    /// `min_sup=` — fractional support; defaults like `MINE` (registry
    /// reference for the store's dataset name, else 0.25).
    pub min_sup: Option<f64>,
    /// `window=` — mine only the last N store blocks (sliding window).
    pub window: Option<usize>,
    /// `step=` — window slide granularity in blocks (needs `window=`).
    pub step: Option<usize>,
    /// `id=` — opaque client tag, echoed in the response header.
    pub id: Option<String>,
}

impl RefreshParams {
    /// Parse the `key=value` tokens of a `REFRESH` line; same strictness
    /// as [`MineParams`]: known keys, no duplicates, in-domain values.
    fn parse_tokens<'a>(tokens: impl Iterator<Item = &'a str>) -> Result<RefreshParams, ServeError> {
        fn dup<T>(slot: &Option<T>, key: &str) -> Result<(), ServeError> {
            if slot.is_some() {
                return Err(ServeError::Protocol(format!("duplicate key {key:?}")));
            }
            Ok(())
        }
        fn bad(key: &str, value: &str, what: &str) -> ServeError {
            ServeError::Protocol(format!("key {key:?}: {value:?} is not {what}"))
        }
        let mut p = RefreshParams::default();
        for token in tokens {
            let (key, value) = token.split_once('=').ok_or_else(|| {
                ServeError::Protocol(format!("expected key=value, got {token:?}"))
            })?;
            match key {
                "store" => {
                    if !p.store.is_empty() {
                        return Err(ServeError::Protocol("duplicate key \"store\"".into()));
                    }
                    p.store = value.to_string();
                }
                "algo" => {
                    dup(&p.algorithm, key)?;
                    p.algorithm = Some(
                        Algorithm::parse(value).ok_or_else(|| bad(key, value, "an algorithm"))?,
                    );
                }
                "min_sup" => {
                    dup(&p.min_sup, key)?;
                    p.min_sup =
                        Some(value.parse::<f64>().map_err(|_| bad(key, value, "a number"))?);
                }
                "window" => {
                    dup(&p.window, key)?;
                    p.window =
                        Some(value.parse::<usize>().map_err(|_| bad(key, value, "an integer"))?);
                }
                "step" => {
                    dup(&p.step, key)?;
                    p.step =
                        Some(value.parse::<usize>().map_err(|_| bad(key, value, "an integer"))?);
                }
                "id" => {
                    dup(&p.id, key)?;
                    p.id = Some(value.to_string());
                }
                _ => {
                    return Err(ServeError::Protocol(format!("unknown key {key:?}")));
                }
            }
        }
        if p.store.is_empty() {
            return Err(ServeError::Protocol("missing required key \"store\"".into()));
        }
        if p.step.is_some() && p.window.is_none() {
            return Err(ServeError::Protocol("key \"step\" needs \"window\"".into()));
        }
        Ok(p)
    }
}

/// A refresh response ready to write: header fields plus the change-list
/// body. Unlike [`MineResult`] this is never cached — every `REFRESH`
/// reflects the store revision it actually observed.
#[derive(Debug, Clone, PartialEq)]
pub struct RefreshResult {
    /// The store path the refresh ran against (as requested).
    pub store: String,
    /// Dataset name from the store manifest.
    pub dataset: String,
    /// Algorithm the refresh was issued for.
    pub algorithm: Algorithm,
    /// Fractional minimum support of the refresh.
    pub min_sup: f64,
    /// Absolute minimum support count over the covered range.
    pub min_count: u64,
    /// The store's manifest revision (record count) observed.
    pub rev: usize,
    /// Whether the delta path answered (vs a bootstrap/fallback full run).
    pub delta: bool,
    /// Store blocks this refresh rescanned.
    pub blocks_rescanned: usize,
    /// Store blocks in total at the observed revision.
    pub total_blocks: usize,
    /// Total frequent itemsets at this revision.
    pub itemsets: usize,
    /// Newly frequent itemsets (= `+` body lines).
    pub added: usize,
    /// No-longer-frequent itemsets (= `-` body lines).
    pub removed: usize,
    /// Itemsets frequent both before and after.
    pub retained: usize,
    /// The body: change lines, then `.` — [`format_refresh_body`].
    pub body: String,
}

impl RefreshResult {
    /// Capture a [`DeltaOutcome`] as a servable refresh response.
    pub fn from_outcome(store: &str, rev: usize, out: &DeltaOutcome) -> Self {
        RefreshResult {
            store: store.to_string(),
            dataset: out.dataset.clone(),
            algorithm: out.algorithm,
            min_sup: out.min_sup,
            min_count: out.min_count,
            rev,
            delta: out.delta,
            blocks_rescanned: out.blocks_rescanned,
            total_blocks: out.total_blocks,
            itemsets: out.total_frequent(),
            added: out.added.len(),
            removed: out.removed.len(),
            retained: out.retained,
            body: format_refresh_body(out),
        }
    }

    /// The `OK REFRESH` header line (with trailing newline).
    pub fn header(&self, id: Option<&str>) -> String {
        let mut h = String::from("OK\tREFRESH");
        if let Some(id) = id {
            h.push_str("\tid=");
            h.push_str(id);
        }
        use std::fmt::Write as _;
        let _ = write!(
            h,
            "\tdataset={}\talgo={}\tmin_sup={}\tmin_count={}\trev={}\tdelta={}\
             \tblocks_rescanned={}\ttotal_blocks={}\titemsets={}\tadded={}\tremoved={}\
             \tretained={}",
            self.dataset,
            self.algorithm,
            self.min_sup,
            self.min_count,
            self.rev,
            self.delta,
            self.blocks_rescanned,
            self.total_blocks,
            self.itemsets,
            self.added,
            self.removed,
            self.retained
        );
        h.push('\n');
        h
    }
}

/// Format a refresh outcome's change list as the protocol body: one
/// `+\titem item ...\tcount` line per newly frequent itemset, one
/// `-\titem item ...` line per dropped itemset (both in sorted order),
/// terminated by a lone `.` line. Retained itemsets are summarized in the
/// header only — the delta is the payload.
pub fn format_refresh_body(out: &DeltaOutcome) -> String {
    fn push_items(body: &mut String, itemset: &[u32]) {
        let mut first = true;
        for item in itemset {
            if !first {
                body.push(' ');
            }
            first = false;
            body.push_str(&item.to_string());
        }
    }
    let mut body = String::new();
    for (itemset, count) in &out.added {
        body.push('+');
        body.push('\t');
        push_items(&mut body, itemset);
        body.push('\t');
        body.push_str(&count.to_string());
        body.push('\n');
    }
    for itemset in &out.removed {
        body.push('-');
        body.push('\t');
        push_items(&mut body, itemset);
        body.push('\n');
    }
    body.push_str(".\n");
    body
}

/// A fully resolved, cache-keyable mining query: `MineParams` after
/// defaulting against the dataset registry. Two `MINE` lines that resolve
/// to the same `MineQuery` are the same query for coalescing and result
/// caching, regardless of which keys were spelled out.
#[derive(Debug, Clone, PartialEq)]
pub struct MineQuery {
    /// Registry name of the dataset to mine.
    pub dataset: String,
    /// Algorithm to run.
    pub algorithm: Algorithm,
    /// Fractional minimum support.
    pub min_sup: f64,
    /// FPC's fixed pass count.
    pub fpc_n: usize,
    /// DPC's fast-phase α.
    pub dpc_alpha: f64,
    /// DPC's β threshold in seconds.
    pub dpc_beta: f64,
    /// Whether passes 1+2 fuse into a single job.
    pub fuse12: bool,
    /// Job2 counting backend.
    pub backend: CountingBackend,
}

/// Canonical hash key of a [`MineQuery`]: float tunables keyed by their
/// IEEE-754 bit patterns, so `Eq`/`Hash` are total and two textually
/// different spellings of the same value (e.g. `0.20` / `0.2`) collide
/// exactly when their parsed floats do.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct QueryKey {
    dataset: String,
    algorithm: Algorithm,
    min_sup_bits: u64,
    fpc_n: usize,
    dpc_alpha_bits: u64,
    dpc_beta_bits: u64,
    fuse12: bool,
    backend: CountingBackend,
}

impl MineQuery {
    /// The query's coalescing/result-cache key.
    pub fn key(&self) -> QueryKey {
        QueryKey {
            dataset: self.dataset.clone(),
            algorithm: self.algorithm,
            min_sup_bits: self.min_sup.to_bits(),
            fpc_n: self.fpc_n,
            dpc_alpha_bits: self.dpc_alpha.to_bits(),
            dpc_beta_bits: self.dpc_beta.to_bits(),
            fuse12: self.fuse12,
            backend: self.backend,
        }
    }

    /// The session-API request this query runs as.
    pub fn request(&self) -> MiningRequest {
        MiningRequest::new(self.algorithm)
            .min_sup(self.min_sup)
            .fpc_n(self.fpc_n)
            .dpc_alpha(self.dpc_alpha)
            .dpc_beta(self.dpc_beta)
            .fuse_pass_2(self.fuse12)
            .backend(self.backend)
    }
}

/// A fully mined response, ready to write: the header fields plus the
/// pre-formatted body (itemset lines + terminator). This is what the
/// result cache stores — formatting happens once per *execution*, not per
/// response.
#[derive(Debug, Clone, PartialEq)]
pub struct MineResult {
    /// Dataset the result was mined from.
    pub dataset: String,
    /// Algorithm that produced it.
    pub algorithm: Algorithm,
    /// Fractional minimum support of the run.
    pub min_sup: f64,
    /// Absolute minimum support count.
    pub min_count: u64,
    /// Total frequent itemsets (= body lines before the terminator).
    pub itemsets: usize,
    /// Number of non-empty levels (max frequent itemset length).
    pub levels: usize,
    /// The response body: one line per frequent itemset, then `.` —
    /// exactly [`format_body`] of the outcome.
    pub body: String,
}

impl MineResult {
    /// Capture a finished [`MiningOutcome`] as a servable result.
    pub fn from_outcome(out: &MiningOutcome) -> Self {
        MineResult {
            dataset: out.dataset.clone(),
            algorithm: out.algorithm,
            min_sup: out.min_sup,
            min_count: out.min_count,
            itemsets: out.total_frequent(),
            levels: out.levels.len(),
            body: format_body(out),
        }
    }

    /// The `OK MINE` header line (with trailing newline). `cached` and
    /// `coalesced` report how the daemon satisfied this particular
    /// response; `id` echoes the client's tag when one was sent.
    pub fn header(&self, id: Option<&str>, cached: bool, coalesced: bool) -> String {
        let mut h = String::from("OK\tMINE");
        if let Some(id) = id {
            h.push_str("\tid=");
            h.push_str(id);
        }
        use std::fmt::Write as _;
        let _ = write!(
            h,
            "\tdataset={}\talgo={}\tmin_sup={}\tmin_count={}\titemsets={}\tlevels={}\
             \tcached={cached}\tcoalesced={coalesced}",
            self.dataset, self.algorithm, self.min_sup, self.min_count, self.itemsets, self.levels
        );
        h.push('\n');
        h
    }
}

/// Format a mining outcome's frequent itemsets as the protocol body: one
/// `item item ...\tcount` line per itemset in [`MiningOutcome::all_frequent`]
/// order (sorted), terminated by a lone `.` line. The single source of
/// truth for the byte-identity contract between the daemon and an
/// in-process session.
pub fn format_body(out: &MiningOutcome) -> String {
    let mut body = String::new();
    for (itemset, count) in out.all_frequent() {
        let mut first = true;
        for item in itemset {
            if !first {
                body.push(' ');
            }
            first = false;
            body.push_str(&item.to_string());
        }
        body.push('\t');
        body.push_str(&count.to_string());
        body.push('\n');
    }
    body.push_str(".\n");
    body
}

/// Render a [`ServeError`] as its one-line wire form (with trailing
/// newline), echoing the request's `id` when it carried one.
pub fn format_error(err: &ServeError, id: Option<&str>) -> String {
    match id {
        Some(id) => format!("ERR\tid={id}\t{err}\n"),
        None => format!("ERR\t{err}\n"),
    }
}

impl Request {
    /// Parse one request line (no trailing newline). Empty and
    /// whitespace-only lines are a protocol error — the daemon never
    /// silently skips input.
    pub fn parse(line: &str) -> Result<Request, ServeError> {
        let mut tokens = line.split_ascii_whitespace();
        let verb = tokens
            .next()
            .ok_or_else(|| ServeError::Protocol("empty request line".into()))?
            .to_ascii_uppercase();
        match verb.as_str() {
            "MINE" => Ok(Request::Mine(MineParams::parse_tokens(tokens)?)),
            "REFRESH" => Ok(Request::Refresh(RefreshParams::parse_tokens(tokens)?)),
            "STATS" | "PING" | "SHUTDOWN" => {
                if let Some(extra) = tokens.next() {
                    return Err(ServeError::Protocol(format!(
                        "{verb} takes no arguments, got {extra:?}"
                    )));
                }
                Ok(match verb.as_str() {
                    "STATS" => Request::Stats,
                    "PING" => Request::Ping,
                    _ => Request::Shutdown,
                })
            }
            _ => Err(ServeError::Protocol(format!(
                "unknown verb {verb:?}; expected MINE, REFRESH, STATS, PING or SHUTDOWN"
            ))),
        }
    }
}

impl MineParams {
    /// Parse the `key=value` tokens of a `MINE` line. Every key is known,
    /// appears at most once, and parses in its domain — anything else is a
    /// [`ServeError::Protocol`] naming the offending token.
    fn parse_tokens<'a>(tokens: impl Iterator<Item = &'a str>) -> Result<MineParams, ServeError> {
        fn dup<T>(slot: &Option<T>, key: &str) -> Result<(), ServeError> {
            if slot.is_some() {
                return Err(ServeError::Protocol(format!("duplicate key {key:?}")));
            }
            Ok(())
        }
        fn bad(key: &str, value: &str, what: &str) -> ServeError {
            ServeError::Protocol(format!("key {key:?}: {value:?} is not {what}"))
        }
        let mut p = MineParams::default();
        for token in tokens {
            let (key, value) = token.split_once('=').ok_or_else(|| {
                ServeError::Protocol(format!("expected key=value, got {token:?}"))
            })?;
            match key {
                "dataset" => {
                    if !p.dataset.is_empty() {
                        return Err(ServeError::Protocol("duplicate key \"dataset\"".into()));
                    }
                    p.dataset = value.to_string();
                }
                "algo" => {
                    dup(&p.algorithm, key)?;
                    p.algorithm = Some(
                        Algorithm::parse(value).ok_or_else(|| bad(key, value, "an algorithm"))?,
                    );
                }
                "min_sup" => {
                    dup(&p.min_sup, key)?;
                    p.min_sup =
                        Some(value.parse::<f64>().map_err(|_| bad(key, value, "a number"))?);
                }
                "fpc_n" => {
                    dup(&p.fpc_n, key)?;
                    p.fpc_n =
                        Some(value.parse::<usize>().map_err(|_| bad(key, value, "an integer"))?);
                }
                "dpc_alpha" => {
                    dup(&p.dpc_alpha, key)?;
                    p.dpc_alpha =
                        Some(value.parse::<f64>().map_err(|_| bad(key, value, "a number"))?);
                }
                "dpc_beta" => {
                    dup(&p.dpc_beta, key)?;
                    p.dpc_beta =
                        Some(value.parse::<f64>().map_err(|_| bad(key, value, "a number"))?);
                }
                "fuse12" => {
                    dup(&p.fuse12, key)?;
                    p.fuse12 = Some(match value {
                        "true" | "1" => true,
                        "false" | "0" => false,
                        _ => return Err(bad(key, value, "a boolean (true/false/1/0)")),
                    });
                }
                "backend" => {
                    dup(&p.backend, key)?;
                    p.backend = Some(
                        CountingBackend::parse(value)
                            .ok_or_else(|| bad(key, value, "a counting backend"))?,
                    );
                }
                "id" => {
                    dup(&p.id, key)?;
                    p.id = Some(value.to_string());
                }
                _ => {
                    return Err(ServeError::Protocol(format!("unknown key {key:?}")));
                }
            }
        }
        if p.dataset.is_empty() {
            return Err(ServeError::Protocol("missing required key \"dataset\"".into()));
        }
        if p.algorithm.is_none() {
            return Err(ServeError::Protocol("missing required key \"algo\"".into()));
        }
        Ok(p)
    }

    /// Resolve defaults against the dataset registry — the same rules the
    /// CLI applies: reference min_sup (0.25 when the registry has none),
    /// the paper's per-dataset DPC α, [`RunOptions`] defaults otherwise.
    /// Rejects names the registry cannot build.
    pub fn resolve(&self) -> Result<MineQuery, ServeError> {
        let name = self.dataset.to_ascii_lowercase();
        let known = registry::NAMES.contains(&name.as_str())
            || registry::quest_params(&name).is_some();
        if !known {
            return Err(ServeError::UnknownDataset(self.dataset.clone()));
        }
        let algorithm = self
            .algorithm
            .ok_or_else(|| ServeError::Protocol("missing required key \"algo\"".into()))?;
        let d = RunOptions::default();
        Ok(MineQuery {
            algorithm,
            min_sup: self
                .min_sup
                .unwrap_or_else(|| registry::reference_min_sup(&name).unwrap_or(0.25)),
            fpc_n: self.fpc_n.unwrap_or(d.fpc_n),
            dpc_alpha: self.dpc_alpha.unwrap_or_else(|| registry::paper_dpc_alpha(&name)),
            dpc_beta: self.dpc_beta.unwrap_or(d.dpc_beta),
            fuse12: self.fuse12.unwrap_or(d.fuse_pass_2),
            backend: self.backend.unwrap_or_default(),
            dataset: name,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mine(line: &str) -> MineParams {
        match Request::parse(line).expect("parses") {
            Request::Mine(p) => p,
            other => panic!("expected MINE, got {other:?}"),
        }
    }

    fn err(line: &str) -> String {
        Request::parse(line).expect_err("must not parse").to_string()
    }

    #[test]
    fn verbs_parse_case_insensitively() {
        assert_eq!(Request::parse("STATS"), Ok(Request::Stats));
        assert_eq!(Request::parse("stats"), Ok(Request::Stats));
        assert_eq!(Request::parse("  Ping  "), Ok(Request::Ping));
        assert_eq!(Request::parse("shutdown"), Ok(Request::Shutdown));
        assert!(err("").contains("empty request"));
        assert!(err("FROBNICATE").contains("unknown verb"));
        assert!(err("STATS now").contains("no arguments"));
    }

    #[test]
    fn mine_requires_dataset_and_algo() {
        assert!(err("MINE").contains("dataset"));
        assert!(err("MINE dataset=chess").contains("algo"));
        assert!(err("MINE algo=spc").contains("dataset"));
        let p = mine("MINE dataset=chess algo=spc");
        assert_eq!(p.dataset, "chess");
        assert_eq!(p.algorithm, Some(Algorithm::Spc));
        assert_eq!(p.min_sup, None);
    }

    #[test]
    fn mine_rejects_malformed_tokens() {
        assert!(err("MINE dataset=chess algo=spc min_sup=lots").contains("min_sup"));
        assert!(err("MINE dataset=chess algo=spc fuse12=maybe").contains("boolean"));
        assert!(err("MINE dataset=chess algo=spc backend=gpu").contains("backend"));
        assert!(err("MINE dataset=chess algo=nope").contains("algorithm"));
        assert!(err("MINE dataset=chess algo=spc flavor=mint").contains("unknown key"));
        assert!(err("MINE dataset=chess algo=spc algo=fpc").contains("duplicate"));
        assert!(err("MINE dataset=chess algo=spc naked").contains("key=value"));
    }

    #[test]
    fn resolve_applies_registry_defaults() {
        let q = mine("MINE dataset=CHESS algo=opt-vfpc").resolve().expect("known dataset");
        assert_eq!(q.dataset, "chess"); // canonicalized
        assert_eq!(q.algorithm, Algorithm::OptimizedVfpc);
        assert_eq!(q.min_sup, registry::reference_min_sup("chess").expect("chess has one"));
        assert_eq!(q.dpc_alpha, registry::paper_dpc_alpha("chess"));
        assert_eq!(q.fpc_n, RunOptions::default().fpc_n);
        assert!(!q.fuse12);
        assert_eq!(q.backend, CountingBackend::Trie);

        let q = mine("MINE dataset=chess algo=spc min_sup=0.9 fuse12=1 backend=auto")
            .resolve()
            .expect("known dataset");
        assert_eq!(q.min_sup, 0.9);
        assert!(q.fuse12);
        assert_eq!(q.backend, CountingBackend::Auto);

        let e = mine("MINE dataset=atlantis algo=spc").resolve().expect_err("unknown");
        assert!(matches!(e, ServeError::UnknownDataset(ref n) if n == "atlantis"), "{e:?}");
    }

    #[test]
    fn query_key_is_spelling_insensitive() {
        let a = mine("MINE dataset=chess algo=spc min_sup=0.20 id=a").resolve().expect("known");
        let b = mine("MINE dataset=Chess algo=SPC min_sup=0.2 id=b").resolve().expect("known");
        assert_eq!(a.key(), b.key());
        let c = mine("MINE dataset=chess algo=spc min_sup=0.21").resolve().expect("known");
        assert_ne!(a.key(), c.key());
        // Defaults spelled out == defaults left implicit.
        let implicit = mine("MINE dataset=chess algo=dpc").resolve().expect("known");
        let explicit = mine("MINE dataset=chess algo=dpc min_sup=0.65 dpc_alpha=3 dpc_beta=60")
            .resolve()
            .expect("known");
        assert_eq!(implicit.key(), explicit.key());
    }

    #[test]
    fn refresh_parses_and_validates() {
        let r = match Request::parse("REFRESH store=/tmp/s algo=spc window=4 step=2 id=r1")
            .expect("parses")
        {
            Request::Refresh(p) => p,
            other => panic!("expected REFRESH, got {other:?}"),
        };
        assert_eq!(r.store, "/tmp/s");
        assert_eq!(r.algorithm, Some(Algorithm::Spc));
        assert_eq!(r.min_sup, None);
        assert_eq!(r.window, Some(4));
        assert_eq!(r.step, Some(2));
        assert_eq!(r.id.as_deref(), Some("r1"));
        assert!(err("REFRESH").contains("store"));
        assert!(err("REFRESH store=/tmp/s step=2").contains("window"));
        assert!(err("REFRESH store=/tmp/s window=two").contains("integer"));
        assert!(err("REFRESH store=/tmp/s flavor=mint").contains("unknown key"));
        assert!(err("REFRESH store=/tmp/s store=/tmp/t").contains("duplicate"));
    }

    #[test]
    fn refresh_body_lists_the_symmetric_difference() {
        let out = DeltaOutcome {
            algorithm: Algorithm::Spc,
            dataset: "d".into(),
            min_sup: 0.2,
            min_count: 3,
            coverage: 0..10,
            levels: vec![vec![(vec![1], 5), (vec![2], 4)]],
            added: vec![(vec![1], 5)],
            removed: vec![vec![2, 3]],
            retained: 1,
            delta: true,
            blocks_rescanned: 1,
            total_blocks: 4,
        };
        assert_eq!(format_refresh_body(&out), "+\t1\t5\n-\t2 3\n.\n");
        let res = RefreshResult::from_outcome("/tmp/s", 10, &out);
        let h = res.header(Some("q1"));
        assert!(h.starts_with("OK\tREFRESH\tid=q1\tdataset=d\t"), "{h}");
        assert!(h.contains("\trev=10\t") && h.contains("\tdelta=true\t"), "{h}");
        assert!(h.contains("\tadded=1\tremoved=1\tretained=1"), "{h}");
        assert!(h.ends_with('\n'));
    }

    #[test]
    fn error_lines_echo_the_request_id() {
        let e = ServeError::Protocol("x".into());
        assert_eq!(format_error(&e, None), "ERR\tprotocol: x\n");
        assert_eq!(format_error(&e, Some("q7")), "ERR\tid=q7\tprotocol: x\n");
        let quota = ServeError::Quota { in_flight: 2, limit: 2 };
        assert!(format_error(&quota, None).starts_with("ERR\tquota: "));
    }
}
