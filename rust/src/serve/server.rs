//! The TCP daemon itself: accept loop, per-connection readers, the fair
//! round-robin dispatcher, and the drain/shutdown state machine
//! (DESIGN.md §12).
//!
//! Thread shape: one accept thread, one reader thread per live
//! connection, and a fixed pool of `query_threads` worker threads that
//! execute admitted MINE queries. Readers answer `STATS`/`PING` inline
//! (they never block on mining) and perform admission control; workers
//! pull queries round-robin *across connections* so interactive clients
//! stay responsive next to batchy ones.
//!
//! These long-lived service threads deliberately do NOT run inside the
//! shared `WorkerPool`: parking a blocking `read_line` (or a worker that
//! itself submits MapReduce jobs to the pool) on pool workers would
//! deadlock the budget the queries need. pallas-lint scopes its
//! `raw-thread-spawn` rule accordingly (DESIGN.md §10).

use super::{lock, ServeError};
use crate::cluster::ClusterConfig;
use crate::coordinator::{Algorithm, CancelToken, FollowSession, MiningRequest, WindowSpec};
use crate::dataset::registry as dataset_registry;
use crate::mapreduce::executor::Executor;
use crate::serve::coalesce::{Coalescer, Fulfillment};
use crate::serve::protocol::{self, MineQuery, MineResult, RefreshParams, RefreshResult, Request};
use crate::serve::registry::SessionRegistry;
use crate::serve::stats::{ServeStats, StatsSnapshot};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

/// Tunables of a [`Server`]; start one with [`Server::start`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Interface to bind (default `127.0.0.1` — the daemon is a local
    /// service; fronting it publicly is a deliberate act).
    pub host: String,
    /// Port to bind; 0 picks an ephemeral port (see [`Server::addr`]).
    pub port: u16,
    /// The simulated cluster every session mines on; `cluster.workers`
    /// sizes the ONE shared executor pool (the global host budget).
    pub cluster: ClusterConfig,
    /// Most sessions (datasets) open at once; LRU-evicted beyond this.
    pub max_sessions: usize,
    /// Admission bound: most queries queued (not yet executing) across
    /// all connections before new ones get `ERR busy:`.
    pub max_pending: usize,
    /// Most queries one connection may hold in flight (queued +
    /// executing) before its next gets `ERR quota:`.
    pub client_quota: usize,
    /// Result-cache capacity in responses; 0 disables caching.
    pub result_cache: usize,
    /// Worker threads executing MINE queries (concurrent queries; their
    /// map/reduce tasks all still share the executor pool).
    pub query_threads: usize,
    /// Coalesce identical concurrent queries into one execution.
    pub coalesce: bool,
}

impl ServeConfig {
    /// Defaults over `cluster`: loopback, ephemeral port, 3 sessions,
    /// 64 pending, quota 4, 32 cached results, 2 query threads,
    /// coalescing on.
    pub fn new(cluster: ClusterConfig) -> Self {
        ServeConfig {
            host: "127.0.0.1".to_string(),
            port: 0,
            cluster,
            max_sessions: 3,
            max_pending: 64,
            client_quota: 4,
            result_cache: 32,
            query_threads: 2,
            coalesce: true,
        }
    }
}

/// One admitted query (MINE or REFRESH), parked in its connection's queue.
struct Job {
    conn: u64,
    work: Work,
    id: Option<String>,
    writer: SharedWriter,
    enqueued: Instant,
}

/// What an admitted job executes: a cache/coalesce-keyed mining query, or
/// a refresh against a followed segment store (never cached — its point
/// is observing the store's current revision).
enum Work {
    Mine(MineQuery),
    Refresh(RefreshParams),
}

/// Per-connection dispatcher bookkeeping.
#[derive(Default)]
struct ConnState {
    queue: VecDeque<Job>,
    /// Queued + executing queries of this connection (the quota basis).
    in_flight: usize,
    /// The reader exited; the entry dies once `in_flight` drains.
    closed: bool,
}

/// The dispatcher: every mutation happens under one mutex, wakeups via
/// the companion condvar (notify AFTER the guard drops — DESIGN.md §10).
#[derive(Default)]
struct DispatchState {
    conns: HashMap<u64, ConnState>,
    /// Round-robin order of connections with non-empty queues. Invariant:
    /// a conn id appears at most once, and exactly when its queue is
    /// non-empty.
    rotation: VecDeque<u64>,
    pending: usize,
    pending_high_water: usize,
    draining: bool,
}

/// A connection's write half, shared by its reader (inline replies) and
/// the workers (query responses); the mutex makes each response atomic
/// on the wire.
type SharedWriter = Arc<Mutex<TcpStream>>;

struct ServerShared {
    config: ServeConfig,
    registry: SessionRegistry,
    coalescer: Coalescer,
    stats: ServeStats,
    state: Mutex<DispatchState>,
    ready: Condvar,
    shutdown: AtomicBool,
    /// Cancels in-flight mining on the non-graceful (drop) path.
    cancel: CancelToken,
    addr: SocketAddr,
    /// Read-half handles of live connections, for shutdown's
    /// `Shutdown::Read` sweep (writes stay open so drained responses
    /// still reach their clients).
    sockets: Mutex<HashMap<u64, TcpStream>>,
    readers: Mutex<Vec<JoinHandle<()>>>,
    /// One follow session per `REFRESH`ed store path, kept warm so
    /// consecutive refreshes answer from the delta blocks. The outer map
    /// lock is held only for lookup/insert; the per-store lock is held
    /// across the refresh itself (refreshes of one store serialize,
    /// different stores proceed concurrently).
    follows: Mutex<HashMap<PathBuf, Arc<Mutex<FollowSession>>>>,
}

/// A running serve daemon. [`Server::wait`] blocks until a client issues
/// `SHUTDOWN` (or [`Server::shutdown`] is called), then joins every
/// thread — pending and executing queries are drained, not dropped.
/// Dropping an un-waited `Server` instead cancels in-flight mining
/// through the shared [`CancelToken`] and then drains: the SIGTERM-safe
/// path for embedders.
pub struct Server {
    shared: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `config.host:config.port` and start serving. Fails only on
    /// socket/thread-spawn errors; dataset sessions open lazily per query.
    pub fn start(config: ServeConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind((config.host.as_str(), config.port))?;
        let addr = listener.local_addr()?;
        let executor = Executor::new(config.cluster.workers.max(1));
        let registry = SessionRegistry::new(config.cluster.clone(), executor, config.max_sessions);
        let coalescer = Coalescer::new(config.result_cache);
        let query_threads = config.query_threads.max(1);
        let shared = Arc::new(ServerShared {
            config,
            registry,
            coalescer,
            stats: ServeStats::new(),
            state: Mutex::new(DispatchState::default()),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
            cancel: CancelToken::new(),
            addr,
            sockets: Mutex::new(HashMap::new()),
            readers: Mutex::new(Vec::new()),
            follows: Mutex::new(HashMap::new()),
        });
        let mut workers = Vec::with_capacity(query_threads);
        for i in 0..query_threads {
            let shared_i = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("serve-q{i}"))
                .spawn(move || worker_loop(&shared_i))?;
            workers.push(handle);
        }
        let shared_a = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("serve-accept".to_string())
            .spawn(move || accept_loop(&shared_a, listener))?;
        Ok(Server { shared, accept: Some(accept), workers })
    }

    /// The bound address — how a caller learns an ephemeral port.
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Snapshot the daemon's counters (what the `STATS` verb renders).
    pub fn stats(&self) -> StatsSnapshot {
        snapshot(&self.shared)
    }

    /// Begin a graceful drain, exactly as a client `SHUTDOWN` would:
    /// admission closes, queued and executing queries finish and respond.
    /// Returns immediately; [`Server::wait`] observes completion.
    pub fn shutdown(&self) {
        begin_shutdown(&self.shared, false);
    }

    /// Block until the daemon has shut down (via client `SHUTDOWN` or
    /// [`Server::shutdown`]) and every thread has drained and exited.
    pub fn wait(mut self) {
        self.join_all();
    }

    fn join_all(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        let readers = std::mem::take(&mut *lock(&self.shared.readers));
        for h in readers {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    /// The non-graceful path: cancel in-flight mining (queries respond
    /// `ERR mining: ... cancelled`), close admission, drain, join. A
    /// server that already [`wait`](Server::wait)ed has nothing left to
    /// do here.
    fn drop(&mut self) {
        if self.accept.is_none() && self.workers.is_empty() {
            return;
        }
        begin_shutdown(&self.shared, true);
        self.join_all();
    }
}

impl std::fmt::Debug for Server {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Server")
            .field("addr", &self.shared.addr)
            .field("draining", &self.shared.shutdown.load(Ordering::SeqCst))
            .finish()
    }
}

/// Flip the daemon into draining exactly once; wake everything that
/// blocks: workers (condvar), readers (read-half shutdown), the accept
/// loop (a self-connection).
fn begin_shutdown(shared: &ServerShared, cancel_inflight: bool) {
    if shared.shutdown.swap(true, Ordering::SeqCst) {
        return;
    }
    if cancel_inflight {
        shared.cancel.cancel();
    }
    {
        let mut st = lock(&shared.state);
        st.draining = true;
    }
    shared.ready.notify_all();
    {
        let socks = lock(&shared.sockets);
        for s in socks.values() {
            let _ = s.shutdown(Shutdown::Read);
        }
    }
    // Poke the accept loop awake; it observes the flag and exits.
    let _ = TcpStream::connect(shared.addr);
}

fn snapshot(shared: &ServerShared) -> StatsSnapshot {
    let (mine_requests, mine_ok, errors) = shared.stats.counts();
    let (pending, pending_high_water) = {
        let st = lock(&shared.state);
        (st.pending, st.pending_high_water)
    };
    let mut registry = shared.registry.stats();
    {
        // Follow sessions live outside the registry; fold their counters
        // (delta runs, rescanned blocks, fallbacks, per-revision queries)
        // into the same totals the `STATS` verb reports.
        let follows = lock(&shared.follows);
        for f in follows.values() {
            registry.totals.absorb(&lock(f).stats());
        }
    }
    StatsSnapshot {
        registry,
        coalesce: shared.coalescer.stats(),
        mine_requests,
        mine_ok,
        errors,
        pending,
        pending_high_water,
        pool_workers: shared.registry.executor().workers(),
        pool_high_water: shared.registry.executor().high_water_mark(),
        latency: shared.stats.latency(),
    }
}

fn accept_loop(shared: &Arc<ServerShared>, listener: TcpListener) {
    let mut next_id: u64 = 0;
    for incoming in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = incoming else { continue };
        let conn = next_id;
        next_id += 1;
        // Register the connection and its read-half handle BEFORE
        // spawning the reader, then re-check the flag: a shutdown racing
        // this accept either sees the socket in its sweep or we close it
        // here — no reader is left blocked forever.
        if let Ok(read_half) = stream.try_clone() {
            lock(&shared.sockets).insert(conn, read_half);
        }
        {
            let mut st = lock(&shared.state);
            st.conns.insert(conn, ConnState::default());
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            let _ = stream.shutdown(Shutdown::Read);
        }
        let shared_r = Arc::clone(shared);
        let spawned = std::thread::Builder::new()
            .name(format!("serve-conn-{conn}"))
            .spawn(move || reader_loop(&shared_r, conn, stream));
        match spawned {
            Ok(handle) => lock(&shared.readers).push(handle),
            Err(_) => reader_cleanup(shared, conn),
        }
    }
}

/// Write one complete response under the connection's writer lock, so
/// concurrent responses to one client never interleave. Write errors mean
/// the client left; the query's work is already done either way.
fn write_response(writer: &SharedWriter, text: &str) {
    let mut w = lock(writer);
    let _ = w.write_all(text.as_bytes());
    let _ = w.flush();
}

fn reader_loop(shared: &Arc<ServerShared>, conn: u64, stream: TcpStream) {
    if let Ok(write_half) = stream.try_clone() {
        let writer: SharedWriter = Arc::new(Mutex::new(write_half));
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            let trimmed = line.trim_end_matches(['\r', '\n']);
            match Request::parse(trimmed) {
                Ok(Request::Ping) => write_response(&writer, "OK\tPONG\n"),
                Ok(Request::Stats) => write_response(&writer, &snapshot(shared).render()),
                Ok(Request::Shutdown) => {
                    write_response(&writer, "OK\tBYE\n");
                    begin_shutdown(shared, false);
                }
                Ok(Request::Mine(params)) => {
                    shared.stats.record_request();
                    let id = params.id.clone();
                    let admitted = params.resolve().and_then(|query| {
                        let job = Job {
                            conn,
                            work: Work::Mine(query),
                            id: id.clone(),
                            writer: Arc::clone(&writer),
                            // lint:allow(wall-clock-in-sim): service latency
                            // meter — host time feeds STATS percentiles only,
                            // never simulated results (DESIGN.md §12).
                            enqueued: Instant::now(),
                        };
                        admit(shared, job)
                    });
                    if let Err(e) = admitted {
                        write_response(&writer, &protocol::format_error(&e, id.as_deref()));
                        shared.stats.record_err();
                    }
                }
                Ok(Request::Refresh(params)) => {
                    shared.stats.record_request();
                    let id = params.id.clone();
                    let job = Job {
                        conn,
                        work: Work::Refresh(params),
                        id: id.clone(),
                        writer: Arc::clone(&writer),
                        // lint:allow(wall-clock-in-sim): service latency
                        // meter — host time feeds STATS percentiles only,
                        // never simulated results (DESIGN.md §12).
                        enqueued: Instant::now(),
                    };
                    if let Err(e) = admit(shared, job) {
                        write_response(&writer, &protocol::format_error(&e, id.as_deref()));
                        shared.stats.record_err();
                    }
                }
                Err(e) => {
                    write_response(&writer, &protocol::format_error(&e, None));
                    shared.stats.record_err();
                }
            }
        }
    }
    reader_cleanup(shared, conn);
}

fn reader_cleanup(shared: &ServerShared, conn: u64) {
    lock(&shared.sockets).remove(&conn);
    let mut st = lock(&shared.state);
    let drained = match st.conns.get_mut(&conn) {
        Some(cs) => {
            cs.closed = true;
            cs.in_flight == 0 && cs.queue.is_empty()
        }
        None => false,
    };
    if drained {
        st.conns.remove(&conn);
    }
}

/// Admission control: draining, queue bound, and per-connection quota, in
/// that order. On success the job is queued and a worker woken.
fn admit(shared: &ServerShared, job: Job) -> Result<(), ServeError> {
    let conn = job.conn;
    {
        let mut st = lock(&shared.state);
        if st.draining {
            return Err(ServeError::ShuttingDown);
        }
        if st.pending >= shared.config.max_pending {
            return Err(ServeError::Busy {
                pending: st.pending,
                limit: shared.config.max_pending,
            });
        }
        let Some(cs) = st.conns.get_mut(&conn) else {
            return Err(ServeError::ShuttingDown);
        };
        if cs.in_flight >= shared.config.client_quota {
            return Err(ServeError::Quota {
                in_flight: cs.in_flight,
                limit: shared.config.client_quota,
            });
        }
        cs.in_flight += 1;
        let was_empty = cs.queue.is_empty();
        cs.queue.push_back(job);
        if was_empty {
            st.rotation.push_back(conn);
        }
        st.pending += 1;
        st.pending_high_water = st.pending_high_water.max(st.pending);
    }
    shared.ready.notify_one();
    Ok(())
}

/// Pull the next job fairly: take the head of the rotation's front
/// connection, and send that connection to the rotation's back if it
/// still has queued work. `None` once draining and nothing is queued.
fn next_job(shared: &ServerShared) -> Option<Job> {
    let mut st = lock(&shared.state);
    loop {
        if let Some(conn) = st.rotation.pop_front() {
            if let Some(cs) = st.conns.get_mut(&conn) {
                if let Some(job) = cs.queue.pop_front() {
                    if !cs.queue.is_empty() {
                        st.rotation.push_back(conn);
                    }
                    st.pending -= 1;
                    return Some(job);
                }
            }
            continue; // stale rotation entry (conn died queue-empty)
        }
        if st.draining {
            return None;
        }
        st = shared.ready.wait(st).unwrap_or_else(PoisonError::into_inner);
    }
}

/// A worker finished a job for `conn`: release its quota slot and reap
/// the connection if its reader is gone and nothing remains in flight.
fn finish(shared: &ServerShared, conn: u64) {
    let mut st = lock(&shared.state);
    let drained = match st.conns.get_mut(&conn) {
        Some(cs) => {
            cs.in_flight -= 1;
            cs.closed && cs.in_flight == 0 && cs.queue.is_empty()
        }
        None => false,
    };
    if drained {
        st.conns.remove(&conn);
    }
}

fn worker_loop(shared: &Arc<ServerShared>) {
    while let Some(job) = next_job(shared) {
        execute(shared, job);
    }
}

/// Execute one admitted job and write its response. Mining runs under
/// the server-wide [`CancelToken`], so the drop path can abort it.
fn execute(shared: &ServerShared, job: Job) {
    match &job.work {
        Work::Mine(query) => execute_mine(shared, &job, query),
        Work::Refresh(params) => execute_refresh(shared, &job, params),
    }
    finish(shared, job.conn);
}

/// One MINE query through the coalescer/result cache.
fn execute_mine(shared: &ServerShared, job: &Job, query: &MineQuery) {
    let key = query.key();
    let run = || -> Result<MineResult, ServeError> {
        let session = shared.registry.get(&query.dataset)?;
        let outcome = session.run_streaming(&query.request(), &shared.cancel, |_| {})?;
        Ok(MineResult::from_outcome(&outcome))
    };
    let (result, how) = if shared.config.coalesce {
        shared.coalescer.fetch(&key, run)
    } else {
        shared.coalescer.fetch_direct(&key, run)
    };
    match result {
        Ok(res) => {
            let mut text = res.header(
                job.id.as_deref(),
                how == Fulfillment::Cached,
                how == Fulfillment::Coalesced,
            );
            text.push_str(&res.body);
            write_response(&job.writer, &text);
            shared.stats.record_ok(job.enqueued.elapsed().as_secs_f64());
        }
        Err(e) => {
            write_response(&job.writer, &protocol::format_error(&e, job.id.as_deref()));
            shared.stats.record_err();
        }
    }
}

/// One REFRESH against a followed store — never cached or coalesced (the
/// whole point is observing the store's current revision).
fn execute_refresh(shared: &ServerShared, job: &Job, params: &RefreshParams) {
    match run_refresh(shared, params) {
        Ok(res) => {
            let mut text = res.header(job.id.as_deref());
            text.push_str(&res.body);
            write_response(&job.writer, &text);
            shared.stats.record_ok(job.enqueued.elapsed().as_secs_f64());
        }
        Err(e) => {
            write_response(&job.writer, &protocol::format_error(&e, job.id.as_deref()));
            shared.stats.record_err();
        }
    }
}

/// Serve one REFRESH: get-or-open the store's warm [`FollowSession`] from
/// the `follows` map, then delta-mine the store's current revision
/// (DESIGN.md §13). Defaults when the request omits them: algorithm
/// Optimized-VFPC (the delta path is algorithm-free — every algorithm
/// yields the same frequent sets), `min_sup` from the dataset registry's
/// reference threshold or 0.25.
fn run_refresh(shared: &ServerShared, params: &RefreshParams) -> Result<RefreshResult, ServeError> {
    let path = PathBuf::from(&params.store);
    let held = lock(&shared.follows).get(&path).map(Arc::clone);
    let follow = match held {
        Some(f) => f,
        None => {
            // Open OUTSIDE the map lock: opening scans the manifest and
            // builds a session. A racing open of the same store resolves
            // to whichever registered first; the loser's copy drops.
            let opened = FollowSession::open(&path, shared.config.cluster.clone())?;
            Arc::clone(
                lock(&shared.follows)
                    .entry(path)
                    .or_insert_with(|| Arc::new(Mutex::new(opened))),
            )
        }
    };
    // Held across the refresh: same-store refreshes serialize (they share
    // one DeltaMiner state); different stores proceed concurrently.
    let mut guard = lock(&follow);
    let dataset = guard.session().file().name.clone();
    let min_sup = params
        .min_sup
        .unwrap_or_else(|| dataset_registry::reference_min_sup(&dataset).unwrap_or(0.25));
    let algo = params.algorithm.unwrap_or(Algorithm::OptimizedVfpc);
    let req = MiningRequest::new(algo)
        .min_sup(min_sup)
        .dpc_alpha(dataset_registry::paper_dpc_alpha(&dataset));
    let out = match params.window {
        Some(blocks) => {
            let spec = WindowSpec::new(blocks).step(params.step.unwrap_or(1));
            guard.refresh_window(&req, spec)?
        }
        None => guard.refresh_always(&req)?,
    };
    Ok(RefreshResult::from_outcome(&params.store, guard.rev(), &out))
}
