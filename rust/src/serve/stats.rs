//! The daemon's observability surface: per-request counters, the latency
//! histogram, and the `STATS` response renderer (DESIGN.md §12).

use super::lock;
use crate::coordinator::Algorithm;
use crate::serve::coalesce::CoalesceStats;
use crate::serve::registry::RegistryStats;
use crate::util::hist::Histogram;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// The server's own request counters and the MINE latency histogram
/// (queue wait + execution, recorded at response time).
pub(crate) struct ServeStats {
    mine_requests: AtomicU64,
    mine_ok: AtomicU64,
    errors: AtomicU64,
    latency: Mutex<Histogram>,
}

impl ServeStats {
    pub(crate) fn new() -> Self {
        ServeStats {
            mine_requests: AtomicU64::new(0),
            mine_ok: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            latency: Mutex::new(Histogram::new()),
        }
    }

    /// A MINE line arrived (admitted or not).
    pub(crate) fn record_request(&self) {
        self.mine_requests.fetch_add(1, Ordering::SeqCst);
    }

    /// A MINE query was answered `OK` after `secs` from admission.
    pub(crate) fn record_ok(&self, secs: f64) {
        self.mine_ok.fetch_add(1, Ordering::SeqCst);
        lock(&self.latency).record(secs);
    }

    /// Any request line was answered with an `ERR` (unparseable line,
    /// rejected admission, or a failed execution).
    pub(crate) fn record_err(&self) {
        self.errors.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn counts(&self) -> (u64, u64, u64) {
        (
            self.mine_requests.load(Ordering::SeqCst),
            self.mine_ok.load(Ordering::SeqCst),
            self.errors.load(Ordering::SeqCst),
        )
    }

    pub(crate) fn latency(&self) -> Histogram {
        lock(&self.latency).clone()
    }
}

/// Everything the `STATS` verb reports, gathered atomically enough for
/// monitoring (each source is snapshotted under its own lock; counters
/// may be mutually off by an in-flight query).
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    /// Session-table counters and cross-session aggregates.
    pub registry: RegistryStats,
    /// Coalescing and result-cache counters.
    pub coalesce: CoalesceStats,
    /// MINE lines received (including rejected ones).
    pub mine_requests: u64,
    /// MINE queries answered `OK`.
    pub mine_ok: u64,
    /// Request lines answered `ERR` — unparseable lines of any verb,
    /// rejected admissions, and failed executions alike.
    pub errors: u64,
    /// Queries currently queued (admitted, not yet executing).
    pub pending: usize,
    /// Highest `pending` ever observed.
    pub pending_high_water: usize,
    /// The shared executor pool's thread budget.
    pub pool_workers: usize,
    /// Most pool workers ever simultaneously busy.
    pub pool_high_water: usize,
    /// Admission-to-response latency of every `OK` MINE query.
    pub latency: Histogram,
}

impl StatsSnapshot {
    /// Render the full `STATS` response: `OK STATS` header, one
    /// `key\tvalue` line per counter in a fixed documented order, `.`
    /// terminator. Latency percentiles render in milliseconds with 3
    /// decimals; `-` when nothing has completed yet.
    pub fn render(&self) -> String {
        let mut out = String::from("OK\tSTATS\n");
        let mut line = |key: &str, value: String| {
            let _ = writeln!(out, "{key}\t{value}");
        };
        let open = if self.registry.open.is_empty() {
            "-".to_string()
        } else {
            self.registry.open.join(" ")
        };
        line("open_sessions", open);
        line("sessions_opened", self.registry.opened.to_string());
        line("session_hits", self.registry.hits.to_string());
        line("session_evictions", self.registry.evictions.to_string());
        line("session_queries", self.registry.totals.queries.to_string());
        line("job1_runs", self.registry.totals.job1_runs.to_string());
        line("job1_cache_hits", self.registry.totals.job1_cache_hits.to_string());
        line("job2_runs", self.registry.totals.job2_runs.to_string());
        line("session_delta_runs", self.registry.totals.delta_runs.to_string());
        line("session_blocks_rescanned", self.registry.totals.blocks_rescanned.to_string());
        line("session_full_fallbacks", self.registry.totals.full_fallbacks.to_string());
        for algo in Algorithm::ALL {
            line(
                &format!("queries[{}]", algo.name()),
                self.registry.totals.queries_by_algorithm[algo.index()].to_string(),
            );
        }
        line("result_cache_hits", self.coalesce.cache_hits.to_string());
        line("result_cache_evictions", self.coalesce.cache_evictions.to_string());
        line("result_cache_len", self.coalesce.cache_len.to_string());
        line("result_cache_capacity", self.coalesce.cache_capacity.to_string());
        line("coalesced_joins", self.coalesce.coalesced_joins.to_string());
        line("mine_requests", self.mine_requests.to_string());
        line("mine_ok", self.mine_ok.to_string());
        line("errors", self.errors.to_string());
        line("pending", self.pending.to_string());
        line("pending_high_water", self.pending_high_water.to_string());
        line("pool_workers", self.pool_workers.to_string());
        line("pool_high_water", self.pool_high_water.to_string());
        line("latency_count", self.latency.count().to_string());
        let ms = |q: Option<f64>| match q {
            Some(secs) => format!("{:.3}", secs * 1e3),
            None => "-".to_string(),
        };
        line("latency_p50_ms", ms(self.latency.p50()));
        line("latency_p95_ms", ms(self.latency.p95()));
        line("latency_p99_ms", ms(self.latency.p99()));
        out.push_str(".\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SessionStats;

    fn snapshot() -> StatsSnapshot {
        let mut latency = Histogram::new();
        latency.record(0.002);
        latency.record(0.004);
        StatsSnapshot {
            registry: RegistryStats {
                open: vec!["chess".into(), "mushroom".into()],
                opened: 2,
                hits: 5,
                evictions: 1,
                totals: SessionStats {
                    queries: 7,
                    job1_runs: 2,
                    job1_cache_hits: 5,
                    job2_runs: 9,
                    delta_runs: 3,
                    blocks_rescanned: 12,
                    full_fallbacks: 1,
                    queries_by_algorithm: [1, 0, 0, 2, 0, 4, 0],
                },
            },
            coalesce: CoalesceStats {
                coalesced_joins: 3,
                cache_hits: 4,
                cache_evictions: 0,
                cache_len: 2,
                cache_capacity: 16,
            },
            mine_requests: 14,
            mine_ok: 11,
            errors: 3,
            pending: 0,
            pending_high_water: 6,
            pool_workers: 8,
            pool_high_water: 8,
            latency,
        }
    }

    #[test]
    fn render_is_line_oriented_and_terminated() {
        let s = snapshot().render();
        assert!(s.starts_with("OK\tSTATS\n"));
        assert!(s.ends_with("\n.\n"));
        assert!(s.contains("open_sessions\tchess mushroom\n"));
        assert!(s.contains("session_hits\t5\n"));
        assert!(s.contains("job2_runs\t9\n"));
        assert!(s.contains("session_delta_runs\t3\n"));
        assert!(s.contains("session_blocks_rescanned\t12\n"));
        assert!(s.contains("session_full_fallbacks\t1\n"));
        assert!(s.contains("queries[SPC]\t1\n"));
        assert!(s.contains("queries[Optimized-VFPC]\t4\n"));
        assert!(s.contains("result_cache_hits\t4\n"));
        assert!(s.contains("coalesced_joins\t3\n"));
        assert!(s.contains("pool_workers\t8\n"));
        assert!(s.contains("latency_count\t2\n"));
        // Every body line is exactly key TAB value.
        for l in s.lines().skip(1) {
            if l == "." {
                break;
            }
            assert_eq!(l.split('\t').count(), 2, "malformed line {l:?}");
        }
    }

    #[test]
    fn empty_latency_renders_dashes() {
        let mut snap = snapshot();
        snap.latency = Histogram::new();
        let s = snap.render();
        assert!(s.contains("latency_p50_ms\t-\n"));
        assert!(s.contains("latency_count\t0\n"));
    }

    #[test]
    fn record_paths_feed_the_counters() {
        let stats = ServeStats::new();
        stats.record_request();
        stats.record_request();
        stats.record_ok(0.005);
        stats.record_err();
        assert_eq!(stats.counts(), (2, 1, 1));
        assert_eq!(stats.latency().count(), 1);
    }
}
