//! `pallas serve` — the multi-tenant mining service daemon over the
//! session API (DESIGN.md §12).
//!
//! The library layers below this one already make *one* caller fast: a
//! [`MiningSession`](crate::coordinator::MiningSession) binds a dataset
//! once, memoizes Job1, and multiplexes every query's tasks onto one
//! executor pool. This module turns that library into a long-running
//! **service**: a zero-dependency TCP daemon speaking a newline-delimited
//! line protocol, hosting a [`SessionRegistry`] of lazily opened,
//! LRU-bounded sessions (one per dataset, all sharing ONE
//! [`Executor`](crate::mapreduce::executor::Executor) so
//! `cluster.workers` is a global host budget), with the service-grade
//! policies the library deliberately does not have:
//!
//! * **admission control** — a bounded pending queue; when it is full new
//!   queries are rejected with a typed `ERR busy:` line instead of piling
//!   onto the host;
//! * **fairness** — pending queries are dispatched round-robin *across
//!   client connections*, so one chatty client cannot starve the rest;
//! * **per-client quotas** — each connection may hold at most N queries
//!   in flight (pending + executing);
//! * **query coalescing** — identical in-flight `(dataset, algorithm,
//!   tunables)` requests join the one execution already running instead
//!   of re-mining ([`Coalescer`]);
//! * **result caching** — a capacity-bounded LRU of full mined responses
//!   keyed on the canonicalized request ([`QueryKey`]), on top of the
//!   session layer's Job1 memoization: a repeated query re-runs *zero*
//!   jobs, observable through the session counters;
//! * **observability** — a `STATS` verb surfacing session/result-cache
//!   hit counters, coalesced-join counts, pool high-water marks, and
//!   per-query latency percentiles from a [`Histogram`](crate::util::hist::Histogram);
//! * **graceful shutdown** — `SHUTDOWN` drains pending and in-flight
//!   queries before the process exits; dropping an un-drained [`Server`]
//!   instead cancels in-flight work through the existing
//!   [`CancelToken`](crate::coordinator::CancelToken) machinery.
//!
//! Protocol sketch (full grammar in DESIGN.md §12):
//!
//! ```text
//! > MINE dataset=c20d10k algo=opt-vfpc min_sup=0.2 backend=auto
//! < OK\tMINE\tdataset=c20d10k\talgo=Optimized-VFPC\t...\titemsets=385\t...
//! < 1 5 9\t4021
//! < ...      (one tab-separated line per frequent itemset)
//! < .
//! > STATS
//! < OK\tSTATS
//! < result_cache_hits\t17
//! < ...
//! < .
//! > SHUTDOWN
//! < OK\tBYE
//! ```
//!
//! Locking discipline: serve-layer mutexes guard plain bookkeeping
//! (queues, caches, counters) whose updates cannot panic halfway through
//! a query's state machine; a poisoned guard is therefore *recovered*
//! ([`lock`]) rather than propagated — a mining daemon must keep serving
//! the other tenants after one request dies. Condition-variable wakeups
//! follow the pool's protocol: mutate under the lock, release, then
//! notify (pallas-lint `guard-across-notify`, DESIGN.md §10).

mod coalesce;
pub mod protocol;
mod registry;
mod server;
mod stats;

pub use coalesce::{CoalesceStats, Coalescer, Fulfillment};
pub use protocol::{
    MineParams, MineQuery, MineResult, QueryKey, RefreshParams, RefreshResult, Request,
};
pub use registry::{RegistryStats, SessionRegistry};
pub use server::{ServeConfig, Server};
pub use stats::StatsSnapshot;

use crate::coordinator::MiningError;
use crate::incremental::FollowError;
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Typed failure modes of the serve layer — every one renders as a
/// single `ERR <category>: <message>` protocol line
/// ([`protocol::format_error`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The request line did not parse; carries the specific violation.
    Protocol(String),
    /// The named dataset is not in the dataset registry.
    UnknownDataset(String),
    /// The underlying mining query failed (validation or execution).
    Mining(MiningError),
    /// A `REFRESH`ed segment store could not be opened or read.
    Store(String),
    /// The connection already has its quota of queries in flight.
    Quota {
        /// Queries this connection currently holds (pending + executing).
        in_flight: usize,
        /// The per-connection limit ([`ServeConfig::client_quota`]).
        limit: usize,
    },
    /// The server's pending queue is full (admission control).
    Busy {
        /// Pending queries at rejection time.
        pending: usize,
        /// The queue bound ([`ServeConfig::max_pending`]).
        limit: usize,
    },
    /// The server is draining and admits no new queries.
    ShuttingDown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Protocol(why) => write!(f, "protocol: {why}"),
            ServeError::UnknownDataset(name) => {
                write!(
                    f,
                    "dataset: unknown dataset {name:?} (known: {})",
                    crate::dataset::registry::NAMES.join(", ")
                )
            }
            ServeError::Mining(e) => write!(f, "mining: {e}"),
            ServeError::Store(why) => write!(f, "store: {why}"),
            ServeError::Quota { in_flight, limit } => {
                write!(f, "quota: client has {in_flight} queries in flight (limit {limit})")
            }
            ServeError::Busy { pending, limit } => {
                write!(f, "busy: pending queue is full ({pending}/{limit})")
            }
            ServeError::ShuttingDown => write!(f, "shutdown: server is draining"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<MiningError> for ServeError {
    fn from(e: MiningError) -> Self {
        ServeError::Mining(e)
    }
}

impl From<FollowError> for ServeError {
    fn from(e: FollowError) -> Self {
        match e {
            FollowError::Store(e) => ServeError::Store(e.to_string()),
            FollowError::Mining(e) => ServeError::Mining(e),
        }
    }
}

/// Lock a serve-layer mutex, recovering from poisoning: the data under
/// these locks is simple bookkeeping that is never left half-updated by a
/// panic inside the critical section (updates are single assignments and
/// counter bumps), and a multi-tenant daemon must outlive any one
/// request's death. This deliberately differs from the engine's
/// `expect`-on-poison stance (where a poisoned lock means a task state
/// machine is torn and continuing would corrupt results).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}
