//! Query coalescing and result caching for the serve daemon
//! (DESIGN.md §12).
//!
//! [`Coalescer::fetch`] is the single entry point for executing a mining
//! query: it answers from the result cache when it can, joins an
//! identical in-flight execution when one exists, and otherwise runs the
//! query itself and publishes the result to both late joiners and the
//! cache. The in-flight table reuses the session layer's
//! `Arc<OnceLock>` exactly-once idiom (each caller offers its own
//! initializer; the first to arrive runs it, racers block inside
//! `get_or_init` until the value lands).

use super::{lock, ServeError};
use crate::serve::protocol::{MineResult, QueryKey};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// How a [`Coalescer::fetch`] call was satisfied — drives the response
/// header's `cached=`/`coalesced=` flags and the daemon counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fulfillment {
    /// This call ran the query itself.
    Executed,
    /// This call joined an identical in-flight execution.
    Coalesced,
    /// This call was answered from the result cache; nothing ran.
    Cached,
}

/// Counters of a [`Coalescer`] snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoalesceStats {
    /// Fetches that joined an in-flight identical query.
    pub coalesced_joins: u64,
    /// Fetches answered from the result cache.
    pub cache_hits: u64,
    /// Results evicted from the cache to respect its capacity.
    pub cache_evictions: u64,
    /// Results currently cached.
    pub cache_len: usize,
    /// The cache's capacity (0 = caching disabled).
    pub cache_capacity: usize,
}

type Cell = Arc<OnceLock<Result<Arc<MineResult>, ServeError>>>;

/// Capacity-bounded LRU of full mined responses, most recently used
/// first. Small by design: entries are whole response bodies, and the
/// interesting hit pattern (dashboards re-issuing the same handful of
/// queries) needs few slots.
struct ResultCache {
    entries: Vec<(QueryKey, Arc<MineResult>)>,
    evictions: u64,
}

impl ResultCache {
    fn get(&mut self, key: &QueryKey) -> Option<Arc<MineResult>> {
        let pos = self.entries.iter().position(|(k, _)| k == key)?;
        let entry = self.entries.remove(pos);
        let hit = entry.1.clone();
        self.entries.insert(0, entry);
        Some(hit)
    }

    fn put(&mut self, key: QueryKey, value: Arc<MineResult>, capacity: usize) {
        if capacity == 0 {
            return;
        }
        if let Some(pos) = self.entries.iter().position(|(k, _)| k == &key) {
            self.entries.remove(pos);
        }
        self.entries.insert(0, (key, value));
        while self.entries.len() > capacity {
            self.entries.pop();
            self.evictions += 1;
        }
    }
}

/// The daemon's execute-at-most-once layer: an in-flight table keyed by
/// [`QueryKey`] (coalescing) in front of a bounded LRU of finished
/// responses (caching). Errors are shared with joiners — everyone waiting
/// on a failed execution sees the same [`ServeError`] — but never cached:
/// the next fetch retries.
pub struct Coalescer {
    inflight: Mutex<HashMap<QueryKey, Cell>>,
    cache: Mutex<ResultCache>,
    capacity: usize,
    coalesced_joins: AtomicU64,
    cache_hits: AtomicU64,
}

impl Coalescer {
    /// A coalescer whose result cache holds up to `cache_capacity`
    /// responses (0 disables caching; coalescing is always on).
    pub fn new(cache_capacity: usize) -> Self {
        Coalescer {
            inflight: Mutex::new(HashMap::new()),
            cache: Mutex::new(ResultCache { entries: Vec::new(), evictions: 0 }),
            capacity: cache_capacity,
            coalesced_joins: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
        }
    }

    /// Satisfy one query: cache hit, join of an identical in-flight run,
    /// or a fresh execution of `run` — whichever is cheapest. Blocks until
    /// the answer exists (joiners block inside the cell's `get_or_init`).
    pub fn fetch(
        &self,
        key: &QueryKey,
        run: impl FnOnce() -> Result<MineResult, ServeError>,
    ) -> (Result<Arc<MineResult>, ServeError>, Fulfillment) {
        if self.capacity > 0 {
            let mut cache = lock(&self.cache);
            if let Some(hit) = cache.get(key) {
                drop(cache);
                self.cache_hits.fetch_add(1, Ordering::SeqCst);
                return (Ok(hit), Fulfillment::Cached);
            }
        }
        let cell: Cell = {
            let mut inflight = lock(&self.inflight);
            inflight.entry(key.clone()).or_default().clone()
        };
        let mut ran = false;
        let result = cell
            .get_or_init(|| {
                ran = true;
                run().map(Arc::new)
            })
            .clone();
        if !ran {
            self.coalesced_joins.fetch_add(1, Ordering::SeqCst);
            return (result, Fulfillment::Coalesced);
        }
        // This call executed: publish a success to the cache FIRST, then
        // retire the in-flight entry (guarded by pointer identity so a
        // successor cell for the same key, created after this one retired
        // on another path, is left alone). With caching on, the order
        // means a fetch can never miss the cache AND the in-flight table
        // for a query that already ran: every concurrent identical fetch
        // is a join or a cache hit, deterministically.
        if let Ok(value) = &result {
            let mut cache = lock(&self.cache);
            cache.put(key.clone(), value.clone(), self.capacity);
        }
        {
            let mut inflight = lock(&self.inflight);
            if inflight.get(key).is_some_and(|cur| Arc::ptr_eq(cur, &cell)) {
                inflight.remove(key);
            }
        }
        (result, Fulfillment::Executed)
    }

    /// [`fetch`](Coalescer::fetch) without the in-flight table: cache hit
    /// or a fresh execution, never a join. The `--no-coalesce` daemon mode
    /// (and its bench ablation) runs through this path.
    pub fn fetch_direct(
        &self,
        key: &QueryKey,
        run: impl FnOnce() -> Result<MineResult, ServeError>,
    ) -> (Result<Arc<MineResult>, ServeError>, Fulfillment) {
        if self.capacity > 0 {
            let mut cache = lock(&self.cache);
            if let Some(hit) = cache.get(key) {
                drop(cache);
                self.cache_hits.fetch_add(1, Ordering::SeqCst);
                return (Ok(hit), Fulfillment::Cached);
            }
        }
        let result = run().map(Arc::new);
        if let Ok(value) = &result {
            let mut cache = lock(&self.cache);
            cache.put(key.clone(), value.clone(), self.capacity);
        }
        (result, Fulfillment::Executed)
    }

    /// Snapshot the coalescer's counters.
    pub fn stats(&self) -> CoalesceStats {
        let cache = lock(&self.cache);
        CoalesceStats {
            coalesced_joins: self.coalesced_joins.load(Ordering::SeqCst),
            cache_hits: self.cache_hits.load(Ordering::SeqCst),
            cache_evictions: cache.evictions,
            cache_len: cache.entries.len(),
            cache_capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Algorithm, CountingBackend};
    use crate::serve::protocol::MineQuery;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Barrier;

    fn query(min_sup: f64) -> MineQuery {
        MineQuery {
            dataset: "chess".into(),
            algorithm: Algorithm::Spc,
            min_sup,
            fpc_n: 3,
            dpc_alpha: 3.0,
            dpc_beta: 60.0,
            fuse12: false,
            backend: CountingBackend::Trie,
        }
    }

    fn result(tag: &str) -> MineResult {
        MineResult {
            dataset: "chess".into(),
            algorithm: Algorithm::Spc,
            min_sup: 0.9,
            min_count: 2877,
            itemsets: 1,
            levels: 1,
            body: format!("{tag}\t1\n.\n"),
        }
    }

    #[test]
    fn repeat_fetches_hit_the_cache_without_rerunning() {
        let c = Coalescer::new(4);
        let runs = AtomicUsize::new(0);
        let run = || {
            runs.fetch_add(1, Ordering::SeqCst);
            Ok(result("a"))
        };
        let (first, how) = c.fetch(&query(0.9).key(), run);
        assert_eq!(how, Fulfillment::Executed);
        let (second, how) = c.fetch(&query(0.9).key(), || panic!("must not run"));
        assert_eq!(how, Fulfillment::Cached);
        assert_eq!(first.unwrap(), second.unwrap());
        assert_eq!(runs.load(Ordering::SeqCst), 1);
        let stats = c.stats();
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(stats.cache_len, 1);
    }

    #[test]
    fn errors_are_shared_but_never_cached() {
        let c = Coalescer::new(4);
        let (r, how) = c.fetch(&query(0.5).key(), || Err(ServeError::Protocol("boom".into())));
        assert_eq!(how, Fulfillment::Executed);
        assert!(r.is_err());
        // The error was not cached: the next fetch runs again and succeeds.
        let (r, how) = c.fetch(&query(0.5).key(), || Ok(result("retry")));
        assert_eq!(how, Fulfillment::Executed);
        assert!(r.is_ok());
        assert_eq!(c.stats().cache_hits, 0);
    }

    #[test]
    fn cache_capacity_evicts_least_recently_used() {
        let c = Coalescer::new(2);
        c.fetch(&query(0.1).key(), || Ok(result("a")));
        c.fetch(&query(0.2).key(), || Ok(result("b")));
        c.fetch(&query(0.1).key(), || panic!("cached")); // touch a
        c.fetch(&query(0.3).key(), || Ok(result("c"))); // evicts b
        let runs = AtomicUsize::new(0);
        c.fetch(&query(0.2).key(), || {
            runs.fetch_add(1, Ordering::SeqCst);
            Ok(result("b2"))
        });
        assert_eq!(runs.load(Ordering::SeqCst), 1, "b was evicted, must re-run");
        let stats = c.stats();
        assert_eq!(stats.cache_evictions, 2); // b, then a on b2's insert
        assert_eq!(stats.cache_len, 2);
    }

    #[test]
    fn zero_capacity_disables_caching_not_coalescing() {
        let c = Coalescer::new(0);
        let runs = AtomicUsize::new(0);
        for _ in 0..3 {
            let (_, how) = c.fetch(&query(0.9).key(), || {
                runs.fetch_add(1, Ordering::SeqCst);
                Ok(result("a"))
            });
            assert_eq!(how, Fulfillment::Executed);
        }
        assert_eq!(runs.load(Ordering::SeqCst), 3);
        assert_eq!(c.stats().cache_len, 0);
    }

    #[test]
    fn concurrent_identical_fetches_run_once() {
        const THREADS: usize = 8;
        let c = Coalescer::new(0); // cache off: pin the coalescing path
        let runs = AtomicUsize::new(0);
        let gate = Barrier::new(THREADS);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..THREADS)
                .map(|_| {
                    scope.spawn(|| {
                        gate.wait();
                        c.fetch(&query(0.9).key(), || {
                            runs.fetch_add(1, Ordering::SeqCst);
                            // Hold the cell long enough for racers to pile up.
                            std::thread::sleep(std::time::Duration::from_millis(30));
                            Ok(result("once"))
                        })
                    })
                })
                .collect();
            let mut executed = 0;
            let mut joined = 0;
            for h in handles {
                let (r, how) = h.join().expect("no panics");
                assert_eq!(r.expect("success").body, result("once").body);
                match how {
                    Fulfillment::Executed => executed += 1,
                    Fulfillment::Coalesced => joined += 1,
                    Fulfillment::Cached => panic!("cache is off"),
                }
            }
            // Racers that arrive while the cell is live join it; stragglers
            // arriving after it retired re-execute. With the barrier + sleep
            // the common case is 1 execution, but the invariant we pin is
            // conservation plus the executed runs matching `runs`.
            assert_eq!(executed + joined, THREADS);
            assert_eq!(runs.load(Ordering::SeqCst), executed);
            assert_eq!(c.stats().coalesced_joins, joined as u64);
        });
    }
}
