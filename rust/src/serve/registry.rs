//! The daemon's session table: one lazily opened
//! [`MiningSession`] per dataset, LRU-bounded, all sharing ONE
//! [`Executor`] so the host-thread budget is global (DESIGN.md §12).

use super::{lock, ServeError};
use crate::cluster::ClusterConfig;
use crate::coordinator::{MiningSession, SessionStats};
use crate::dataset::registry;
use crate::mapreduce::executor::Executor;
use std::sync::Mutex;

/// Counters and aggregates of a [`SessionRegistry`] snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegistryStats {
    /// Names of the currently open sessions, most recently used first.
    pub open: Vec<String>,
    /// Sessions ever opened (cold opens, not lookups).
    pub opened: u64,
    /// Lookups satisfied by an already-open session.
    pub hits: u64,
    /// Sessions evicted to keep the table within its bound.
    pub evictions: u64,
    /// Session counters aggregated across every session this registry ever
    /// opened: the live ones' current stats plus the totals captured from
    /// evicted ones at eviction time.
    pub totals: SessionStats,
}

struct RegistryInner {
    /// Open sessions, most recently used first (LRU = last element).
    sessions: Vec<(String, MiningSession)>,
    opened: u64,
    hits: u64,
    evictions: u64,
    /// Stats captured from evicted sessions, so aggregates survive
    /// eviction instead of silently resetting.
    retired: SessionStats,
}

/// An LRU-bounded table of per-dataset [`MiningSession`]s. Sessions open
/// lazily on first use and close (evict) coldest-first once the bound is
/// reached; every session is built over the registry's one shared
/// [`Executor`], so `cluster.workers` caps host threads across ALL
/// datasets, not per dataset.
///
/// Eviction drops only the registry's handle: a query already executing on
/// the evicted session finishes on its own clone (sessions are
/// `Arc`-shared), and the evicted session's counters are folded into
/// [`RegistryStats::totals`] at eviction time. Counter increments a
/// still-running query makes *after* its session was evicted are not
/// re-captured — an accepted, bounded under-count on a path that requires
/// `max_sessions` distinct datasets to race one slow query.
pub struct SessionRegistry {
    cluster: ClusterConfig,
    executor: Executor,
    max_sessions: usize,
    inner: Mutex<RegistryInner>,
}

impl SessionRegistry {
    /// A registry opening sessions over `cluster`, capped at
    /// `max_sessions` (clamped to at least 1), every session sharing
    /// `executor`.
    pub fn new(cluster: ClusterConfig, executor: Executor, max_sessions: usize) -> Self {
        SessionRegistry {
            cluster,
            executor,
            max_sessions: max_sessions.max(1),
            inner: Mutex::new(RegistryInner {
                sessions: Vec::new(),
                opened: 0,
                hits: 0,
                evictions: 0,
                retired: SessionStats::default(),
            }),
        }
    }

    /// The shared executor every session of this registry submits to.
    pub fn executor(&self) -> &Executor {
        &self.executor
    }

    /// The session for `name`, opening (and possibly evicting) as needed.
    /// A hit moves the session to the front of the LRU order. Opening
    /// happens under the registry lock: concurrent first-touches of the
    /// same dataset must open it exactly once, and dataset builds are
    /// bounded (registry datasets are generated, not downloaded).
    pub fn get(&self, name: &str) -> Result<MiningSession, ServeError> {
        let mut inner = lock(&self.inner);
        if let Some(pos) = inner.sessions.iter().position(|(n, _)| n == name) {
            let entry = inner.sessions.remove(pos);
            let session = entry.1.clone();
            inner.sessions.insert(0, entry);
            inner.hits += 1;
            return Ok(session);
        }
        let db = registry::try_load(name)
            .ok_or_else(|| ServeError::UnknownDataset(name.to_string()))?;
        let session = MiningSession::for_db(&db, self.cluster.clone())
            .executor(self.executor.clone())
            .build()?;
        inner.opened += 1;
        inner.sessions.insert(0, (name.to_string(), session.clone()));
        while inner.sessions.len() > self.max_sessions {
            if let Some((_, evicted)) = inner.sessions.pop() {
                let stats = evicted.stats();
                inner.retired.absorb(&stats);
                inner.evictions += 1;
            }
        }
        Ok(session)
    }

    /// Snapshot the registry's counters and the aggregate session stats.
    pub fn stats(&self) -> RegistryStats {
        let inner = lock(&self.inner);
        let mut totals = inner.retired;
        for (_, session) in &inner.sessions {
            let stats = session.stats();
            totals.absorb(&stats);
        }
        RegistryStats {
            open: inner.sessions.iter().map(|(n, _)| n.clone()).collect(),
            opened: inner.opened,
            hits: inner.hits,
            evictions: inner.evictions,
            totals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Algorithm, MiningRequest};

    fn registry(max: usize) -> SessionRegistry {
        let cluster = ClusterConfig::paper_cluster();
        let executor = Executor::new(4);
        SessionRegistry::new(cluster, executor, max)
    }

    #[test]
    fn lookups_hit_the_open_session() {
        let reg = registry(4);
        let a = reg.get("t5i2d200").expect("quest name opens");
        let b = reg.get("t5i2d200").expect("second lookup");
        // Same underlying session: counters are shared.
        a.run(&MiningRequest::new(Algorithm::Spc).min_sup(0.4)).expect("mines");
        assert_eq!(b.stats().queries, 1);
        let stats = reg.stats();
        assert_eq!(stats.opened, 1);
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.evictions, 0);
        assert_eq!(stats.open, vec!["t5i2d200".to_string()]);
        assert_eq!(stats.totals.queries, 1);
    }

    #[test]
    fn unknown_names_are_typed_errors() {
        let reg = registry(4);
        let err = reg.get("atlantis").expect_err("unknown dataset");
        assert!(matches!(err, ServeError::UnknownDataset(ref n) if n == "atlantis"), "{err:?}");
    }

    #[test]
    fn coldest_session_is_evicted_and_its_stats_survive() {
        let reg = registry(2);
        let a = reg.get("t5i2d200").expect("open a");
        a.run(&MiningRequest::new(Algorithm::Spc).min_sup(0.4)).expect("mines");
        reg.get("t5i2d300").expect("open b");
        reg.get("t5i2d200").expect("touch a"); // a is now most recent
        reg.get("t5i2d400").expect("open c evicts b");
        let stats = reg.stats();
        assert_eq!(stats.opened, 3);
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.open, vec!["t5i2d400".to_string(), "t5i2d200".to_string()]);
        // a's query survived the churn in the aggregate.
        assert_eq!(stats.totals.queries, 1);
        // Re-opening the evicted dataset is a cold open, not a hit.
        reg.get("t5i2d300").expect("reopen b");
        assert_eq!(reg.stats().opened, 4);
    }

    #[test]
    fn all_sessions_share_the_one_executor() {
        let reg = registry(4);
        let a = reg.get("t5i2d200").expect("open a");
        let b = reg.get("t5i2d300").expect("open b");
        assert_eq!(a.executor().workers(), reg.executor().workers());
        assert_eq!(b.executor().workers(), reg.executor().workers());
    }
}
