//! Regenerates every table and figure of the paper's evaluation (§5).
//! Each bench binary in `rust/benches/` is a thin wrapper over this module.

pub mod calibrate;
pub mod report;
pub mod tables;
pub mod timing;

/// Shared figure-bench runner for Figs 2-4: paper sweep on one dataset,
/// printing both sub-figures and saving CSVs.
pub fn run_figure_bench(dataset: &str, figure_no: usize) {
    use report::figure_csv;
    use tables::{figure_a, figure_b, sweep, SweepSpec};

    let db = crate::dataset::registry::load(dataset);
    let spec = SweepSpec::paper(&db);
    eprintln!(
        "fig{figure_no}: sweeping {} over min_sup {:?} with {} algorithms...",
        dataset, spec.min_sups, spec.algorithms.len()
    );
    let t0 = std::time::Instant::now();
    let result = sweep(&spec).expect("paper sweep specs are always valid");
    let fa = figure_a(&result, dataset);
    let fb = figure_b(&result, dataset);
    println!("{fa}");
    println!("{fb}");
    // CSVs for plotting.
    let mut all = Vec::new();
    for (ai, &algo) in result.algorithms.iter().enumerate() {
        let mut s = report::Series::new(algo.name());
        for (si, &ms) in result.min_sups.iter().enumerate() {
            s.push(ms, result.runs[ai][si].actual_time);
        }
        all.push(s);
    }
    timing::save_report(&format!("fig{figure_no}_{dataset}.csv"), &figure_csv("min_sup", &all));
    timing::save_report(&format!("fig{figure_no}_{dataset}.txt"), &format!("{fa}\n{fb}"));
    eprintln!("fig{figure_no} done in {:.1} s host time", t0.elapsed().as_secs_f64());
}

pub use report::{fmt_row, Series};
