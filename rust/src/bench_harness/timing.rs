//! Wall-clock micro-benchmark helper (criterion is unavailable offline):
//! warmup + N timed iterations, reporting min/median/mean.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
/// Timing summary of one micro-benchmark.
pub struct Stats {
    /// Timed iterations.
    pub iters: usize,
    /// Fastest iteration, seconds.
    pub min_s: f64,
    /// Median iteration, seconds.
    pub median_s: f64,
    /// Mean iteration, seconds.
    pub mean_s: f64,
}

impl Stats {
    /// Throughput at the median: units per second.
    pub fn per_sec(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.median_s
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "min {:>10.3} ms   median {:>10.3} ms   mean {:>10.3} ms   ({} iters)",
            self.min_s * 1e3,
            self.median_s * 1e3,
            self.mean_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs. The closure should
/// include a `std::hint::black_box` on its result to defeat DCE.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let min_s = samples[0];
    let median_s = samples[samples.len() / 2];
    let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
    Stats { iters: samples.len(), min_s, median_s, mean_s }
}

/// Write a report file under `target/paper_results/` (best effort — bench
/// output is also printed to stdout).
pub fn save_report(name: &str, contents: &str) {
    let dir = std::path::Path::new("target/paper_results");
    if std::fs::create_dir_all(dir).is_ok() {
        let _ = std::fs::write(dir.join(name), contents);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_ordered_stats() {
        let mut x = 0u64;
        let s = bench(1, 9, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(s.iters, 9);
        assert!(s.min_s <= s.median_s);
        assert!(s.min_s > 0.0);
        assert!(s.per_sec(1000.0) > 0.0);
    }

    #[test]
    fn save_report_writes() {
        save_report("test_report.txt", "hello");
        let p = std::path::Path::new("target/paper_results/test_report.txt");
        assert!(p.exists());
        let _ = std::fs::remove_file(p);
    }
}
