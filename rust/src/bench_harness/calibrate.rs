//! Cost-model calibration against the paper's Table 3 (SPC on c20d10k at
//! min_sup 0.15).
//!
//! One global scale factor is fitted over the compute weights (the fixed
//! overheads are taken from the paper's pass-1 rows directly): weights are
//! multiplied by `Σ paper_compute / Σ model_compute`, aligning total compute
//! while leaving *relative* per-operation costs untouched — so no
//! per-algorithm fitting can occur.

use crate::cluster::ClusterConfig;
use crate::coordinator::{Algorithm, MiningRequest, MiningSession, RunOptions};
use crate::dataset::registry;

/// Paper Table 3, SPC row: per-phase elapsed seconds on c20d10k @ 0.15.
pub const PAPER_TABLE3_SPC: [f64; 14] =
    [16.0, 18.0, 24.0, 32.0, 48.0, 70.0, 91.0, 83.0, 51.0, 34.0, 22.0, 18.0, 16.0, 21.0];

#[derive(Debug)]
/// Output of the calibration fit: scale plus agreement metrics.
pub struct Calibration {
    /// Simulated per-phase seconds with current weights.
    pub model: Vec<f64>,
    /// Fitted global scale for compute weights.
    pub scale: f64,
    /// Shape agreement after scaling: Pearson correlation model vs paper.
    pub correlation: f64,
}

/// Run SPC on c20d10k and fit the global compute scale.
pub fn calibrate(cluster: &ClusterConfig) -> Calibration {
    let db = registry::c20d10k();
    let opts = RunOptions { split_lines: registry::split_lines("c20d10k"), ..Default::default() };
    let out = MiningSession::for_db(&db, cluster.clone())
        .options(&opts)
        .build()
        .expect("calibration session")
        .run(&MiningRequest::new(Algorithm::Spc).min_sup(0.15))
        .expect("calibration run");
    let model: Vec<f64> = out.phases.iter().map(|p| p.elapsed).collect();

    // Compare compute portions (subtract the fixed per-job floor).
    let floor = cluster.overhead.job_submit;
    let n = model.len().min(PAPER_TABLE3_SPC.len());
    let paper_compute: f64 = PAPER_TABLE3_SPC[..n].iter().map(|t| (t - floor).max(0.0)).sum();
    let model_compute: f64 = model[..n].iter().map(|t| (t - floor).max(0.0)).sum();
    let scale = if model_compute > 0.0 { paper_compute / model_compute } else { 1.0 };

    let correlation = pearson(&model[..n], &PAPER_TABLE3_SPC[..n]);
    Calibration { model, scale, correlation }
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    if n < 2 {
        return 1.0;
    }
    let ma = a[..n].iter().sum::<f64>() / n as f64;
    let mb = b[..n].iter().sum::<f64>() / n as f64;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..n {
        cov += (a[i] - ma) * (b[i] - mb);
        va += (a[i] - ma).powi(2);
        vb += (b[i] - mb).powi(2);
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Apply a calibration's scale to a weight set.
pub fn apply_scale(cluster: &mut ClusterConfig, scale: f64) {
    let w = &mut cluster.weights;
    w.record *= scale;
    w.map_tuple *= scale;
    w.join_pair *= scale;
    w.prune_check *= scale;
    w.cand_built *= scale;
    w.subset_visit *= scale;
    w.combine_tuple *= scale;
    w.shuffle_tuple *= scale;
    w.reduce_tuple *= scale;
}

/// CLI entry: calibrate, report, optionally emit fitted TOML.
pub fn run_calibration(emit: bool) -> String {
    use std::fmt::Write as _;
    let mut cluster = ClusterConfig::paper_cluster();
    let cal = calibrate(&cluster);
    let mut s = String::new();
    let _ = writeln!(s, "SPC c20d10k @0.15 — model vs paper Table 3");
    let _ = writeln!(s, "{:<8} {:>10} {:>10}", "phase", "model(s)", "paper(s)");
    for (i, m) in cal.model.iter().enumerate() {
        let p = PAPER_TABLE3_SPC.get(i).copied().unwrap_or(f64::NAN);
        let _ = writeln!(s, "{:<8} {:>10.1} {:>10.1}", i + 1, m, p);
    }
    let _ = writeln!(s, "suggested compute-weight scale: {:.3}", cal.scale);
    let _ = writeln!(s, "shape correlation (pearson): {:.3}", cal.correlation);
    if emit {
        apply_scale(&mut cluster, cal.scale);
        let _ =
            writeln!(s, "\n# fitted cluster config:\n{}", crate::config::render_cluster(&cluster));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_basics() {
        assert!((pearson(&[1.0, 2.0, 3.0], &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&[1.0, 2.0, 3.0], &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1.0, 1.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn apply_scale_scales_all() {
        let mut c = ClusterConfig::paper_cluster();
        let before = c.weights;
        apply_scale(&mut c, 2.0);
        assert_eq!(c.weights.record, before.record * 2.0);
        assert_eq!(c.weights.reduce_tuple, before.reduce_tuple * 2.0);
    }
}
