//! Shared runners for the paper's tables and figures: sweep helpers that
//! run algorithm × min_sup grids and format phase-breakdown tables.

use super::report::{figure_table, Series};
use crate::cluster::{ClusterConfig, FaultModel};
use crate::coordinator::{
    Algorithm, CountingBackend, MiningError, MiningOutcome, MiningRequest, MiningSession,
    RunOptions,
};
use crate::dataset::{registry, TransactionDb};
use crate::hdfs;

/// Options for a figure sweep on one dataset.
pub struct SweepSpec<'a> {
    /// Dataset under test.
    pub db: &'a TransactionDb,
    /// The min_sup x-axis, paper order (high -> low).
    pub min_sups: Vec<f64>,
    /// Algorithms to run.
    pub algorithms: Vec<Algorithm>,
    /// Simulated cluster configuration.
    pub cluster: ClusterConfig,
    /// Shared run options (split size, DPC α, ...).
    pub opts: RunOptions,
}

impl<'a> SweepSpec<'a> {
    /// The paper's setup for `db`: its Figs 2-4 min_sup sweep, split size,
    /// DPC α (3.0 on chess, 2.0 elsewhere — §5.2), paper cluster.
    pub fn paper(db: &'a TransactionDb) -> Self {
        let name = db.name.as_str();
        let min_sups = registry::figure_min_sups(name)
            .unwrap_or_else(|| vec![0.35, 0.30, 0.25, 0.20, 0.15]);
        let opts = RunOptions {
            split_lines: registry::split_lines(name),
            dpc_alpha: registry::paper_dpc_alpha(name),
            ..Default::default()
        };
        Self {
            db,
            min_sups,
            algorithms: Algorithm::ALL.to_vec(),
            cluster: ClusterConfig::paper_cluster(),
            opts,
        }
    }
}

/// Result grid of a sweep: `runs[algo_idx][sup_idx]`.
pub struct SweepResult {
    /// Algorithms of the grid, row order.
    pub algorithms: Vec<Algorithm>,
    /// min_sup values of the grid, column order.
    pub min_sups: Vec<f64>,
    /// Outcome grid indexed `[algo_idx][sup_idx]`.
    pub runs: Vec<Vec<MiningOutcome>>,
}

/// Run the full grid over one shared [`MiningSession`]: the split plan is
/// computed once, and every cell at the same support reuses the memoized
/// Job1 scan across all algorithms (DESIGN.md §8). Spec values are
/// user-reachable (CLI `--min-sups`), so validation errors propagate as
/// typed [`MiningError`]s rather than panicking.
pub fn sweep(spec: &SweepSpec<'_>) -> Result<SweepResult, MiningError> {
    let session =
        MiningSession::for_db(spec.db, spec.cluster.clone()).options(&spec.opts).build()?;
    let mut runs = Vec::with_capacity(spec.algorithms.len());
    for &algo in &spec.algorithms {
        let mut row = Vec::with_capacity(spec.min_sups.len());
        for &ms in &spec.min_sups {
            let req = MiningRequest::from_options(algo, ms, &spec.opts);
            row.push(session.run(&req)?);
        }
        runs.push(row);
    }
    Ok(SweepResult { algorithms: spec.algorithms.clone(), min_sups: spec.min_sups.clone(), runs })
}

/// Figure (a) of Figs 2-4: SPC/FPC/VFPC/DPC/ETDPC execution time vs min_sup.
pub fn figure_a(result: &SweepResult, dataset: &str) -> String {
    render_figure(result, dataset, "(a)", &[
        Algorithm::Spc,
        Algorithm::Fpc,
        Algorithm::Vfpc,
        Algorithm::Dpc,
        Algorithm::Etdpc,
    ])
}

/// Figure (b): VFPC/Optimized-VFPC/ETDPC/Optimized-ETDPC.
pub fn figure_b(result: &SweepResult, dataset: &str) -> String {
    render_figure(result, dataset, "(b)", &[
        Algorithm::Vfpc,
        Algorithm::OptimizedVfpc,
        Algorithm::Etdpc,
        Algorithm::OptimizedEtdpc,
    ])
}

fn render_figure(result: &SweepResult, dataset: &str, sub: &str, algos: &[Algorithm]) -> String {
    let mut series = Vec::new();
    for &a in algos {
        let Some(ai) = result.algorithms.iter().position(|&x| x == a) else { continue };
        let mut s = Series::new(a.name());
        for (si, &ms) in result.min_sups.iter().enumerate() {
            s.push(ms, result.runs[ai][si].actual_time);
        }
        series.push(s);
    }
    figure_table(
        &format!("{dataset} {sub}: execution time (simulated s) vs minimum support"),
        "min_sup",
        &series,
    )
}

/// Phase-breakdown table (Tables 3-5 / 10-12 layout): per-phase elapsed
/// time spread over the passes each phase combined, plus Total and Actual.
pub fn phase_time_table(outcomes: &[&MiningOutcome], title: &str) -> String {
    use std::fmt::Write as _;
    let max_pass = outcomes
        .iter()
        .map(|o| {
            o.phases
                .iter()
                .map(|p| p.first_pass + p.n_passes.max(1) - 1)
                .max()
                .unwrap_or(1)
        })
        .max()
        .unwrap_or(1);
    let mut s = String::new();
    let _ = writeln!(s, "# {title}");
    let _ = write!(s, "{:<22}", "Algorithm (phases)");
    for p in 1..=max_pass {
        let _ = write!(s, " {:>9}", format!("Pass {p}"));
    }
    let _ = writeln!(s, " {:>9} {:>9}", "Total", "Actual");
    for o in outcomes {
        let _ = write!(s, "{:<22}", format!("{} ({})", o.algorithm.name(), o.n_phases()));
        let mut cells: Vec<String> = vec![String::new(); max_pass];
        for ph in &o.phases {
            // A combined phase spans several pass columns: put the elapsed
            // time in the first column and a ditto marker in the rest,
            // mirroring the paper's merged cells.
            let first = ph.first_pass - 1;
            cells[first] = format!("{:.0}", ph.elapsed);
            for c in cells
                .iter_mut()
                .take(ph.first_pass + ph.n_passes.max(1) - 1)
                .skip(ph.first_pass)
            {
                *c = "·".into();
            }
        }
        for c in &cells {
            let _ = write!(s, " {:>9}", if c.is_empty() { "-" } else { c });
        }
        let _ = writeln!(s, " {:>9.0} {:>9.0}", o.total_time, o.actual_time);
    }
    // Attribute each row's phases to the MapReduce jobs that ran them (the
    // executor threads the JobBuilder name through its task meters into
    // PhaseRecord), tagged with each phase's resolved counting backend.
    let _ = writeln!(s);
    for o in outcomes {
        let jobs: Vec<String> = o
            .phases
            .iter()
            .map(|p| match p.backend_label().as_str() {
                "-" => p.job.clone(),
                backend => format!("{} [{backend}]", p.job),
            })
            .collect();
        let _ =
            writeln!(s, "{:<22} {}", format!("  {} jobs:", o.algorithm.name()), jobs.join(" | "));
    }
    s
}

/// One row of a Fig 5(a)-style scale grid: a dataset mined once per
/// algorithm at a single min_sup.
pub struct ScaleRun {
    /// Dataset name (registry, Quest-family, or file stem).
    pub dataset: String,
    /// Transactions in the dataset.
    pub n_txns: usize,
    /// Fractional minimum support used for this row.
    pub min_sup: f64,
    /// Counting backend every cell of this row was mined with (as
    /// *requested* — `auto` resolves per pass; the per-phase picks live on
    /// each outcome's [`crate::coordinator::PhaseRecord::backends`]).
    pub backend: CountingBackend,
    /// One outcome per algorithm, parallel to the grid's algorithm list.
    pub outcomes: Vec<MiningOutcome>,
}

/// One streamed scale-grid row: build (or reuse) the Quest store for
/// `name` under `cache`, then mine it once per algorithm at the dataset's
/// reference min_sup with splits at the store's block granularity. Shared
/// by `mrapriori sweep --datasets` and the fig5 bench so the two cannot
/// drift.
pub fn quest_scale_run(
    name: &str,
    algorithms: &[Algorithm],
    backend: CountingBackend,
    cluster: &ClusterConfig,
    cache: &std::path::Path,
) -> anyhow::Result<ScaleRun> {
    let src = registry::quest_store(name, cache)?;
    let seed = RunOptions::default().seed;
    let file = hdfs::put_segmented(
        std::sync::Arc::new(src),
        cluster.nodes.len(),
        hdfs::DEFAULT_REPLICATION,
        seed,
    );
    let min_sup = registry::reference_min_sup(&file.name).unwrap_or(0.01);
    // One session per row: splits follow the store's block granularity
    // (the builder's default for pre-stored files) and every algorithm
    // after the first reuses the row's Job1 scan. Errors (e.g. a
    // degenerate caller-supplied cluster) propagate instead of panicking.
    let session = MiningSession::builder(file, cluster.clone()).build()?;
    let mut outcomes = Vec::with_capacity(algorithms.len());
    for &algo in algorithms {
        outcomes
            .push(session.run(&MiningRequest::new(algo).min_sup(min_sup).backend(backend))?);
    }
    Ok(ScaleRun {
        dataset: session.file().name.clone(),
        n_txns: session.file().len(),
        min_sup,
        backend,
        outcomes,
    })
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Markdown scale table (the paper's Fig 5(a) as a table): one row per
/// dataset, one simulated-seconds column per algorithm.
pub fn scale_markdown(algorithms: &[Algorithm], runs: &[ScaleRun]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = write!(s, "| dataset | transactions | min_sup | backend |");
    for a in algorithms {
        let _ = write!(s, " {} (s) |", a.name());
    }
    let _ = writeln!(s);
    let _ = write!(s, "|---|---:|---:|---|");
    for _ in algorithms {
        let _ = write!(s, "---:|");
    }
    let _ = writeln!(s);
    for run in runs {
        let _ = write!(
            s,
            "| {} | {} | {:.4} | {} |",
            run.dataset,
            run.n_txns,
            run.min_sup,
            run.backend.name()
        );
        for o in &run.outcomes {
            let _ = write!(s, " {:.1} |", o.actual_time);
        }
        let _ = writeln!(s);
    }
    s
}

/// JSON dump of the same grid, with per-run detail (phase count, frequent
/// itemsets, simulated and host times) for downstream tooling.
pub fn scale_json(algorithms: &[Algorithm], runs: &[ScaleRun]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = write!(s, "{{\n  \"algorithms\": [");
    for (i, a) in algorithms.iter().enumerate() {
        let _ = write!(s, "{}\"{}\"", if i > 0 { ", " } else { "" }, json_escape(a.name()));
    }
    let _ = writeln!(s, "],\n  \"runs\": [");
    for (ri, run) in runs.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"dataset\": \"{}\", \"n_txns\": {}, \"min_sup\": {}, \"backend\": \"{}\", \
             \"results\": [",
            json_escape(&run.dataset),
            run.n_txns,
            run.min_sup,
            run.backend.name(),
        );
        for (i, o) in run.outcomes.iter().enumerate() {
            let _ = write!(
                s,
                "{}{{\"algorithm\": \"{}\", \"actual_time\": {:.3}, \"total_time\": {:.3}, \
                 \"wall_time\": {:.3}, \"phases\": {}, \"frequent\": {}, \"levels\": {}}}",
                if i > 0 { ", " } else { "" },
                json_escape(o.algorithm.name()),
                o.actual_time,
                o.total_time,
                o.wall_time,
                o.n_phases(),
                o.total_frequent(),
                o.levels.len(),
            );
        }
        let _ = writeln!(s, "]}}{}", if ri + 1 < runs.len() { "," } else { "" });
    }
    let _ = writeln!(s, "  ]\n}}");
    s
}

/// One labeled cell column of a fault-robustness grid: a scenario either
/// runs clean (`model: None`, the baseline) or under a [`FaultModel`].
pub struct FaultScenario {
    /// Human-readable column label (e.g. `5% failures`).
    pub label: String,
    /// The injection model; `None` is the clean baseline.
    pub model: Option<FaultModel>,
}

impl FaultScenario {
    /// The default robustness grid: clean baseline, task failures alone,
    /// stragglers alone, and stragglers rescued by speculation — the
    /// scenario family of the fault ablation and `sweep --faults`.
    pub fn grid(fail_prob: f64, straggler_prob: f64) -> Vec<FaultScenario> {
        vec![
            FaultScenario { label: "clean".into(), model: None },
            FaultScenario {
                label: format!("{:.0}% failures", fail_prob * 100.0),
                model: Some(FaultModel { fail_prob, ..Default::default() }),
            },
            FaultScenario {
                label: format!("{:.0}% stragglers", straggler_prob * 100.0),
                model: Some(FaultModel { straggler_prob, ..Default::default() }),
            },
            FaultScenario {
                label: "stragglers + speculation".into(),
                model: Some(FaultModel {
                    straggler_prob,
                    speculation: true,
                    ..Default::default()
                }),
            },
        ]
    }
}

/// Run `algorithms` × `scenarios` over one shared session (every cell at
/// the same support reuses the memoized Job1 scan — fault models do not
/// split the cache key). Returns the grid indexed `[scenario][algo]`.
/// `request_for` supplies each algorithm's base request (support, α, ...);
/// the scenario's fault model is layered on top.
pub fn fault_sweep(
    session: &MiningSession,
    algorithms: &[Algorithm],
    scenarios: &[FaultScenario],
    request_for: impl Fn(Algorithm) -> MiningRequest,
) -> Result<Vec<Vec<MiningOutcome>>, MiningError> {
    let mut grid = Vec::with_capacity(scenarios.len());
    for scenario in scenarios {
        let mut row = Vec::with_capacity(algorithms.len());
        for &algo in algorithms {
            let mut req = request_for(algo);
            if let Some(model) = &scenario.model {
                req = req.faults(model.clone());
            }
            row.push(session.run(&req)?);
        }
        grid.push(row);
    }
    Ok(grid)
}

/// Markdown robustness table over a [`fault_sweep`] grid: one row per
/// algorithm, one actual-time column per scenario (fault columns annotated
/// with the slowdown vs the clean column), followed by a per-cell
/// injection-counter table.
pub fn fault_markdown(
    algorithms: &[Algorithm],
    scenarios: &[FaultScenario],
    grid: &[Vec<MiningOutcome>],
) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = write!(s, "| algorithm |");
    for sc in scenarios {
        let _ = write!(s, " {} (s) |", sc.label);
    }
    let _ = writeln!(s);
    let _ = write!(s, "|---|");
    for _ in scenarios {
        let _ = write!(s, "---:|");
    }
    let _ = writeln!(s);
    for (ai, algo) in algorithms.iter().enumerate() {
        let _ = write!(s, "| {} |", algo.name());
        let clean = grid[0][ai].actual_time;
        for (si, _) in scenarios.iter().enumerate() {
            let out = &grid[si][ai];
            match out.faulted_actual_time() {
                None => {
                    let _ = write!(s, " {:.1} |", out.actual_time);
                }
                Some(faulted) => {
                    let _ = write!(
                        s,
                        " {:.1} ({:+.1}%) |",
                        faulted,
                        100.0 * (faulted / clean - 1.0)
                    );
                }
            }
        }
        let _ = writeln!(s);
    }
    let _ = writeln!(s);
    let _ = writeln!(
        s,
        "| algorithm | scenario | phases | attempts | failures | stragglers | spec launches | spec wins |"
    );
    let _ = writeln!(s, "|---|---|---:|---:|---:|---:|---:|---:|");
    for (ai, algo) in algorithms.iter().enumerate() {
        for (si, sc) in scenarios.iter().enumerate() {
            let out = &grid[si][ai];
            let Some(t) = out.fault_totals() else { continue };
            let _ = writeln!(
                s,
                "| {} | {} | {} | {} | {} | {} | {} | {} |",
                algo.name(),
                sc.label,
                out.n_phases(),
                t.attempts,
                t.failures,
                t.stragglers,
                t.speculative_launches,
                t.speculative_wins,
            );
        }
    }
    s
}

/// Per-phase clean→faulted makespan table for fault-model runs (the
/// `mine --algo all` fault view): each cell shows the phase's clean and
/// faulted elapsed seconds, with per-run injection counters at the right.
pub fn fault_phase_table(outcomes: &[&MiningOutcome], title: &str) -> String {
    use std::fmt::Write as _;
    let max_phases = outcomes.iter().map(|o| o.n_phases()).max().unwrap_or(0);
    let mut s = String::new();
    let _ = writeln!(s, "# {title}");
    let _ = write!(s, "{:<22}", "Algorithm (phases)");
    for p in 1..=max_phases {
        let _ = write!(s, " {:>11}", format!("Phase {p}"));
    }
    let _ = writeln!(
        s,
        " {:>13} {:>26}",
        "Total", "attempts/fail/strag/spec"
    );
    for o in outcomes {
        let _ = write!(s, "{:<22}", format!("{} ({})", o.algorithm.name(), o.n_phases()));
        for p in 0..max_phases {
            let cell = match o.phases.get(p) {
                None => "-".to_string(),
                Some(ph) => match &ph.faults {
                    None => format!("{:.0}", ph.elapsed),
                    Some(f) => format!("{:.0}→{:.0}", ph.elapsed, f.elapsed()),
                },
            };
            let _ = write!(s, " {:>11}", cell);
        }
        let total = match o.faulted_total_time() {
            None => format!("{:.0}", o.total_time),
            Some(faulted) => format!("{:.0}→{:.0}", o.total_time, faulted),
        };
        let counters = match o.fault_totals() {
            None => "-".to_string(),
            Some(t) => format!(
                "{}/{}/{}/{}+{}",
                t.attempts, t.failures, t.stragglers, t.speculative_launches, t.speculative_wins
            ),
        };
        let _ = writeln!(s, " {total:>13} {counters:>26}");
    }
    s
}

/// Candidates-per-phase table (Tables 7-9 layout).
pub fn candidates_table(outcomes: &[&MiningOutcome], title: &str) -> String {
    use std::fmt::Write as _;
    let max_pass = outcomes
        .iter()
        .map(|o| {
            o.phases
                .iter()
                .map(|p| p.first_pass + p.n_passes.max(1) - 1)
                .max()
                .unwrap_or(1)
        })
        .max()
        .unwrap_or(1);
    let mut s = String::new();
    let _ = writeln!(s, "# {title}");
    let _ = write!(s, "{:<22}", "Algorithm");
    for p in 2..=max_pass {
        let _ = write!(s, " {:>9}", format!("Pass {p}"));
    }
    let _ = writeln!(s);
    for o in outcomes {
        let _ = write!(s, "{:<22}", o.algorithm.name());
        let mut cells: Vec<String> = vec![String::new(); max_pass + 1];
        for ph in o.phases.iter().skip(1) {
            let first = ph.first_pass;
            cells[first] = format!("{}", ph.candidates);
            for c in cells
                .iter_mut()
                .take(ph.first_pass + ph.n_passes.max(1))
                .skip(ph.first_pass + 1)
            {
                *c = "·".into();
            }
        }
        for c in cells.iter().take(max_pass + 1).skip(2) {
            let _ = write!(s, " {:>9}", if c.is_empty() { "-" } else { c });
        }
        let _ = writeln!(s);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ibm::{generate, IbmParams};

    fn tiny_db() -> TransactionDb {
        generate(&IbmParams {
            n_txns: 120,
            n_items: 30,
            avg_txn_len: 6.0,
            avg_pattern_len: 3.0,
            n_patterns: 8,
            seed: 5,
            ..Default::default()
        })
    }

    fn tiny_spec(db: &TransactionDb) -> SweepSpec<'_> {
        SweepSpec {
            db,
            min_sups: vec![0.4, 0.2],
            algorithms: vec![Algorithm::Spc, Algorithm::Vfpc, Algorithm::OptimizedVfpc],
            cluster: ClusterConfig::uniform(2, 2),
            opts: RunOptions { split_lines: 30, ..Default::default() },
        }
    }

    #[test]
    fn sweep_grid_shape() {
        let db = tiny_db();
        let r = sweep(&tiny_spec(&db)).unwrap();
        assert_eq!(r.runs.len(), 3);
        assert_eq!(r.runs[0].len(), 2);
    }

    #[test]
    fn figures_render() {
        let db = tiny_db();
        let r = sweep(&tiny_spec(&db)).unwrap();
        let fa = figure_a(&r, "tiny");
        assert!(fa.contains("SPC"));
        assert!(fa.contains("VFPC"));
        let fb = figure_b(&r, "tiny");
        assert!(fb.contains("Optimized-VFPC"));
    }

    #[test]
    fn phase_tables_render() {
        let db = tiny_db();
        let r = sweep(&tiny_spec(&db)).unwrap();
        let outs: Vec<&MiningOutcome> = r.runs.iter().map(|row| &row[1]).collect();
        let t = phase_time_table(&outs, "tiny 0.2");
        assert!(t.contains("Total"));
        assert!(t.contains("SPC"));
        let c = candidates_table(&outs, "tiny 0.2 candidates");
        assert!(c.contains("Pass 2"));
    }

    #[test]
    fn scale_table_renders_markdown_and_json() {
        let db = tiny_db();
        let algorithms = vec![Algorithm::Spc, Algorithm::OptimizedEtdpc];
        let cluster = ClusterConfig::uniform(2, 2);
        let opts = RunOptions { split_lines: 30, ..Default::default() };
        let session =
            MiningSession::for_db(&db, cluster).options(&opts).build().unwrap();
        let outcomes: Vec<MiningOutcome> = algorithms
            .iter()
            .map(|&a| session.run(&MiningRequest::from_options(a, 0.3, &opts)).unwrap())
            .collect();
        let runs = vec![ScaleRun {
            dataset: db.name.clone(),
            n_txns: db.len(),
            min_sup: 0.3,
            backend: CountingBackend::Trie,
            outcomes,
        }];
        let md = scale_markdown(&algorithms, &runs);
        assert!(md.contains("| dataset |"));
        assert!(md.contains("| backend |"));
        assert!(md.contains("SPC (s)"));
        assert!(md.contains("Optimized-ETDPC (s)"));
        assert!(md.contains(&format!("| {} | 120 | 0.3000 | trie |", db.name)));
        let json = scale_json(&algorithms, &runs);
        assert!(json.contains("\"algorithms\": [\"SPC\", \"Optimized-ETDPC\"]"));
        assert!(json.contains("\"n_txns\": 120"));
        assert!(json.contains("\"backend\": \"trie\""));
        assert!(json.contains("\"frequent\":"));
        // Balanced braces/brackets (cheap well-formedness check).
        for (open, close) in [('{', '}'), ('[', ']')] {
            let o = json.matches(open).count();
            let c = json.matches(close).count();
            assert_eq!(o, c, "unbalanced {open}{close} in {json}");
        }
    }

    #[test]
    fn quest_scale_run_streams_and_renders() {
        let cache = std::env::temp_dir().join("mrapriori_tables_quest_cache");
        let _ = std::fs::remove_dir_all(&cache);
        let algorithms = vec![Algorithm::Spc];
        let run = quest_scale_run(
            "t6i2d300",
            &algorithms,
            CountingBackend::Bitmap,
            &ClusterConfig::uniform(2, 2),
            &cache,
        )
        .unwrap();
        assert_eq!(run.dataset, "t6i2d300");
        assert_eq!(run.backend, CountingBackend::Bitmap);
        assert_eq!(run.n_txns, 300);
        assert_eq!(run.outcomes.len(), 1);
        assert!(run.outcomes[0].total_frequent() > 0);
        let md = scale_markdown(&algorithms, &[run]);
        assert!(md.contains("t6i2d300"));
        std::fs::remove_dir_all(&cache).unwrap();
    }

    #[test]
    fn fault_sweep_renders_robustness_tables() {
        let db = tiny_db();
        let algorithms = [Algorithm::Spc, Algorithm::OptimizedVfpc];
        let session = MiningSession::for_db(&db, ClusterConfig::uniform(2, 2))
            .split_lines(30)
            .build()
            .unwrap();
        let scenarios = FaultScenario::grid(0.05, 0.15);
        assert_eq!(scenarios.len(), 4);
        assert!(scenarios[0].model.is_none(), "scenario 0 is the clean baseline");
        let grid = fault_sweep(&session, &algorithms, &scenarios, |algo| {
            MiningRequest::new(algo).min_sup(0.3)
        })
        .unwrap();
        assert_eq!(grid.len(), 4);
        assert_eq!(grid[0].len(), 2);
        // Output invariance across every cell of the grid.
        let reference = grid[0][0].all_frequent();
        for row in &grid {
            for out in row {
                assert_eq!(out.all_frequent(), reference, "fault model changed the mining");
            }
        }
        // All cells share one Job1 scan (fault models do not split the key).
        assert_eq!(session.stats().job1_runs, 1);
        let md = fault_markdown(&algorithms, &scenarios, &grid);
        assert!(md.contains("| algorithm |"), "{md}");
        assert!(md.contains("5% failures"), "{md}");
        assert!(md.contains("stragglers + speculation"), "{md}");
        assert!(md.contains("| SPC | 5% failures |"), "{md}");
        let refs: Vec<&MiningOutcome> = grid[1].iter().collect();
        let t = fault_phase_table(&refs, "tiny faults");
        assert!(t.contains("Phase 1"), "{t}");
        assert!(t.contains('→'), "fault cells must show clean→faulted: {t}");
        assert!(t.contains("attempts/fail/strag/spec"), "{t}");
    }

    #[test]
    fn paper_spec_uses_registry_settings() {
        let db = crate::dataset::registry::load("chess");
        let spec = SweepSpec::paper(&db);
        assert_eq!(spec.opts.split_lines, 400);
        assert_eq!(spec.opts.dpc_alpha, 3.0);
        assert_eq!(spec.min_sups.len(), 5);
    }
}
