//! Report formatting for the bench harness: aligned tables and CSV dumps
//! that mirror the rows/series the paper prints.

use std::fmt::Write as _;

/// One named series of (x, y) points — e.g. an algorithm's execution time
/// across the min_sup sweep of Figs 2–4.
#[derive(Debug, Clone)]
pub struct Series {
    /// Series label (e.g. an algorithm name).
    pub name: String,
    /// (x, y) points in plot order.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// Empty series with a label.
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    /// Append one (x, y) point.
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Render a figure's series as an aligned text table (x column + one column
/// per series), like the paper's figure data.
pub fn figure_table(title: &str, x_label: &str, series: &[Series]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# {title}");
    let _ = write!(s, "{x_label:>10}");
    for ser in series {
        let _ = write!(s, " {:>16}", ser.name);
    }
    let _ = writeln!(s);
    let n = series.iter().map(|s| s.points.len()).max().unwrap_or(0);
    for i in 0..n {
        let x = series.iter().find_map(|s| s.points.get(i)).map(|p| p.0).unwrap_or(f64::NAN);
        let _ = write!(s, "{x:>10.2}");
        for ser in series {
            match ser.points.get(i) {
                Some(&(_, y)) => {
                    let _ = write!(s, " {y:>16.1}");
                }
                None => {
                    let _ = write!(s, " {:>16}", "-");
                }
            }
        }
        let _ = writeln!(s);
    }
    s
}

/// CSV dump of the same series (one row per x; `figure.csv` artifacts).
pub fn figure_csv(x_label: &str, series: &[Series]) -> String {
    let mut s = String::new();
    let _ = write!(s, "{x_label}");
    for ser in series {
        let _ = write!(s, ",{}", ser.name);
    }
    let _ = writeln!(s);
    let n = series.iter().map(|s| s.points.len()).max().unwrap_or(0);
    for i in 0..n {
        let x = series.iter().find_map(|s| s.points.get(i)).map(|p| p.0).unwrap_or(f64::NAN);
        let _ = write!(s, "{x}");
        for ser in series {
            match ser.points.get(i) {
                Some(&(_, y)) => {
                    let _ = write!(s, ",{y}");
                }
                None => {
                    let _ = write!(s, ",");
                }
            }
        }
        let _ = writeln!(s);
    }
    s
}

/// Format one aligned row of labelled cells (phase tables).
pub fn fmt_row(label: &str, cells: &[String]) -> String {
    let mut s = format!("{label:<22}");
    for c in cells {
        let _ = write!(s, " {c:>9}");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Vec<Series> {
        let mut a = Series::new("SPC");
        a.push(0.3, 100.0);
        a.push(0.2, 200.0);
        let mut b = Series::new("VFPC");
        b.push(0.3, 80.0);
        b.push(0.2, 120.0);
        vec![a, b]
    }

    #[test]
    fn table_contains_all_points() {
        let t = figure_table("Fig X", "min_sup", &demo());
        assert!(t.contains("SPC"));
        assert!(t.contains("VFPC"));
        assert!(t.contains("200.0"));
        assert!(t.contains("0.30"));
    }

    #[test]
    fn csv_shape() {
        let c = figure_csv("min_sup", &demo());
        let lines: Vec<&str> = c.trim().lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "min_sup,SPC,VFPC");
        assert!(lines[1].starts_with("0.3,100"));
    }

    #[test]
    fn row_alignment() {
        let r = fmt_row("VFPC (7)", &["17".into(), "39".into()]);
        assert!(r.starts_with("VFPC (7)"));
        assert!(r.contains("17"));
    }

    #[test]
    fn ragged_series_handled() {
        let mut a = Series::new("A");
        a.push(1.0, 2.0);
        let b = Series::new("B");
        let t = figure_table("t", "x", &[a, b]);
        assert!(t.contains('-'));
    }
}
