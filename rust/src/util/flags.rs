//! A small command-line flag parser (clap is not available offline).
//!
//! Supports `--name value`, `--name=value`, boolean `--flag`, repeated flags,
//! positional arguments, subcommands (first bare word), and `--help` text
//! generation. Typed accessors parse on demand and produce readable errors.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
/// Declaration of one flag.
pub struct FlagSpec {
    /// Flag name (without the leading `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Whether the flag consumes a value.
    pub takes_value: bool,
    /// Default value, for value-taking flags.
    pub default: Option<&'static str>,
}

/// Declarative flag set for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct FlagSet {
    /// Subcommand name.
    pub command: &'static str,
    /// One-line subcommand description.
    pub about: &'static str,
    specs: Vec<FlagSpec>,
}

#[derive(Debug)]
/// Parse failures surfaced to the CLI user.
pub enum FlagError {
    /// A flag that was never declared.
    Unknown(String),
    /// A value-taking flag at the end of argv.
    MissingValue(String),
    /// A value that does not parse as the requested type.
    BadValue {
        /// Flag name.
        name: String,
        /// Offending raw value.
        value: String,
        /// Requested type name.
        ty: &'static str,
    },
    /// A required flag that was not given.
    MissingRequired(String),
}

impl std::fmt::Display for FlagError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FlagError::Unknown(name) => write!(f, "unknown flag --{name}"),
            FlagError::MissingValue(name) => write!(f, "flag --{name} requires a value"),
            FlagError::BadValue { name, value, ty } => {
                write!(f, "flag --{name}: cannot parse {value:?} as {ty}")
            }
            FlagError::MissingRequired(name) => write!(f, "missing required flag --{name}"),
        }
    }
}

impl std::error::Error for FlagError {}

impl FlagSet {
    /// Empty flag set for `command`.
    pub fn new(command: &'static str, about: &'static str) -> Self {
        Self { command, about, specs: Vec::new() }
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, takes_value: false, default: None });
        self
    }

    /// Add a value-taking flag.
    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, takes_value: true, default: None });
        self
    }

    /// Add a value-taking flag with a default.
    pub fn opt_default(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.specs.push(FlagSpec { name, help, takes_value: true, default: Some(default) });
        self
    }

    /// Render the help text.
    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.command, self.about);
        let _ = writeln!(s, "\nFlags:");
        for spec in &self.specs {
            let arg = if spec.takes_value {
                format!("--{} <v>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            let def = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let _ = writeln!(s, "  {arg:<26} {}{def}", spec.help);
        }
        s
    }

    /// Parse a raw argv slice into a [`Parsed`] bag.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, FlagError> {
        let mut values: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| FlagError::Unknown(name.clone()))?;
                let value = if spec.takes_value {
                    match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| FlagError::MissingValue(name.clone()))?
                        }
                    }
                } else {
                    inline.unwrap_or_else(|| "true".to_string())
                };
                values.entry(name).or_default().push(value);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        // Fill defaults.
        for spec in &self.specs {
            if let Some(d) = spec.default {
                values.entry(spec.name.to_string()).or_insert_with(|| vec![d.to_string()]);
            }
        }
        Ok(Parsed { values, positional })
    }
}

/// Result of parsing; typed accessors.
#[derive(Debug, Clone, Default)]
pub struct Parsed {
    values: BTreeMap<String, Vec<String>>,
    /// Bare (non-flag) arguments, in order.
    pub positional: Vec<String>,
}

impl Parsed {
    /// Last value of `name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every value of a repeated flag.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values.get(name).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    /// Whether the flag appeared (or defaulted).
    pub fn has(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    /// Boolean view of a flag.
    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Value of `name`, or a `MissingRequired` error.
    pub fn required(&self, name: &str) -> Result<&str, FlagError> {
        self.get(name).ok_or_else(|| FlagError::MissingRequired(name.to_string()))
    }

    fn parse_as<T: std::str::FromStr>(
        &self,
        name: &str,
        ty: &'static str,
    ) -> Result<Option<T>, FlagError> {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw.parse::<T>().map(Some).map_err(|_| FlagError::BadValue {
                name: name.to_string(),
                value: raw.to_string(),
                ty,
            }),
        }
    }

    /// Parse `name` as `usize`.
    pub fn usize(&self, name: &str) -> Result<Option<usize>, FlagError> {
        self.parse_as::<usize>(name, "usize")
    }

    /// Parse `name` as `u64`.
    pub fn u64(&self, name: &str) -> Result<Option<u64>, FlagError> {
        self.parse_as::<u64>(name, "u64")
    }

    /// Parse `name` as `f64`.
    pub fn f64(&self, name: &str) -> Result<Option<f64>, FlagError> {
        self.parse_as::<f64>(name, "f64")
    }

    /// Comma-separated list of f64 (e.g. `--min-sups 0.35,0.30,0.25`).
    pub fn f64_list(&self, name: &str) -> Result<Option<Vec<f64>>, FlagError> {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .split(',')
                .map(|p| {
                    p.trim().parse::<f64>().map_err(|_| FlagError::BadValue {
                        name: name.to_string(),
                        value: raw.to_string(),
                        ty: "list of f64",
                    })
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    fn demo_set() -> FlagSet {
        FlagSet::new("mine", "run a miner")
            .opt("dataset", "dataset name")
            .opt_default("min-sup", "0.25", "minimum support")
            .flag("verbose", "chatty output")
    }

    #[test]
    fn parses_space_and_equals_forms() {
        let p = demo_set().parse(&argv(&["--dataset", "chess", "--min-sup=0.5"])).unwrap();
        assert_eq!(p.get("dataset"), Some("chess"));
        assert_eq!(p.f64("min-sup").unwrap(), Some(0.5));
    }

    #[test]
    fn defaults_apply() {
        let p = demo_set().parse(&argv(&[])).unwrap();
        assert_eq!(p.f64("min-sup").unwrap(), Some(0.25));
        assert_eq!(p.get("dataset"), None);
    }

    #[test]
    fn bool_flags() {
        let p = demo_set().parse(&argv(&["--verbose"])).unwrap();
        assert!(p.bool("verbose"));
        let p = demo_set().parse(&argv(&[])).unwrap();
        assert!(!p.bool("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        let err = demo_set().parse(&argv(&["--nope"])).unwrap_err();
        assert!(matches!(err, FlagError::Unknown(_)));
    }

    #[test]
    fn missing_value_rejected() {
        let err = demo_set().parse(&argv(&["--dataset"])).unwrap_err();
        assert!(matches!(err, FlagError::MissingValue(_)));
    }

    #[test]
    fn positional_collected() {
        let p = demo_set().parse(&argv(&["chess", "--verbose", "extra"])).unwrap();
        assert_eq!(p.positional, vec!["chess".to_string(), "extra".to_string()]);
    }

    #[test]
    fn bad_typed_value() {
        let p = demo_set().parse(&argv(&["--min-sup", "abc"])).unwrap();
        assert!(p.f64("min-sup").is_err());
    }

    #[test]
    fn f64_list_parses() {
        let set = FlagSet::new("x", "y").opt("sups", "list");
        let p = set.parse(&argv(&["--sups", "0.3, 0.25,0.2"])).unwrap();
        assert_eq!(p.f64_list("sups").unwrap(), Some(vec![0.3, 0.25, 0.2]));
    }

    #[test]
    fn repeated_flags_accumulate() {
        let set = FlagSet::new("x", "y").opt("algo", "algorithm");
        let p = set.parse(&argv(&["--algo", "spc", "--algo", "fpc"])).unwrap();
        assert_eq!(p.get_all("algo"), vec!["spc", "fpc"]);
        assert_eq!(p.get("algo"), Some("fpc")); // last wins for scalar view
    }

    #[test]
    fn usage_mentions_flags() {
        let u = demo_set().usage();
        assert!(u.contains("--dataset"));
        assert!(u.contains("default: 0.25"));
    }
}
