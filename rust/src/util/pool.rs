//! A small scoped thread pool (rayon is not available offline).
//!
//! [`run_batch_scoped`] is the MapReduce engine's task-execution primitive
//! for both map and reduce tasks: a batch runner for jobs that borrow from
//! the caller's stack (mapper factories, combiners, reducers and
//! partitioners all borrow from the driver), built on
//! [`std::thread::scope`]. An earlier queue-based `ThreadPool` for
//! `'static` jobs was removed when the engine migrated here — resurrect it
//! from history if long-lived workers are ever needed.
//!
//! On the single-core CI box the simulator usually runs with `workers = 1`
//! (sequential, zero-overhead path); the pool still gets exercised by tests
//! so the engine is correct on multi-core machines.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run a batch of *borrowing* closures to completion on up to `workers`
/// scoped threads, returning their outputs in job order.
///
/// Workers pull jobs from a shared cursor — dynamic load balancing, so one
/// straggler task never idles the remaining workers the way fixed chunking
/// would.
///
/// `workers <= 1` or a single job degrades to the sequential in-place path
/// (no threads spawned). A panicking job propagates on scope exit.
pub fn run_batch_scoped<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    if workers <= 1 || jobs.len() <= 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let n = jobs.len();
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().expect("job claimed twice");
                let out = job();
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("missing job result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn scoped_batch_borrows_from_caller() {
        // The whole point of the scoped runner: jobs borrow local data.
        let data: Vec<u64> = (0..100).collect();
        let jobs: Vec<_> = data.chunks(7).map(|c| move || c.iter().sum::<u64>()).collect();
        let out = run_batch_scoped(4, jobs);
        assert_eq!(out.iter().sum::<u64>(), 4950);
    }

    #[test]
    fn scoped_batch_preserves_order() {
        let jobs: Vec<_> = (0..32).map(|i| move || i * 3).collect();
        assert_eq!(run_batch_scoped(4, jobs), (0..32).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_batch_sequential_and_empty() {
        let jobs: Vec<_> = (0..5).map(|i| move || i + 1).collect();
        assert_eq!(run_batch_scoped(1, jobs), vec![1, 2, 3, 4, 5]);
        let out: Vec<i32> = run_batch_scoped(4, Vec::<fn() -> i32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn scoped_batch_runs_each_job_once() {
        let counter = AtomicU64::new(0);
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let c = &counter;
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        run_batch_scoped(3, jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_batch_more_workers_than_jobs() {
        let jobs: Vec<_> = (0..2).map(|i| move || i).collect();
        assert_eq!(run_batch_scoped(16, jobs), vec![0, 1]);
    }

    #[test]
    fn scoped_batch_single_job_runs_inline() {
        let jobs: Vec<_> = vec![|| 42];
        assert_eq!(run_batch_scoped(8, jobs), vec![42]);
    }
}
