//! A small scoped thread pool (rayon is not available offline).
//!
//! The MapReduce engine uses this to run map/reduce tasks on real OS threads
//! when `workers > 1`. On the single-core CI box the simulator usually runs
//! with `workers = 1` (sequential, zero-overhead path); the pool still gets
//! exercised by tests so the engine is correct on multi-core machines.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<QueueState>,
    cond: Condvar,
    active: AtomicUsize,
}

struct QueueState {
    jobs: std::collections::VecDeque<Job>,
    shutdown: bool,
}

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: Default::default(), shutdown: false }),
            cond: Condvar::new(),
            active: AtomicUsize::new(0),
        });
        let handles = (0..size)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(shared))
            })
            .collect();
        Self { shared, handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.push_back(Box::new(f));
        drop(q);
        self.shared.cond.notify_one();
    }

    /// Run a batch of closures to completion, returning outputs in order.
    ///
    /// This is the map-phase primitive: the closures borrow nothing from the
    /// caller (inputs must be moved in), results come back through a channel.
    pub fn run_batch<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let (tx, rx) = std::sync::mpsc::channel::<(usize, T)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.spawn(move || {
                let out = job();
                // Receiver can only hang up if the caller panicked.
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rx.recv().expect("worker thread panicked");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cond.wait(q).unwrap();
            }
        };
        shared.active.fetch_add(1, Ordering::SeqCst);
        job();
        shared.active.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cond.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run jobs either sequentially (`workers <= 1`) or on a transient pool.
/// The engine's entry point: keeps the fast path allocation-free of threads.
pub fn run_parallel<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    if workers <= 1 || jobs.len() <= 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let pool = ThreadPool::new(workers.min(jobs.len()));
    pool.run_batch(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn batch_preserves_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..32).map(|i| move || i * 10).collect();
        let out = pool.run_batch(jobs);
        assert_eq!(out, (0..32).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.run_batch(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn sequential_fallback() {
        let out = run_parallel(1, (0..5).map(|i| move || i + 1).collect::<Vec<_>>());
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_path() {
        let out = run_parallel(4, (0..16).map(|i| move || i * i).collect::<Vec<_>>());
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        for _ in 0..10 {
            pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(1)));
        }
        drop(pool); // must not hang or leak
    }

    #[test]
    fn empty_batch() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.run_batch(Vec::<fn() -> i32>::new());
        assert!(out.is_empty());
    }
}
