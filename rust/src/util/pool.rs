//! A persistent worker pool (rayon is not available offline).
//!
//! [`WorkerPool`] is the execution substrate of
//! [`crate::mapreduce::executor::Executor`]: a fixed set of long-lived
//! worker threads pulling `'static` tasks from one shared queue. Sizing it
//! once per session is what keeps N concurrent mining queries inside ONE
//! host-thread budget instead of N scoped batches oversubscribing the host
//! (DESIGN.md §9). The pool instruments a concurrently-executing-task
//! high-water mark so tests can *prove* the budget held.
//!
//! An earlier scoped batch runner for *borrowing* jobs
//! (`run_batch_scoped`, on [`std::thread::scope`]) was removed when the
//! engine migrated to the executor's `'static` tasks — resurrect it from
//! history if stack-borrowing batches are ever needed again, as was done
//! before it for the original queue-based `ThreadPool`.
//!
//! On the single-core CI box the simulator usually runs with `workers = 1`;
//! multi-worker paths are exercised by tests so the engine is correct on
//! multi-core machines.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A queued unit of work.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// State shared between the pool handle and its worker threads.
struct PoolShared {
    queue: Mutex<VecDeque<Task>>,
    /// Signalled when a task is queued or shutdown begins.
    available: Condvar,
    shutdown: AtomicBool,
    /// Tasks executing right now.
    active: AtomicUsize,
    /// Maximum `active` ever observed — the oversubscription proof.
    high_water: AtomicUsize,
}

/// A persistent pool of `workers` threads executing `'static` tasks from a
/// shared FIFO queue.
///
/// Workers pull from one shared queue (dynamic load balancing, like the
/// scoped runner below); tasks from many concurrent submitters interleave
/// on the same fixed thread set, so total execution concurrency is bounded
/// by the pool size no matter how many jobs are in flight. Dropping the
/// pool joins all workers after the queue drains (tasks already queued
/// still run).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: usize,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `workers.max(1)` threads.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            high_water: AtomicUsize::new(0),
        });
        let joins = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("mr-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning a pool worker thread")
            })
            .collect();
        Self { shared, workers, joins }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Enqueue one task; it runs on some worker thread, FIFO relative to
    /// other submissions.
    ///
    /// A panicking task is caught and DROPPED — the worker thread and the
    /// queue keep going. Submitters that need the panic (the executor
    /// does) must catch it inside the task and forward it through their
    /// own result channel.
    pub fn spawn(&self, task: impl FnOnce() + Send + 'static) {
        let mut queue = self.shared.queue.lock().expect("pool queue poisoned");
        queue.push_back(Box::new(task));
        drop(queue);
        self.shared.available.notify_one();
    }

    /// The maximum number of tasks this pool ever executed concurrently —
    /// by construction never above [`WorkerPool::workers`]. This is the
    /// instrument the oversubscription regression test reads.
    pub fn high_water_mark(&self) -> usize {
        self.shared.high_water.load(Ordering::SeqCst)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            // Set shutdown UNDER the queue lock: a worker's empty-queue /
            // shutdown check runs with the lock held, and `wait` releases
            // it only at park time — storing without the lock could land
            // inside that window, the notification would find no waiter
            // yet, and the worker would park forever (lost wakeup).
            let _queue = self.shared.queue.lock().expect("pool queue poisoned");
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.available.notify_all();
        for join in self.joins.drain(..) {
            // Workers contain task panics, so a dead worker is a pool bug;
            // surface it loudly (unless already unwinding).
            if join.join().is_err() && !std::thread::panicking() {
                panic!("a pool worker thread panicked");
            }
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut queue = shared.queue.lock().expect("pool queue poisoned");
            loop {
                if let Some(task) = queue.pop_front() {
                    break task;
                }
                // Drain-then-exit: shutdown only once the queue is empty.
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                queue = shared.available.wait(queue).expect("pool queue poisoned");
            }
        };
        let live = shared.active.fetch_add(1, Ordering::SeqCst) + 1;
        shared.high_water.fetch_max(live, Ordering::SeqCst);
        // Contain task panics: a panicking task must not kill the shared
        // worker, leak the `active` count, or strand the queue behind it
        // (`spawn` documents the drop; the executor forwards panics to its
        // driver through its own channel before they ever reach here).
        let _ = catch_unwind(AssertUnwindSafe(task));
        shared.active.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn worker_pool_runs_every_task_once() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..50 {
            let counter = Arc::clone(&counter);
            let tx = tx.clone();
            pool.spawn(move || {
                counter.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..50 {
            rx.recv().expect("task completion");
        }
        assert_eq!(counter.load(Ordering::SeqCst), 50);
        assert!((1..=3).contains(&pool.high_water_mark()));
    }

    #[test]
    fn worker_pool_drains_queue_before_drop_returns() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..20 {
                let counter = Arc::clone(&counter);
                pool.spawn(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Dropping joins the workers only after the queue is empty.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn worker_pool_bounds_task_concurrency() {
        // 12 sleeping tasks on 2 workers: at most 2 ever run at once, and
        // the overlap actually happens (the sleeps force it).
        let pool = WorkerPool::new(2);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..12 {
            let live = Arc::clone(&live);
            let peak = Arc::clone(&peak);
            let tx = tx.clone();
            pool.spawn(move || {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(5));
                live.fetch_sub(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..12 {
            rx.recv().expect("task completion");
        }
        assert_eq!(peak.load(Ordering::SeqCst), 2);
        assert_eq!(pool.high_water_mark(), 2);
    }

    #[test]
    fn single_worker_pool_is_serial() {
        // Panics inside tasks are contained, so record overlap in a flag
        // and assert on the test thread afterwards.
        let pool = WorkerPool::new(1);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..8 {
            let live = Arc::clone(&live);
            let peak = Arc::clone(&peak);
            let tx = tx.clone();
            pool.spawn(move || {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                live.fetch_sub(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..8 {
            rx.recv().expect("task completion");
        }
        assert_eq!(peak.load(Ordering::SeqCst), 1, "overlap on 1 worker");
        assert_eq!(pool.high_water_mark(), 1);
        // Zero requested workers clamps to one.
        assert_eq!(WorkerPool::new(0).workers(), 1);
    }

    #[test]
    fn worker_pool_survives_a_panicking_task() {
        // A raw `spawn`ed task that panics must not kill the worker, leak
        // the active count, or strand the tasks queued behind it.
        let pool = WorkerPool::new(1);
        pool.spawn(|| panic!("task boom"));
        let (tx, rx) = std::sync::mpsc::channel();
        pool.spawn(move || {
            let _ = tx.send(());
        });
        rx.recv().expect("the pool still serves tasks after a task panic");
        assert_eq!(pool.high_water_mark(), 1, "active count leaked past the panic");
    }
}
