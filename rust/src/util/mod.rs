//! Self-contained utility substrates: PRNG, CLI flags, TOML-subset config
//! parser, the persistent worker pool, a pinned SipHash-1-3, a streaming
//! latency histogram, a property-test mini-framework, and logging.
//!
//! These stand in for `rand`, `clap`, `toml`, `rayon`, `proptest`, and
//! `env_logger`, none of which are available in the offline build
//! environment. Each is deliberately small and fully tested.

pub mod check;
pub mod flags;
pub mod hist;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod siphash;
pub mod tomlmini;
