//! A minimal TOML-subset parser for configuration files.
//!
//! Supports exactly what our config files need: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! boolean / flat-array values, comments (`#`), and blank lines. Values are
//! exposed through a dotted-path lookup (`"cluster.data_nodes"`).
//!
//! This is intentionally not a full TOML implementation (no inline tables,
//! no multi-line strings, no dates); the config loader rejects anything
//! outside the subset with a line-numbered error.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
/// A parsed TOML-subset value.
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A `[...]` list.
    Array(Vec<Value>),
}

impl Value {
    /// The string, if this value is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The integer, if this value is an integer.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`60` is a valid f64 setting).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// The bool, if this value is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this value is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

#[derive(Debug)]
/// Parse failure: line number plus message.
pub struct ParseError {
    /// 1-based line of the failure.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parsed document: dotted-path -> value.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    /// Parse a TOML-subset document into dotted-key entries.
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(ParseError { line: lineno, msg: "empty section name".into() });
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| ParseError {
                line: lineno,
                msg: format!("expected `key = value`, got {line:?}"),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ParseError { line: lineno, msg: "empty key".into() });
            }
            let value = parse_value(value.trim(), lineno)?;
            let path =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            if entries.insert(path.clone(), value).is_some() {
                return Err(ParseError { line: lineno, msg: format!("duplicate key {path:?}") });
            }
        }
        Ok(Doc { entries })
    }

    /// Raw value at a dotted key path.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    /// String at a dotted key path.
    pub fn str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }
    /// Integer at a dotted key path.
    pub fn int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }
    /// Float at a dotted key path (integers widen).
    pub fn float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }
    /// Bool at a dotted key path.
    pub fn bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// All keys under a section prefix (e.g. `weights.`), with prefix stripped.
    pub fn section(&self, prefix: &str) -> Vec<(String, &Value)> {
        let want = format!("{prefix}.");
        self.entries
            .iter()
            .filter(|(k, _)| k.starts_with(&want))
            .map(|(k, v)| (k[want.len()..].to_string(), v))
            .collect()
    }

    /// All dotted keys in the document, sorted.
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str, lineno: usize) -> Result<Value, ParseError> {
    let err = |msg: String| ParseError { line: lineno, msg };
    if raw.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(inner) = raw.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| err("unterminated string".into()))?;
        if inner.contains('"') {
            return Err(err("embedded quotes are not supported".into()));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = raw.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| err("unterminated array".into()))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim(), lineno))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    // Numbers: int if it parses as i64 and has no '.', 'e'; else float.
    let has_float_syntax = raw.contains('.') || raw.contains('e') || raw.contains('E');
    if !has_float_syntax {
        if let Ok(i) = raw.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("cannot parse value {raw:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
name = "demo"        # trailing comment
count = 42
ratio = 0.75
big = 1_000_000
on = true

[cluster]
data_nodes = 4
slots = [2, 2, 4]

[cluster.overhead]
job = 15.0
"#;

    #[test]
    fn parses_sample() {
        let d = Doc::parse(SAMPLE).unwrap();
        assert_eq!(d.str("name"), Some("demo"));
        assert_eq!(d.int("count"), Some(42));
        assert_eq!(d.float("ratio"), Some(0.75));
        assert_eq!(d.int("big"), Some(1_000_000));
        assert_eq!(d.bool("on"), Some(true));
        assert_eq!(d.int("cluster.data_nodes"), Some(4));
        assert_eq!(d.float("cluster.overhead.job"), Some(15.0));
    }

    #[test]
    fn int_readable_as_float() {
        let d = Doc::parse("x = 60").unwrap();
        assert_eq!(d.float("x"), Some(60.0));
    }

    #[test]
    fn arrays() {
        let d = Doc::parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nempty = []").unwrap();
        let xs = d.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_int(), Some(3));
        let ys = d.get("ys").unwrap().as_array().unwrap();
        assert_eq!(ys[1].as_str(), Some("b"));
        assert_eq!(d.get("empty").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn section_listing() {
        let d = Doc::parse("[w]\na = 1.0\nb = 2.0").unwrap();
        let mut got = d.section("w");
        got.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, "a");
    }

    #[test]
    fn hash_inside_string_kept() {
        let d = Doc::parse("s = \"a#b\"").unwrap();
        assert_eq!(d.str("s"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Doc::parse("x = ").unwrap_err();
        assert_eq!(err.line, 1);
        let err = Doc::parse("[sec\nx = 1").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(Doc::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let d = Doc::parse("a = -5\nb = 1e-3\nc = -0.5").unwrap();
        assert_eq!(d.int("a"), Some(-5));
        assert_eq!(d.float("b"), Some(1e-3));
        assert_eq!(d.float("c"), Some(-0.5));
    }
}
