//! Deterministic pseudo-random number generation.
//!
//! The whole system — dataset generators, the cluster simulator, and the
//! property-test mini-framework — must be reproducible from a single `u64`
//! seed, so we carry our own small PRNG instead of depending on external
//! crates. `SplitMix64` seeds `Xoshiro256**`, the same construction the
//! reference implementations recommend.

/// SplitMix64: used to expand a user seed into Xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the expander.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Derive an independent child stream (for per-partition determinism).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard exponential variate (mean 1).
    #[inline]
    pub fn exp(&mut self) -> f64 {
        // Inverse transform; clamp away from exactly 0.
        let u = self.next_f64().max(1e-300);
        -u.ln()
    }

    /// Poisson variate with the given mean (Knuth for small, normal
    /// approximation for large means — generators only use small means).
    pub fn poisson(&mut self, mean: f64) -> usize {
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let limit = (-mean).exp();
            let mut k = 0usize;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= limit {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation, good enough for mean >= 30.
            let g = self.gaussian();
            let v = mean + mean.sqrt() * g;
            if v < 0.0 {
                0
            } else {
                v.round() as usize
            }
        }
    }

    /// Standard normal variate (Box–Muller, one value per call).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Partial Fisher–Yates over an index map for small k relative to n.
        if k * 4 < n {
            let mut chosen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n as u64) as usize;
                if chosen.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }

    /// Draw an index from a cumulative weight table (binary search).
    /// `cum` must be non-decreasing with `cum.last() > 0`.
    pub fn weighted(&mut self, cum: &[f64]) -> usize {
        // lint:allow(unwrap-in-library): documented precondition — callers
        // pass a non-empty cumulative table, and an empty one is a caller
        // bug worth a loud panic, not a recoverable error.
        let total = *cum.last().expect("weights must be non-empty");
        let x = self.next_f64() * total;
        match cum.binary_search_by(|w| w.total_cmp(&x)) {
            Ok(i) => (i + 1).min(cum.len() - 1),
            Err(i) => i.min(cum.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for n in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Rng::new(11);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let mean = 20.0;
        let total: usize = (0..n).map(|_| r.poisson(mean)).sum();
        let emp = total as f64 / n as f64;
        assert!((emp - mean).abs() < 0.3, "empirical mean {emp}");
    }

    #[test]
    fn poisson_large_mean_normal_path() {
        let mut r = Rng::new(6);
        let n = 20_000;
        let mean = 100.0;
        let total: usize = (0..n).map(|_| r.poisson(mean)).sum();
        let emp = total as f64 / n as f64;
        assert!((emp - mean).abs() < 1.0, "empirical mean {emp}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        for (n, k) in [(100, 5), (10, 10), (1000, 999), (50, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(17);
        // weights 1, 3 -> cum 1, 4
        let cum = [1.0, 4.0];
        let mut counts = [0usize; 2];
        for _ in 0..10_000 {
            counts[r.weighted(&cum)] += 1;
        }
        let frac = counts[1] as f64 / 10_000.0;
        assert!((frac - 0.75).abs() < 0.03, "frac {frac}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(21);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(23);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
