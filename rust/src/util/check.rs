//! Mini property-based testing framework (proptest is not available offline).
//!
//! Provides generators over a seeded [`Rng`](crate::util::rng::Rng), a
//! `forall` runner with iteration budget, and greedy shrinking for failing
//! cases. Test modules use it for invariants on the trie, candidate
//! generation, the schedulers, and the drivers.

use crate::util::rng::Rng;

/// A reproducible generator of test inputs with an optional shrinker.
pub trait Gen {
    /// Generated value type.
    type Item: Clone + std::fmt::Debug;
    /// Draw one value.
    fn generate(&self, rng: &mut Rng) -> Self::Item;
    /// Candidate smaller versions of `item`, tried in order during shrinking.
    fn shrink(&self, _item: &Self::Item) -> Vec<Self::Item> {
        Vec::new()
    }
}

/// Run `prop` against `iters` generated inputs; on failure, shrink greedily
/// and panic with the minimal reproducer and the seed.
pub fn forall<G: Gen>(seed: u64, iters: usize, gen: &G, prop: impl Fn(&G::Item) -> bool) {
    let mut rng = Rng::new(seed);
    for i in 0..iters {
        let item = gen.generate(&mut rng);
        if !prop(&item) {
            let minimal = shrink_loop(gen, item, &prop);
            // lint:allow(unwrap-in-library): panicking IS the framework's
            // failure channel — forall() reports a counterexample the same
            // way assert! does.
            panic!(
                "property failed (seed={seed}, iteration={i});\n minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(gen: &G, mut item: G::Item, prop: &impl Fn(&G::Item) -> bool) -> G::Item {
    // Greedy descent: repeatedly take the first shrink that still fails.
    'outer: loop {
        for cand in gen.shrink(&item) {
            if !prop(&cand) {
                item = cand;
                continue 'outer;
            }
        }
        return item;
    }
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// usize in [lo, hi], shrinking toward lo.
pub struct UsizeGen {
    /// Inclusive lower bound.
    pub lo: usize,
    /// Inclusive upper bound.
    pub hi: usize,
}

impl Gen for UsizeGen {
    type Item = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.lo, self.hi)
    }
    fn shrink(&self, item: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *item > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*item - self.lo) / 2);
            out.push(*item - 1);
        }
        out.dedup();
        out
    }
}

/// Vec of items with length in [0, max_len], shrinking by halving / popping.
pub struct VecGen<G> {
    /// Element generator.
    pub inner: G,
    /// Maximum vector length.
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Item = Vec<G::Item>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Item> {
        let len = rng.range(0, self.max_len);
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, item: &Vec<G::Item>) -> Vec<Vec<G::Item>> {
        let mut out = Vec::new();
        if item.is_empty() {
            return out;
        }
        out.push(item[..item.len() / 2].to_vec()); // front half
        out.push(item[item.len() / 2..].to_vec()); // back half
        let mut popped = item.clone();
        popped.pop();
        out.push(popped);
        // Element-wise shrinks on the first element (cheap but effective).
        for smaller in self.inner.shrink(&item[0]) {
            let mut v = item.clone();
            v[0] = smaller;
            out.push(v);
        }
        out
    }
}

/// A sorted set of distinct item-ids in [0, universe): a random itemset.
pub struct ItemsetGen {
    /// Item ids are drawn from `[0, universe)`.
    pub universe: usize,
    /// Maximum itemset length.
    pub max_len: usize,
}

impl Gen for ItemsetGen {
    type Item = Vec<u32>;
    fn generate(&self, rng: &mut Rng) -> Vec<u32> {
        let len = rng.range(0, self.max_len.min(self.universe));
        let mut ids: Vec<u32> =
            rng.sample_indices(self.universe, len).into_iter().map(|i| i as u32).collect();
        ids.sort_unstable();
        ids
    }
    fn shrink(&self, item: &Vec<u32>) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        if item.is_empty() {
            return out;
        }
        out.push(item[..item.len() / 2].to_vec());
        out.push(item[1..].to_vec());
        let mut popped = item.clone();
        popped.pop();
        out.push(popped);
        out
    }
}

/// A small transaction database: Vec<sorted itemset>, plus the universe size.
pub struct DbGen {
    /// Item ids are drawn from `[0, universe)`.
    pub universe: usize,
    /// Maximum transaction count.
    pub max_txns: usize,
    /// Maximum transaction width.
    pub max_width: usize,
}

#[derive(Clone, Debug)]
/// A generated mini transaction database.
pub struct SmallDb {
    /// Size of the item universe.
    pub universe: usize,
    /// The transactions (canonical itemsets).
    pub txns: Vec<Vec<u32>>,
}

impl Gen for DbGen {
    type Item = SmallDb;
    fn generate(&self, rng: &mut Rng) -> SmallDb {
        let n = rng.range(1, self.max_txns);
        let item_gen = ItemsetGen { universe: self.universe, max_len: self.max_width };
        let txns = (0..n)
            .map(|_| {
                let mut t = item_gen.generate(rng);
                if t.is_empty() {
                    t.push(rng.below(self.universe as u64) as u32);
                }
                t
            })
            .collect();
        SmallDb { universe: self.universe, txns }
    }
    fn shrink(&self, item: &SmallDb) -> Vec<SmallDb> {
        let mut out = Vec::new();
        if item.txns.len() > 1 {
            out.push(SmallDb {
                universe: item.universe,
                txns: item.txns[..item.txns.len() / 2].to_vec(),
            });
            out.push(SmallDb {
                universe: item.universe,
                txns: item.txns[item.txns.len() / 2..].to_vec(),
            });
            let mut popped = item.txns.clone();
            popped.pop();
            out.push(SmallDb { universe: item.universe, txns: popped });
        }
        // Narrow the first transaction.
        if let Some(first) = item.txns.first() {
            if first.len() > 1 {
                let mut txns = item.txns.clone();
                txns[0] = first[..first.len() / 2].to_vec();
                out.push(SmallDb { universe: item.universe, txns });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(1, 200, &UsizeGen { lo: 0, hi: 100 }, |&x| x <= 100);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn forall_reports_failure() {
        forall(2, 500, &UsizeGen { lo: 0, hi: 100 }, |&x| x < 90);
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Capture the panic message to check the counterexample is minimal-ish.
        let result = std::panic::catch_unwind(|| {
            forall(3, 500, &VecGen { inner: UsizeGen { lo: 0, hi: 9 }, max_len: 30 }, |v| {
                v.len() < 5 // fails for any vec of len >= 5
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrinking should land on exactly length 5.
        let needle = "minimal counterexample: [";
        let idx = msg.find(needle).unwrap();
        let tail = &msg[idx + needle.len()..];
        let count = tail.split(']').next().unwrap().split(',').count();
        assert_eq!(count, 5, "msg: {msg}");
    }

    #[test]
    fn itemset_gen_sorted_distinct() {
        let gen = ItemsetGen { universe: 50, max_len: 20 };
        forall(4, 300, &gen, |set| {
            set.windows(2).all(|w| w[0] < w[1]) && set.iter().all(|&i| (i as usize) < 50)
        });
    }

    #[test]
    fn db_gen_nonempty_txns() {
        let gen = DbGen { universe: 30, max_txns: 20, max_width: 10 };
        forall(5, 100, &gen, |db| !db.txns.is_empty() && db.txns.iter().all(|t| !t.is_empty()));
    }
}
