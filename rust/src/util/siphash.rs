//! An explicit, fixed-key SipHash-1-3 implementation.
//!
//! Why this exists: the engine's `HashPartitioner` routes every shuffled
//! key to its reduce task via a hash. It used to rely on
//! `std::collections::hash_map::DefaultHasher`, which happens to be
//! SipHash-1-3 with zero keys today — but the standard library documents
//! the algorithm as unspecified and subject to change between releases.
//! A toolchain bump could silently re-route every key, changing reduce-task
//! workload splits (and therefore simulated per-task timings) and the
//! concatenation order of stored job outputs. Pinning the algorithm here
//! makes partition placement a *specified* property of this crate: stored
//! segment outputs and golden tests survive toolchain bumps by
//! construction.
//!
//! The implementation is the SipHash-1-3 variant (1 compression round,
//! 3 finalization rounds) of Aumasson & Bernstein's SipHash, streaming like
//! [`std::hash::Hasher`] requires, with all integer writes pinned to
//! little-endian byte order so the digest is also independent of the host's
//! endianness. Validated by pinned test vectors below (generated from an
//! independent implementation cross-checked against the SipHash paper's
//! Appendix A vectors).

use std::hash::Hasher;

/// Streaming SipHash-1-3 with caller-fixed keys (default: zero keys).
///
/// Implements [`std::hash::Hasher`], so any `Hash` type can be fed to it;
/// integer writes are pinned to little-endian regardless of host
/// endianness. Unkeyed use is fine for partitioning (there is no
/// hash-flooding adversary inside the engine); callers needing DoS
/// resistance should supply random keys via [`SipHasher13::new_with_keys`].
#[derive(Debug, Clone, Copy)]
pub struct SipHasher13 {
    length: usize,
    v0: u64,
    v1: u64,
    v2: u64,
    v3: u64,
    /// Up to 7 pending bytes, packed little-endian into the low bits.
    tail: u64,
    ntail: usize,
}

impl SipHasher13 {
    /// Zero-key hasher — the partitioner's configuration.
    pub fn new() -> Self {
        Self::new_with_keys(0, 0)
    }

    /// Hasher with explicit 128-bit key `(k0, k1)`.
    pub fn new_with_keys(k0: u64, k1: u64) -> Self {
        Self {
            length: 0,
            v0: k0 ^ 0x736f_6d65_7073_6575,
            v1: k1 ^ 0x646f_7261_6e64_6f6d,
            v2: k0 ^ 0x6c79_6765_6e65_7261,
            v3: k1 ^ 0x7465_6462_7974_6573,
            tail: 0,
            ntail: 0,
        }
    }

    #[inline]
    fn round(&mut self) {
        self.v0 = self.v0.wrapping_add(self.v1);
        self.v1 = self.v1.rotate_left(13);
        self.v1 ^= self.v0;
        self.v0 = self.v0.rotate_left(32);
        self.v2 = self.v2.wrapping_add(self.v3);
        self.v3 = self.v3.rotate_left(16);
        self.v3 ^= self.v2;
        self.v0 = self.v0.wrapping_add(self.v3);
        self.v3 = self.v3.rotate_left(21);
        self.v3 ^= self.v0;
        self.v2 = self.v2.wrapping_add(self.v1);
        self.v1 = self.v1.rotate_left(17);
        self.v1 ^= self.v2;
        self.v2 = self.v2.rotate_left(32);
    }

    /// One message block: the "1" of SipHash-1-3.
    #[inline]
    fn compress(&mut self, m: u64) {
        self.v3 ^= m;
        self.round();
        self.v0 ^= m;
    }
}

impl Default for SipHasher13 {
    fn default() -> Self {
        Self::new()
    }
}

/// Little-endian load of up to 8 bytes.
#[inline]
fn load_le(buf: &[u8]) -> u64 {
    let mut out = 0u64;
    for (i, &b) in buf.iter().enumerate() {
        out |= (b as u64) << (8 * i);
    }
    out
}

impl Hasher for SipHasher13 {
    fn write(&mut self, msg: &[u8]) {
        let length = msg.len();
        self.length = self.length.wrapping_add(length);
        let mut msg = msg;
        if self.ntail != 0 {
            // Top up the pending block first.
            let needed = 8 - self.ntail;
            let take = needed.min(length);
            self.tail |= load_le(&msg[..take]) << (8 * self.ntail);
            if length < needed {
                self.ntail += length;
                return;
            }
            let block = self.tail;
            self.compress(block);
            self.tail = 0;
            self.ntail = 0;
            msg = &msg[take..];
        }
        let mut blocks = msg.chunks_exact(8);
        for block in &mut blocks {
            // lint:allow(unwrap-in-library): chunks_exact(8) yields exactly
            // 8-byte slices, so the conversion cannot fail.
            let m = u64::from_le_bytes(block.try_into().expect("8-byte block"));
            self.compress(m);
        }
        let rem = blocks.remainder();
        self.tail = load_le(rem);
        self.ntail = rem.len();
    }

    fn finish(&self) -> u64 {
        let mut s = *self;
        // Final block: total length (mod 256) in the top byte, pending
        // bytes below it.
        let b = ((s.length as u64 & 0xff) << 56) | s.tail;
        s.compress(b);
        s.v2 ^= 0xff;
        // The "3" of SipHash-1-3.
        s.round();
        s.round();
        s.round();
        s.v0 ^ s.v1 ^ s.v2 ^ s.v3
    }

    // Pin every integer write to little-endian so the digest does not
    // depend on the host's native byte order (the Hasher defaults use
    // `to_ne_bytes`).
    fn write_u8(&mut self, i: u8) {
        self.write(&[i]);
    }
    fn write_u16(&mut self, i: u16) {
        self.write(&i.to_le_bytes());
    }
    fn write_u32(&mut self, i: u32) {
        self.write(&i.to_le_bytes());
    }
    fn write_u64(&mut self, i: u64) {
        self.write(&i.to_le_bytes());
    }
    fn write_u128(&mut self, i: u128) {
        self.write(&i.to_le_bytes());
    }
    fn write_usize(&mut self, i: usize) {
        // Always 8 bytes, so 32- and 64-bit hosts agree.
        self.write(&(i as u64).to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sip13(data: &[u8]) -> u64 {
        let mut h = SipHasher13::new();
        h.write(data);
        h.finish()
    }

    /// Pinned digests for the zero-key SipHash-1-3 this crate specifies.
    /// Generated from an independent reference implementation whose
    /// SipHash-2-4 instantiation reproduces the SipHash paper's Appendix A
    /// vectors (key 00..0f, 15-byte message -> 0xa129ca6149be45e5). These
    /// values must NEVER change: stored segment outputs and simulated
    /// timings depend on them.
    #[test]
    fn pinned_byte_vectors() {
        assert_eq!(sip13(b""), 0xd1fba762150c532c);
        assert_eq!(sip13(b"a"), 0x407448d2b89b1813);
        assert_eq!(sip13(b"abcdefg"), 0x6db12aae9070f506); // 7B: tail only
        assert_eq!(sip13(b"abcdefgh"), 0x3f7b849c0b8e35ea); // 8B: one block
        assert_eq!(sip13(b"abcdefghi"), 0xf89b34a3d11eb6e5); // block + tail
        let long: Vec<u8> = (0u8..64).collect();
        assert_eq!(sip13(&long), 0x75e05fd5bbc870c6);
    }

    #[test]
    fn streaming_is_chunking_invariant() {
        // The digest is a function of the byte stream, not of how callers
        // slice their writes — the property the buffered `write` maintains.
        let data: Vec<u8> = (0u8..=255).cycle().take(500).collect();
        let oneshot = sip13(&data);
        for chunk in [1usize, 2, 3, 5, 7, 8, 9, 11, 64] {
            let mut h = SipHasher13::new();
            for piece in data.chunks(chunk) {
                h.write(piece);
            }
            assert_eq!(h.finish(), oneshot, "chunk size {chunk}");
        }
        // Ragged chunking crossing block boundaries mid-write.
        let mut h = SipHasher13::new();
        let mut i = 0;
        for step in [3usize, 6, 1, 13, 2, 9].iter().cycle() {
            if i >= data.len() {
                break;
            }
            let end = (i + step).min(data.len());
            h.write(&data[i..end]);
            i = end;
        }
        assert_eq!(h.finish(), oneshot);
    }

    #[test]
    fn integer_writes_are_little_endian() {
        // write_u32 must equal writing the LE bytes explicitly.
        let mut a = SipHasher13::new();
        a.write_u32(0x0403_0201);
        let mut b = SipHasher13::new();
        b.write(&[1, 2, 3, 4]);
        assert_eq!(a.finish(), b.finish());

        let mut a = SipHasher13::new();
        a.write_usize(7);
        let mut b = SipHasher13::new();
        b.write(&[7, 0, 0, 0, 0, 0, 0, 0]);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn keyed_hashing_differs_from_unkeyed() {
        let mut keyed = SipHasher13::new_with_keys(1, 2);
        keyed.write(b"abc");
        let mut unkeyed = SipHasher13::new();
        unkeyed.write(b"abc");
        assert_ne!(keyed.finish(), unkeyed.finish());
    }

    #[test]
    fn finish_is_idempotent() {
        // finish(&self) must not consume state: hash, observe, keep writing.
        let mut h = SipHasher13::new();
        h.write(b"abcd");
        let first = h.finish();
        assert_eq!(h.finish(), first);
        h.write(b"efgh");
        assert_eq!(h.finish(), sip13(b"abcdefgh"));
    }
}
