//! Fixed-bucket streaming latency histogram.
//!
//! [`Histogram`] accumulates non-negative duration samples (seconds) into
//! a fixed array of log-spaced buckets, so recording is O(1), memory is
//! constant, two histograms [`merge`](Histogram::merge) by adding bucket
//! counts, and quantiles come out with a bounded *relative* error of one
//! bucket ratio ([`Histogram::BUCKET_RATIO`], 25%). This is the `serve`
//! daemon's per-query latency instrument (`STATS` p50/p95/p99, DESIGN.md
//! §12) and is exported for bench-harness reuse — per-thread histograms
//! merge into one report without sharing a lock on the hot path.
//!
//! The bucket layout is pinned: bucket 0 holds everything below
//! [`Histogram::MIN_SECS`] (1 µs), buckets grow geometrically by
//! `BUCKET_RATIO`, and the last bucket absorbs everything past the top
//! edge (≈ 1.9 h). Quantiles report the *upper edge* of the bucket holding
//! the requested rank, so they never under-state a latency.

/// Streaming log-bucket histogram of durations in seconds; see the module
/// docs for the bucket layout and error bound.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; Histogram::BUCKETS],
    total: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Number of buckets; fixed so histograms always merge shape-to-shape.
    pub const BUCKETS: usize = 96;
    /// Lower edge of bucket 1 — samples below land in bucket 0.
    pub const MIN_SECS: f64 = 1e-6;
    /// Geometric growth factor between consecutive bucket edges: the
    /// worst-case relative error of any reported quantile.
    pub const BUCKET_RATIO: f64 = 1.25;

    /// An empty histogram.
    pub fn new() -> Self {
        Self { counts: [0; Self::BUCKETS], total: 0 }
    }

    /// The bucket a sample falls into. Non-finite and non-positive samples
    /// clamp to bucket 0; samples past the top edge clamp to the last
    /// bucket.
    fn bucket(secs: f64) -> usize {
        if !secs.is_finite() || secs < Self::MIN_SECS {
            return 0;
        }
        // floor(log_ratio(secs / MIN)) + 1: bucket 1 starts at MIN_SECS.
        let idx = (secs / Self::MIN_SECS).ln() / Self::BUCKET_RATIO.ln();
        let idx = idx.floor();
        if idx < 0.0 {
            0
        } else {
            ((idx as usize) + 1).min(Self::BUCKETS - 1)
        }
    }

    /// Upper edge (seconds) of `bucket` — what quantiles report. The last
    /// bucket is unbounded; it reports its lower edge (a floor, so a
    /// clamped outlier still reads as "at least this").
    fn upper_edge(bucket: usize) -> f64 {
        if bucket == 0 {
            return Self::MIN_SECS;
        }
        let exp = if bucket == Self::BUCKETS - 1 { bucket - 1 } else { bucket };
        Self::MIN_SECS * Self::BUCKET_RATIO.powi(exp as i32)
    }

    /// Record one duration sample.
    pub fn record(&mut self, secs: f64) {
        self.counts[Self::bucket(secs)] += 1;
        self.total += 1;
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Add every bucket of `other` into `self` (the parallel-collection
    /// reduction step).
    pub fn merge(&mut self, other: &Histogram) {
        for (mine, theirs) in self.counts.iter_mut().zip(other.counts.iter()) {
            *mine += *theirs;
        }
        self.total += other.total;
    }

    /// The `q`-quantile (`0.0 < q <= 1.0`) as the upper edge of the bucket
    /// holding the rank-`ceil(q·n)` sample — an upper bound within one
    /// [`BUCKET_RATIO`](Self::BUCKET_RATIO) of the exact order statistic.
    /// `None` when empty or `q` is out of domain.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.total == 0 || !(q > 0.0 && q <= 1.0) {
            return None;
        }
        // ceil without going through floats on the rank itself.
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(Self::upper_edge(i));
            }
        }
        None // unreachable: seen == total >= rank by the loop's end
    }

    /// Median upper bound (see [`quantile`](Self::quantile)).
    pub fn p50(&self) -> Option<f64> {
        self.quantile(0.50)
    }

    /// 95th-percentile upper bound.
    pub fn p95(&self) -> Option<f64> {
        self.quantile(0.95)
    }

    /// 99th-percentile upper bound.
    pub fn p99(&self) -> Option<f64> {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{forall, Gen};
    use crate::util::rng::Rng;

    #[test]
    fn empty_histogram_has_no_quantiles() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.is_empty());
        assert_eq!(h.p50(), None);
        assert_eq!(h.quantile(1.0), None);
    }

    #[test]
    fn out_of_domain_q_is_none() {
        let mut h = Histogram::new();
        h.record(0.5);
        assert_eq!(h.quantile(0.0), None);
        assert_eq!(h.quantile(1.5), None);
        assert_eq!(h.quantile(f64::NAN), None);
    }

    #[test]
    fn degenerate_samples_clamp_to_the_edge_buckets() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(f64::NAN);
        h.record(1e-9); // below MIN_SECS
        assert_eq!(h.count(), 4);
        assert_eq!(h.p50(), Some(Histogram::MIN_SECS));
        // A sample beyond the top edge lands in (and reports) the last
        // bucket instead of being dropped.
        let mut top = Histogram::new();
        top.record(1e12);
        assert_eq!(top.count(), 1);
        let p = top.p50().expect("one sample has a median");
        assert!(p > 1e3, "top bucket edge should be huge, got {p}");
    }

    #[test]
    fn single_sample_quantile_brackets_the_sample() {
        for &s in &[2e-6, 1e-3, 0.7, 12.0, 900.0] {
            let mut h = Histogram::new();
            h.record(s);
            let p = h.p50().expect("non-empty");
            assert!(p >= s, "quantile must not under-state: {p} < {s}");
            assert!(
                p <= s * Histogram::BUCKET_RATIO * (1.0 + 1e-12),
                "quantile exceeded one bucket of error: {p} vs {s}"
            );
        }
    }

    #[test]
    fn merge_equals_recording_the_union() {
        let samples_a = [1e-5, 3e-4, 0.02, 0.02, 1.5];
        let samples_b = [2e-6, 0.4, 7.0];
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut union = Histogram::new();
        for &s in &samples_a {
            a.record(s);
            union.record(s);
        }
        for &s in &samples_b {
            b.record(s);
            union.record(s);
        }
        a.merge(&b);
        assert_eq!(a, union);
        assert_eq!(a.count(), (samples_a.len() + samples_b.len()) as u64);
    }

    /// Generator of latency-shaped sample vectors: log-uniform in
    /// (~1 µs, ~1000 s), plus occasional exact-zero samples.
    struct SamplesGen;

    impl Gen for SamplesGen {
        type Item = Vec<f64>;
        fn generate(&self, rng: &mut Rng) -> Vec<f64> {
            let len = rng.range(1, 400);
            (0..len)
                .map(|_| {
                    if rng.below(20) == 0 {
                        0.0
                    } else {
                        // exp maps uniform [-14, 7] to ~[8e-7, 1.1e3] s.
                        (rng.next_f64() * 21.0 - 14.0).exp()
                    }
                })
                .collect()
        }
        fn shrink(&self, item: &Vec<f64>) -> Vec<Vec<f64>> {
            let mut out = Vec::new();
            if item.len() > 1 {
                out.push(item[..item.len() / 2].to_vec());
                out.push(item[item.len() / 2..].to_vec());
            }
            out
        }
    }

    /// The quantile bound property against a sorted reference: for every q,
    /// the histogram's answer is an upper bound on the exact order
    /// statistic and within one bucket ratio of it (coarser only for the
    /// clamped edge buckets, which the generator avoids).
    #[test]
    fn quantiles_bound_the_sorted_reference() {
        forall(0xB0C4, 60, &SamplesGen, |samples| {
            let mut h = Histogram::new();
            for &s in samples {
                h.record(s);
            }
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite samples"));
            for &q in &[0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
                let exact = sorted[rank - 1];
                let est = h.quantile(q).expect("non-empty");
                if est < exact {
                    return false; // never under-state
                }
                // Within one bucket of relative error once past the floor
                // bucket; the floor bucket reports MIN_SECS exactly.
                let ceiling = (exact * Histogram::BUCKET_RATIO).max(Histogram::MIN_SECS);
                if est > ceiling * (1.0 + 1e-9) {
                    return false;
                }
            }
            true
        });
    }

    /// Merging per-thread histograms must agree with one histogram fed
    /// everything, for any split of the sample stream.
    #[test]
    fn merge_is_union_for_any_split() {
        forall(0x5EED, 40, &SamplesGen, |samples| {
            let mut whole = Histogram::new();
            for &s in samples {
                whole.record(s);
            }
            let mid = samples.len() / 2;
            let (mut left, mut right) = (Histogram::new(), Histogram::new());
            for &s in &samples[..mid] {
                left.record(s);
            }
            for &s in &samples[mid..] {
                right.record(s);
            }
            left.merge(&right);
            left == whole
        });
    }
}
