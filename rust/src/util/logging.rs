//! Tiny leveled logger writing to stderr (the `log` facade is vendored but a
//! backend is not, and we want zero-config verbosity from the CLI anyway).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
/// Verbosity levels, most severe first.
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but non-fatal conditions.
    Warn = 1,
    /// High-level progress (the default).
    Info = 2,
    /// Per-job engine detail (`--verbose`).
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Whether level `l` would currently print.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Print `args` at level `l` (used by the logging macros).
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

/// Log at [`Level::Info`] with `format!` syntax.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}

/// Log at [`Level::Warn`] (named `warn_` to dodge the built-in lint name).
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}

/// Log at [`Level::Debug`] with `format!` syntax.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

/// Log at [`Level::Error`] with `format!` syntax.
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
