//! Named datasets matching the paper's Table 2.
//!
//! The paper evaluates on `c20d10k` (IBM Quest synthetic), `chess` and
//! `mushroom` (UCI real datasets from the FIMI/SPMF repositories). The real
//! datasets are not redistributable here, so the registry generates
//! *synthetic analogs* matched to Table 2's attributes (N, |I|, w) and tuned
//! so the |L_k| profile at the paper's reference min_sup has the same shape
//! as Table 6: unimodal, peak near k=6..7, maximal frequent length ~13–15.
//! See DESIGN.md §3 (substitution table).

use super::ibm::{self, IbmParams, QuestGen};
use super::attr::{self, AttrParams, AttrSpec};
use super::TransactionDb;
use crate::hdfs::segment::{self, SegmentError, SegmentSource};
use std::path::Path;

/// Dataset names accepted by the CLI and the bench harness.
pub const NAMES: [&str; 3] = ["c20d10k", "chess", "mushroom"];

/// Canonical members of the large-synthetic Quest family. Any name of the
/// shape `t{T}i{I}d{D}` (D suffixed `k`/`m`) is accepted by
/// [`quest_params`]; these four are the documented scale-sweep entries.
pub const QUEST_NAMES: [&str; 4] = ["t10i4d100k", "t40i10d100k", "t10i4d1m", "t40i10d1m"];

/// Parse a Quest-family name like `t10i4d100k` or `t40i10d1m` into
/// generator parameters: `T` = mean transaction width, `I` = mean pattern
/// size, `D` = transaction count (with `k`/`m` multipliers). Standard
/// Quest settings otherwise: 1000 items, 2000 maximal patterns, 0.5
/// correlation and corruption. The seed is a fixed function of (T, I, D),
/// so every entry is deterministic across builds and machines.
pub fn quest_params(name: &str) -> Option<IbmParams> {
    let lower = name.to_ascii_lowercase();
    let rest = lower.strip_prefix('t')?;
    let ipos = rest.find('i')?;
    let (t_str, rest) = rest.split_at(ipos);
    let rest = rest.strip_prefix('i')?;
    let dpos = rest.find('d')?;
    let (i_str, rest) = rest.split_at(dpos);
    let rest = rest.strip_prefix('d')?;
    let (d_str, mult) = match rest.strip_suffix('m') {
        Some(d) => (d, 1_000_000usize),
        None => match rest.strip_suffix('k') {
            Some(d) => (d, 1_000),
            None => (rest, 1),
        },
    };
    let t: usize = t_str.parse().ok().filter(|&v| v >= 1)?;
    let i: usize = i_str.parse().ok().filter(|&v| v >= 1)?;
    let d: usize = d_str.parse().ok().filter(|&v| v >= 1)?;
    let n_txns = d.checked_mul(mult)?;
    let seed = 0x9E37_79B9_7F4A_7C15u64
        ^ (t as u64).wrapping_mul(1_000_003)
        ^ (i as u64).wrapping_mul(7_919)
        ^ n_txns as u64;
    Some(IbmParams {
        n_txns,
        n_items: 1000,
        avg_txn_len: t as f64,
        avg_pattern_len: i as f64,
        n_patterns: 2000,
        correlation: 0.5,
        corruption_mean: 0.5,
        corruption_sd: 0.1,
        anchor_len: None,
        anchor_weight: 0.0,
        seed,
    })
}

/// Generate-to-disk cache for Quest-family datasets: the store lives at
/// `<cache_dir>/<name>/` and is reused when present (stale stores whose
/// record count disagrees with the name are regenerated). Generation
/// streams [`QuestGen`] into a [`segment::SegmentWriter`], so even the
/// million-transaction entries never materialize in memory. Block size is
/// the dataset's [`split_lines`], keeping one lazily-decoded block per
/// paper-style map task.
pub fn quest_store(name: &str, cache_dir: &Path) -> Result<SegmentSource, SegmentError> {
    let p = quest_params(name).ok_or_else(|| {
        SegmentError::InvalidName(format!(
            "{name:?} is not a Quest-family name (expected t<T>i<I>d<D>, e.g. t10i4d100k)"
        ))
    })?;
    let canonical = name.to_ascii_lowercase();
    let dir = cache_dir.join(&canonical);
    let block_lines = split_lines(&canonical);
    if segment::exists(&dir) {
        let src = segment::open(&dir)?;
        // A store is current only if both the record count and the block
        // granularity match what this name implies (someone may have
        // `generate --segmented`-ed a custom-block store into the cache).
        if src.len() == p.n_txns && src.block_lines() == block_lines {
            return Ok(src);
        }
    }
    segment::write_store(&dir, canonical.as_str(), block_lines, p.n_items, QuestGen::new(&p))
}

/// The paper's reference minimum support for each dataset (§5.3). Quest
/// entries use scale-sweep defaults: sparse T10 mines at 1%, the denser
/// T40 at 3% (keeping the candidate space sane at 10^5–10^6 rows).
pub fn reference_min_sup(name: &str) -> Option<f64> {
    match name {
        "c20d10k" => Some(0.15),
        "chess" => Some(0.65),
        "mushroom" => Some(0.15),
        _ => quest_params(name).map(|p| if p.avg_txn_len >= 20.0 { 0.03 } else { 0.01 }),
    }
}

/// The paper's per-dataset DPC fast-phase α (§5.2): 3.0 on chess, 2.0
/// everywhere else. THE one copy of the rule — the figure sweeps, the
/// fault grid, and the CLI defaults all call this, so they cannot drift.
pub fn paper_dpc_alpha(name: &str) -> f64 {
    if name == "chess" {
        3.0
    } else {
        2.0
    }
}

/// The min_sup sweep used in the paper's Figs 2-4 (x-axes, high -> low).
pub fn figure_min_sups(name: &str) -> Option<Vec<f64>> {
    match name {
        "c20d10k" => Some(vec![0.35, 0.30, 0.25, 0.20, 0.15]),
        "chess" => Some(vec![0.85, 0.80, 0.75, 0.70, 0.65]),
        "mushroom" => Some(vec![0.35, 0.30, 0.25, 0.20, 0.15]),
        _ => None,
    }
}

/// The paper's InputSplit (lines per split, §5.2) per dataset. Quest
/// entries keep the paper's 10-map-task shape: the split (and segment
/// block) scales with D, as in the Fig 5(a) setup.
pub fn split_lines(name: &str) -> usize {
    match name {
        "chess" => 400,
        "c20d10k" | "mushroom" => 1000,
        _ => match quest_params(name) {
            Some(p) => (p.n_txns / 10).max(1),
            // Unknown names (file paths): 1K lines, the paper's common case.
            None => 1000,
        },
    }
}

/// Build a dataset by name. Panics on unknown names (CLI validates first).
pub fn load(name: &str) -> TransactionDb {
    // lint:allow(unwrap-in-library): the panicking convenience wrapper is
    // this fn's contract; fallible callers use try_load().
    try_load(name).unwrap_or_else(|| panic!("unknown dataset {name:?}; known: {NAMES:?}"))
}

/// Build a dataset by name, including Quest-family `t*i*d*` names (which
/// are materialized in memory — prefer [`quest_store`] +
/// [`crate::hdfs::put_segmented`] for the large entries).
pub fn try_load(name: &str) -> Option<TransactionDb> {
    match name {
        "c20d10k" => Some(c20d10k()),
        "chess" => Some(chess()),
        "mushroom" => Some(mushroom()),
        _ => {
            let p = quest_params(name)?;
            let mut db = ibm::generate(&p);
            db.name = name.to_ascii_lowercase();
            Some(db)
        }
    }
}

/// IBM Quest synthetic: 10k transactions, 192 items, avg width 20.
/// Tuned so min_sup=0.15 mining yields a Table-6-shaped profile
/// (L1≈38, unimodal peak near k=6, maximal length ≈13).
pub fn c20d10k() -> TransactionDb {
    let mut db = ibm::generate(&IbmParams {
        n_txns: 10_000,
        n_items: 192,
        avg_txn_len: 20.0,
        avg_pattern_len: 7.0,
        n_patterns: 22,
        correlation: 0.30,
        corruption_mean: 0.38,
        corruption_sd: 0.10,
        anchor_len: Some(13),
        anchor_weight: 0.30,
        seed: 0xC20D10,
    });
    db.name = "c20d10k".into();
    db
}

/// Chess analog: 3196 transactions, 37 attributes, 75 items, width 37.
/// kr-vs-kp is almost fully binary; dense with very long frequent sets at
/// min_sup=0.65 (paper Table 6: max length 13, L1=29, peak |L_7|≈26k).
pub fn chess() -> TransactionDb {
    let mut attrs: Vec<AttrSpec> = Vec::with_capacity(37);
    // 16 "core" binary attributes (correlated, near-constant): the long
    // frequent itemsets. 13 mid-dominance binaries: frequent singletons and
    // small mixed itemsets only. 6 low binaries + 1 ternary + 1 binary:
    // noise. Items: 35*2 + 3 + 2 = 75.
    attrs.extend(attr::ramp(16, 2, 0.98, 0.82));
    attrs.extend(attr::ramp(13, 2, 0.76, 0.70));
    attrs.extend(attr::ramp(6, 2, 0.52, 0.48));
    attrs.push(AttrSpec { domain: 3, dominance: 0.45 });
    attrs.push(AttrSpec { domain: 2, dominance: 0.5 });
    attr::generate(&AttrParams {
        name: "chess".into(),
        n_txns: 3196,
        attrs,
        conform_prob: 0.62,
        conform_hi: 0.99,
        core_attrs: 16,
        seed: 0xC4E55,
    })
}

/// Mushroom analog: 8124 transactions, 23 attributes, 119 items, width 23.
/// Moderately dense; at min_sup=0.15 the paper finds max length 15, L1=48.
pub fn mushroom() -> TransactionDb {
    let mut attrs: Vec<AttrSpec> = Vec::with_capacity(23);
    // 23 attributes, mixed domains summing to 119 items:
    // 10 attrs of domain 6 (60) + 8 of domain 5 (40) + 4 of domain 4 (16)
    // + 1 of domain 3 (3) = 119.
    attrs.extend(attr::ramp(10, 6, 0.82, 0.55));
    attrs.extend(attr::ramp(8, 5, 0.60, 0.36));
    attrs.extend(attr::ramp(4, 4, 0.38, 0.28));
    attrs.push(AttrSpec { domain: 3, dominance: 0.40 });
    attr::generate(&AttrParams {
        name: "mushroom".into(),
        n_txns: 8124,
        attrs,
        conform_prob: 0.24,
        conform_hi: 0.975,
        core_attrs: 15,
        seed: 0x3445400,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_attributes_match() {
        // Table 2 of the paper: (N, |I|, w).
        let c = c20d10k();
        assert_eq!(c.len(), 10_000);
        assert_eq!(c.n_items, 192);
        let w = c.avg_width();
        assert!((15.0..25.0).contains(&w), "c20d10k width {w}");

        let ch = chess();
        assert_eq!(ch.len(), 3196);
        assert_eq!(ch.n_items, 75);
        assert!(ch.txns.iter().all(|t| t.len() == 37));

        let m = mushroom();
        assert_eq!(m.len(), 8124);
        assert_eq!(m.n_items, 119);
        assert!(m.txns.iter().all(|t| t.len() == 23));
    }

    #[test]
    fn registry_lookup() {
        for name in NAMES {
            assert!(try_load(name).is_some());
            assert!(reference_min_sup(name).is_some());
            assert!(figure_min_sups(name).is_some());
        }
        assert!(try_load("nope").is_none());
        assert_eq!(split_lines("chess"), 400);
        assert_eq!(split_lines("c20d10k"), 1000);
    }

    #[test]
    fn quest_name_parsing() {
        let p = quest_params("t10i4d100k").unwrap();
        assert_eq!(p.n_txns, 100_000);
        assert!((p.avg_txn_len - 10.0).abs() < 1e-9);
        assert!((p.avg_pattern_len - 4.0).abs() < 1e-9);
        let p = quest_params("T40I10D1M").unwrap(); // case-insensitive
        assert_eq!(p.n_txns, 1_000_000);
        assert!((p.avg_txn_len - 40.0).abs() < 1e-9);
        let tiny = quest_params("t5i2d300").unwrap(); // bare D, family is open
        assert_eq!(tiny.n_txns, 300);
        for bad in ["", "c20d10k", "t10", "t10i4", "t10i4dxk", "ti4d100k", "t0i4d100k"] {
            assert!(quest_params(bad).is_none(), "{bad:?} must not parse");
        }
        // Distinct entries get distinct seeds.
        assert_ne!(
            quest_params("t10i4d100k").unwrap().seed,
            quest_params("t40i10d100k").unwrap().seed
        );
        for name in QUEST_NAMES {
            assert!(quest_params(name).is_some(), "{name}");
            assert!(reference_min_sup(name).is_some(), "{name}");
            assert_eq!(split_lines(name), quest_params(name).unwrap().n_txns / 10);
        }
    }

    #[test]
    fn quest_try_load_materializes_small_entries() {
        let db = try_load("t8i3d500").expect("family name loads");
        assert_eq!(db.len(), 500);
        assert_eq!(db.name, "t8i3d500");
        assert_eq!(db.n_items, 1000);
        assert!(db.validate().is_ok());
    }

    #[test]
    fn quest_store_caches_and_matches_materialized() {
        let cache = std::env::temp_dir().join("mrapriori_registry_quest_cache");
        let _ = std::fs::remove_dir_all(&cache);
        let src = quest_store("t6i3d400", &cache).unwrap();
        assert_eq!(src.len(), 400);
        assert_eq!(src.n_items(), 1000);
        // Reopen hits the cache (same manifest, no regeneration) and the
        // records equal the in-memory generator's output.
        let again = quest_store("t6i3d400", &cache).unwrap();
        assert_eq!(again.len(), 400);
        let db = try_load("t6i3d400").unwrap();
        let mut streamed = Vec::new();
        use crate::hdfs::RecordSource as _;
        src.for_each(0..400, &mut |_, r| streamed.push(r.clone()));
        assert_eq!(streamed, db.txns);
        assert!(quest_store("chess", &cache).is_err());
        std::fs::remove_dir_all(&cache).unwrap();
    }

    #[test]
    fn datasets_validate_and_are_deterministic() {
        for name in NAMES {
            let a = load(name);
            assert!(a.validate().is_ok(), "{name}");
            let b = load(name);
            assert_eq!(a.txns, b.txns, "{name} not deterministic");
        }
    }
}
