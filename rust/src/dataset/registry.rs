//! Named datasets matching the paper's Table 2.
//!
//! The paper evaluates on `c20d10k` (IBM Quest synthetic), `chess` and
//! `mushroom` (UCI real datasets from the FIMI/SPMF repositories). The real
//! datasets are not redistributable here, so the registry generates
//! *synthetic analogs* matched to Table 2's attributes (N, |I|, w) and tuned
//! so the |L_k| profile at the paper's reference min_sup has the same shape
//! as Table 6: unimodal, peak near k=6..7, maximal frequent length ~13–15.
//! See DESIGN.md §3 (substitution table).

use super::attr::{self, AttrParams, AttrSpec};
use super::ibm::{self, IbmParams};
use super::TransactionDb;

/// Dataset names accepted by the CLI and the bench harness.
pub const NAMES: [&str; 3] = ["c20d10k", "chess", "mushroom"];

/// The paper's reference minimum support for each dataset (§5.3).
pub fn reference_min_sup(name: &str) -> Option<f64> {
    match name {
        "c20d10k" => Some(0.15),
        "chess" => Some(0.65),
        "mushroom" => Some(0.15),
        _ => None,
    }
}

/// The min_sup sweep used in the paper's Figs 2-4 (x-axes, high -> low).
pub fn figure_min_sups(name: &str) -> Option<Vec<f64>> {
    match name {
        "c20d10k" => Some(vec![0.35, 0.30, 0.25, 0.20, 0.15]),
        "chess" => Some(vec![0.85, 0.80, 0.75, 0.70, 0.65]),
        "mushroom" => Some(vec![0.35, 0.30, 0.25, 0.20, 0.15]),
        _ => None,
    }
}

/// The paper's InputSplit (lines per split, §5.2) per dataset.
pub fn split_lines(name: &str) -> usize {
    match name {
        "chess" => 400,
        // c20d10k and mushroom: 1K lines -> 10 and 9 mappers.
        _ => 1000,
    }
}

/// Build a dataset by name. Panics on unknown names (CLI validates first).
pub fn load(name: &str) -> TransactionDb {
    try_load(name).unwrap_or_else(|| panic!("unknown dataset {name:?}; known: {NAMES:?}"))
}

pub fn try_load(name: &str) -> Option<TransactionDb> {
    match name {
        "c20d10k" => Some(c20d10k()),
        "chess" => Some(chess()),
        "mushroom" => Some(mushroom()),
        _ => None,
    }
}

/// IBM Quest synthetic: 10k transactions, 192 items, avg width 20.
/// Tuned so min_sup=0.15 mining yields a Table-6-shaped profile
/// (L1≈38, unimodal peak near k=6, maximal length ≈13).
pub fn c20d10k() -> TransactionDb {
    let mut db = ibm::generate(&IbmParams {
        n_txns: 10_000,
        n_items: 192,
        avg_txn_len: 20.0,
        avg_pattern_len: 7.0,
        n_patterns: 22,
        correlation: 0.30,
        corruption_mean: 0.38,
        corruption_sd: 0.10,
        anchor_len: Some(13),
        anchor_weight: 0.30,
        seed: 0xC20D10,
    });
    db.name = "c20d10k".into();
    db
}

/// Chess analog: 3196 transactions, 37 attributes, 75 items, width 37.
/// kr-vs-kp is almost fully binary; dense with very long frequent sets at
/// min_sup=0.65 (paper Table 6: max length 13, L1=29, peak |L_7|≈26k).
pub fn chess() -> TransactionDb {
    let mut attrs: Vec<AttrSpec> = Vec::with_capacity(37);
    // 16 "core" binary attributes (correlated, near-constant): the long
    // frequent itemsets. 13 mid-dominance binaries: frequent singletons and
    // small mixed itemsets only. 6 low binaries + 1 ternary + 1 binary:
    // noise. Items: 35*2 + 3 + 2 = 75.
    attrs.extend(attr::ramp(16, 2, 0.98, 0.82));
    attrs.extend(attr::ramp(13, 2, 0.76, 0.70));
    attrs.extend(attr::ramp(6, 2, 0.52, 0.48));
    attrs.push(AttrSpec { domain: 3, dominance: 0.45 });
    attrs.push(AttrSpec { domain: 2, dominance: 0.5 });
    attr::generate(&AttrParams {
        name: "chess".into(),
        n_txns: 3196,
        attrs,
        conform_prob: 0.62,
        conform_hi: 0.99,
        core_attrs: 16,
        seed: 0xC4E55,
    })
}

/// Mushroom analog: 8124 transactions, 23 attributes, 119 items, width 23.
/// Moderately dense; at min_sup=0.15 the paper finds max length 15, L1=48.
pub fn mushroom() -> TransactionDb {
    let mut attrs: Vec<AttrSpec> = Vec::with_capacity(23);
    // 23 attributes, mixed domains summing to 119 items:
    // 10 attrs of domain 6 (60) + 8 of domain 5 (40) + 4 of domain 4 (16)
    // + 1 of domain 3 (3) = 119.
    attrs.extend(attr::ramp(10, 6, 0.82, 0.55));
    attrs.extend(attr::ramp(8, 5, 0.60, 0.36));
    attrs.extend(attr::ramp(4, 4, 0.38, 0.28));
    attrs.push(AttrSpec { domain: 3, dominance: 0.40 });
    attr::generate(&AttrParams {
        name: "mushroom".into(),
        n_txns: 8124,
        attrs,
        conform_prob: 0.24,
        conform_hi: 0.975,
        core_attrs: 15,
        seed: 0x3445400,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_attributes_match() {
        // Table 2 of the paper: (N, |I|, w).
        let c = c20d10k();
        assert_eq!(c.len(), 10_000);
        assert_eq!(c.n_items, 192);
        let w = c.avg_width();
        assert!((15.0..25.0).contains(&w), "c20d10k width {w}");

        let ch = chess();
        assert_eq!(ch.len(), 3196);
        assert_eq!(ch.n_items, 75);
        assert!(ch.txns.iter().all(|t| t.len() == 37));

        let m = mushroom();
        assert_eq!(m.len(), 8124);
        assert_eq!(m.n_items, 119);
        assert!(m.txns.iter().all(|t| t.len() == 23));
    }

    #[test]
    fn registry_lookup() {
        for name in NAMES {
            assert!(try_load(name).is_some());
            assert!(reference_min_sup(name).is_some());
            assert!(figure_min_sups(name).is_some());
        }
        assert!(try_load("nope").is_none());
        assert_eq!(split_lines("chess"), 400);
        assert_eq!(split_lines("c20d10k"), 1000);
    }

    #[test]
    fn datasets_validate_and_are_deterministic() {
        for name in NAMES {
            let a = load(name);
            assert!(a.validate().is_ok(), "{name}");
            let b = load(name);
            assert_eq!(a.txns, b.txns, "{name} not deterministic");
        }
    }
}
