//! IBM Quest-style synthetic transaction generator (Agrawal & Srikant,
//! VLDB'94 §2.1.3) — the tool behind the paper's `c20d10k` dataset.
//!
//! The generative process:
//! 1. Draw `n_patterns` maximal potentially-frequent itemsets. Pattern sizes
//!    are Poisson with mean `avg_pattern_len`; a fraction of each pattern's
//!    items is inherited from the previous pattern (correlation), the rest
//!    are drawn randomly. Item popularity is exponentially skewed.
//! 2. Each pattern gets an exponential weight (normalized to sum 1) and a
//!    corruption level drawn from N(corruption_mean, corruption_sd).
//! 3. Each transaction draws its size from Poisson(avg_txn_len), then packs
//!    weighted patterns into it, dropping items from a pattern while a coin
//!    flip stays below its corruption level. Oversize patterns go in anyway
//!    half of the time (per the original), otherwise they are discarded.

use super::TransactionDb;
use crate::itemset::{Item, Itemset};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// Parameters of the IBM Quest generative process.
pub struct IbmParams {
    /// `D`: number of transactions.
    pub n_txns: usize,
    /// `N`: size of the item universe.
    pub n_items: usize,
    /// `T`: mean transaction width.
    pub avg_txn_len: f64,
    /// `I`: mean size of the maximal potentially-frequent itemsets.
    pub avg_pattern_len: f64,
    /// `L`: number of maximal potentially-frequent itemsets.
    pub n_patterns: usize,
    /// Fraction of a pattern inherited from its predecessor.
    pub correlation: f64,
    /// Mean of the per-pattern corruption level.
    pub corruption_mean: f64,
    /// Std-dev of the per-pattern corruption level.
    pub corruption_sd: f64,
    /// Optional "anchor": force pattern 0 to have exactly this many items.
    /// Long anchors model the heavy maximal itemsets that give dense Quest
    /// datasets their deep L_k tails (c20d10k reaches k = 13 in Table 6).
    pub anchor_len: Option<usize>,
    /// Fraction of the total pattern weight given to the anchor.
    pub anchor_weight: f64,
    /// Generator seed.
    pub seed: u64,
}

impl Default for IbmParams {
    fn default() -> Self {
        Self {
            n_txns: 10_000,
            n_items: 1000,
            avg_txn_len: 10.0,
            avg_pattern_len: 4.0,
            n_patterns: 2000,
            correlation: 0.5,
            corruption_mean: 0.5,
            corruption_sd: 0.1,
            anchor_len: None,
            anchor_weight: 0.0,
            seed: 20180348, // volume/page of the paper; any constant works
        }
    }
}

/// Streaming Quest generator: the pattern tables are built eagerly (they
/// are small — `O(n_patterns · avg_pattern_len)`), then transactions are
/// drawn one at a time from the iterator, so a T40I10D1M-class dataset can
/// be written straight to a segment store without ever being resident.
///
/// The RNG call sequence is identical to the original batch [`generate`],
/// so `generate(p).txns == QuestGen::new(p).collect::<Vec<_>>()` for every
/// parameter set (tested below) — the registry's tuned datasets keep their
/// exact |L_k| profiles.
pub struct QuestGen {
    rng: Rng,
    item_cum: Vec<f64>,
    patterns: Vec<Itemset>,
    corruption: Vec<f64>,
    weight_cum: Vec<f64>,
    avg_txn_len: f64,
    n_items: usize,
    remaining: usize,
}

impl QuestGen {
    /// Build the pattern tables for `p` (steps 1–2 of the generative
    /// process); transactions are then drawn lazily via [`Iterator`].
    pub fn new(p: &IbmParams) -> Self {
        assert!(p.n_items >= 2 && p.n_txns > 0 && p.n_patterns > 0);
        let mut rng = Rng::new(p.seed);

        // Exponentially-skewed item popularity (common items get low ids).
        let mut item_cum = Vec::with_capacity(p.n_items);
        let mut acc = 0.0;
        for i in 0..p.n_items {
            // weight ∝ exp(-i / (n/5)): a few hundred dominate, long tail.
            acc += (-(i as f64) / (p.n_items as f64 / 5.0)).exp();
            item_cum.push(acc);
        }

        // 1-2. Maximal potential patterns with weights and corruption levels.
        let mut patterns: Vec<Itemset> = Vec::with_capacity(p.n_patterns);
        let mut weights = Vec::with_capacity(p.n_patterns);
        let mut corruption = Vec::with_capacity(p.n_patterns);
        for pi in 0..p.n_patterns {
            let len = p.avg_pattern_len.max(1.0);
            let mut size = rng.poisson(len).max(1).min(p.n_items);
            if pi == 0 {
                if let Some(a) = p.anchor_len {
                    size = a.min(p.n_items);
                }
            }
            let mut set: Itemset = Vec::with_capacity(size);
            if pi > 0 && !patterns[pi - 1].is_empty() {
                // Inherit ~correlation fraction from the previous pattern.
                let prev = &patterns[pi - 1];
                for &it in prev.iter() {
                    if set.len() < size && rng.chance(p.correlation) {
                        set.push(it);
                    }
                }
            }
            while set.len() < size {
                set.push(rng.weighted(&item_cum) as Item);
            }
            crate::itemset::canonicalize(&mut set);
            patterns.push(set);
            weights.push(rng.exp());
            corruption
                .push((p.corruption_mean + p.corruption_sd * rng.gaussian()).clamp(0.0, 0.95));
        }
        if p.anchor_len.is_some() && p.anchor_weight > 0.0 && p.n_patterns > 1 {
            // Give the anchor `anchor_weight` of the total mass.
            let others: f64 = weights[1..].iter().sum();
            weights[0] = p.anchor_weight / (1.0 - p.anchor_weight) * others;
        }
        let mut weight_cum = Vec::with_capacity(p.n_patterns);
        let mut acc = 0.0;
        for w in &weights {
            acc += w;
            weight_cum.push(acc);
        }

        Self {
            rng,
            item_cum,
            patterns,
            corruption,
            weight_cum,
            avg_txn_len: p.avg_txn_len,
            n_items: p.n_items,
            remaining: p.n_txns,
        }
    }

    /// Size of the dense item universe the generator draws from.
    pub fn n_items(&self) -> usize {
        self.n_items
    }
}

impl Iterator for QuestGen {
    type Item = Itemset;

    /// Step 3 of the generative process: one transaction per call.
    fn next(&mut self) -> Option<Itemset> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let rng = &mut self.rng;
        let size = rng.poisson(self.avg_txn_len).max(1);
        let mut t: Itemset = Vec::with_capacity(size + 4);
        let mut guard = 0;
        while t.len() < size && guard < 64 {
            guard += 1;
            let pat = &self.patterns[rng.weighted(&self.weight_cum)];
            let corr = self.corruption[guard % self.corruption.len()];
            // Corrupt: drop items while the coin stays below the level.
            let mut chosen: Vec<Item> = pat.clone();
            while !chosen.is_empty() && rng.chance(corr) {
                let idx = rng.below(chosen.len() as u64) as usize;
                chosen.swap_remove(idx);
            }
            if chosen.is_empty() {
                continue;
            }
            if t.len() + chosen.len() > size + 2 && !rng.chance(0.5) {
                // Oversize pattern skipped half the time.
                continue;
            }
            t.extend_from_slice(&chosen);
        }
        crate::itemset::canonicalize(&mut t);
        if t.is_empty() {
            t.push(rng.weighted(&self.item_cum) as Item);
        }
        Some(t)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

/// Generate a database according to `p`, fully materialized.
pub fn generate(p: &IbmParams) -> TransactionDb {
    let txns: Vec<Itemset> = QuestGen::new(p).collect();
    let db = TransactionDb::new(
        format!("ibm-t{}-d{}", p.avg_txn_len as usize, p.n_txns),
        p.n_items,
        txns,
    );
    debug_assert!(db.validate().is_ok());
    db
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> IbmParams {
        IbmParams {
            n_txns: 500,
            n_items: 100,
            avg_txn_len: 8.0,
            avg_pattern_len: 3.0,
            n_patterns: 50,
            ..Default::default()
        }
    }

    #[test]
    fn generates_valid_db() {
        let db = generate(&small());
        assert_eq!(db.len(), 500);
        assert!(db.validate().is_ok());
        assert!(db.max_item().unwrap() < 100);
    }

    #[test]
    fn streamed_matches_batch() {
        // The lazy iterator must replay the exact batch RNG sequence —
        // the registry's tuned |L_k| profiles depend on it.
        let p = small();
        let streamed: Vec<Itemset> = QuestGen::new(&p).collect();
        assert_eq!(generate(&p).txns, streamed);
        let mut gen = QuestGen::new(&p);
        assert_eq!(gen.size_hint(), (500, Some(500)));
        gen.next();
        assert_eq!(gen.size_hint(), (499, Some(499)));
        assert_eq!(gen.n_items(), 100);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&small());
        let b = generate(&small());
        assert_eq!(a.txns, b.txns);
        let c = generate(&IbmParams { seed: 999, ..small() });
        assert_ne!(a.txns, c.txns);
    }

    #[test]
    fn mean_width_tracks_parameter() {
        let db = generate(&IbmParams { n_txns: 3000, avg_txn_len: 12.0, ..small() });
        let w = db.avg_width();
        assert!(w > 7.0 && w < 17.0, "avg width {w}");
    }

    #[test]
    fn skew_makes_low_ids_frequent() {
        let db = generate(&IbmParams { n_txns: 3000, ..small() });
        let mut freq = vec![0usize; db.n_items];
        for t in &db.txns {
            for &i in t {
                freq[i as usize] += 1;
            }
        }
        let head: usize = freq[..20].iter().sum();
        let tail: usize = freq[80..].iter().sum();
        assert!(head > tail * 3, "head {head} tail {tail}");
    }
}
