//! Dataset summary statistics and report formatting (the `inspect` CLI verb
//! and the Table-2/Table-6 bench output).

use super::TransactionDb;

#[derive(Debug, Clone)]
/// Table-2-style dataset summary.
pub struct Summary {
    /// Dataset name.
    pub name: String,
    /// Transaction count (N).
    pub n_txns: usize,
    /// Item-universe size |I|.
    pub n_items: usize,
    /// Mean transaction width (w).
    pub avg_width: f64,
    /// Smallest transaction width.
    pub min_width: usize,
    /// Largest transaction width.
    pub max_width: usize,
    /// Fraction of the N x |I| grid that is set.
    pub density: f64,
    /// Top-10 item frequencies (fraction of transactions).
    pub top_items: Vec<(u32, f64)>,
}

/// The slice of a dataset's shape the counting-backend auto-pick consumes
/// (DESIGN.md §11): transaction count, universe size, and how full the
/// N × |I| grid is. Unlike [`Summary`] it needs no materialized
/// [`TransactionDb`] — sessions over streamed [`crate::hdfs::RecordSource`]
/// backends derive it from Job1's counters via [`DensityProfile::from_counts`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DensityProfile {
    /// Transaction count (N).
    pub n_txns: usize,
    /// Item-universe size |I|.
    pub n_items: usize,
    /// Mean transaction width (w).
    pub avg_width: f64,
    /// Fraction of the N × |I| grid that is set (w / |I|).
    pub density: f64,
}

impl DensityProfile {
    /// Profile from aggregate counts: `total_items` is the summed
    /// transaction width over all N transactions (the Job1
    /// `record_items` counter), so no second dataset scan is needed.
    pub fn from_counts(n_txns: usize, n_items: usize, total_items: u64) -> Self {
        let avg_width = total_items as f64 / n_txns.max(1) as f64;
        Self { n_txns, n_items, avg_width, density: avg_width / n_items.max(1) as f64 }
    }
}

impl Summary {
    /// The summary's [`DensityProfile`] slice.
    pub fn profile(&self) -> DensityProfile {
        DensityProfile {
            n_txns: self.n_txns,
            n_items: self.n_items,
            avg_width: self.avg_width,
            density: self.density,
        }
    }
}

/// Compute a [`Summary`] in one scan.
pub fn summarize(db: &TransactionDb) -> Summary {
    let mut freq = vec![0usize; db.n_items];
    let mut min_w = usize::MAX;
    let mut max_w = 0usize;
    for t in &db.txns {
        min_w = min_w.min(t.len());
        max_w = max_w.max(t.len());
        for &i in t {
            freq[i as usize] += 1;
        }
    }
    if db.txns.is_empty() {
        min_w = 0;
    }
    let mut by_freq: Vec<(u32, usize)> =
        freq.iter().enumerate().map(|(i, &c)| (i as u32, c)).collect();
    by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let n = db.txns.len().max(1) as f64;
    Summary {
        name: db.name.clone(),
        n_txns: db.txns.len(),
        n_items: db.n_items,
        avg_width: db.avg_width(),
        min_width: min_w,
        max_width: max_w,
        density: db.density(),
        top_items: by_freq.into_iter().take(10).map(|(i, c)| (i, c as f64 / n)).collect(),
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "dataset {}", self.name)?;
        writeln!(f, "  transactions : {}", self.n_txns)?;
        writeln!(f, "  items        : {}", self.n_items)?;
        writeln!(
            f,
            "  width        : avg {:.2}, min {}, max {}",
            self.avg_width, self.min_width, self.max_width
        )?;
        writeln!(f, "  density      : {:.4}", self.density)?;
        write!(f, "  top items    :")?;
        for (i, p) in &self.top_items {
            write!(f, " i{i}:{p:.2}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_fields() {
        let db = TransactionDb::new("t", 4, vec![vec![0, 1, 2], vec![0], vec![0, 3]]);
        let s = summarize(&db);
        assert_eq!(s.n_txns, 3);
        assert_eq!(s.min_width, 1);
        assert_eq!(s.max_width, 3);
        assert_eq!(s.top_items[0], (0, 1.0)); // item 0 in all three
        let text = s.to_string();
        assert!(text.contains("transactions : 3"));
    }

    #[test]
    fn density_profile_from_counts_matches_summary() {
        let db = TransactionDb::new("t", 4, vec![vec![0, 1, 2], vec![0], vec![0, 3]]);
        let total_items: u64 = db.txns.iter().map(|t| t.len() as u64).sum();
        let from_counts = DensityProfile::from_counts(db.txns.len(), db.n_items, total_items);
        let from_summary = summarize(&db).profile();
        assert_eq!(from_counts, from_summary);
        assert!((from_counts.density - 5.0 / 12.0).abs() < 1e-12);
        // Degenerate inputs must not divide by zero.
        let empty = DensityProfile::from_counts(0, 0, 0);
        assert_eq!(empty.avg_width, 0.0);
        assert_eq!(empty.density, 0.0);
    }

    #[test]
    fn top_items_sorted() {
        let db = TransactionDb::new("t", 3, vec![vec![2], vec![1, 2], vec![0, 1, 2]]);
        let s = summarize(&db);
        assert_eq!(s.top_items[0].0, 2);
        assert_eq!(s.top_items[1].0, 1);
        assert_eq!(s.top_items[2].0, 0);
    }
}
