//! Attribute–value dataset generator: synthetic stand-ins for the UCI
//! `chess` (kr-vs-kp) and `mushroom` datasets used in the paper.
//!
//! Those datasets are *relational*: every transaction has exactly `w` items,
//! one per attribute, where attribute `a` contributes one of its values
//! (encoded as distinct item ids). They are *dense*: many attributes have a
//! heavily dominant value, and dominant values co-occur, which is what
//! produces the very long frequent itemsets of the paper's Table 6 (maximal
//! length 13 at min_sup 0.65 on chess, 15 at 0.15 on mushroom).
//!
//! The generator models that structure directly:
//! * each attribute has a domain size and a *dominance* level `d`;
//! * a per-transaction conformity coin decides whether the transaction is
//!   "conformist" (takes dominant values with probability `hi`) or "free"
//!   (with probability `lo_scale * d`);
//! the mixture creates the positive correlation between dominant values that
//! a per-attribute-independent model cannot (joint support of k dominant
//! values would collapse as d^k).

use super::TransactionDb;
use crate::itemset::{Item, Itemset};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// One relational attribute of the generated dataset.
pub struct AttrSpec {
    /// Number of distinct values of this attribute.
    pub domain: usize,
    /// Dominance of the attribute's first value for "free" transactions.
    pub dominance: f64,
}

#[derive(Debug, Clone)]
/// Parameters of the attribute-value generator.
pub struct AttrParams {
    /// Dataset name.
    pub name: String,
    /// Transactions to generate.
    pub n_txns: usize,
    /// Attribute domains and dominances.
    pub attrs: Vec<AttrSpec>,
    /// Probability a transaction is conformist.
    pub conform_prob: f64,
    /// Probability a conformist transaction takes the dominant value *of a
    /// core attribute*.
    pub conform_hi: f64,
    /// Number of leading attributes the conformity mixture applies to. The
    /// core is what produces the long jointly-frequent itemsets; non-core
    /// attributes always draw independently with their own dominance —
    /// without this split, *every* k-subset of dominant values inherits the
    /// conformist joint support and |L_k| explodes combinatorially.
    pub core_attrs: usize,
    /// Generator seed.
    pub seed: u64,
}

impl AttrParams {
    /// Total items: the sum of attribute domains.
    pub fn n_items(&self) -> usize {
        self.attrs.iter().map(|a| a.domain).sum()
    }
}

/// Generate a relational-style database: each transaction has exactly one
/// item per attribute.
pub fn generate(p: &AttrParams) -> TransactionDb {
    assert!(!p.attrs.is_empty() && p.n_txns > 0);
    let mut rng = Rng::new(p.seed);
    // Item id layout: attribute a's values occupy a contiguous block.
    let mut offsets = Vec::with_capacity(p.attrs.len());
    let mut off = 0u32;
    for a in &p.attrs {
        assert!(a.domain >= 1);
        offsets.push(off);
        off += a.domain as u32;
    }
    let n_items = off as usize;

    let mut txns: Vec<Itemset> = Vec::with_capacity(p.n_txns);
    for _ in 0..p.n_txns {
        let conformist = rng.chance(p.conform_prob);
        let mut t: Itemset = Vec::with_capacity(p.attrs.len());
        for (ai, a) in p.attrs.iter().enumerate() {
            let p_dom = if conformist && ai < p.core_attrs {
                p.conform_hi.min(1.0)
            } else {
                a.dominance
            };
            let value: u32 = if a.domain == 1 || rng.chance(p_dom) {
                0
            } else {
                // Uniform over the non-dominant values.
                1 + rng.below((a.domain - 1) as u64) as u32
            };
            t.push(offsets[ai] + value as Item);
        }
        // Blocks are disjoint and ordered, so t is already canonical.
        txns.push(t);
    }
    let db = TransactionDb::new(p.name.clone(), n_items, txns);
    debug_assert!(db.validate().is_ok());
    db
}

/// Convenience: `n` attributes sharing a domain size and a dominance ramp
/// from `d_hi` (first attribute) down to `d_lo` (last attribute).
pub fn ramp(n: usize, domain: usize, d_hi: f64, d_lo: f64) -> Vec<AttrSpec> {
    (0..n)
        .map(|i| {
            let frac = if n == 1 { 0.0 } else { i as f64 / (n - 1) as f64 };
            AttrSpec { domain, dominance: d_hi + (d_lo - d_hi) * frac }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> AttrParams {
        AttrParams {
            name: "attr-test".into(),
            n_txns: 2000,
            attrs: ramp(10, 3, 0.95, 0.4),
            conform_prob: 0.5,
            conform_hi: 0.98,
            core_attrs: 10,
            seed: 7,
        }
    }

    #[test]
    fn fixed_width_transactions() {
        let p = small();
        let db = generate(&p);
        assert_eq!(db.len(), 2000);
        assert!(db.txns.iter().all(|t| t.len() == 10));
        assert_eq!(db.n_items, 30);
        assert!(db.validate().is_ok());
    }

    #[test]
    fn one_item_per_attribute_block() {
        let p = small();
        let db = generate(&p);
        for t in &db.txns {
            for (ai, &item) in t.iter().enumerate() {
                let lo = (ai * 3) as u32;
                assert!(item >= lo && item < lo + 3, "item {item} outside block {ai}");
            }
        }
    }

    #[test]
    fn dominant_values_are_dominant() {
        let p = small();
        let db = generate(&p);
        // Attribute 0 has dominance 0.95 free / 0.98 conform: its value 0
        // (item id 0) should appear in ~96% of transactions.
        let hits = db.txns.iter().filter(|t| t[0] == 0).count();
        let frac = hits as f64 / db.len() as f64;
        assert!(frac > 0.9, "dominant frac {frac}");
    }

    #[test]
    fn conformity_creates_correlation() {
        // Joint support of the first 6 dominant values must exceed the
        // product of their marginals (positive correlation).
        let p = AttrParams {
            attrs: ramp(6, 3, 0.7, 0.7),
            conform_prob: 0.5,
            conform_hi: 0.99,
            core_attrs: 6,
            n_txns: 8000,
            ..small()
        };
        let db = generate(&p);
        let dominant: Vec<Item> = (0..6).map(|a| (a * 3) as Item).collect();
        let joint = db
            .txns
            .iter()
            .filter(|t| dominant.iter().all(|d| t.contains(d)))
            .count() as f64
            / db.len() as f64;
        let mut marg_product = 1.0;
        for a in 0..6 {
            let m = db.txns.iter().filter(|t| t[a] == (a * 3) as Item).count() as f64
                / db.len() as f64;
            marg_product *= m;
        }
        assert!(joint > marg_product * 1.3, "joint {joint} vs product {marg_product}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&small());
        let b = generate(&small());
        assert_eq!(a.txns, b.txns);
    }

    #[test]
    fn ramp_endpoints() {
        let r = ramp(5, 2, 0.9, 0.5);
        assert!((r[0].dominance - 0.9).abs() < 1e-9);
        assert!((r[4].dominance - 0.5).abs() < 1e-9);
        assert!(r.windows(2).all(|w| w[0].dominance >= w[1].dominance));
    }
}
