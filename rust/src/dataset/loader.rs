//! Text I/O for transaction databases in the FIMI / SPMF format the paper's
//! datasets use: one transaction per line, space-separated integer items.

use super::TransactionDb;
use crate::itemset::Itemset;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

#[derive(Debug)]
pub enum LoadError {
    Io(std::io::Error),
    BadItem { line: usize, token: String },
    Empty,
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::Io(e) => write!(f, "io error: {e}"),
            LoadError::BadItem { line, token } => {
                write!(f, "line {line}: cannot parse item {token:?}")
            }
            LoadError::Empty => write!(f, "dataset is empty"),
        }
    }
}

impl std::error::Error for LoadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LoadError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LoadError {
    fn from(e: std::io::Error) -> Self {
        LoadError::Io(e)
    }
}

/// Parse the FIMI text format from any reader. Item ids are kept as-is
/// (already dense in FIMI dumps); `n_items` = max item + 1.
pub fn read_transactions<R: std::io::Read>(r: R, name: &str) -> Result<TransactionDb, LoadError> {
    let reader = BufReader::new(r);
    let mut txns: Vec<Itemset> = Vec::new();
    let mut max_item = 0u32;
    for (idx, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut t: Itemset = Vec::new();
        for tok in line.split_whitespace() {
            let item: u32 = tok
                .parse()
                .map_err(|_| LoadError::BadItem { line: idx + 1, token: tok.to_string() })?;
            t.push(item);
        }
        crate::itemset::canonicalize(&mut t);
        if let Some(&m) = t.last() {
            max_item = max_item.max(m);
        }
        if !t.is_empty() {
            txns.push(t);
        }
    }
    if txns.is_empty() {
        return Err(LoadError::Empty);
    }
    Ok(TransactionDb::new(name, max_item as usize + 1, txns))
}

pub fn load_file(path: &Path) -> Result<TransactionDb, LoadError> {
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset").to_string();
    let f = std::fs::File::open(path)?;
    read_transactions(f, &name)
}

/// Write in the same format (round-trips with [`read_transactions`]).
pub fn write_file(db: &TransactionDb, path: &Path) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for t in &db.txns {
        let mut first = true;
        for &i in t {
            if !first {
                write!(w, " ")?;
            }
            write!(w, "{i}")?;
            first = false;
        }
        writeln!(w)?;
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_simple_input() {
        let text = "1 2 3\n\n# comment\n2 4\n";
        let db = read_transactions(text.as_bytes(), "t").unwrap();
        assert_eq!(db.len(), 2);
        assert_eq!(db.n_items, 5);
        assert_eq!(db.txns[0], vec![1, 2, 3]);
        assert_eq!(db.txns[1], vec![2, 4]);
    }

    #[test]
    fn canonicalizes_lines() {
        let db = read_transactions("3 1 2 1".as_bytes(), "t").unwrap();
        assert_eq!(db.txns[0], vec![1, 2, 3]);
    }

    #[test]
    fn rejects_garbage() {
        let err = read_transactions("1 x 3".as_bytes(), "t").unwrap_err();
        assert!(matches!(err, LoadError::BadItem { line: 1, .. }));
    }

    #[test]
    fn rejects_empty() {
        assert!(matches!(read_transactions("".as_bytes(), "t"), Err(LoadError::Empty)));
        assert!(matches!(read_transactions("\n#c\n".as_bytes(), "t"), Err(LoadError::Empty)));
    }

    #[test]
    fn file_roundtrip() {
        let db = TransactionDb::new("rt", 6, vec![vec![0, 3, 5], vec![1], vec![2, 4]]);
        let dir = std::env::temp_dir().join("mrapriori_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.txt");
        write_file(&db, &path).unwrap();
        let back = load_file(&path).unwrap();
        assert_eq!(back.txns, db.txns);
        assert_eq!(back.n_items, db.n_items);
        assert_eq!(back.name, "rt");
        std::fs::remove_file(&path).unwrap();
    }
}
