//! Text I/O for transaction databases in the FIMI / SPMF format the paper's
//! datasets use: one transaction per line, space-separated integer items.

use super::TransactionDb;
use crate::hdfs::segment::{SegmentError, SegmentSource, SegmentWriter};
use crate::itemset::Itemset;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Errors reading a transaction file.
#[derive(Debug)]
pub enum LoadError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// A token on `line` is not a parsable item id.
    BadItem {
        /// 1-based source line number.
        line: usize,
        /// The offending token.
        token: String,
    },
    /// The file holds no transactions.
    Empty,
    /// A segment-store error during import.
    Store(SegmentError),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::Io(e) => write!(f, "io error: {e}"),
            LoadError::BadItem { line, token } => {
                write!(f, "line {line}: cannot parse item {token:?}")
            }
            LoadError::Empty => write!(f, "dataset is empty"),
            LoadError::Store(e) => write!(f, "segment store: {e}"),
        }
    }
}

impl std::error::Error for LoadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LoadError::Io(e) => Some(e),
            LoadError::Store(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LoadError {
    fn from(e: std::io::Error) -> Self {
        LoadError::Io(e)
    }
}

impl From<SegmentError> for LoadError {
    fn from(e: SegmentError) -> Self {
        LoadError::Store(e)
    }
}

/// Parse the FIMI text format from any reader. Item ids are kept as-is
/// (already dense in FIMI dumps); `n_items` = max item + 1.
pub fn read_transactions<R: std::io::Read>(r: R, name: &str) -> Result<TransactionDb, LoadError> {
    let reader = BufReader::new(r);
    let mut txns: Vec<Itemset> = Vec::new();
    let mut max_item = 0u32;
    for (idx, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut t: Itemset = Vec::new();
        for tok in line.split_whitespace() {
            let item: u32 = tok
                .parse()
                .map_err(|_| LoadError::BadItem { line: idx + 1, token: tok.to_string() })?;
            t.push(item);
        }
        crate::itemset::canonicalize(&mut t);
        if let Some(&m) = t.last() {
            max_item = max_item.max(m);
        }
        if !t.is_empty() {
            txns.push(t);
        }
    }
    if txns.is_empty() {
        return Err(LoadError::Empty);
    }
    Ok(TransactionDb::new(name, max_item as usize + 1, txns))
}

/// Load a FIMI text file, fully materialized.
pub fn load_file(path: &Path) -> Result<TransactionDb, LoadError> {
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset").to_string();
    let f = std::fs::File::open(path)?;
    read_transactions(f, &name)
}

/// Statistics of a streamed FIMI scan.
#[derive(Debug, Clone, Copy)]
pub struct StreamStats {
    /// Transactions visited.
    pub n_records: usize,
    /// Largest item id seen (None when the file had no records).
    pub max_item: Option<u32>,
}

/// Stream the FIMI text format record by record: `f` sees each canonical
/// transaction once, and only one line is resident at a time. The
/// out-of-core counterpart of [`read_transactions`].
pub fn stream_transactions<R: std::io::Read>(
    r: R,
    mut f: impl FnMut(&Itemset) -> Result<(), LoadError>,
) -> Result<StreamStats, LoadError> {
    let reader = BufReader::new(r);
    let mut stats = StreamStats { n_records: 0, max_item: None };
    let mut t: Itemset = Vec::new();
    for (idx, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        t.clear();
        for tok in line.split_whitespace() {
            let item: u32 = tok
                .parse()
                .map_err(|_| LoadError::BadItem { line: idx + 1, token: tok.to_string() })?;
            t.push(item);
        }
        crate::itemset::canonicalize(&mut t);
        if let Some(&m) = t.last() {
            stats.max_item = Some(stats.max_item.map_or(m, |prev| prev.max(m)));
        }
        if !t.is_empty() {
            stats.n_records += 1;
            f(&t)?;
        }
    }
    if stats.n_records == 0 {
        return Err(LoadError::Empty);
    }
    Ok(stats)
}

/// Import a FIMI text file into an on-disk segment store at `dir` without
/// materializing it: the streaming bridge from arbitrary user files into
/// the out-of-core mining path ([`crate::hdfs::put_segmented`]).
pub fn import_segmented(
    path: &Path,
    dir: &Path,
    block_lines: usize,
) -> Result<SegmentSource, LoadError> {
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset").to_string();
    let f = std::fs::File::open(path)?;
    let mut w = SegmentWriter::create(dir, name, block_lines)?;
    stream_transactions(f, |t| w.push(t).map_err(LoadError::from))?;
    Ok(w.finish()?)
}

/// Serialize one transaction as a FIMI line (shared with the segment
/// store's writer so the two on-disk formats can never drift).
pub(crate) fn write_txn(w: &mut impl Write, t: &Itemset) -> std::io::Result<()> {
    let mut first = true;
    for &i in t {
        if !first {
            write!(w, " ")?;
        }
        write!(w, "{i}")?;
        first = false;
    }
    writeln!(w)
}

/// Write in the same format (round-trips with [`read_transactions`]).
pub fn write_file(db: &TransactionDb, path: &Path) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for t in &db.txns {
        write_txn(&mut w, t)?;
    }
    w.flush()
}

/// Stream transactions from an iterator (e.g. a running
/// [`crate::dataset::ibm::QuestGen`]) to a FIMI text file without ever
/// materializing the dataset; returns the record count.
pub fn write_file_streamed(
    txns: impl IntoIterator<Item = Itemset>,
    path: &Path,
) -> std::io::Result<usize> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let mut n = 0;
    for t in txns {
        write_txn(&mut w, &t)?;
        n += 1;
    }
    w.flush()?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_simple_input() {
        let text = "1 2 3\n\n# comment\n2 4\n";
        let db = read_transactions(text.as_bytes(), "t").unwrap();
        assert_eq!(db.len(), 2);
        assert_eq!(db.n_items, 5);
        assert_eq!(db.txns[0], vec![1, 2, 3]);
        assert_eq!(db.txns[1], vec![2, 4]);
    }

    #[test]
    fn canonicalizes_lines() {
        let db = read_transactions("3 1 2 1".as_bytes(), "t").unwrap();
        assert_eq!(db.txns[0], vec![1, 2, 3]);
    }

    #[test]
    fn rejects_garbage() {
        let err = read_transactions("1 x 3".as_bytes(), "t").unwrap_err();
        assert!(matches!(err, LoadError::BadItem { line: 1, .. }));
    }

    #[test]
    fn rejects_empty() {
        assert!(matches!(read_transactions("".as_bytes(), "t"), Err(LoadError::Empty)));
        assert!(matches!(read_transactions("\n#c\n".as_bytes(), "t"), Err(LoadError::Empty)));
    }

    #[test]
    fn stream_matches_batch_reader() {
        let text = "1 2 3\n\n# c\n3 1 2 1\n9\n";
        let batch = read_transactions(text.as_bytes(), "t").unwrap();
        let mut streamed: Vec<Itemset> = Vec::new();
        let stats = stream_transactions(text.as_bytes(), |t| {
            streamed.push(t.clone());
            Ok(())
        })
        .unwrap();
        assert_eq!(streamed, batch.txns);
        assert_eq!(stats.n_records, batch.len());
        assert_eq!(stats.max_item, Some(9));
        assert!(matches!(
            stream_transactions("#only\n".as_bytes(), |_| Ok(())),
            Err(LoadError::Empty)
        ));
    }

    #[test]
    fn import_segmented_roundtrip() {
        use crate::hdfs::RecordSource as _;
        let dir = std::env::temp_dir().join("mrapriori_loader_import");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("in.txt");
        let db = TransactionDb::new("in", 8, vec![vec![0, 3], vec![1, 7], vec![2], vec![4, 5, 6]]);
        write_file(&db, &file).unwrap();
        let store_dir = dir.join("store");
        let src = import_segmented(&file, &store_dir, 3).unwrap();
        assert_eq!(src.len(), 4);
        assert_eq!(src.name(), "in");
        assert_eq!(src.block_lines(), 3);
        let mut got = Vec::new();
        src.for_each(0..4, &mut |_, r| got.push(r.clone()));
        assert_eq!(got, db.txns);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn file_roundtrip() {
        let db = TransactionDb::new("rt", 6, vec![vec![0, 3, 5], vec![1], vec![2, 4]]);
        let dir = std::env::temp_dir().join("mrapriori_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.txt");
        write_file(&db, &path).unwrap();
        let back = load_file(&path).unwrap();
        assert_eq!(back.txns, db.txns);
        assert_eq!(back.n_items, db.n_items);
        assert_eq!(back.name, "rt");
        std::fs::remove_file(&path).unwrap();
    }
}
