//! Transaction databases: in-memory representation, text I/O, synthetic
//! generators (IBM Quest-style and attribute–value), the named-dataset
//! registry matching the paper's Table 2, and summary statistics.

pub mod attr;
pub mod ibm;
pub mod loader;
pub mod registry;
pub mod stats;

use crate::itemset::{Item, Itemset};

/// An in-memory transaction database with a dense item universe `0..n_items`.
#[derive(Debug, Clone)]
pub struct TransactionDb {
    /// Dataset name (registry key / report label).
    pub name: String,
    /// Size of the dense item universe.
    pub n_items: usize,
    /// Transactions, each a canonical itemset.
    pub txns: Vec<Itemset>,
}

impl TransactionDb {
    /// Assemble a database from its parts.
    pub fn new(name: impl Into<String>, n_items: usize, txns: Vec<Itemset>) -> Self {
        Self { name: name.into(), n_items, txns }
    }

    /// Number of transactions.
    pub fn len(&self) -> usize {
        self.txns.len()
    }

    /// Whether the database holds no transactions.
    pub fn is_empty(&self) -> bool {
        self.txns.is_empty()
    }

    /// Minimum support count for a fractional threshold (ceil, >= 1).
    pub fn min_count(&self, min_sup: f64) -> u64 {
        ((min_sup * self.txns.len() as f64).ceil() as u64).max(1)
    }

    /// Average transaction width (the paper's `w`).
    pub fn avg_width(&self) -> f64 {
        if self.txns.is_empty() {
            return 0.0;
        }
        self.txns.iter().map(|t| t.len()).sum::<usize>() as f64 / self.txns.len() as f64
    }

    /// Fraction of the item-universe grid that is set (dataset density).
    pub fn density(&self) -> f64 {
        if self.txns.is_empty() || self.n_items == 0 {
            return 0.0;
        }
        self.txns.iter().map(|t| t.len()).sum::<usize>() as f64
            / (self.txns.len() * self.n_items) as f64
    }

    /// Largest item id actually used (for encoding width checks).
    pub fn max_item(&self) -> Option<Item> {
        self.txns.iter().filter_map(|t| t.last()).copied().max()
    }

    /// Validate structural invariants: canonical transactions in range.
    pub fn validate(&self) -> Result<(), String> {
        for (i, t) in self.txns.iter().enumerate() {
            if t.is_empty() {
                return Err(format!("transaction {i} is empty"));
            }
            if !crate::itemset::is_canonical(t) {
                return Err(format!("transaction {i} is not sorted/deduped"));
            }
            if let Some(&last) = t.last() {
                if last as usize >= self.n_items {
                    return Err(format!(
                        "transaction {i} has item i{last} >= n_items {}",
                        self.n_items
                    ));
                }
            }
        }
        Ok(())
    }

    /// Concatenate the database with itself until it holds `target` rows
    /// (used by the Fig 5(a) scalability sweep: c20d10k scaled to c20d200k).
    pub fn scaled_to(&self, target: usize, name: impl Into<String>) -> TransactionDb {
        let mut txns = Vec::with_capacity(target);
        while txns.len() < target {
            let take = (target - txns.len()).min(self.txns.len());
            txns.extend_from_slice(&self.txns[..take]);
        }
        TransactionDb { name: name.into(), n_items: self.n_items, txns }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TransactionDb {
        TransactionDb::new("tiny", 5, vec![vec![0, 1], vec![1, 2, 3], vec![4]])
    }

    #[test]
    fn basic_stats() {
        let db = tiny();
        assert_eq!(db.len(), 3);
        assert!((db.avg_width() - 2.0).abs() < 1e-9);
        assert_eq!(db.max_item(), Some(4));
        assert!((db.density() - 6.0 / 15.0).abs() < 1e-9);
    }

    #[test]
    fn min_count_rounds_up() {
        let db = tiny();
        assert_eq!(db.min_count(0.5), 2); // ceil(1.5)
        assert_eq!(db.min_count(0.34), 2); // ceil(1.02)
        assert_eq!(db.min_count(0.0), 1); // floor of 1
    }

    #[test]
    fn validate_catches_problems() {
        let db = tiny();
        assert!(db.validate().is_ok());
        let bad = TransactionDb::new("b", 5, vec![vec![2, 1]]);
        assert!(bad.validate().is_err());
        let bad = TransactionDb::new("b", 2, vec![vec![0, 5]]);
        assert!(bad.validate().is_err());
        let bad = TransactionDb::new("b", 2, vec![vec![]]);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn scaling_repeats_rows() {
        let db = tiny();
        let big = db.scaled_to(8, "tiny8");
        assert_eq!(big.len(), 8);
        assert_eq!(big.txns[0], big.txns[3]); // wrapped around
        assert_eq!(big.txns[1], big.txns[4]);
    }
}
