//! Candidate generation: `apriori-gen` (join + prune) and the paper's
//! `non-apriori-gen` (join only, §4.2), both over tries, both metered.
//!
//! Given a source trie at level `k` (frequent k-itemsets — or, inside a
//! multi-pass phase, *candidate* k-itemsets), produce the candidate trie at
//! level `k+1`:
//!
//! * **join**: sibling self-join on the trie — every pair of leaves sharing
//!   a (k-1)-prefix yields one (k+1)-candidate;
//! * **prune** (`apriori_gen` only): a candidate survives iff *all* its
//!   k-subsets are present in the source. The two subsets formed by dropping
//!   either of the two joined items are present by construction, so only
//!   `k-1` membership probes are needed — exactly the `(k-2)·|trieL_{k-1}|`
//!   factor of the paper's §4.3 cost analysis (their k = our k+1).

use crate::itemset::{Item, Trie};

/// Operation meters for one generation call; feeds the cluster cost model.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct GenStats {
    /// Sibling pairs considered by the join step.
    pub join_pairs: u64,
    /// Subset membership probes performed by the prune step.
    pub prune_checks: u64,
    /// Candidates removed by pruning.
    pub pruned: u64,
    /// Candidates in the output trie.
    pub kept: u64,
}

impl GenStats {
    /// Accumulate another pass's meters.
    pub fn merge(&mut self, o: &GenStats) {
        self.join_pairs += o.join_pairs;
        self.prune_checks += o.prune_checks;
        self.pruned += o.pruned;
        self.kept += o.kept;
    }
}

/// Join + prune. Source level `k` -> candidates at level `k+1`.
pub fn apriori_gen(source: &Trie) -> (Trie, GenStats) {
    generate(source, true)
}

/// Join only (skipped pruning). Source level `k` -> candidates at `k+1`.
pub fn non_apriori_gen(source: &Trie) -> (Trie, GenStats) {
    generate(source, false)
}

fn generate(source: &Trie, prune: bool) -> (Trie, GenStats) {
    let k1 = source.level() + 1;
    let mut out = Trie::new(k1);
    let mut stats = GenStats::default();
    let mut scratch: Vec<Item> = Vec::with_capacity(k1 - 1);
    let join_pairs = source.self_join(|cand| {
        if prune && !survives_prune(source, cand, &mut scratch, &mut stats) {
            stats.pruned += 1;
            return;
        }
        out.insert(cand);
    });
    stats.join_pairs = join_pairs;
    stats.kept = out.len() as u64;
    (out, stats)
}

/// Check the k-subsets of `cand` obtained by dropping each position except
/// the last two (those are the joined leaves, present by construction).
fn survives_prune(
    source: &Trie,
    cand: &[Item],
    scratch: &mut Vec<Item>,
    stats: &mut GenStats,
) -> bool {
    let k1 = cand.len();
    for drop_idx in 0..k1.saturating_sub(2) {
        scratch.clear();
        scratch.extend(cand.iter().enumerate().filter(|(i, _)| *i != drop_idx).map(|(_, &x)| x));
        stats.prune_checks += 1;
        if !source.contains(scratch) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::itemset::Itemset;
    use crate::util::check::{forall, ItemsetGen, VecGen};

    fn trie_of(k: usize, sets: &[&[Item]]) -> Trie {
        let owned: Vec<Itemset> = sets.iter().map(|s| s.to_vec()).collect();
        Trie::from_itemsets(k, owned.iter())
    }

    #[test]
    fn textbook_example() {
        // L3 = {123, 124, 134, 234, 135}
        // join -> {1234 (from 123+124), 1345 (from 134+135)}
        // prune: 1234 survives (all 3-subsets frequent);
        //        1345 dies (145 not in L3, neither is 345... 134,135 present, 145 missing)
        let l3 = trie_of(3, &[&[1, 2, 3], &[1, 2, 4], &[1, 3, 4], &[2, 3, 4], &[1, 3, 5]]);
        let (c4, stats) = apriori_gen(&l3);
        assert_eq!(c4.itemsets(), vec![vec![1, 2, 3, 4]]);
        assert_eq!(stats.join_pairs, 2);
        assert_eq!(stats.pruned, 1);
        assert_eq!(stats.kept, 1);

        let (c4u, ustats) = non_apriori_gen(&l3);
        assert_eq!(c4u.itemsets(), vec![vec![1, 2, 3, 4], vec![1, 3, 4, 5]]);
        assert_eq!(ustats.prune_checks, 0);
        assert_eq!(ustats.kept, 2);
    }

    #[test]
    fn level1_join_has_no_prunable_subsets() {
        // From L1, candidates are pairs; both 1-subsets are the joined items.
        let l1 = trie_of(1, &[&[3], &[7], &[9]]);
        let (c2, stats) = apriori_gen(&l1);
        assert_eq!(c2.len(), 3);
        assert_eq!(stats.prune_checks, 0);
        assert_eq!(stats.pruned, 0);
    }

    #[test]
    fn empty_source_empty_output() {
        let l2 = Trie::new(2);
        let (c3, stats) = apriori_gen(&l2);
        assert!(c3.is_empty());
        assert_eq!(stats.join_pairs, 0);
    }

    #[test]
    fn prop_pruned_subset_of_unpruned() {
        // apriori_gen ⊆ non_apriori_gen, and the paper's Fig.1 claim:
        // counting either candidate set yields the same frequent sets.
        let gen = VecGen { inner: ItemsetGen { universe: 12, max_len: 3 }, max_len: 30 };
        forall(301, 80, &gen, |sets| {
            let mut l3: Vec<Itemset> = sets.iter().filter(|s| s.len() == 3).cloned().collect();
            l3.sort();
            l3.dedup();
            if l3.is_empty() {
                return true;
            }
            let trie = Trie::from_itemsets(3, l3.iter());
            let (pruned, _) = apriori_gen(&trie);
            let (unpruned, _) = non_apriori_gen(&trie);
            let ps = pruned.itemsets();
            let us = unpruned.itemsets();
            ps.iter().all(|s| unpruned.contains(s)) && ps.len() <= us.len()
        });
    }

    #[test]
    fn prop_pruned_candidates_have_frequent_subsets() {
        let gen = VecGen { inner: ItemsetGen { universe: 10, max_len: 2 }, max_len: 40 };
        forall(302, 80, &gen, |sets| {
            let mut l2: Vec<Itemset> = sets.iter().filter(|s| s.len() == 2).cloned().collect();
            l2.sort();
            l2.dedup();
            if l2.is_empty() {
                return true;
            }
            let trie = Trie::from_itemsets(2, l2.iter());
            let (c3, _) = apriori_gen(&trie);
            // Every kept candidate has all 2-subsets in L2.
            c3.itemsets().iter().all(|c| {
                (0..3).all(|drop| {
                    let sub: Itemset =
                        c.iter().enumerate().filter(|(i, _)| *i != drop).map(|(_, &x)| x).collect();
                    trie.contains(&sub)
                })
            })
        });
    }

    #[test]
    fn stats_merge() {
        let mut a = GenStats { join_pairs: 1, prune_checks: 2, pruned: 3, kept: 4 };
        let b = GenStats { join_pairs: 10, prune_checks: 20, pruned: 30, kept: 40 };
        a.merge(&b);
        assert_eq!(a, GenStats { join_pairs: 11, prune_checks: 22, pruned: 33, kept: 44 });
    }
}
