//! PARMA-style parallel randomized approximate mining (the paper's ref
//! [14], Riondato et al., CIKM'12): mine several independent random samples
//! in parallel map tasks, then aggregate — itemsets reported by a majority
//! of samples form the approximate result, with an (ε, δ) sample-size bound.
//!
//! This gives the repo the approximate-mining baseline the related-work
//! section positions against the exact algorithms; the bench compares its
//! simulated time and recall against Optimized-VFPC.

use super::sequential::mine;
use crate::dataset::TransactionDb;
use crate::itemset::Itemset;
use crate::util::rng::Rng;
use std::collections::HashMap;

#[derive(Debug, Clone)]
/// Parameters of the PARMA-style sampling miner.
pub struct ParmaParams {
    /// Absolute-frequency error tolerance ε.
    pub epsilon: f64,
    /// Failure probability δ.
    pub delta: f64,
    /// Number of parallel samples (map tasks).
    pub n_samples: usize,
    /// Require an itemset in this fraction of samples (majority by default).
    pub quorum: f64,
    /// Sampling seed.
    pub seed: u64,
}

impl Default for ParmaParams {
    fn default() -> Self {
        Self { epsilon: 0.05, delta: 0.05, n_samples: 8, quorum: 0.5, seed: 99 }
    }
}

/// Riondato-style sample size: w = (4 + 4ε/3) / ε² · ln(4/δ) — the
/// two-sided Chernoff bound on a single itemset's frequency estimate,
/// with a union-bound slack folded into δ. Clamped to the database size.
pub fn sample_size(epsilon: f64, delta: f64, db_size: usize) -> usize {
    assert!(epsilon > 0.0 && delta > 0.0 && delta < 1.0);
    let w = (4.0 + 4.0 * epsilon / 3.0) / (epsilon * epsilon) * (4.0 / delta).ln();
    (w.ceil() as usize).min(db_size)
}

#[derive(Debug, Clone)]
/// Result of a sampling run: approximate itemsets plus metadata.
pub struct ParmaResult {
    /// Approximate frequent itemsets with averaged estimated supports
    /// (fraction of transactions).
    pub itemsets: Vec<(Itemset, f64)>,
    /// Rows drawn per sample (Riondato bound, clamped to |D|).
    pub sample_size: usize,
    /// Number of independent samples mined.
    pub n_samples: usize,
}

impl ParmaResult {
    /// Recall against an exact result (fraction of exact itemsets found).
    pub fn recall(&self, exact: &[(Itemset, u64)]) -> f64 {
        if exact.is_empty() {
            return 1.0;
        }
        let found: std::collections::HashSet<&Itemset> =
            self.itemsets.iter().map(|(s, _)| s).collect();
        exact.iter().filter(|(s, _)| found.contains(s)).count() as f64 / exact.len() as f64
    }

    /// False-positive rate against an exact result.
    pub fn false_positive_rate(&self, exact: &[(Itemset, u64)]) -> f64 {
        if self.itemsets.is_empty() {
            return 0.0;
        }
        let truth: std::collections::HashSet<&Itemset> = exact.iter().map(|(s, _)| s).collect();
        self.itemsets.iter().filter(|(s, _)| !truth.contains(s)).count() as f64
            / self.itemsets.len() as f64
    }
}

/// Mine approximately: each "map task" mines an independent with-replacement
/// sample at a *lowered* threshold (min_sup − ε/2, per PARMA), and the
/// aggregation keeps itemsets reported by ≥ quorum of the samples.
pub fn mine_approximate(db: &TransactionDb, min_sup: f64, p: &ParmaParams) -> ParmaResult {
    let w = sample_size(p.epsilon, p.delta, db.len());
    let lowered = (min_sup - p.epsilon / 2.0).max(1.0 / w as f64);
    let mut rng = Rng::new(p.seed);
    let mut votes: HashMap<Itemset, (usize, f64)> = HashMap::new();
    for _ in 0..p.n_samples {
        let mut sample_rng = rng.fork(0xA11CE);
        let txns: Vec<Itemset> = (0..w)
            .map(|_| db.txns[sample_rng.below(db.len() as u64) as usize].clone())
            .collect();
        let sample = TransactionDb::new("sample", db.n_items, txns);
        let local = mine(&sample, lowered);
        for level in &local.levels {
            for (set, count) in level {
                let e = votes.entry(set.clone()).or_insert((0, 0.0));
                e.0 += 1;
                e.1 += *count as f64 / w as f64;
            }
        }
    }
    let need = ((p.n_samples as f64) * p.quorum).ceil() as usize;
    let mut itemsets: Vec<(Itemset, f64)> = votes
        .into_iter()
        .filter(|(_, (n, _))| *n >= need)
        .map(|(s, (n, sup))| (s, sup / n as f64))
        .filter(|(_, sup)| *sup >= min_sup - p.epsilon)
        .collect();
    itemsets.sort_by(|a, b| a.0.cmp(&b.0));
    ParmaResult { itemsets, sample_size: w, n_samples: p.n_samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ibm::{generate, IbmParams};

    fn db() -> TransactionDb {
        generate(&IbmParams {
            n_txns: 4000,
            n_items: 60,
            avg_txn_len: 10.0,
            avg_pattern_len: 4.0,
            n_patterns: 12,
            corruption_mean: 0.3,
            seed: 31,
            ..Default::default()
        })
    }

    #[test]
    fn sample_size_formula() {
        let w = sample_size(0.05, 0.05, usize::MAX);
        // (4 + 0.0667)/0.0025 * ln(80) ≈ 1627 * 4.38 ≈ 7127
        assert!((7000..7400).contains(&w), "w = {w}");
        // Clamps to db size.
        assert_eq!(sample_size(0.05, 0.05, 100), 100);
        // Tighter epsilon -> more samples.
        assert!(sample_size(0.01, 0.05, usize::MAX) > w * 20);
    }

    #[test]
    fn high_recall_on_clearly_frequent_sets() {
        let db = db();
        let exact = mine(&db, 0.20).all_frequent();
        let approx = mine_approximate(&db, 0.20, &ParmaParams::default());
        let recall = approx.recall(&exact);
        assert!(recall > 0.9, "recall {recall}");
        // False positives bounded: everything reported is within ε of
        // frequent in truth (we check FPR is small, not zero).
        let fpr = approx.false_positive_rate(&exact);
        assert!(fpr < 0.35, "fpr {fpr}");
    }

    #[test]
    fn deterministic_per_seed() {
        let db = db();
        let a = mine_approximate(&db, 0.25, &ParmaParams::default());
        let b = mine_approximate(&db, 0.25, &ParmaParams::default());
        assert_eq!(a.itemsets, b.itemsets);
    }

    #[test]
    fn quorum_filters_noise() {
        let db = db();
        let lax = mine_approximate(
            &db,
            0.25,
            &ParmaParams { quorum: 0.125, n_samples: 8, ..Default::default() },
        );
        let strict = mine_approximate(
            &db,
            0.25,
            &ParmaParams { quorum: 1.0, n_samples: 8, ..Default::default() },
        );
        assert!(strict.itemsets.len() <= lax.itemsets.len());
    }

    #[test]
    fn recall_edge_cases() {
        let r = ParmaResult { itemsets: vec![], sample_size: 10, n_samples: 1 };
        assert_eq!(r.recall(&[]), 1.0);
        assert_eq!(r.false_positive_rate(&[]), 0.0);
    }
}
