//! Apriori algorithm core: candidate generation (`apriori-gen` with join +
//! prune, and the paper's `non-apriori-gen` join-only variant, §4.2),
//! transaction subset counting, the sequential reference miner (correctness
//! oracle for every MapReduce driver), and association-rule derivation.

pub mod gen;
pub mod rules;
pub mod sampling;
pub mod sequential;
pub mod triangular;

pub use gen::{apriori_gen, non_apriori_gen, GenStats};
pub use sequential::{mine, MineResult};
