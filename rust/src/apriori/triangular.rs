//! Triangular-matrix pair counting (the paper's ref [6], Kovacs & Illes,
//! ICCC'13): count ALL 1-itemsets and 2-itemsets in a single database scan
//! using a dense upper-triangular count array — no candidate generation for
//! pass 2 at all. Used as the optional fused first phase (`fuse_pass_2` in
//! the run options): Job1 then emits both L1 and L2, and Job2 starts at
//! k = 3, saving one entire MapReduce job.

use crate::itemset::{Item, Itemset};

/// Dense upper-triangular pair counter over `n` items, plus item counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TriangularCounter {
    n: usize,
    item_counts: Vec<u64>,
    /// Row-major packed upper triangle: pair (i, j), i < j, lives at
    /// `offset(i) + (j - i - 1)`.
    pair_counts: Vec<u64>,
    row_offset: Vec<usize>,
}

impl TriangularCounter {
    /// Zeroed counter for the dense universe `0..n_items`.
    pub fn new(n_items: usize) -> Self {
        let mut row_offset = Vec::with_capacity(n_items);
        let mut acc = 0usize;
        for i in 0..n_items {
            row_offset.push(acc);
            acc += n_items - i - 1;
        }
        Self {
            n: n_items,
            item_counts: vec![0; n_items],
            pair_counts: vec![0; acc],
            row_offset,
        }
    }

    #[inline]
    fn pair_index(&self, i: Item, j: Item) -> usize {
        debug_assert!(i < j && (j as usize) < self.n);
        self.row_offset[i as usize] + (j as usize - i as usize - 1)
    }

    /// Count one canonical transaction: every item and every item pair.
    pub fn add_transaction(&mut self, txn: &[Item]) {
        for (a, &i) in txn.iter().enumerate() {
            self.item_counts[i as usize] += 1;
            for &j in &txn[a + 1..] {
                let idx = self.pair_index(i, j);
                self.pair_counts[idx] += 1;
            }
        }
    }

    /// Support count of the single item `i`.
    pub fn item_count(&self, i: Item) -> u64 {
        self.item_counts[i as usize]
    }

    /// Support count of pair `(i, j)`; `i == j` gives the item count.
    pub fn pair_count(&self, i: Item, j: Item) -> u64 {
        if i == j {
            return self.item_counts[i as usize];
        }
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        self.pair_counts[self.pair_index(lo, hi)]
    }

    /// Merge another counter (the Reducer's job for this fused phase).
    pub fn merge(&mut self, other: &TriangularCounter) {
        assert_eq!(self.n, other.n);
        for (a, b) in self.item_counts.iter_mut().zip(&other.item_counts) {
            *a += b;
        }
        for (a, b) in self.pair_counts.iter_mut().zip(&other.pair_counts) {
            *a += b;
        }
    }

    /// Frequent 1-itemsets and *pruned* frequent 2-itemsets (both endpoints
    /// frequent, pair count >= min_count), in lexicographic order.
    pub fn frequent(&self, min_count: u64) -> (Vec<(Itemset, u64)>, Vec<(Itemset, u64)>) {
        let l1: Vec<(Itemset, u64)> = (0..self.n)
            .filter(|&i| self.item_counts[i] >= min_count)
            .map(|i| (vec![i as Item], self.item_counts[i]))
            .collect();
        let frequent_item: Vec<bool> =
            (0..self.n).map(|i| self.item_counts[i] >= min_count).collect();
        let mut l2 = Vec::new();
        for i in 0..self.n {
            if !frequent_item[i] {
                continue;
            }
            for j in (i + 1)..self.n {
                if !frequent_item[j] {
                    continue;
                }
                let c = self.pair_counts[self.row_offset[i] + (j - i - 1)];
                if c >= min_count {
                    l2.push((vec![i as Item, j as Item], c));
                }
            }
        }
        (l1, l2)
    }

    /// Memory of the dense triangle in bytes (the trade against tries).
    pub fn bytes(&self) -> usize {
        (self.pair_counts.len() + self.item_counts.len()) * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::sequential::mine;
    use crate::dataset::TransactionDb;
    use crate::util::check::{forall, DbGen};

    #[test]
    fn counts_pairs_and_items() {
        let mut tc = TriangularCounter::new(5);
        tc.add_transaction(&[0, 2, 4]);
        tc.add_transaction(&[0, 2]);
        tc.add_transaction(&[1]);
        assert_eq!(tc.item_count(0), 2);
        assert_eq!(tc.item_count(1), 1);
        assert_eq!(tc.pair_count(0, 2), 2);
        assert_eq!(tc.pair_count(2, 0), 2); // symmetric access
        assert_eq!(tc.pair_count(0, 4), 1);
        assert_eq!(tc.pair_count(1, 4), 0);
        assert_eq!(tc.pair_count(3, 3), 0);
    }

    #[test]
    fn merge_adds() {
        let mut a = TriangularCounter::new(4);
        a.add_transaction(&[0, 1]);
        let mut b = TriangularCounter::new(4);
        b.add_transaction(&[0, 1, 2]);
        a.merge(&b);
        assert_eq!(a.pair_count(0, 1), 2);
        assert_eq!(a.pair_count(1, 2), 1);
        assert_eq!(a.item_count(0), 2);
    }

    #[test]
    fn frequent_matches_oracle_l1_l2() {
        let db = TransactionDb::new(
            "t",
            6,
            vec![
                vec![0, 1, 2],
                vec![0, 1, 3],
                vec![0, 2, 4],
                vec![1, 2, 5],
                vec![0, 1, 2],
            ],
        );
        let mut tc = TriangularCounter::new(6);
        for t in &db.txns {
            tc.add_transaction(t);
        }
        let (l1, l2) = tc.frequent(db.min_count(0.4));
        let oracle = mine(&db, 0.4);
        assert_eq!(l1, oracle.levels[0]);
        assert_eq!(l2, oracle.levels[1]);
    }

    #[test]
    fn prop_matches_oracle() {
        let gen = DbGen { universe: 12, max_txns: 30, max_width: 6 };
        forall(903, 60, &gen, |sdb| {
            let db = TransactionDb::new("p", sdb.universe, sdb.txns.clone());
            let mut tc = TriangularCounter::new(db.n_items);
            for t in &db.txns {
                tc.add_transaction(t);
            }
            for min_sup in [0.2, 0.5] {
                let (l1, l2) = tc.frequent(db.min_count(min_sup));
                let oracle = mine(&db, min_sup);
                let ol1 = oracle.levels.first().cloned().unwrap_or_default();
                let ol2 = oracle.levels.get(1).cloned().unwrap_or_default();
                if l1 != ol1 || l2 != ol2 {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn prop_merge_commutative_and_associative() {
        // Split a random DB into three chunks, count each independently
        // (three map tasks), and check the reduce-side merge is insensitive
        // to order and grouping — the property the fused job and the
        // triangular backend's combiner both rely on.
        let gen = DbGen { universe: 10, max_txns: 24, max_width: 6 };
        forall(904, 60, &gen, |sdb| {
            let third = (sdb.txns.len() / 3).max(1);
            let mut parts = vec![TriangularCounter::new(sdb.universe); 3];
            for (i, t) in sdb.txns.iter().enumerate() {
                parts[(i / third).min(2)].add_transaction(t);
            }
            let (a, b, c) = (&parts[0], &parts[1], &parts[2]);
            let merged = |xs: &[&TriangularCounter]| {
                let mut acc = xs[0].clone();
                for x in &xs[1..] {
                    acc.merge(x);
                }
                acc
            };
            // Commutativity: a ⊕ b == b ⊕ a.
            if merged(&[a, b]) != merged(&[b, a]) {
                return false;
            }
            // Associativity: (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c).
            let left = {
                let mut ab = merged(&[a, b]);
                ab.merge(c);
                ab
            };
            let right = {
                let bc = merged(&[b, c]);
                let mut acc = a.clone();
                acc.merge(&bc);
                acc
            };
            left == right
        });
    }

    #[test]
    fn bytes_is_quadratic() {
        assert!(TriangularCounter::new(200).bytes() > TriangularCounter::new(100).bytes() * 3);
    }
}
