//! Sequential Apriori reference miner.
//!
//! This is the correctness oracle: every MapReduce driver must produce
//! exactly the same frequent itemsets, level by level. It also regenerates
//! the paper's Table 6 (|L_k| per pass) and is what the dataset-registry
//! calibration uses to match L_k-curve shapes.

use super::gen::{apriori_gen, GenStats};
use crate::dataset::TransactionDb;
use crate::itemset::{Itemset, Trie};

/// Frequent itemsets of one level, with support counts, lexicographic order.
pub type Level = Vec<(Itemset, u64)>;

#[derive(Debug, Clone)]
/// Everything the sequential miner reports: levels, counts, meters.
pub struct MineResult {
    /// `levels[k-1]` = frequent k-itemsets. Trailing empty levels trimmed.
    pub levels: Vec<Level>,
    /// Absolute minimum support count used.
    pub min_count: u64,
    /// Per-pass candidate counts (|C_k| for k >= 2; index 0 is pass 2).
    pub candidates_per_pass: Vec<u64>,
    /// Accumulated generation meters across all passes.
    pub gen_stats: GenStats,
    /// Accumulated trie-node visits in subset counting.
    pub subset_visits: u64,
}

impl MineResult {
    /// Total number of frequent itemsets across levels.
    pub fn total_frequent(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// Length of the longest frequent itemset (0 if none).
    pub fn max_len(&self) -> usize {
        self.levels.len()
    }

    /// |L_k| profile, as in the paper's Table 6.
    pub fn lk_profile(&self) -> Vec<usize> {
        self.levels.iter().map(|l| l.len()).collect()
    }

    /// Flatten to a sorted `(itemset, count)` list for equality checks.
    pub fn all_frequent(&self) -> Vec<(Itemset, u64)> {
        let mut out: Vec<(Itemset, u64)> =
            self.levels.iter().flat_map(|l| l.iter().cloned()).collect();
        out.sort();
        out
    }
}

/// Mine all frequent itemsets of `db` at fractional support `min_sup`.
pub fn mine(db: &TransactionDb, min_sup: f64) -> MineResult {
    let min_count = db.min_count(min_sup);
    let mut levels: Vec<Level> = Vec::new();
    let mut candidates_per_pass = Vec::new();
    let mut gen_stats = GenStats::default();
    let mut subset_visits = 0u64;

    // Pass 1: direct item counting.
    let mut item_counts = vec![0u64; db.n_items];
    for t in &db.txns {
        for &i in t {
            item_counts[i as usize] += 1;
        }
    }
    let l1: Level = item_counts
        .iter()
        .enumerate()
        .filter(|(_, &c)| c >= min_count)
        .map(|(i, &c)| (vec![i as u32], c))
        .collect();
    if l1.is_empty() {
        return MineResult { levels, min_count, candidates_per_pass, gen_stats, subset_visits };
    }
    let mut current = Trie::from_itemsets(1, l1.iter().map(|(s, _)| s));
    levels.push(l1);

    // Passes k >= 2: generate from L_{k-1}, count, filter.
    loop {
        let (mut ck, stats) = apriori_gen(&current);
        gen_stats.merge(&stats);
        if ck.is_empty() {
            break;
        }
        candidates_per_pass.push(ck.len() as u64);
        for t in &db.txns {
            subset_visits += ck.count_transaction(t).0;
        }
        let lk: Level = ck.frequent(min_count);
        if lk.is_empty() {
            break;
        }
        current = Trie::from_itemsets(ck.level(), lk.iter().map(|(s, _)| s));
        levels.push(lk);
    }

    MineResult { levels, min_count, candidates_per_pass, gen_stats, subset_visits }
}

/// Brute-force miner over all subsets of observed transactions — O(2^w per
/// txn), only for tiny property-test databases, as an oracle for the oracle.
pub fn mine_bruteforce(db: &TransactionDb, min_sup: f64) -> Vec<(Itemset, u64)> {
    use std::collections::HashMap;
    let min_count = db.min_count(min_sup);
    let mut counts: HashMap<Itemset, u64> = HashMap::new();
    for t in &db.txns {
        let w = t.len();
        assert!(w <= 20, "bruteforce oracle is for tiny transactions only");
        for mask in 1u32..(1 << w) {
            let subset: Itemset =
                (0..w).filter(|b| mask & (1 << b) != 0).map(|b| t[b]).collect();
            *counts.entry(subset).or_insert(0) += 1;
        }
    }
    let mut out: Vec<(Itemset, u64)> =
        counts.into_iter().filter(|(_, c)| *c >= min_count).collect();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::TransactionDb;
    use crate::util::check::{forall, DbGen};

    /// The worked example every FIM paper uses (Tan et al. ch.6 style).
    fn market() -> TransactionDb {
        TransactionDb::new(
            "market",
            5,
            vec![
                vec![0, 1],          // bread milk
                vec![0, 2, 3, 4],    // bread diaper beer eggs
                vec![1, 2, 3],       // milk diaper beer
                vec![0, 1, 2, 3],    // bread milk diaper beer
                vec![0, 1, 2],       // bread milk diaper
            ],
        )
    }

    #[test]
    fn market_basket_known_answer() {
        let r = mine(&market(), 0.6); // min_count = 3
        assert_eq!(r.min_count, 3);
        assert_eq!(r.lk_profile(), vec![4, 4]); // L1: 0,1,2,3; L2: {01},{02},{12},{23}
        let all = r.all_frequent();
        let sets: Vec<Itemset> = all.iter().map(|(s, _)| s.clone()).collect();
        assert_eq!(
            sets,
            vec![
                vec![0],
                vec![0, 1],
                vec![0, 2],
                vec![1],
                vec![1, 2],
                vec![2],
                vec![2, 3],
                vec![3]
            ]
        );
        // Support spot checks.
        let sup = |s: &[u32]| all.iter().find(|(x, _)| x == s).map(|(_, c)| *c);
        assert_eq!(sup(&[0]), Some(4));
        assert_eq!(sup(&[2, 3]), Some(3));
        assert_eq!(sup(&[0, 1]), Some(3));
    }

    #[test]
    fn min_sup_one_keeps_only_universal_items() {
        let r = mine(&market(), 1.0);
        assert!(r.levels.is_empty()); // no item appears in all 5 txns
    }

    #[test]
    fn low_support_finds_long_itemsets() {
        let r = mine(&market(), 0.2); // min_count 1: everything observed
        assert_eq!(r.max_len(), 4); // {0,2,3,4} and {0,1,2,3} appear once
        assert_eq!(r.levels[3].len(), 2);
    }

    #[test]
    fn prop_matches_bruteforce() {
        let gen = DbGen { universe: 8, max_txns: 14, max_width: 5 };
        forall(401, 40, &gen, |db| {
            let tdb = TransactionDb::new("p", db.universe, db.txns.clone());
            for min_sup in [0.2, 0.4, 0.7] {
                let fast = mine(&tdb, min_sup).all_frequent();
                let slow = mine_bruteforce(&tdb, min_sup);
                if fast != slow {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn prop_downward_closure() {
        // Every (k-1)-subset of a frequent k-itemset is frequent.
        let gen = DbGen { universe: 10, max_txns: 20, max_width: 6 };
        forall(402, 40, &gen, |db| {
            let tdb = TransactionDb::new("p", db.universe, db.txns.clone());
            let r = mine(&tdb, 0.3);
            for k in 1..r.levels.len() {
                let prev = Trie::from_itemsets(k, r.levels[k - 1].iter().map(|(s, _)| s));
                for (set, _) in &r.levels[k] {
                    for drop in 0..set.len() {
                        let sub: Itemset = set
                            .iter()
                            .enumerate()
                            .filter(|(i, _)| *i != drop)
                            .map(|(_, &x)| x)
                            .collect();
                        if !prev.contains(&sub) {
                            return false;
                        }
                    }
                }
            }
            true
        });
    }

    #[test]
    fn prop_supports_monotone_in_minsup() {
        let gen = DbGen { universe: 9, max_txns: 16, max_width: 5 };
        forall(403, 30, &gen, |db| {
            let tdb = TransactionDb::new("p", db.universe, db.txns.clone());
            let lo = mine(&tdb, 0.2).all_frequent();
            let hi = mine(&tdb, 0.5).all_frequent();
            // High-threshold result must be a subset of the low-threshold one.
            hi.iter().all(|x| lo.contains(x))
        });
    }

    #[test]
    fn meters_accumulate() {
        let r = mine(&market(), 0.4);
        assert!(r.gen_stats.join_pairs > 0);
        assert!(r.subset_visits > 0);
        assert!(!r.candidates_per_pass.is_empty());
    }
}
