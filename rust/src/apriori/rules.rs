//! Association-rule derivation from mined frequent itemsets.
//!
//! Apriori is "the basic algorithm of Association Rule Mining" (§1); the
//! second half of ARM — deriving `X ⇒ Y` rules above a confidence threshold
//! from the frequent itemsets — completes the pipeline for the examples.

use super::sequential::MineResult;
use crate::itemset::Itemset;
use std::collections::HashMap;

#[derive(Debug, Clone, PartialEq)]
/// One association rule `X ⇒ Y` with its quality metrics.
pub struct Rule {
    /// Left-hand side X.
    pub antecedent: Itemset,
    /// Right-hand side Y (disjoint from X).
    pub consequent: Itemset,
    /// Fraction of transactions containing X ∪ Y.
    pub support: f64,
    /// support(X ∪ Y) / support(X).
    pub confidence: f64,
    /// Confidence over the consequent's base rate.
    pub lift: f64,
}

/// Derive all rules with confidence >= `min_conf` from a mining result.
/// `n_txns` is needed to turn counts into supports.
pub fn derive_rules(result: &MineResult, n_txns: usize, min_conf: f64) -> Vec<Rule> {
    // Support lookup over every frequent itemset.
    let mut support: HashMap<Itemset, u64> = HashMap::new();
    for level in &result.levels {
        for (set, count) in level {
            support.insert(set.clone(), *count);
        }
    }
    let n = n_txns as f64;
    let mut rules = Vec::new();
    for level in result.levels.iter().skip(1) {
        for (set, set_count) in level {
            // Enumerate proper non-empty antecedent subsets by bitmask.
            let w = set.len();
            for mask in 1u32..((1 << w) - 1) {
                let antecedent: Itemset =
                    (0..w).filter(|b| mask & (1 << b) != 0).map(|b| set[b]).collect();
                let consequent: Itemset =
                    (0..w).filter(|b| mask & (1 << b) == 0).map(|b| set[b]).collect();
                let Some(&a_count) = support.get(&antecedent) else { continue };
                let confidence = *set_count as f64 / a_count as f64;
                if confidence + 1e-12 < min_conf {
                    continue;
                }
                let Some(&c_count) = support.get(&consequent) else { continue };
                let lift = confidence / (c_count as f64 / n);
                rules.push(Rule {
                    antecedent,
                    consequent,
                    support: *set_count as f64 / n,
                    confidence,
                    lift,
                });
            }
        }
    }
    rules.sort_by(|a, b| {
        b.confidence
            .total_cmp(&a.confidence)
            .then(b.support.total_cmp(&a.support))
            .then(a.antecedent.cmp(&b.antecedent))
    });
    rules
}

impl std::fmt::Display for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{{{}}} => {{{}}}  sup={:.3} conf={:.3} lift={:.2}",
            crate::itemset::format_itemset(&self.antecedent),
            crate::itemset::format_itemset(&self.consequent),
            self.support,
            self.confidence,
            self.lift
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apriori::sequential::mine;
    use crate::dataset::TransactionDb;

    fn market() -> TransactionDb {
        TransactionDb::new(
            "market",
            5,
            vec![
                vec![0, 1],
                vec![0, 2, 3, 4],
                vec![1, 2, 3],
                vec![0, 1, 2, 3],
                vec![0, 1, 2],
            ],
        )
    }

    #[test]
    fn rules_from_market() {
        let r = mine(&market(), 0.6);
        let rules = derive_rules(&r, 5, 0.9);
        // {3} => {2}: sup({2,3})=3, sup({3})=3 -> conf 1.0
        let rule = rules.iter().find(|r| r.antecedent == vec![3]).expect("rule {3}=>{2}");
        assert_eq!(rule.consequent, vec![2]);
        assert!((rule.confidence - 1.0).abs() < 1e-9);
        assert!((rule.support - 0.6).abs() < 1e-9);
        // lift = conf / sup({2}) = 1.0 / (4/5)
        assert!((rule.lift - 1.25).abs() < 1e-9);
    }

    #[test]
    fn confidence_threshold_filters() {
        let r = mine(&market(), 0.6);
        let all = derive_rules(&r, 5, 0.0);
        let strict = derive_rules(&r, 5, 0.95);
        assert!(strict.len() < all.len());
        assert!(strict.iter().all(|r| r.confidence >= 0.95));
    }

    #[test]
    fn rules_sorted_by_confidence() {
        let r = mine(&market(), 0.4);
        let rules = derive_rules(&r, 5, 0.5);
        assert!(rules.windows(2).all(|w| w[0].confidence >= w[1].confidence));
    }

    #[test]
    fn no_rules_without_l2() {
        let r = mine(&market(), 1.0);
        assert!(derive_rules(&r, 5, 0.1).is_empty());
    }

    #[test]
    fn display_format() {
        let rule = Rule {
            antecedent: vec![1],
            consequent: vec![2, 3],
            support: 0.5,
            confidence: 0.75,
            lift: 1.5,
        };
        let s = rule.to_string();
        assert!(s.contains("{i1} => {i2 i3}"), "{s}");
        assert!(s.contains("conf=0.750"), "{s}");
    }
}
