//! `mrapriori` CLI — launcher for mining runs, dataset generation,
//! benchmark sweeps, and cost-model calibration.

use anyhow::{bail, Result};
use mrapriori::bench_harness::tables::{self, SweepSpec};
use mrapriori::cluster::ClusterConfig;
use mrapriori::coordinator::{self, mappers::GenMode, Algorithm, RunOptions};
use mrapriori::dataset::{loader, registry, stats};
use mrapriori::util::flags::FlagSet;
use mrapriori::util::logging::{self, Level};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd {
        "mine" => cmd_mine(rest),
        "inspect" => cmd_inspect(rest),
        "generate" => cmd_generate(rest),
        "sweep" => cmd_sweep(rest),
        "calibrate" => cmd_calibrate(rest),
        "lk" => cmd_lk(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `mrapriori help`"),
    }
}

fn print_help() {
    println!(
        "mrapriori — MapReduce-based Apriori on a simulated Hadoop cluster

Commands:
  mine       run one algorithm on a dataset, print phase breakdown
  sweep      run the paper's Figs 2-4 sweep on a dataset
  lk         print the |L_k| profile (paper Table 6) via the oracle
  inspect    dataset summary statistics (paper Table 2)
  generate   write a registry dataset to a FIMI text file
  calibrate  fit cost-model weights against the paper's Table 3
  help       this message

Run `mrapriori <command> --help` for flags."
    );
}

fn common_cluster(p: &mrapriori::util::flags::Parsed) -> Result<ClusterConfig> {
    let mut cluster = match p.get("cluster-config") {
        Some(path) => mrapriori::config::load_cluster(std::path::Path::new(path))?,
        None => ClusterConfig::paper_cluster(),
    };
    if let Some(n) = p.usize("data-nodes")? {
        let slots = cluster.nodes.first().map(|n| n.map_slots).unwrap_or(4);
        cluster = ClusterConfig::uniform(n, slots);
    }
    if let Some(w) = p.usize("workers")? {
        cluster.workers = w;
    }
    Ok(cluster)
}

/// Resolve `--dataset` through [`registry::try_load`] (never the panicking
/// [`registry::load`]): unknown names come back as a clean error listing
/// the known registry datasets, and the process exits 1 without a backtrace.
fn load_db(p: &mrapriori::util::flags::Parsed) -> Result<mrapriori::dataset::TransactionDb> {
    let name = p.required("dataset")?;
    if let Some(db) = registry::try_load(name) {
        return Ok(db);
    }
    let path = std::path::Path::new(name);
    if path.exists() {
        return Ok(loader::load_file(path)?);
    }
    bail!(
        "unknown dataset {name:?}: not a registry dataset (known: {}) and not a readable file",
        registry::NAMES.join(", ")
    )
}

fn cmd_mine(args: &[String]) -> Result<()> {
    let set = FlagSet::new("mine", "run one algorithm on a dataset")
        .opt("dataset", "registry name (c20d10k|chess|mushroom) or FIMI file path")
        .opt("algo", "algorithm: spc|fpc|dpc|vfpc|etdpc|opt-vfpc|opt-etdpc")
        .opt("min-sup", "fractional minimum support (default: paper reference)")
        .opt("split-lines", "lines per input split (default: paper setting)")
        .opt("cluster-config", "TOML cluster config path")
        .opt("data-nodes", "override: uniform cluster of N DataNodes")
        .opt("workers", "host threads for real execution")
        .opt_default("gen-mode", "per-record", "per-record|per-task generation cost")
        .flag("fuse-12", "fuse passes 1+2 via triangular matrix (ref [6])")
        .flag("verbose", "debug logging")
        .flag("rules", "derive association rules (conf >= 0.9) at the end")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    if p.bool("verbose") {
        logging::set_level(Level::Debug);
    }
    let db = load_db(&p)?;
    let algo = Algorithm::parse(p.get("algo").unwrap_or("opt-vfpc"))
        .ok_or_else(|| anyhow::anyhow!("unknown algorithm"))?;
    let min_sup = p
        .f64("min-sup")?
        .or_else(|| registry::reference_min_sup(&db.name))
        .unwrap_or(0.25);
    let cluster = common_cluster(&p)?;
    let opts = RunOptions {
        split_lines: p.usize("split-lines")?.unwrap_or_else(|| registry::split_lines(&db.name)),
        gen_mode: match p.get("gen-mode") {
            Some("per-task") => GenMode::PerTask,
            _ => GenMode::PerRecord,
        },
        dpc_alpha: if db.name == "chess" { 3.0 } else { 2.0 },
        fuse_pass_2: p.bool("fuse-12"),
        ..Default::default()
    };

    let out = coordinator::run_with(algo, &db, min_sup, &cluster, &opts);
    println!(
        "{} on {} @ min_sup {:.2} (min_count {})",
        algo.name(),
        db.name,
        min_sup,
        out.min_count
    );
    println!(
        "{:>5} {:>6} {:>7} {:>11} {:>12} {:>10}  {}",
        "phase", "passes", "k-range", "candidates", "elapsed(s)", "wall(s)", "job"
    );
    for ph in &out.phases {
        let k_range = if ph.n_passes <= 1 {
            format!("{}", ph.first_pass)
        } else {
            format!("{}-{}", ph.first_pass, ph.first_pass + ph.n_passes - 1)
        };
        println!(
            "{:>5} {:>6} {:>7} {:>11} {:>12.1} {:>10.3}  {}",
            ph.phase, ph.n_passes, k_range, ph.candidates, ph.elapsed, ph.wall, ph.job
        );
    }
    println!(
        "total {:.1} s simulated, actual {:.1} s, wall {:.3} s host",
        out.total_time, out.actual_time, out.wall_time
    );
    println!("frequent itemsets: {} across {} levels", out.total_frequent(), out.levels.len());
    println!("|L_k| profile: {:?}", out.lk_profile());
    if p.bool("verbose") {
        let mut total = mrapriori::mapreduce::Counters::new();
        for ph in &out.phases {
            total.merge(&ph.counters);
        }
        println!("aggregate counters: {total}");
        let w = cluster.weights;
        use mrapriori::mapreduce::keys as K;
        println!(
            "compute split (s): join={:.0} prune={:.0} cand={:.0} visit={:.0} tuples={:.0}",
            w.join_pair * total.get(K::JOIN_PAIRS) as f64,
            w.prune_check * total.get(K::PRUNE_CHECKS) as f64,
            w.cand_built * total.get(K::CANDS_BUILT) as f64,
            w.subset_visit * total.get(K::SUBSET_VISITS) as f64,
            w.map_tuple * total.get(K::MAP_OUTPUT_TUPLES) as f64,
        );
    }

    if p.bool("rules") {
        let mined = mrapriori::apriori::sequential::MineResult {
            levels: out.levels.clone(),
            min_count: out.min_count,
            candidates_per_pass: vec![],
            gen_stats: Default::default(),
            subset_visits: 0,
        };
        let rules = mrapriori::apriori::rules::derive_rules(&mined, db.len(), 0.9);
        println!("\ntop association rules (conf >= 0.9):");
        for r in rules.iter().take(15) {
            println!("  {r}");
        }
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let set = FlagSet::new("inspect", "dataset summary statistics")
        .opt("dataset", "registry name or file path")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    let db = load_db(&p)?;
    println!("{}", stats::summarize(&db));
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let set = FlagSet::new("generate", "write a registry dataset to a FIMI file")
        .opt("dataset", "registry name")
        .opt("out", "output path")
        .opt("scale", "repeat to N transactions (e.g. 200000 for c20d200k)")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    let mut db = load_db(&p)?;
    if let Some(target) = p.usize("scale")? {
        let name = format!("{}-x{}", db.name, target);
        db = db.scaled_to(target, name);
    }
    let out = p.required("out")?;
    loader::write_file(&db, std::path::Path::new(out))?;
    println!("wrote {} transactions to {}", db.len(), out);
    Ok(())
}

fn cmd_lk(args: &[String]) -> Result<()> {
    let set = FlagSet::new("lk", "|L_k| per pass via the sequential oracle (Table 6)")
        .opt("dataset", "registry name or file path")
        .opt("min-sup", "fractional minimum support")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    let db = load_db(&p)?;
    let min_sup = p
        .f64("min-sup")?
        .or_else(|| registry::reference_min_sup(&db.name))
        .unwrap_or(0.25);
    let r = mrapriori::apriori::sequential::mine(&db, min_sup);
    println!("{} @ min_sup {:.2}: |L_k| = {:?}", db.name, min_sup, r.lk_profile());
    println!("total {} frequent itemsets, max length {}", r.total_frequent(), r.max_len());
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let set = FlagSet::new("sweep", "run the paper's figure sweep on a dataset")
        .opt("dataset", "registry name or file path")
        .opt("min-sups", "comma-separated min_sup list (default: paper sweep)")
        .opt("workers", "host threads")
        .opt("cluster-config", "TOML cluster config path")
        .opt("data-nodes", "uniform cluster of N DataNodes")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    let db = load_db(&p)?;
    let mut spec = SweepSpec::paper(&db);
    spec.cluster = common_cluster(&p)?;
    if let Some(sups) = p.f64_list("min-sups")? {
        spec.min_sups = sups;
    }
    let result = tables::sweep(&spec);
    println!("{}", tables::figure_a(&result, &db.name));
    println!("{}", tables::figure_b(&result, &db.name));
    Ok(())
}

fn cmd_calibrate(args: &[String]) -> Result<()> {
    let set = FlagSet::new("calibrate", "fit cost weights against the paper's Table 3")
        .flag("emit", "print the fitted config as TOML")
        .flag("help", "show usage");
    let p = set.parse(args)?;
    if p.bool("help") {
        println!("{}", set.usage());
        return Ok(());
    }
    let report = mrapriori::bench_harness::calibrate::run_calibration(p.bool("emit"));
    println!("{report}");
    Ok(())
}
